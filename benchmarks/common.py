"""Shared row type + tiny report helpers for the benchmark suite."""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Callable, Optional

# All BENCH_*.json artifacts land in the repo root regardless of the CWD
# the suite was launched from — CI uploads them by that fixed path and the
# perf-trajectory files are committed there.
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def write_report(name: str, payload: dict) -> pathlib.Path:
    """Dump one benchmark's JSON report to ``REPO_ROOT/name``."""
    path = REPO_ROOT / name
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


@dataclasses.dataclass
class Row:
    bench: str
    metric: str
    value: float
    target: Optional[float] = None  # paper's number, when one exists
    tol: float = 0.25  # relative tolerance vs target
    note: str = ""

    @property
    def status(self) -> str:
        if self.target is None:
            return "info"
        if self.target == 0:
            return "ok" if abs(self.value) <= self.tol else "FAIL"
        return ("ok" if abs(self.value - self.target) <=
                self.tol * abs(self.target) else "FAIL")

    def csv(self) -> str:
        t = "" if self.target is None else f"{self.target:.4g}"
        return (f"{self.bench},{self.metric},{self.value:.4g},{t},"
                f"{self.status},{self.note}")


def timed(fn: Callable[[], list[Row]], name: str) -> tuple[list[Row], float]:
    t0 = time.perf_counter()
    rows = fn()
    return rows, time.perf_counter() - t0


HEADER = "bench,metric,value,paper_target,status,note"
