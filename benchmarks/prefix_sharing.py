"""Prefix sharing on the live paged data plane: bytes and concurrency.

A 4-tenant chat mix where ~90% of every prompt is the tenant's system
prefix (56 of 60 tokens) and the last few tokens are the per-request
user turn — the canonical serverless-inference case for cross-request
KV sharing.  Two measurements against the identical arrival sequence,
sharing ON vs OFF, tokens asserted bit-identical both times:

* **memory** — an uncontended block pool (the dense-equivalent default):
  both planes admit up to ``max_batch``, so the peak physical KV bytes
  isolate what content-hash sharing + COW save at equal concurrency.
  Acceptance: shared peak <= ``RATIO_CEIL`` x unshared peak.
* **concurrency** — a tight pool (2 unshared requests' worth of
  blocks): admission is block-limited, so the same byte budget must
  hold strictly more in-flight requests when prefixes dedupe.
  Acceptance: shared peak admitted concurrency > unshared.

One scenario cannot show both wins at once — under contention the
winner's bytes are capped at the pool size — so the benchmark reports
the two axes separately, which is also how the shared-fraction
admission axis (``kv_shared_frac``) is meant to be read: fewer bytes
per request, or more requests per byte.

Emits ``BENCH_prefix.json`` (uploaded by CI) and runs in seconds on the
tiny config, so it doubles as the tier-1 prefix-sharing smoke.
"""

from __future__ import annotations

import time

from benchmarks.common import Row, write_report

BLOCK = 8
MAX_LEN = 64
MAX_BATCH = 8
N_TENANTS = 4
REQS_PER_TENANT = 4
PREFIX_LEN = 56          # 7 full blocks shared within a tenant
SUFFIX_LEN = 4           # the ~10% unique user turn
MAX_NEW = 4              # rows = 64 = max_len exactly
RATIO_CEIL = 0.6         # acceptance: shared peak bytes <= 0.6x unshared
TIGHT_BLOCKS = 17        # 16 usable: exactly two unshared requests


def _workload(vocab: int, seed: int = 13):
    """Tenant-grouped arrivals: 4 tenants x 4 chats, 90%-shared prompts."""
    import numpy as np

    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, PREFIX_LEN, dtype=np.int32)
                for _ in range(N_TENANTS)]
    out = []
    for t in range(N_TENANTS):
        for _ in range(REQS_PER_TENANT):
            suffix = rng.integers(0, vocab, SUFFIX_LEN, dtype=np.int32)
            out.append(np.concatenate([prefixes[t], suffix]))
    return out


def _model():
    import jax

    from repro.models import build_model
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                      vocab_size=64, vocab_pad_multiple=32)
    model = build_model(cfg)
    return model, model.init(jax.random.key(7))


def _serve(model, params, *, prefix_sharing: bool, n_kv_blocks=None):
    """-> (token streams, peak KV bytes, peak admitted concurrency,
    peak observed shared fraction, engine telemetry)."""
    from repro.core.resources import Alloc
    from repro.serving import ClusterFrontend

    fe = ClusterFrontend(n_nodes=1, window=0.05)
    fe.deploy("chat", model, params,
              Alloc(sm=0.9, quota_request=0.9, quota_limit=0.9),
              max_batch=MAX_BATCH, max_len=MAX_LEN, batching="paged",
              block_size=BLOCK, n_kv_blocks=n_kv_blocks,
              prefix_sharing=prefix_sharing)
    reqs = [fe.submit("chat", p, max_new_tokens=MAX_NEW)
            for p in _workload(model.cfg.vocab_size)]
    insts = [i for e in fe.engines for i in e.instances.values()]
    # Short pump slices so admitted concurrency is sampled between decode
    # rounds (requests live for MAX_NEW rounds, so the peak is observed).
    peak_active, peak_frac, deadline = 0, 0.0, time.monotonic() + 120.0
    while sum(r.done for r in reqs) < len(reqs):
        assert time.monotonic() < deadline, "benchmark stalled"
        fe.pump(budget_s=0.02)
        peak_active = max(peak_active, sum(i.n_active() for i in insts))
        peak_frac = max(peak_frac, fe.kv_shared_fraction())
    assert fe.kv_bytes_in_use() == 0, "drained fleet leaked KV blocks"
    [inst] = insts
    stats = inst.allocator.stats()
    assert stats["in_use"] == 0 and inst.pages.n_spares == 0
    return ([r.tokens_out for r in reqs], inst.kv_bytes_peak, peak_active,
            peak_frac, {"shared_hits": inst.shared_block_hits,
                        "cow": inst.cow_count,
                        "block_high_watermark": stats["high_watermark"]})


def _phase(model, params, name: str, n_kv_blocks) -> tuple[dict, list[Row]]:
    shared_toks, s_bytes, s_conc, s_frac, tel = _serve(
        model, params, prefix_sharing=True, n_kv_blocks=n_kv_blocks)
    unshared_toks, u_bytes, u_conc, _, _ = _serve(
        model, params, prefix_sharing=False, n_kv_blocks=n_kv_blocks)
    assert shared_toks == unshared_toks, \
        f"{name}: sharing changed the token streams"
    ratio = s_bytes / max(u_bytes, 1)
    report = {"shared_peak_kv_bytes": s_bytes,
              "unshared_peak_kv_bytes": u_bytes,
              "peak_bytes_ratio": ratio,
              "shared_peak_concurrency": s_conc,
              "unshared_peak_concurrency": u_conc,
              "peak_shared_fraction": s_frac,
              "tokens_bit_identical": True, **tel}
    rows = [
        Row("prefix", f"{name}.unshared_peak_kv_bytes", float(u_bytes)),
        Row("prefix", f"{name}.shared_peak_kv_bytes", float(s_bytes)),
        Row("prefix", f"{name}.peak_bytes_ratio", ratio,
            note="shared/unshared physical KV peak (<1 = dedupe won)"),
        Row("prefix", f"{name}.shared_peak_concurrency", float(s_conc)),
        Row("prefix", f"{name}.unshared_peak_concurrency", float(u_conc)),
        Row("prefix", f"{name}.shared_block_hits",
            float(tel["shared_hits"])),
        Row("prefix", f"{name}.tokens_equal", 1.0,
            note="bit-identical streams, sharing on vs off"),
    ]
    assert ratio < 1.0, f"{name}: sharing did not reduce the KV peak"
    return report, rows


def run() -> list[Row]:
    model, params = _model()
    report: dict = {"config": {
        "n_tenants": N_TENANTS, "reqs_per_tenant": REQS_PER_TENANT,
        "prefix_len": PREFIX_LEN, "suffix_len": SUFFIX_LEN,
        "max_new_tokens": MAX_NEW, "block_size": BLOCK,
        "max_len": MAX_LEN, "max_batch": MAX_BATCH,
        "tight_pool_blocks": TIGHT_BLOCKS, "ratio_ceiling": RATIO_CEIL}}

    mem, rows = _phase(model, params, "memory", None)
    report["memory"] = mem
    tight, t_rows = _phase(model, params, "concurrency", TIGHT_BLOCKS)
    report["concurrency"] = tight
    rows += t_rows

    # Acceptance: the uncontended pool shows the byte win, the tight pool
    # shows the same budget admitting strictly more requests.
    assert mem["peak_bytes_ratio"] <= RATIO_CEIL, (
        f"memory: shared peak {mem['shared_peak_kv_bytes']} > "
        f"{RATIO_CEIL}x unshared {mem['unshared_peak_kv_bytes']}")
    assert (tight["shared_peak_concurrency"]
            > tight["unshared_peak_concurrency"]), (
        f"concurrency: shared admitted {tight['shared_peak_concurrency']} "
        f"<= unshared {tight['unshared_peak_concurrency']} on the tight "
        f"pool")
    rows.append(Row("prefix", "memory.ratio_vs_ceiling",
                    mem["peak_bytes_ratio"] / RATIO_CEIL,
                    note=f"must be <= 1 (ceiling {RATIO_CEIL})"))

    write_report("BENCH_prefix.json", report)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
