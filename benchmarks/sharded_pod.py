"""Tensor-parallel sharded pods: per-shard HBM high-watermark and
aggregate decode throughput across pod widths.

Serves the same decode-heavy workload through one ``ClusterFrontend``
pod at ``shards`` = 1, 2 and 4 (column-only exact TP over the forced
host-device mesh) and reports, per width:

* **per-shard HBM high-watermark** — resident weight + KV bytes on each
  member device (``FunctionInstance.hbm_bytes_by_device``, counted by
  ``addressable_shards`` so a sharded leaf charges each device only its
  shard while the replicated row-parallel projections charge fully);
* **aggregate decode tokens/s** of the lockstep pod.

Hard acceptance checks: every sharded width emits a token stream
bit-identical to the single-device reference (float32 params — the
documented recipe, see ``src/repro/distributed/README.md``), and each
member's watermark stays strictly below the single-device footprint.

Emits ``BENCH_sharding.json`` (perf-trajectory artifact uploaded by CI,
committed at the repo root) and runs as a CI smoke step with
``--smoke``.

Run:  PYTHONPATH=src python -m benchmarks.sharded_pod [--smoke]
"""

from __future__ import annotations

import os

# The mesh needs 4 host devices *before* jax initializes.  Appended, not
# overwritten, so an explicit user topology wins (same guard as
# tests/conftest.py).
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4").strip()

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, write_report
from repro.core.resources import Alloc
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serving import ClusterFrontend

MAX_BATCH = 4
MAX_LEN = 64
PROMPT_LEN = 8
SHARD_WIDTHS = (1, 2, 4)
ALLOC = Alloc(sm=0.25, quota_request=0.25, quota_limit=0.5)


def _model():
    cfg = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                      vocab_size=64, vocab_pad_multiple=32)
    model = build_model(cfg)
    params = model.init(jax.random.key(7))
    # float32: column-only TP is exact, but bf16 still wobbles by one ulp
    # (constraint-induced codegen), which can flip near-tie argmax — f32
    # keeps the bit-identity check meaningful.
    params = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), params)
    return model, params


def _measure(model, params, shards: int, *, n_reqs: int,
             max_new: int) -> dict:
    """Serve ``n_reqs`` decode-heavy requests through one ``shards``-wide
    pod; returns throughput + per-member HBM watermark + token streams."""
    fe = ClusterFrontend(n_nodes=4)
    handle = fe.place_instance("pod", model, params, ALLOC,
                               max_batch=MAX_BATCH, max_len=MAX_LEN,
                               shards=shards)
    assert handle is not None, f"placement failed for shards={shards}"
    [p] = fe.placements
    inst = fe.engines[p.node].instances[p.inst_id]
    rng = np.random.default_rng(3)

    def submit(n):
        return [fe.submit(
            "pod", rng.integers(0, model.cfg.vocab_size, PROMPT_LEN,
                                dtype=np.int32), max_new_tokens=max_new)
            for _ in range(n)]

    # Warm-up: compile the (mesh-keyed) executors outside the timed phase.
    submit(2)
    fe.pump(budget_s=60.0)

    reqs = submit(n_reqs)
    t0 = time.perf_counter()
    fe.pump(budget_s=300.0)
    elapsed = time.perf_counter() - t0
    assert all(r.done for r in reqs), "requests left unfinished"
    tokens = sum(len(r.tokens_out) for r in reqs)
    hbm = inst.hbm_bytes_by_device()
    return {
        "shards": shards,
        "member_nodes": list(p.member_nodes),
        "requests": len(reqs),
        "tokens": tokens,
        "elapsed_s": elapsed,
        "tokens_per_s": tokens / elapsed,
        "hbm_bytes_by_device": {str(d): int(b) for d, b in sorted(
            hbm.items())},
        "hbm_high_watermark_bytes": max(hbm.values()),
        "tokens_out": [list(r.tokens_out) for r in reqs],
    }


def _strip(stats: dict) -> dict:
    return {k: v for k, v in stats.items() if k != "tokens_out"}


def run(smoke: bool = False) -> list[Row]:
    n_reqs = 8 if smoke else 32
    max_new = 8 if smoke else 24
    model, params = _model()
    report: dict = {"config": {"max_batch": MAX_BATCH, "max_len": MAX_LEN,
                               "prompt_len": PROMPT_LEN, "n_reqs": n_reqs,
                               "max_new_tokens": max_new,
                               "dtype": "float32",
                               "shard_widths": list(SHARD_WIDTHS)}}
    rows: list[Row] = []
    results = {s: _measure(model, params, s, n_reqs=n_reqs,
                           max_new=max_new) for s in SHARD_WIDTHS}
    ref = results[1]
    for s in SHARD_WIDTHS:
        r = results[s]
        report[f"shards{s}"] = _strip(r)
        rows += [
            Row("sharding", f"shards{s}.tokens_per_s", r["tokens_per_s"]),
            Row("sharding", f"shards{s}.hbm_watermark_mib",
                r["hbm_high_watermark_bytes"] / (1 << 20),
                note="max per-member resident weight+KV bytes"),
        ]
        # Hard acceptance checks.
        assert s == 1 or len(set(r["member_nodes"])) == s, r["member_nodes"]
        assert r["tokens_out"] == ref["tokens_out"], (
            f"shards={s}: token stream diverged from the single-device "
            f"reference")
        if s > 1:
            assert (r["hbm_high_watermark_bytes"]
                    < ref["hbm_high_watermark_bytes"]), (
                f"shards={s}: per-member watermark "
                f"{r['hbm_high_watermark_bytes']} >= single-device "
                f"{ref['hbm_high_watermark_bytes']}")
    rows.append(Row(
        "sharding", "shards2.hbm_shrink",
        results[2]["hbm_high_watermark_bytes"]
        / ref["hbm_high_watermark_bytes"],
        note="per-member watermark vs single device; < 1.0 but > 1/shards "
             "(row-parallel projections replicate)"))
    write_report("BENCH_sharding.json", report)
    return rows


if __name__ == "__main__":
    import sys

    rows = run(smoke="--smoke" in sys.argv[1:])
    for r in rows:
        print(r.csv())
