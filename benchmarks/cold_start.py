"""Cold-start tier: scale-from-zero TTFT across the fleet weight tiers.

Measures time-to-first-token (place_instance entry -> first landed
token) for one function scaling from zero under each source tier of the
fleet model store (``repro.serving.modelstore``):

* **cold, blocking** — nothing staged anywhere: the placement pays the
  origin fetch (``weights_loader``: init from scratch, fully
  materialized), host staging, and a full synchronous weight upload
  (``cold_start="blocking"``: device-resident before the engine
  deploys);
* **cold, overlap** — the same genuinely-cold placement, but every
  staged leaf is ``jax.device_put`` asynchronously and left in flight
  while instance creation and the first chunked-prefill admissions
  proceed (``cold_start="overlap"``, the default pipelined mode);
* **host-warm** — the node's own host-RAM cache holds the staged
  shards: TTFT is just the re-upload plus first prefill;
* **peer-warm** — only a peer node's cache holds them: one host-to-host
  copy ahead of the host-warm path.

Methodology: executors are compiled once during a warm-up placement and
shared per model (``engine._executor``) — jit compile is the same
additive constant in every tier and mode, so the benchmark isolates the
weight movement the tier actually changes.  The Python garbage
collector is paused inside each measured window (a collection pass over
the accumulated dead frontends costs more than the effects being
measured) and TTFT floors are min-of-N.

Hard acceptance checks: the overlapped and blocking cold paths produce
bit-identical tokens, host-warm TTFT <= 0.5x cold TTFT, and the
overlapped upload beats blocking on the cold scale-from-zero path —
asserted on the upload stall it removes from the critical path, with
end-to-end TTFT no worse than blocking.

Emits ``BENCH_coldstart.json`` (the artifact uploaded by CI) and runs
as a tier-1 smoke step with ``--smoke``.

Run:  PYTHONPATH=src python -m benchmarks.cold_start [--smoke]
"""

from __future__ import annotations

import gc
import time

import jax
import numpy as np

from benchmarks.common import Row, write_report
from repro.core.resources import Alloc
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serving import ClusterFrontend, FleetModelStore, stage_params

FN = "chat"
MAX_BATCH = 2
MAX_LEN = 64
PROMPT_LEN = 8
HOST_WARM_CEIL = 0.5  # host-warm TTFT <= ceil x cold TTFT (acceptance)
TTFT_REGRESS_CEIL = 1.1  # overlap cold TTFT <= ceil x blocking (no regression)
ALLOC = Alloc(sm=0.5, quota_request=0.9, quota_limit=0.9)


def _model():
    # Fat-but-shallow on purpose: ~57 MB of staged weights makes the
    # origin fetch and the blocking upload's host re-stack dominate the
    # run-to-run noise floor, while first-token execution (which scales
    # with the same parameter count) stays ~100 ms.
    cfg = ModelConfig(name="cold-bench", family="dense", n_layers=4,
                      d_model=512, n_heads=4, n_kv_heads=2, d_ff=4096,
                      vocab_size=128, vocab_pad_multiple=32)
    return build_model(cfg)


def _measure(model, loader, staged, prompt, max_new: int, *, tier: str,
             mode: str = "overlap") -> tuple[dict, list[int]]:
    """One placement + one request through the requested tier; returns
    the resolved cold-start event stats and the request's tokens."""
    store = FleetModelStore()
    if tier == "host":
        # Every node's own cache is warm: the placement hits host
        # wherever it lands.
        for node in range(2):
            store.cache(node).put(FN, staged.copy())
    elif tier == "peer":
        # Only node 1 is warm and node 1 is cordoned, so the placement
        # lands on node 0 and pulls the shards from its peer.
        store.cache(1).put(FN, staged.copy())
    frontend = ClusterFrontend(n_nodes=2, window=0.05, model_store=store,
                               cold_start=mode)
    if tier == "peer":
        frontend.pool.cordon(1)
    gc.collect()
    gc.disable()  # no collection pauses inside the measured window
    try:
        handle = frontend.place_instance(FN, model, None, ALLOC,
                                         max_batch=MAX_BATCH,
                                         max_len=MAX_LEN,
                                         weights_loader=loader)
        assert handle is not None
        req = frontend.submit(FN, prompt, max_new_tokens=max_new)
        frontend.pump(budget_s=300.0)
    finally:
        gc.enable()
    assert req.done, "request did not complete"
    events = frontend.cold_start_events()
    assert len(events) == 1, f"expected one placement, saw {len(events)}"
    e = events[0]
    assert e.ttft_s is not None, "first token never landed"
    assert e.tier == tier, f"expected {tier} tier, hit {e.tier}"
    if tier == "peer":
        assert e.peer == 1 and handle.startswith("0:")
    return ({"tier": e.tier, "mode": e.mode, "nbytes": e.nbytes,
             "upload_s": e.upload_s, "peer": e.peer, "ttft_s": e.ttft_s},
            list(req.tokens_out))


def run(smoke: bool = False) -> list[Row]:
    repeats = 3 if smoke else 5
    max_new = 4 if smoke else 8
    model = _model()

    def loader():
        # The origin fetch: init from scratch, fully materialized — paid
        # inside the measured cold-start window.
        return jax.block_until_ready(model.init(jax.random.key(0)))

    prompt = np.asarray(
        np.random.default_rng(0).integers(0, model.cfg.vocab_size,
                                          PROMPT_LEN), dtype=np.int32)
    staged = stage_params(model, loader())
    # Warm-up: compile the model's shared executors (and the RNG cascade
    # the loader uses) once, so every measured run sees the same warm
    # jit caches — the tiers differ in weight movement, not compile.
    _measure(model, loader, staged, prompt, max_new, tier="cold")

    samples: dict[str, list[dict]] = {}
    tokens: dict[str, list[int]] = {}
    scenarios = [("cold_blocking", "cold", "blocking"),
                 ("cold_overlap", "cold", "overlap"),
                 ("host_warm", "host", "overlap"),
                 ("peer_warm", "peer", "overlap")]
    for name, tier, mode in scenarios:
        runs = [_measure(model, loader, staged, prompt, max_new,
                         tier=tier, mode=mode) for _ in range(repeats)]
        samples[name] = [s for s, _ in runs]
        tokens[name] = runs[0][1]

    floor = {name: min(s["ttft_s"] for s in samples[name])
             for name in samples}
    # Upload stall: how long the placement is blocked on the weight
    # upload (upload_params duration).  Blocking mode re-stacks the
    # layer shards on host and waits for residency; overlap mode only
    # dispatches the per-layer transfers — this is the cold-start time
    # the pipelined upload removes from the critical path.
    stall = {name: min(s["upload_s"] for s in samples[name])
             for name in samples}
    t_cold = min(floor["cold_blocking"], floor["cold_overlap"])

    report = {
        "config": {"model_nbytes": staged.nbytes, "prompt_len": PROMPT_LEN,
                   "max_new_tokens": max_new, "repeats": repeats,
                   "host_warm_ceil": HOST_WARM_CEIL, "smoke": smoke},
        "samples": samples,
        "ttft_s": floor,
        "upload_stall_s": stall,
    }
    rows = [
        Row("cold", "cold_blocking_ttft_s", floor["cold_blocking"],
            note="scale-from-zero, full synchronous upload"),
        Row("cold", "cold_overlap_ttft_s", floor["cold_overlap"],
            note="scale-from-zero, pipelined per-layer upload"),
        Row("cold", "cold_blocking_upload_stall_s", stall["cold_blocking"],
            note="placement blocked on host re-stack + sync transfer"),
        Row("cold", "cold_overlap_upload_stall_s", stall["cold_overlap"],
            note="placement only dispatches; transfers stay in flight"),
        Row("cold", "overlap_vs_blocking_stall",
            stall["cold_overlap"] / stall["cold_blocking"],
            note="cold upload-stall ratio; pipelined upload must win"),
        Row("cold", "overlap_vs_blocking_ttft",
            floor["cold_overlap"] / floor["cold_blocking"],
            note=f"cold TTFT ratio; overlap must not regress "
                 f"(<= {TTFT_REGRESS_CEIL})"),
        Row("cold", "host_warm_ttft_s", floor["host_warm"]),
        Row("cold", "peer_warm_ttft_s", floor["peer_warm"]),
        Row("cold", "host_warm_vs_cold", floor["host_warm"] / t_cold,
            note=f"acceptance: <= {HOST_WARM_CEIL} x cold"),
        Row("cold", "peer_warm_vs_cold", floor["peer_warm"] / t_cold),
        Row("cold", "staged_mbytes", staged.nbytes / 1e6),
    ]
    # Hard acceptance checks.  "Overlap beats blocking" is asserted on
    # the upload stall (the serial cold-start time the pipelined mode
    # provably removes) plus a no-regression bound on end-to-end TTFT:
    # on this container H2D transfers are host memcpys, so the stall is
    # the structural difference while TTFT floors differ only by it.
    assert tokens["cold_blocking"] == tokens["cold_overlap"], (
        f"overlapped upload changed tokens: {tokens['cold_overlap']} vs "
        f"{tokens['cold_blocking']}")
    assert floor["host_warm"] <= HOST_WARM_CEIL * t_cold, (
        f"host-warm TTFT {floor['host_warm']:.3f}s > {HOST_WARM_CEIL} x "
        f"cold {t_cold:.3f}s")
    assert stall["cold_overlap"] < stall["cold_blocking"], (
        f"overlapped upload stall {stall['cold_overlap']*1e3:.1f}ms did "
        f"not beat blocking {stall['cold_blocking']*1e3:.1f}ms")
    assert (floor["cold_overlap"]
        <= TTFT_REGRESS_CEIL * floor["cold_blocking"]), (
        f"overlapped cold TTFT {floor['cold_overlap']:.3f}s regressed "
        f"past blocking {floor['cold_blocking']:.3f}s")
    assert floor["peer_warm"] < t_cold, (
        f"peer-warm TTFT {floor['peer_warm']:.3f}s did not beat cold "
        f"{t_cold:.3f}s")
    write_report("BENCH_coldstart.json", report)
    return rows


if __name__ == "__main__":
    import sys

    t0 = time.perf_counter()
    rows = run(smoke="--smoke" in sys.argv[1:])
    for r in rows:
        print(r.csv())
    print(f"# total {time.perf_counter() - t0:.1f}s")
