"""Paper Fig. 8 — FaST-Profiler throughput curves.

Profiles each model over the paper's (spatial x temporal) grid with the
real Experiment->Trial workflow (dedicated node, TokenScheduler in the
loop) and checks the figure's three qualitative laws plus its quantitative
anchors:

1. *temporal proportionality*: T(s, q) ~= q x T(s, 1);
2. *spatial saturation*: throughput stops growing at ``sm_sat``;
3. larger models saturate later (resnet @24% < gnmt/bert @50% < vit @80%).
"""

from __future__ import annotations

from benchmarks.common import Row
from repro.core.profiler import profile_function
from repro.core.workload import PAPER_ZOO

MODELS = ("resnet", "rnnt", "gnmt", "bert")
GRID_T = (0.2, 0.4, 0.6, 0.8, 1.0)
GRID_S = (0.06, 0.12, 0.24, 0.5, 1.0)


def run() -> list[Row]:
    rows: list[Row] = []
    for name in MODELS:
        curve = PAPER_ZOO[name]
        db = profile_function(curve, temporal=GRID_T, spatial=GRID_S,
                              duration=20.0)
        pts = {(round(p.sm, 2), round(p.quota, 2)): p.throughput
               for p in db.table(name)}
        # 1. temporal proportionality at sm=0.24: T(0.4)/T(1.0) ~ 0.4
        ratio = pts[(0.24, 0.4)] / max(pts[(0.24, 1.0)], 1e-9)
        rows.append(Row("fig8", f"{name}.temporal_ratio_q40", ratio,
                        target=0.4, tol=0.2,
                        note="T(s,0.4q)/T(s,1.0q) ~ 0.4"))
        # 2. spatial saturation: beyond sm_sat, gain < 10%
        sat_gain = pts[(1.0, 1.0)] / max(pts[(round(curve.sm_sat, 2), 1.0)]
                                         if (round(curve.sm_sat, 2), 1.0)
                                         in pts else pts[(0.5, 1.0)], 1e-9)
        rows.append(Row("fig8", f"{name}.saturation_gain", sat_gain,
                        target=1.0, tol=0.1,
                        note="T(100% SM)/T(sm_sat) — flat past saturation"))
        # Quantitative anchor: racing throughput (paper §5.3)
        rows.append(Row("fig8", f"{name}.racing_rps", pts[(1.0, 1.0)],
                        target=curve.r_max, tol=0.1,
                        note="single pod, full GPU"))
    # 3. saturation ordering (info)
    order = [PAPER_ZOO[m].sm_sat for m in ("resnet", "gnmt", "vit_huge")]
    rows.append(Row("fig8", "saturation_monotone",
                    1.0 if order == sorted(order) else 0.0, target=1.0,
                    tol=0.0, note="larger models saturate later"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
