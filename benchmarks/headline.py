"""Headline claims — §1/§6: vs time sharing, FaST-GShare delivers
3.15x higher throughput, 1.34x GPU utilization, 3.13x SM occupancy.

Aggregates the Fig.-10 spatial-sharing gains (throughput) and the Fig.-11
scheduler comparison (utilization / occupancy), exactly as the paper does.
"""

from __future__ import annotations

from benchmarks import scheduler_packing, spatial_sharing
from benchmarks.common import Row


def run(continuous: bool = False) -> list[Row]:
    rows: list[Row] = []
    fig10 = {r.metric: r.value for r in spatial_sharing.run()}
    fig11 = {r.metric: r.value for r in scheduler_packing.run()}
    # Throughput: the paper's headline is the *best* (ResNet) gain.
    rows.append(Row("headline", "throughput_gain_resnet",
                    fig10["resnet.throughput_gain"], target=3.15, tol=0.15,
                    note="'improve throughput by 3.15x' (ResNet anchor)"))
    rows.append(Row("headline", "gpu_utilization_gain",
                    fig11["gpu_utilization_gain"], target=1.34, tol=0.25))
    rows.append(Row("headline", "sm_occupancy_gain",
                    fig11["sm_occupancy_gain"], target=3.13, tol=0.3))
    if continuous:
        # Beyond-paper: slot-level batching on top of spatial sharing.
        fig10c = {r.metric: r.value for r in spatial_sharing.run_continuous()}
        for fn in spatial_sharing.CONT_FNS:
            rows.append(Row(
                "headline", f"continuous_occupancy_gain_{fn}",
                fig10c[f"{fn}.occupancy_gain"],
                note="slot-level vs static batching, decode-heavy load"))
    return rows


if __name__ == "__main__":
    import sys

    for r in run(continuous="--continuous" in sys.argv[1:]):
        print(r.csv())
