"""Paper Fig. 10 + §5.3 — spatial-sharing performance vs time sharing.

One node; compare a single *racing* pod (100% SM = the maximum time
sharing can deliver) against 8 pods at 12% SM partitions.  The paper's
quantitative anchors (V100, MLPerf models):

  resnet: 296.8 vs 71.37 req/s  -> +3.15x higher
  rnnt:   43.24 vs 12.51 req/s  -> +2.45x higher
  gnmt:   43.79 vs 28.85 req/s  -> +0.52x higher

and tail latency / utilization / SM occupancy all improve.

``--continuous`` runs the beyond-paper comparison instead: multi-token
(autoregressive) requests through static vs continuous (slot-level)
batching on the same spatial partitions — continuous batching re-fills
freed decode slots mid-flight, so token-granted rounds stay full and SM
occupancy / tail latency improve.

``--paged`` drives the LIVE data plane (real JAX, tiny config): the same
mixed-length workload through the dense slot pool (``continuous``) and
the block-paged KV cache (``paged``) behind ``ClusterFrontend``,
reporting peak physical KV bytes-in-use vs. the dense ``max_len``
reservation, token-stream equivalence, and allocator stats.  Fast enough
(seconds) to run as the tier-1 CI paged smoke.
"""

from __future__ import annotations

from benchmarks.common import Row
from repro.core.cluster import Cluster
from repro.core.scaling import ProfilePoint
from repro.core.workload import PAPER_ZOO, poisson_arrivals

DURATION = 40.0
# Continuous-batching scenario: decode-heavy requests on 8x12% partitions,
# driven past the pods' serial token capacity so slot fill is the
# bottleneck.
N_TOKENS = 8
MAX_BATCH = 8
CONT_FNS = ("rnnt", "resnet")
CONT_OVERDRIVE = 1.6
PAPER = {  # (racing_rps, 8x12% rps, gain = spatial/racing - 1)
    "resnet": (71.37, 296.8, 3.15),
    "rnnt": (12.51, 43.24, 2.45),
    "gnmt": (28.85, 43.79, 0.52),
}


def _run_pods(fn: str, n_pods: int, sm: float, *, rps: float
              ) -> tuple[float, float, float, float]:
    """-> (completed RPS, p99, utilization, occupancy)."""
    curve = PAPER_ZOO[fn]
    cluster = Cluster(n_nodes=1, sharing=True)
    cluster.register_function(fn, curve)
    for _ in range(n_pods):
        assert cluster.deploy(
            fn, ProfilePoint(sm=sm, quota=1.0, throughput=0.0)) is not None
    cluster.submit_all(poisson_arrivals(fn, rps, DURATION, seed=11))
    cluster.run(DURATION + 5)
    warm = DURATION * 0.2
    rec = cluster.recorders[fn]
    node = cluster.nodes[0]
    return (rec.throughput(warm, DURATION), rec.p99(since=warm),
            node.scheduler.utilization(last_n=30),
            node.scheduler.occupancy(last_n=30))


def run() -> list[Row]:
    rows: list[Row] = []
    for fn, (racing_t, spatial_t, gain_t) in PAPER.items():
        drive = spatial_t * 1.3
        racing = _run_pods(fn, 1, 1.0, rps=drive)
        spatial = _run_pods(fn, 8, 0.12, rps=drive)
        gain = spatial[0] / max(racing[0], 1e-9) - 1.0
        rows.append(Row("fig10", f"{fn}.racing_rps", racing[0],
                        target=racing_t, tol=0.15))
        rows.append(Row("fig10", f"{fn}.spatial8x12_rps", spatial[0],
                        target=spatial_t, tol=0.15))
        rows.append(Row("fig10", f"{fn}.throughput_gain", gain,
                        target=gain_t, tol=0.25,
                        note="spatial/racing - 1 (paper 'x higher')"))
        rows.append(Row("fig10", f"{fn}.p99_improvement",
                        racing[1] / max(spatial[1], 1e-9),
                        note="racing p99 / spatial p99 (>1 = better tail)"))
        rows.append(Row("fig10", f"{fn}.occupancy_spatial", spatial[3],
                        note="SM occupancy, 8x12% pods"))
        rows.append(Row("fig10", f"{fn}.occupancy_racing", racing[3],
                        note="SM occupancy, racing pod"))
    # RNNT anchor from §5.3: 8 spatial pods ~40 req/s with <=500ms tail.
    rnnt8 = _run_pods("rnnt", 8, 0.12, rps=45.0)
    rows.append(Row("fig10", "rnnt.eight_pod_rps", rnnt8[0], target=40.0,
                    tol=0.15))
    rows.append(Row("fig10", "rnnt.eight_pod_p99_s", rnnt8[1],
                    note="paper: below 0.5 s"))
    return rows


def _run_batched(fn: str, *, continuous: bool, rps: float
                 ) -> tuple[float, float, float, int]:
    """-> (completed RPS, p99, occupancy, mid-flight slot refills)."""
    curve = PAPER_ZOO[fn]
    cluster = Cluster(n_nodes=1, sharing=True, max_batch=MAX_BATCH,
                      continuous=continuous)
    cluster.register_function(fn, curve)
    for _ in range(8):
        assert cluster.deploy(
            fn, ProfilePoint(sm=0.12, quota=1.0, throughput=0.0)) is not None
    cluster.submit_all(poisson_arrivals(fn, rps, DURATION, seed=11,
                                        n_tokens=N_TOKENS))
    cluster.run(DURATION + 5)
    warm = DURATION * 0.2
    rec = cluster.recorders[fn]
    node = cluster.nodes[0]
    refills = sum(p.refills for p in cluster.pods.values())
    return (rec.throughput(warm, DURATION), rec.p99(since=warm),
            node.scheduler.occupancy(last_n=30), refills)


def run_continuous() -> list[Row]:
    """Static vs continuous (slot-level) batching, decode-heavy workload."""
    rows: list[Row] = []
    for fn in CONT_FNS:
        # Serial token capacity of the partition, overdriven so the pods
        # are never starved and slot fill is what limits occupancy.
        rps = PAPER_ZOO[fn].rate(0.12) * 8 / N_TOKENS * CONT_OVERDRIVE
        static = _run_batched(fn, continuous=False, rps=rps)
        cont = _run_batched(fn, continuous=True, rps=rps)
        rows.append(Row("fig10c", f"{fn}.occupancy_static", static[2]))
        rows.append(Row("fig10c", f"{fn}.occupancy_continuous", cont[2],
                        note="must be strictly higher than static"))
        rows.append(Row("fig10c", f"{fn}.occupancy_gain",
                        cont[2] / max(static[2], 1e-9),
                        note="continuous / static SM occupancy"))
        rows.append(Row("fig10c", f"{fn}.throughput_gain",
                        cont[0] / max(static[0], 1e-9)))
        rows.append(Row("fig10c", f"{fn}.p99_improvement",
                        static[1] / max(cont[1], 1e-9),
                        note=">1 = continuous has the better tail"))
        rows.append(Row("fig10c", f"{fn}.slot_refills", float(cont[3]),
                        note="mid-flight admissions (static: 0 by design)"))
    return rows


# -- live paged-KV comparison (tiny model, real JAX data plane) ------------

PAGED_BLOCK = 8
PAGED_MAX_LEN = 32
PAGED_MAX_BATCH = 4


def _paged_workload(vocab: int, n: int = 16, seed: int = 5):
    """Mixed-length prompts/output budgets — the fragmentation case."""
    import numpy as np

    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = int(rng.integers(3, PAGED_MAX_LEN // 2))
        max_new = int(rng.integers(2, 7))
        out.append((rng.integers(0, vocab, plen, dtype=np.int32), max_new))
    return out


def _serve_paged(batching: str):
    from repro.core.resources import Alloc
    from repro.models import build_model
    from repro.models.config import ModelConfig
    from repro.serving import ClusterFrontend

    import jax

    cfg = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                      vocab_size=64, vocab_pad_multiple=32)
    model = build_model(cfg)
    params = model.init(jax.random.key(7))
    frontend = ClusterFrontend(n_nodes=1, window=0.1)
    frontend.deploy("lm", model, params,
                    Alloc(sm=0.9, quota_request=0.9, quota_limit=0.9),
                    max_batch=PAGED_MAX_BATCH, max_len=PAGED_MAX_LEN,
                    batching=batching, block_size=PAGED_BLOCK)
    reqs = [frontend.submit("lm", p, max_new_tokens=m)
            for p, m in _paged_workload(cfg.vocab_size)]
    done = frontend.pump(budget_s=120.0)
    assert done == len(reqs), f"{batching}: {done}/{len(reqs)} completed"
    inst = [i for e in frontend.engines
            for i in e.instances.values()][0]
    peak = (inst.kv_bytes_peak if batching == "paged"
            else inst.dense_kv_reserved())
    stats = inst.allocator.stats() if batching == "paged" else {}
    return [r.tokens_out for r in reqs], peak, inst.dense_kv_reserved(), stats


def run_paged() -> list[Row]:
    """Paged vs dense-slot KV bytes on the live engine (same tokens out)."""
    dense_toks, dense_peak, dense_reserved, _ = _serve_paged("continuous")
    paged_toks, paged_peak, _, stats = _serve_paged("paged")
    rows = [
        Row("paged", "lm.dense_kv_reserved_bytes", float(dense_reserved)),
        Row("paged", "lm.paged_kv_peak_bytes", float(paged_peak),
            note="must be strictly below the dense reservation"),
        Row("paged", "lm.kv_bytes_ratio", paged_peak / max(dense_reserved, 1),
            note="paged peak / dense reservation (<1 = fragmentation won)"),
        Row("paged", "lm.tokens_equal",
            1.0 if paged_toks == dense_toks else 0.0,
            note="paged decode must match the dense path token-for-token"),
        Row("paged", "lm.block_high_watermark",
            float(stats.get("high_watermark", 0))),
        Row("paged", "lm.blocks_leaked", float(stats.get("in_use", 0)),
            note="must be 0 after drain"),
    ]
    assert paged_peak < dense_reserved, "paged KV must beat dense reservation"
    assert paged_toks == dense_toks, "paged decode diverged from dense"
    assert stats.get("in_use", 0) == 0, "paged engine leaked KV blocks"
    return rows


if __name__ == "__main__":
    import sys

    if "--paged" in sys.argv[1:]:
        rows = run_paged()
    elif "--continuous" in sys.argv[1:]:
        rows = run_continuous()
    else:
        rows = run()
    for r in rows:
        print(r.csv())
