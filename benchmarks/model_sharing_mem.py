"""Paper Fig. 13 + §5.5 — model sharing memory footprints.

Anchors from the paper (V100 16G):
  * resnet single pod: 1525M -> 1427M + (98M weights shared) [~6.4% smaller
    marginal]; vit_huge marginal instance: 4735M -> 2101M (55.6% smaller);
  * vit_huge x3: 14205M unshared vs 9282M shared (~4.8G saved);
  * 16G fits 7 ResNeXt pods with sharing, 4 without.
"""

from __future__ import annotations

from benchmarks.common import Row
from repro.core.cluster import Cluster
from repro.core.model_sharing import MemoryModel, ModelStore
from repro.core.scaling import ProfilePoint
from repro.core.workload import PAPER_ZOO

GIB16 = 16 * 1024**3


def _mm(name: str) -> MemoryModel:
    c = PAPER_ZOO[name]
    return MemoryModel(weight_bytes=c.weight_bytes,
                       framework_bytes=c.framework_bytes)


def run() -> list[Row]:
    rows: list[Row] = []
    mb = 1024**2
    vit = _mm("vit_huge")
    resnet = _mm("resnet")
    resnext = _mm("resnext")
    # Marginal per-instance reduction (paper: 55.6% for vit, 6.4% resnet).
    vit_marginal = 1.0 - vit.framework_bytes / (
        vit.weight_bytes + vit.framework_bytes)
    rows.append(Row("fig13", "vit_huge.marginal_reduction", vit_marginal,
                    target=0.556, tol=0.02))
    rows.append(Row("fig13", "resnet.marginal_reduction",
                    1.0 - resnet.framework_bytes /
                    (resnet.weight_bytes + resnet.framework_bytes),
                    target=0.064, tol=0.05))
    # 3-pod footprint (paper: 9282M shared vs 14205M unshared).
    rows.append(Row("fig13", "vit_huge.x3_shared_mb",
                    vit.footprint(3, True) / mb, target=9282, tol=0.02))
    rows.append(Row("fig13", "vit_huge.x3_unshared_mb",
                    vit.footprint(3, False) / mb, target=14205, tol=0.01))
    # Single-pod overhead: sharing is slightly *worse* for one pod.
    rows.append(Row("fig13", "vit_huge.x1_overhead_mb",
                    (vit.footprint(1, True) - vit.footprint(1, False)) / mb,
                    target=300, tol=0.05,
                    note="server context overhead dominates at n=1"))
    # Packing claim: 7 ResNeXt pods with sharing vs 4 without on 16G.
    rows.append(Row("fig13", "resnext.max_pods_shared",
                    resnext.max_instances(GIB16, True), target=7, tol=0.0))
    rows.append(Row("fig13", "resnext.max_pods_unshared",
                    resnext.max_instances(GIB16, False), target=4, tol=0.0))

    # Live store semantics: zero-copy GET (the actual data plane).
    store = ModelStore()
    import numpy as np
    tree = {"w": np.zeros((1024, 1024), np.float32)}
    store.store("vit", tree)
    a = store.get("vit")
    b = store.get("vit")
    rows.append(Row("fig13", "store.zero_copy",
                    1.0 if a["w"] is b["w"] else 0.0, target=1.0, tol=0.0,
                    note="same buffer object for every GET"))
    rows.append(Row("fig13", "store.refcount", store.refcount("vit"),
                    target=2, tol=0.0))

    # Admission control in the cluster: a node admits more shared pods.
    cl_s = Cluster(n_nodes=1, mem_bytes=GIB16, sharing=True)
    cl_u = Cluster(n_nodes=1, mem_bytes=GIB16, sharing=False)
    for cl in (cl_s, cl_u):
        cl.register_function("resnext", PAPER_ZOO["resnext"])
    pt = ProfilePoint(sm=0.12, quota=0.5, throughput=1.0)
    n_s = sum(cl_s.deploy("resnext", pt) is not None for _ in range(10))
    n_u = sum(cl_u.deploy("resnext", pt) is not None for _ in range(10))
    rows.append(Row("fig13", "cluster.admitted_shared", n_s, target=7,
                    tol=0.0, note="node admission control honors sharing"))
    rows.append(Row("fig13", "cluster.admitted_unshared", n_u, target=4,
                    tol=0.0))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
