"""Paper Fig. 9 — effectiveness of spatial isolation.

The paper's experiment: ResNet (quota request-limit 50%-80%) and RNNT
(50%-50%) co-located.  With *time sharing only* (both at 100% SM) RNNT's
elastic quota expansion interferes with ResNet.  With *spatio-temporal
sharing* (both capped at 24% SM) the two pods cannot touch each other's
compute, so ResNet's throughput is unchanged whether RNNT runs or not.
"""

from __future__ import annotations

from benchmarks.common import Row
from repro.core.cluster import Cluster
from repro.core.scaling import ProfilePoint
from repro.core.workload import PAPER_ZOO, poisson_arrivals

DURATION = 40.0


def _throughput(co_locate: bool, spatial: bool) -> float:
    """ResNet completed RPS, optionally next to an elastic RNNT pod."""
    cluster = Cluster(n_nodes=1, sharing=True)
    resnet, rnnt = PAPER_ZOO["resnet"], PAPER_ZOO["rnnt"]
    cluster.register_function("resnet", resnet)
    cluster.register_function("rnnt", rnnt)
    sm = 0.24 if spatial else 1.0
    # ResNet: Q_request 0.5, Q_limit 0.8 (paper 50%-80%).
    cluster.deploy("resnet", ProfilePoint(sm=sm, quota=0.5, throughput=0.0),
                   elastic_limit=0.8)
    if co_locate:
        # RNNT: 50%-50%, but *elastic* in the time-sharing-only case the
        # paper demonstrates interference with (80%+50% > 100%).
        cluster.deploy("rnnt", ProfilePoint(sm=sm, quota=0.5, throughput=0.0),
                       elastic_limit=1.0 if not spatial else 0.5)
        cluster.submit_all(poisson_arrivals(
            "rnnt", rnnt.rate(sm, 1.0) * 1.5, DURATION, seed=7))
    cluster.submit_all(poisson_arrivals(
        "resnet", resnet.rate(sm, 0.8) * 1.5, DURATION, seed=3))
    cluster.run(DURATION + 5)
    warm = DURATION * 0.2
    return cluster.recorders["resnet"].throughput(warm, DURATION)


def run() -> list[Row]:
    rows: list[Row] = []
    # Time sharing only: co-location hurts ResNet (interference).
    alone_t = _throughput(co_locate=False, spatial=False)
    shared_t = _throughput(co_locate=True, spatial=False)
    interference = 1.0 - shared_t / max(alone_t, 1e-9)
    rows.append(Row("fig9", "time_sharing.resnet_interference",
                    interference, note="fraction of RPS lost to RNNT "
                    "(elastic 80%+50% > 100%)"))
    # Spatio-temporal sharing: no mutual influence.
    alone_s = _throughput(co_locate=False, spatial=True)
    shared_s = _throughput(co_locate=True, spatial=True)
    iso_err = abs(1.0 - shared_s / max(alone_s, 1e-9))
    rows.append(Row("fig9", "spatial_sharing.resnet_isolation_err",
                    iso_err, target=0.0, tol=0.05,
                    note="|1 - co-located/alone| ~ 0 with 24%/24% partitions"))
    rows.append(Row("fig9", "interference_detected",
                    1.0 if interference > 0.1 else 0.0, target=1.0, tol=0.0,
                    note="time-sharing-only case must show interference"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
