"""Fault tolerance — reconciler healing after a mid-ramp node failure.

The busiest node is killed halfway through a rising RPS ramp.  The failure
path itself (``Cluster.fail_node``) only records the damage: pods are
marked dead, stranded requests re-queue to survivors (or park until a
replica exists).  Healing is entirely the reconciler's: the next
``ControlPlane.reconcile`` tick prunes the dead pods from L_j via
``Backend.alive`` and the processing gap + below-floor healing re-place
the lost capacity.

Two trials, identical workload and failure:

* **healed** — the 0.5 s reconcile loop keeps running through the
  failure; reported are the SLO-violation window (how long completions
  keep violating the SLO after the kill) and the time-to-reconverge
  (first tick whose L_j capacity is back to the pre-failure level).
* **unhealed** — the reconcile loop stops at the failure (a control
  plane that cannot see dead pods): lost capacity stays lost, and
  requests stranded on the dead node are never served.

Run:  PYTHONPATH=src python -m benchmarks.fault_tolerance [--smoke]
"""

from __future__ import annotations

import argparse

from benchmarks.common import HEADER, Row, write_report
from repro.control import ControlPlane, FunctionSpec, SimBackend, ramp
from repro.core.cluster import Cluster
from repro.core.scaling import ProfilePoint
from repro.core.workload import PAPER_ZOO, trace_arrivals

SLO_S = 0.069
CONTROL_PERIOD = 0.5
HEADROOM = 1.6


def _profile() -> tuple[ProfilePoint, ...]:
    c = PAPER_ZOO["resnet"]
    return tuple(
        ProfilePoint(sm=sm, quota=q, throughput=c.rate(sm, q),
                     p99_latency=0.04)
        for sm, q in ((0.12, 1.0), (0.24, 1.0), (0.12, 0.5)))


def _trial(heal: bool, duration: float) -> dict[str, float]:
    t_fail = duration / 2
    trace = [(0.0, 15.0), (duration * 0.25, 40.0), (duration, 0.0)]
    c = PAPER_ZOO["resnet"]
    cluster = Cluster(n_nodes=4, sharing=True)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(FunctionSpec(
        name="resnet", profile=_profile(), slo_latency=SLO_S,
        target_rps=ramp(trace[:-1]), headroom=HEADROOM,
        min_instances=1, max_instances=32, curve=c))
    arrivals = trace_arrivals("resnet", trace, seed=11)
    cluster.submit_all(arrivals)
    state = {"pre_fail_capacity": 0.0, "reconverged_at": float("inf")}

    def fail() -> None:
        state["pre_fail_capacity"] = plane.capacity("resnet")
        busiest = max((n for n in cluster.nodes if n.alive and n.pods),
                      key=lambda n: len(n.pods))
        cluster.fail_node(busiest.node_id)

    cluster.sim.at(t_fail, fail)

    def control() -> None:
        if cluster.sim.now >= t_fail and not heal:
            return  # frozen control plane: nothing prunes, nothing heals
        plane.reconcile()
        if (cluster.sim.now > t_fail
                and state["reconverged_at"] == float("inf")
                and plane.capacity("resnet")
                >= state["pre_fail_capacity"] - 1e-9):
            state["reconverged_at"] = cluster.sim.now
        if cluster.sim.now < duration:
            cluster.sim.after(CONTROL_PERIOD, control)

    cluster.sim.after(CONTROL_PERIOD, control)
    cluster.run(duration + 15.0)
    rec = cluster.recorders["resnet"]
    violations = [t for lat, t in zip(rec.latencies, rec.completion_times)
                  if t > t_fail and lat > SLO_S]
    return {
        "served_fraction": rec.count() / max(len(arrivals), 1),
        "violation_window_s": (max(violations) - t_fail) if violations
        else 0.0,
        "time_to_reconverge_s": state["reconverged_at"] - t_fail,
        "pods_lost": cluster.rescheduled,
    }


def run(duration: float = 40.0) -> list[Row]:
    healed = _trial(heal=True, duration=duration)
    unhealed = _trial(heal=False, duration=duration)
    write_report("BENCH_fault.json", {
        "bench": "fault_tolerance",
        "duration_s": duration,
        "control_period_s": CONTROL_PERIOD,
        "slo_s": SLO_S,
        "healed": healed,
        "unhealed": unhealed,
    })
    return [
        Row("fault", "served_fraction_healed", healed["served_fraction"],
            target=1.0, tol=0.001,
            note="reconciler healing: zero lost requests"),
        Row("fault", "time_to_reconverge_s",
            healed["time_to_reconverge_s"],
            note="first tick with L_j capacity back at pre-failure level"),
        Row("fault", "violation_window_s", healed["violation_window_s"],
            note="completions violating the SLO after the kill (healed)"),
        Row("fault", "served_fraction_unhealed",
            unhealed["served_fraction"],
            note="control loop frozen at the failure: stranded work "
                 "never completes"),
        Row("fault", "pods_lost", healed["pods_lost"],
            note="pods on the killed node (busiest of 4)"),
    ]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="short run + hard assertions (CI tier-1)")
    parser.add_argument("--duration", type=float, default=40.0)
    args = parser.parse_args()
    rows = run(duration=20.0 if args.smoke else args.duration)
    print(HEADER)
    by_metric = {}
    for r in rows:
        print(r.csv())
        by_metric[r.metric] = r.value
    if args.smoke:
        assert by_metric["served_fraction_healed"] == 1.0, \
            "healing dropped requests"
        assert by_metric["time_to_reconverge_s"] <= 5 * CONTROL_PERIOD, \
            "healing took more than a few control periods"
        assert by_metric["served_fraction_unhealed"] < 1.0, \
            "the unhealed baseline should strand requests"
        print("smoke: OK (healed fleet reconverged, zero lost requests)")


if __name__ == "__main__":
    main()
