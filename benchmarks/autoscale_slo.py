"""Paper Fig. 12 — heuristic auto-scaling holds the SLO under varying load.

ResNet with a 69 ms latency SLO (the paper's number).  The offered RPS
follows a diurnal ramp (20 -> 240 -> 20 req/s, 8x swing, as Fig. 12's
varying load); every 0.5 s the control loop predicts RPS from the trailing
window and runs Alg. 1 (scale-up with p_eff/p_ideal, scale-down lowest-RPR
first).  Acceptance (paper): SLO violations <= 1%.

Profile points carry p99s measured at 0.8x capacity (not the saturating
capacity probe), so Alg. 1's SLO-feasibility filter can reject
configurations whose *service time alone* eats the latency budget.

A second, harsher trace with abrupt 2-4x steps is reported as info: a
purely reactive scaler necessarily violates during the detection lag.
"""

from __future__ import annotations

from benchmarks.common import Row, write_report
from repro.control import ControlPlane, FunctionSpec, SimBackend
from repro.core.cluster import Cluster
from repro.core.profiler import profile_points
from repro.core.workload import PAPER_ZOO, diurnal_trace, trace_arrivals

SLO_S = 0.069
DURATION = 160.0
CONTROL_PERIOD = 0.5
HORIZON = 2.0
HEADROOM = 1.6  # target utilization ~0.6: bounded queueing at p99
STEP_TRACE = [(0.0, 30.0), (30.0, 120.0), (60.0, 240.0), (100.0, 90.0),
              (130.0, 20.0), (160.0, 0.0)]


def _run_trace(trace, profiles) -> tuple[float, float, float, int]:
    cluster = Cluster(n_nodes=8, sharing=True, max_batch=2)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(FunctionSpec(
        name="resnet", profile=tuple(profiles["resnet"]),
        slo_latency=SLO_S, rps_window=HORIZON, headroom=HEADROOM,
        min_instances=1, max_instances=64, elastic_limit=1.0,
        curve=PAPER_ZOO["resnet"]))
    arrivals = trace_arrivals("resnet", trace, seed=5)
    cluster.submit_all(arrivals)
    peak_pods = [1]

    def control() -> None:
        # Observed-RPS mode: the reconciler predicts demand from the
        # cluster's trailing arrival window (gateway-style).
        plane.reconcile()
        peak_pods[0] = max(peak_pods[0], plane.instances("resnet"))
        if cluster.sim.now < DURATION:
            cluster.sim.after(CONTROL_PERIOD, control)

    cluster.sim.after(CONTROL_PERIOD, control)
    cluster.run(DURATION + 10)
    rec = cluster.recorders["resnet"]
    warm = 5.0
    return (rec.violation_ratio(since=warm),
            rec.count() / max(len(arrivals), 1),
            rec.p99(since=warm), peak_pods[0])


def run() -> list[Row]:
    profiles = {"resnet": profile_points(
        PAPER_ZOO["resnet"], spatial=(0.12, 0.24, 0.5), temporal=(0.4, 1.0),
        duration=15.0)}
    ramp = diurnal_trace(base_rps=20.0, peak_rps=240.0, period=DURATION,
                         duration=DURATION, step=5.0) + [(DURATION, 0.0)]
    v, served, p99, pods = _run_trace(ramp, profiles)
    rows = [
        Row("fig12", "slo_violation_ratio", v, target=0.0, tol=0.01,
            note="paper: <=1% at 69 ms SLO (diurnal 20->240->20 RPS)"),
        Row("fig12", "served_fraction", served, target=1.0, tol=0.02,
            note="dropped requests break the SLO too"),
        Row("fig12", "p99_s", p99, note="end-to-end p99 under autoscaling"),
        Row("fig12", "peak_pods", pods,
            note="Alg. 1 scaled up to this many pods at the 240 RPS peak"),
    ]
    v2, served2, p99_2, pods2 = _run_trace(STEP_TRACE, profiles)
    rows.append(Row("fig12", "abrupt_step_violation_ratio", v2,
                    note="2-4x RPS steps: reactive detection lag shows up "
                         "as transient violations"))
    rows.append(Row("fig12", "abrupt_step_peak_pods", pods2))
    write_report("BENCH_autoscale.json", {
        "bench": "autoscale_slo",
        "slo_s": SLO_S,
        "duration_s": DURATION,
        "diurnal": {"violation_ratio": v, "served_fraction": served,
                    "p99_s": p99, "peak_pods": pods},
        "abrupt_step": {"violation_ratio": v2, "served_fraction": served2,
                        "p99_s": p99_2, "peak_pods": pods2},
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
