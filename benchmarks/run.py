"""Benchmark harness: one module per paper table/figure.

  fig8   profiler_curves      FaST-Profiler throughput curves
  fig9   isolation            spatial isolation vs time-sharing interference
  fig10  spatial_sharing      spatial sharing vs racing (throughput/tail)
  fig11  scheduler_packing    MRA packing, utilization/occupancy gains
  fig12  autoscale_slo        Alg.-1 autoscaling holds the 69 ms SLO
  fig13  model_sharing_mem    model-sharing memory footprints
  fault  fault_tolerance      reconciler healing after a node failure
  prefix prefix_sharing       prefix-cache KV dedupe: bytes + concurrency
  head   headline             3.15x / 1.34x / 3.13x aggregate claims
  roof   roofline_table       (arch x shape x mesh) roofline from dry-run
  cold   cold_start           fleet model-store cold-start tiers (TTFT)
  decode decode_throughput    sync-free fused decode hot path
  spec   decode_throughput    speculative draft/verify round (--speculate)
  shard  sharded_pod          tensor-parallel pods: HBM/shard + tokens/s
  chaos  chaos_soak           seeded fault schedule: goodput + quarantine

Every module writes its ``BENCH_*.json`` artifact to the repo root
(``benchmarks.common.write_report``) regardless of the launch CWD.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only fig10,fig11]
Output: ``bench,metric,value,paper_target,status,note`` CSV rows; exits
non-zero if any targeted metric misses its tolerance.
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import HEADER, Row

# (key, module, entry-point attr): one module may expose several benches.
MODULES = [
    ("fig8", "benchmarks.profiler_curves", "run"),
    ("fig9", "benchmarks.isolation", "run"),
    ("fig10", "benchmarks.spatial_sharing", "run"),
    ("fig11", "benchmarks.scheduler_packing", "run"),
    ("fig12", "benchmarks.autoscale_slo", "run"),
    ("fig13", "benchmarks.model_sharing_mem", "run"),
    ("fault", "benchmarks.fault_tolerance", "run"),
    ("prefix", "benchmarks.prefix_sharing", "run"),
    ("head", "benchmarks.headline", "run"),
    ("roof", "benchmarks.roofline_table", "run"),
    ("cold", "benchmarks.cold_start", "run"),
    ("decode", "benchmarks.decode_throughput", "run"),
    ("spec", "benchmarks.decode_throughput", "run_spec"),
    ("shard", "benchmarks.sharded_pod", "run"),
    ("chaos", "benchmarks.chaos_soak", "run"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset "
                         "(fig8..fig13,fault,prefix,head,roof,cold,"
                         "decode,spec,shard,chaos)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    import importlib
    all_rows: list[Row] = []
    print(HEADER)
    t_total = time.perf_counter()
    for key, modname, attr in MODULES:
        if only and key not in only:
            continue
        t0 = time.perf_counter()
        mod = importlib.import_module(modname)
        try:
            rows = getattr(mod, attr)()
        except Exception as e:  # noqa: BLE001 — report and keep going
            rows = [Row(key, "crashed", 0.0, target=1.0, tol=0.0,
                        note=f"{type(e).__name__}: {e}")]
        dt = time.perf_counter() - t0
        for r in rows:
            print(r.csv())
        print(f"# {modname}: {len(rows)} rows in {dt:.1f}s", flush=True)
        all_rows.extend(rows)

    n_fail = sum(1 for r in all_rows if r.status == "FAIL")
    n_ok = sum(1 for r in all_rows if r.status == "ok")
    print(f"# TOTAL: {n_ok} ok, {n_fail} FAIL, "
          f"{sum(1 for r in all_rows if r.status == 'info')} info rows in "
          f"{time.perf_counter() - t_total:.1f}s")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
