"""Chaos soak — multi-tenant ramp under a seeded fault schedule.

Three tenants share a 4-node fleet, one per SLO tier:

* **resnet** (guaranteed) — never shed or expired, retried without bound;
  losing even one request is a bug the soak asserts against.
* **bert** (best-effort) — deadline-armed: sheddable at admission when the
  queue estimate says the deadline cannot be met, expirable mid-queue,
  bounded jittered-backoff retries after a failure.
* **rnnt** (batch) — the preemptible lane: queued behind every non-batch
  request, generous deadline.

Halfway through a rising ramp a deterministic :class:`ChaosSchedule`
injects a gray-failure straggler (6x slowdown), a hard node kill, and a
link degradation — the same three faults the sim-vs-live parity tests
replay.  Two trials run the identical workload and schedule:

* **quarantine on** — ``ControlPlane(quarantine_threshold=0.6)``: the
  straggler's health EWMA trips the sweep, routing stops, occupants
  drain, and the reconciler heals the capacity on healthy nodes.
* **quarantine off** — the straggler keeps serving at 6x latency for the
  fault's whole duration.

Asserted: goodput >= floor, ZERO lost guaranteed-tier requests, and
quarantine-on beats quarantine-off on the guaranteed tenant's p99.

Run:  PYTHONPATH=src python -m benchmarks.chaos_soak [--smoke]
"""

from __future__ import annotations

import argparse

from benchmarks.common import HEADER, Row, write_report
from repro.control import ControlPlane, FunctionSpec, SimBackend, ramp
from repro.core.chaos import ChaosInjector, ChaosSchedule, FaultEvent, \
    SimChaosTarget
from repro.core.cluster import Cluster
from repro.core.scaling import ProfilePoint
from repro.core.slo import (RetryPolicy, TIER_BATCH, TIER_BEST_EFFORT,
                            TIER_GUARANTEED)
from repro.core.workload import PAPER_ZOO, trace_arrivals

CONTROL_PERIOD = 0.5
QUARANTINE_THRESHOLD = 0.6
GOODPUT_FLOOR = 0.90
SEED = 17

TENANTS = (
    # (fn, tier, deadline_s, slo_s, peak_rps)
    ("resnet", TIER_GUARANTEED, 1.5, 0.069, 25.0),
    ("bert", TIER_BEST_EFFORT, 0.8, 0.10, 12.0),
    ("rnnt", TIER_BATCH, 6.0, None, 4.0),
)


def _profile(fn: str) -> tuple[ProfilePoint, ...]:
    c = PAPER_ZOO[fn]
    return tuple(
        ProfilePoint(sm=sm, quota=q, throughput=c.rate(sm, q),
                     p99_latency=0.04)
        for sm, q in ((0.12, 1.0), (0.24, 1.0), (0.12, 0.5)))


def _schedule(duration: float) -> ChaosSchedule:
    """The soak's fault timeline: straggler + kill + link, deterministic."""
    # Node 0 is where best-area-fit packs first, so the straggler is
    # guaranteed to hit loaded pods (a gray failure nobody can ignore).
    return ChaosSchedule(seed=SEED, events=(
        FaultEvent(at=0.35 * duration, kind="straggler", node=0,
                   magnitude=6.0, duration=0.5 * duration),
        FaultEvent(at=0.45 * duration, kind="kill", node=2),
        FaultEvent(at=0.55 * duration, kind="link", node=3,
                   magnitude=3.0, duration=0.25 * duration),
    ))


def _trial(quarantine: bool, duration: float) -> dict:
    cluster = Cluster(n_nodes=4, sharing=True,
                      retry=RetryPolicy(max_attempts=3, base_s=0.05,
                                        seed=SEED))
    plane = ControlPlane(
        SimBackend(cluster),
        quarantine_threshold=QUARANTINE_THRESHOLD if quarantine else None)
    arrivals: dict[str, int] = {}
    for fn, tier, deadline_s, slo_s, peak in TENANTS:
        trace = [(0.0, peak * 0.4), (duration * 0.25, peak),
                 (duration, 0.0)]
        plane.register(FunctionSpec(
            name=fn, profile=_profile(fn), slo_latency=slo_s,
            slo_tier=tier, deadline_s=deadline_s,
            target_rps=ramp(trace[:-1]), headroom=1.6,
            min_instances=1, max_instances=24, curve=PAPER_ZOO[fn]))
        reqs = trace_arrivals(fn, trace, seed=SEED + len(arrivals))
        arrivals[fn] = len(reqs)
        cluster.submit_all(reqs)
    injector = ChaosInjector(_schedule(duration), SimChaosTarget(cluster))

    def control() -> None:
        injector.advance(cluster.sim.now)
        plane.reconcile()
        if cluster.sim.now < duration:
            cluster.sim.after(CONTROL_PERIOD, control)

    cluster.sim.after(CONTROL_PERIOD, control)
    cluster.run(duration + 20.0)
    out: dict = {"tenants": {}, "quarantines": len(plane.quarantines),
                 "shed": cluster.shed, "expired": cluster.expired,
                 "lost": cluster.lost}
    for fn, tier, _, _, _ in TENANTS:
        rec = cluster.recorders[fn]
        out["tenants"][fn] = {
            "tier": tier,
            "offered": arrivals[fn],
            "completed": rec.count(),
            "goodput": rec.goodput(),
            "p99_s": rec.p99(),
            "deadline_met": rec.deadline_met,
            "deadline_missed": rec.deadline_missed,
            "shed": rec.shed,
            "expired": rec.expired,
            "lost": rec.lost,
        }
    met = sum(t["deadline_met"] for t in out["tenants"].values())
    total = met + sum(t["deadline_missed"] + t["shed"] + t["expired"]
                      + t["lost"] for t in out["tenants"].values())
    out["goodput"] = met / max(total, 1)
    return out


def run(duration: float = 40.0, assert_floors: bool = True) -> list[Row]:
    on = _trial(quarantine=True, duration=duration)
    off = _trial(quarantine=False, duration=duration)
    g_on = on["tenants"]["resnet"]
    g_off = off["tenants"]["resnet"]
    write_report("BENCH_chaos.json", {
        "bench": "chaos_soak",
        "duration_s": duration,
        "seed": SEED,
        "quarantine_threshold": QUARANTINE_THRESHOLD,
        "goodput_floor": GOODPUT_FLOOR,
        "schedule": [dataclasses_asdict(e)
                     for e in _schedule(duration).events],
        "quarantine_on": on,
        "quarantine_off": off,
    })
    rows = [
        Row("chaos", "goodput_quarantine_on", on["goodput"],
            note=f"deadline-met fraction under chaos (floor "
                 f"{GOODPUT_FLOOR})"),
        Row("chaos", "goodput_quarantine_off", off["goodput"],
            note="same chaos, gray-failure sweep disabled"),
        Row("chaos", "guaranteed_lost_on", g_on["lost"], target=0.0,
            tol=0.0, note="guaranteed tier must never lose a request"),
        Row("chaos", "guaranteed_p99_on_s", g_on["p99_s"],
            note="guaranteed tenant p99, straggler quarantined"),
        Row("chaos", "guaranteed_p99_off_s", g_off["p99_s"],
            note="guaranteed tenant p99, straggler left in rotation"),
        Row("chaos", "quarantines", on["quarantines"],
            note="nodes the health sweep took out of rotation"),
        Row("chaos", "shed_plus_expired_on", on["shed"] + on["expired"],
            note="typed rejections under chaos (best-effort/batch only)"),
    ]
    if assert_floors:
        assert on["goodput"] >= GOODPUT_FLOOR, (
            f"goodput {on['goodput']:.3f} under chaos fell below the "
            f"{GOODPUT_FLOOR} floor")
        assert g_on["lost"] == 0 and g_on["shed"] == 0 \
            and g_on["expired"] == 0, (
            f"guaranteed tier dropped requests: lost={g_on['lost']} "
            f"shed={g_on['shed']} expired={g_on['expired']}")
        assert g_on["completed"] == g_on["offered"], (
            f"guaranteed tier served {g_on['completed']}/"
            f"{g_on['offered']} requests")
        assert on["quarantines"] >= 1, (
            "the straggler never tripped the quarantine sweep")
        assert g_on["p99_s"] <= g_off["p99_s"], (
            f"quarantine-on p99 {g_on['p99_s']:.3f}s did not beat "
            f"quarantine-off {g_off['p99_s']:.3f}s")
    return rows


def dataclasses_asdict(e: FaultEvent) -> dict:
    return {"at": e.at, "kind": e.kind, "node": e.node,
            "magnitude": e.magnitude, "duration": e.duration}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="short run + hard assertions (CI tier-1)")
    parser.add_argument("--duration", type=float, default=40.0)
    args = parser.parse_args()
    rows = run(duration=20.0 if args.smoke else args.duration)
    print(HEADER)
    for r in rows:
        print(r.csv())
    if args.smoke:
        print("smoke: OK (goodput floor held, zero guaranteed losses, "
              "quarantine beat the straggler)")


if __name__ == "__main__":
    main()
