"""Decode hot-path throughput: fused on-device sampling + overlapped
multi-instance dispatch on the live JAX data plane.

Measures steady-state decode tokens/s and host-synchronisation count per
round on a tiny deterministic config (real jitted executors, CPU-cheap):

* **single instance**, continuous and paged batching — the fused round
  (``Model.decode_step_tokens`` / ``decode_step_paged_tokens``, donated
  KV + token + position buffers, device-resident block tables) must spend
  exactly ONE host sync per pump pass, vs ``1 + admissions`` for the old
  host-argmax path;
* **4 co-located instances** sharing the node under the token scheduler —
  ``ServingEngine.pump(overlap=True)`` dispatches every granted
  instance's round before pulling any result (JAX async dispatch keeps
  the device busy while Python walks the siblings), and the benchmark
  asserts the overlapped aggregate tokens/s is >= 0.9x the serialized
  reference (``overlap=False``: dispatch + sync one instance at a time).

Emits ``BENCH_decode.json`` (the perf-trajectory artifact uploaded by
CI) and runs as a tier-1 smoke step with ``--smoke``.

Run:  PYTHONPATH=src python -m benchmarks.decode_throughput [--smoke]
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import Row
from repro.core.resources import Alloc
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serving import ServingEngine

MAX_BATCH = 4
MAX_LEN = 64
BLOCK_SIZE = 16
PROMPT_LEN = 8
OVERLAP_FLOOR = 0.9  # overlapped >= floor x serialized (relative check)


def _model():
    cfg = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                      vocab_size=64, vocab_pad_multiple=32)
    model = build_model(cfg)
    return model, model.init(jax.random.key(7))


def _measure(model, params, *, batching: str, n_instances: int,
             overlap: bool, fused: bool = True, n_reqs: int,
             max_new: int) -> dict:
    """Serve ``n_reqs`` decode-heavy requests; returns the steady-state
    stats dict (tokens/s, syncs per round, paged uploads per round)."""
    engine = ServingEngine(window=0.1)
    sm = 1.0 / n_instances
    engine.deploy("lm", model, params,
                  Alloc(sm=sm, quota_request=0.9, quota_limit=0.9),
                  n_instances=n_instances, max_batch=MAX_BATCH,
                  max_len=MAX_LEN, batching=batching,
                  block_size=BLOCK_SIZE, fused=fused)
    rng = np.random.default_rng(3)

    def submit(n):
        return [engine.submit(
            "lm", rng.integers(0, model.cfg.vocab_size, PROMPT_LEN,
                               dtype=np.int32), max_new_tokens=max_new)
            for _ in range(n)]

    # Warm-up: compile prefill/decode executors and fill the caches so the
    # measured phase is steady-state decode, not jit time.
    submit(2 * n_instances)
    engine.pump(budget_s=60.0, overlap=overlap)
    pre = {k: dict(v) for k, v in engine.telemetry().items()}

    reqs = submit(n_reqs * n_instances)
    t0 = time.perf_counter()
    done = engine.pump(budget_s=300.0, overlap=overlap)
    elapsed = time.perf_counter() - t0
    assert done == len(reqs), f"{done}/{len(reqs)} completed"
    tokens = sum(len(r.tokens_out) for r in reqs)
    post = engine.telemetry()
    steps = sum(v["steps"] - pre.get(k, {}).get("steps", 0)
                for k, v in post.items())
    syncs = sum(v["syncs"] - pre.get(k, {}).get("syncs", 0)
                for k, v in post.items())
    uploads = sum(v["uploads"] - pre.get(k, {}).get("uploads", 0)
                  for k, v in post.items())
    return {
        "batching": batching,
        "n_instances": n_instances,
        "overlap": overlap,
        "fused": fused,
        "requests": len(reqs),
        "tokens": tokens,
        "elapsed_s": elapsed,
        "tokens_per_s": tokens / elapsed,
        "rounds": steps,
        "host_syncs": syncs,
        "syncs_per_round": syncs / max(steps, 1),
        "paged_uploads_per_round": uploads / max(steps, 1),
    }


def _best_of(n: int, measure) -> dict:
    """Best-of-n throughput (one-sided noise reduction on shared CI CPUs;
    the syncs/uploads counters are deterministic across repeats)."""
    results = [measure() for _ in range(n)]
    return max(results, key=lambda r: r["tokens_per_s"])


def run(smoke: bool = False) -> list[Row]:
    n_reqs = 16 if smoke else 48
    max_new = 12 if smoke else 24
    repeats = 2
    model, params = _model()
    report: dict = {"config": {"max_batch": MAX_BATCH, "max_len": MAX_LEN,
                               "block_size": BLOCK_SIZE,
                               "prompt_len": PROMPT_LEN, "n_reqs": n_reqs,
                               "max_new_tokens": max_new,
                               "overlap_floor": OVERLAP_FLOOR}}
    rows: list[Row] = []
    for batching in ("continuous", "paged"):
        single = _best_of(repeats, lambda: _measure(
            model, params, batching=batching, n_instances=1,
            overlap=True, n_reqs=n_reqs, max_new=max_new))
        host = _best_of(repeats, lambda: _measure(
            model, params, batching=batching, n_instances=1,
            overlap=True, fused=False, n_reqs=n_reqs, max_new=max_new))
        multi = _best_of(repeats, lambda: _measure(
            model, params, batching=batching, n_instances=4,
            overlap=True, n_reqs=n_reqs, max_new=max_new))
        serial = _best_of(repeats, lambda: _measure(
            model, params, batching=batching, n_instances=4,
            overlap=False, n_reqs=n_reqs, max_new=max_new))
        report[batching] = {"single": single, "single_host_argmax": host,
                            "colocated4_overlapped": multi,
                            "colocated4_serialized": serial}
        rows += [
            Row("decode", f"{batching}.single_tokens_per_s",
                single["tokens_per_s"]),
            Row("decode", f"{batching}.single_syncs_per_round",
                single["syncs_per_round"],
                note="fused hot path: exactly 1 host sync per pump pass"),
            Row("decode", f"{batching}.host_argmax_syncs_per_round",
                host["syncs_per_round"],
                note="old reference path: 1 per round + 1 per admission"),
            Row("decode", f"{batching}.fused_speedup_vs_host",
                single["tokens_per_s"] / max(host["tokens_per_s"], 1e-9)),
            Row("decode", f"{batching}.colocated4_tokens_per_s",
                multi["tokens_per_s"]),
            Row("decode", f"{batching}.colocated4_serialized_tokens_per_s",
                serial["tokens_per_s"]),
            Row("decode", f"{batching}.overlap_ratio",
                multi["tokens_per_s"] / max(serial["tokens_per_s"], 1e-9),
                note=f"overlapped/serialized aggregate; floor "
                     f"{OVERLAP_FLOOR}"),
        ]
        if batching == "paged":
            rows.append(Row("decode", "paged.uploads_per_round",
                            single["paged_uploads_per_round"],
                            note="device-resident tables/pos: uploads only "
                                 "on admit/release, << 1 per round"))
        # Hard acceptance checks (relative, no absolute thresholds).
        assert single["syncs_per_round"] <= 1.0 + 1e-9, (
            f"{batching}: fused path spent "
            f"{single['syncs_per_round']:.2f} host syncs per round")
        assert (multi["tokens_per_s"]
                >= OVERLAP_FLOOR * serial["tokens_per_s"]), (
            f"{batching}: overlapped 4-instance throughput "
            f"{multi['tokens_per_s']:.0f} tok/s < {OVERLAP_FLOOR}x the "
            f"serialized {serial['tokens_per_s']:.0f} tok/s")
    with open("BENCH_decode.json", "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    import sys

    rows = run(smoke="--smoke" in sys.argv[1:])
    for r in rows:
        print(r.csv())
