"""Decode hot-path throughput: fused on-device sampling + overlapped
multi-instance dispatch on the live JAX data plane.

Measures steady-state decode tokens/s and host-synchronisation count per
round on a tiny deterministic config (real jitted executors, CPU-cheap):

* **single instance**, continuous and paged batching — the fused round
  (``Model.decode_step_tokens`` / ``decode_step_paged_tokens``, donated
  KV + token + position buffers, device-resident block tables) must spend
  exactly ONE host sync per pump pass, vs ``1 + admissions`` for the old
  host-argmax path;
* **4 co-located instances** sharing the node under the token scheduler —
  ``ServingEngine.pump(overlap=True)`` dispatches every granted
  instance's round before pulling any result (JAX async dispatch keeps
  the device busy while Python walks the siblings), and the benchmark
  asserts the overlapped aggregate tokens/s is >= 0.9x the serialized
  reference (``overlap=False``: dispatch + sync one instance at a time).

``--speculate`` instead benchmarks the **speculative draft/verify round**
on the same hot path (``run_spec``): draft == target on the
synthetic-agreement harness, so greedy acceptance is ~1.0 and the
effective decode tokens/s must reach ``SPEC_SPEEDUP_FLOOR`` x the plain
fused round (``SPEC_SMOKE_FLOOR`` under ``--smoke``), while still
spending exactly one host sync per pump pass and emitting a
bit-identical greedy token stream.

Emits ``BENCH_decode.json`` / ``BENCH_spec.json`` (the perf-trajectory
artifacts uploaded by CI, committed at the repo root) and runs as a
tier-1 smoke step with ``--smoke``.

Run:  PYTHONPATH=src python -m benchmarks.decode_throughput \
          [--smoke] [--speculate]
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Row, write_report
from repro.core.resources import Alloc
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serving import ServingEngine
from repro.serving.speculative import SpecConfig, expected_tokens_per_round

MAX_BATCH = 4
MAX_LEN = 64
BLOCK_SIZE = 16
PROMPT_LEN = 8
OVERLAP_FLOOR = 0.9  # overlapped >= floor x serialized (relative check)
SPEC_K = 6  # draft depth for the speculative benchmark
SPEC_ACCEPT_FLOOR = 0.6  # measured acceptance floor (draft == target)
SPEC_SPEEDUP_FLOOR = 1.5  # effective tokens/s vs plain fused greedy
SPEC_SMOKE_FLOOR = 0.9  # CI smoke floor (shared runners, tiny workload)


def _model():
    cfg = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                      vocab_size=64, vocab_pad_multiple=32)
    model = build_model(cfg)
    return model, model.init(jax.random.key(7))


DRAFT_LAYERS = 2
TARGET_LAYERS = 12


def _spec_models():
    """Synthetic-agreement draft/target pair.

    The target is a ``TARGET_LAYERS``-deep model whose layers beyond
    ``DRAFT_LAYERS`` have zeroed output projections (``attn/wo`` and
    ``mlp/w_down``), so they contribute exactly 0 to the residual stream;
    embed / head / ln_f and the live layers are shared with the
    ``DRAFT_LAYERS``-deep draft.  Target and draft logits are therefore
    bit-identical (greedy acceptance ~1.0) while a draft step costs
    ``DRAFT_LAYERS / TARGET_LAYERS`` of a target step — the regime
    speculative decoding is for, constructed instead of trained.
    """
    import jax.numpy as jnp

    dcfg = ModelConfig(name="bench-draft", family="dense",
                       n_layers=DRAFT_LAYERS, d_model=32, n_heads=4,
                       n_kv_heads=2, d_ff=64, vocab_size=64,
                       vocab_pad_multiple=32)
    tcfg = ModelConfig(name="bench-target", family="dense",
                       n_layers=TARGET_LAYERS, d_model=32, n_heads=4,
                       n_kv_heads=2, d_ff=64, vocab_size=64,
                       vocab_pad_multiple=32)
    draft = build_model(dcfg)
    dparams = draft.init(jax.random.key(7))
    target = build_model(tcfg)
    tparams = target.init(jax.random.key(8))

    def splice(path, tleaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        dleaf = dparams
        for k in keys:
            dleaf = dleaf[k]
        if keys[0] != "layers":
            return dleaf  # embed / head / ln_f: shared verbatim
        tail = tleaf[DRAFT_LAYERS:]
        if keys[-1] in ("wo", "w_down"):
            tail = jnp.zeros_like(tail)
        return jnp.concatenate([dleaf, tail], axis=0)

    tparams = jax.tree_util.tree_map_with_path(splice, tparams)
    return target, tparams, dcfg, dparams


def _measure(model, params, *, batching: str, n_instances: int,
             overlap: bool, fused: bool = True, n_reqs: int,
             max_new: int, speculate: SpecConfig | None = None,
             draft_params=None) -> dict:
    """Serve ``n_reqs`` decode-heavy requests; returns the steady-state
    stats dict (tokens/s, syncs per round, paged uploads per round)."""
    engine = ServingEngine(window=0.1)
    sm = 1.0 / n_instances
    engine.deploy("lm", model, params,
                  Alloc(sm=sm, quota_request=0.9, quota_limit=0.9),
                  n_instances=n_instances, max_batch=MAX_BATCH,
                  max_len=MAX_LEN, batching=batching,
                  block_size=BLOCK_SIZE, fused=fused, speculate=speculate,
                  draft_params=draft_params)
    rng = np.random.default_rng(3)

    def submit(n):
        return [engine.submit(
            "lm", rng.integers(0, model.cfg.vocab_size, PROMPT_LEN,
                               dtype=np.int32), max_new_tokens=max_new)
            for _ in range(n)]

    # Warm-up: compile prefill/decode executors and fill the caches so the
    # measured phase is steady-state decode, not jit time.
    submit(2 * n_instances)
    engine.pump(budget_s=60.0, overlap=overlap)
    pre = {k: dict(v) for k, v in engine.telemetry().items()}

    reqs = submit(n_reqs * n_instances)
    t0 = time.perf_counter()
    done = engine.pump(budget_s=300.0, overlap=overlap)
    elapsed = time.perf_counter() - t0
    assert done == len(reqs), f"{done}/{len(reqs)} completed"
    tokens = sum(len(r.tokens_out) for r in reqs)
    post = engine.telemetry()
    steps = sum(v["steps"] - pre.get(k, {}).get("steps", 0)
                for k, v in post.items())
    syncs = sum(v["syncs"] - pre.get(k, {}).get("syncs", 0)
                for k, v in post.items())
    uploads = sum(v["uploads"] - pre.get(k, {}).get("uploads", 0)
                  for k, v in post.items())
    proposed = sum(v["spec_proposed"] - pre.get(k, {}).get("spec_proposed", 0)
                   for k, v in post.items())
    accepted = sum(v["spec_accepted"] - pre.get(k, {}).get("spec_accepted", 0)
                   for k, v in post.items())
    return {
        "batching": batching,
        "n_instances": n_instances,
        "overlap": overlap,
        "fused": fused,
        "spec_k": speculate.k if speculate is not None else 0,
        "requests": len(reqs),
        "tokens": tokens,
        "elapsed_s": elapsed,
        "tokens_per_s": tokens / elapsed,
        "rounds": steps,
        "host_syncs": syncs,
        "syncs_per_round": syncs / max(steps, 1),
        "paged_uploads_per_round": uploads / max(steps, 1),
        "spec_proposed": proposed,
        "spec_accepted": accepted,
        "acceptance": accepted / proposed if proposed else 0.0,
        "tokens_out": [list(r.tokens_out) for r in reqs],
    }


def _best_of(n: int, measure) -> dict:
    """Best-of-n throughput (one-sided noise reduction on shared CI CPUs;
    the syncs/uploads counters are deterministic across repeats)."""
    results = [measure() for _ in range(n)]
    return max(results, key=lambda r: r["tokens_per_s"])


def _strip(stats: dict) -> dict:
    """Report form of a ``_measure`` dict (drop the raw token streams)."""
    return {k: v for k, v in stats.items() if k != "tokens_out"}


def run(smoke: bool = False) -> list[Row]:
    n_reqs = 16 if smoke else 48
    max_new = 12 if smoke else 24
    repeats = 2
    model, params = _model()
    report: dict = {"config": {"max_batch": MAX_BATCH, "max_len": MAX_LEN,
                               "block_size": BLOCK_SIZE,
                               "prompt_len": PROMPT_LEN, "n_reqs": n_reqs,
                               "max_new_tokens": max_new,
                               "overlap_floor": OVERLAP_FLOOR}}
    rows: list[Row] = []
    for batching in ("continuous", "paged"):
        single = _best_of(repeats, lambda: _measure(
            model, params, batching=batching, n_instances=1,
            overlap=True, n_reqs=n_reqs, max_new=max_new))
        host = _best_of(repeats, lambda: _measure(
            model, params, batching=batching, n_instances=1,
            overlap=True, fused=False, n_reqs=n_reqs, max_new=max_new))
        multi = _best_of(repeats, lambda: _measure(
            model, params, batching=batching, n_instances=4,
            overlap=True, n_reqs=n_reqs, max_new=max_new))
        serial = _best_of(repeats, lambda: _measure(
            model, params, batching=batching, n_instances=4,
            overlap=False, n_reqs=n_reqs, max_new=max_new))
        report[batching] = {"single": _strip(single),
                            "single_host_argmax": _strip(host),
                            "colocated4_overlapped": _strip(multi),
                            "colocated4_serialized": _strip(serial)}
        rows += [
            Row("decode", f"{batching}.single_tokens_per_s",
                single["tokens_per_s"]),
            Row("decode", f"{batching}.single_syncs_per_round",
                single["syncs_per_round"],
                note="fused hot path: exactly 1 host sync per pump pass"),
            Row("decode", f"{batching}.host_argmax_syncs_per_round",
                host["syncs_per_round"],
                note="old reference path: 1 per round + 1 per admission"),
            Row("decode", f"{batching}.fused_speedup_vs_host",
                single["tokens_per_s"] / max(host["tokens_per_s"], 1e-9)),
            Row("decode", f"{batching}.colocated4_tokens_per_s",
                multi["tokens_per_s"]),
            Row("decode", f"{batching}.colocated4_serialized_tokens_per_s",
                serial["tokens_per_s"]),
            Row("decode", f"{batching}.overlap_ratio",
                multi["tokens_per_s"] / max(serial["tokens_per_s"], 1e-9),
                note=f"overlapped/serialized aggregate; floor "
                     f"{OVERLAP_FLOOR}"),
        ]
        if batching == "paged":
            rows.append(Row("decode", "paged.uploads_per_round",
                            single["paged_uploads_per_round"],
                            note="device-resident tables/pos: uploads only "
                                 "on admit/release, << 1 per round"))
        # Hard acceptance checks (relative, no absolute thresholds).
        assert single["syncs_per_round"] <= 1.0 + 1e-9, (
            f"{batching}: fused path spent "
            f"{single['syncs_per_round']:.2f} host syncs per round")
        assert (multi["tokens_per_s"]
                >= OVERLAP_FLOOR * serial["tokens_per_s"]), (
            f"{batching}: overlapped 4-instance throughput "
            f"{multi['tokens_per_s']:.0f} tok/s < {OVERLAP_FLOOR}x the "
            f"serialized {serial['tokens_per_s']:.0f} tok/s")
    write_report("BENCH_decode.json", report)
    return rows


def run_spec(smoke: bool = False) -> list[Row]:
    """Speculative decoding on the sync-free hot path (``--speculate``).

    Draft == target (the synthetic-agreement harness): greedy acceptance
    is ~1.0, so each verify round emits up to ``SPEC_K + 1`` tokens for
    one pump pass — the effective tokens/s floor is pure hot-path
    arithmetic, not model quality.  Asserts, per batching plane:

    * exactly ONE host sync per pump pass with speculation on;
    * measured acceptance >= ``SPEC_ACCEPT_FLOOR``;
    * effective tokens/s >= floor x the plain fused greedy round
      (``SPEC_SPEEDUP_FLOOR`` full, ``SPEC_SMOKE_FLOOR`` smoke);
    * the emitted greedy token streams are bit-identical to the
      non-speculative fused path.
    """
    n_reqs = 16 if smoke else 48
    max_new = 12 if smoke else 24
    repeats = 2
    floor = SPEC_SMOKE_FLOOR if smoke else SPEC_SPEEDUP_FLOOR
    model, params, dcfg, dparams = _spec_models()
    spec_cfg = SpecConfig(draft_cfg=dcfg, k=SPEC_K)
    report: dict = {"config": {"max_batch": MAX_BATCH, "max_len": MAX_LEN,
                               "block_size": BLOCK_SIZE,
                               "prompt_len": PROMPT_LEN, "n_reqs": n_reqs,
                               "max_new_tokens": max_new, "spec_k": SPEC_K,
                               "accept_floor": SPEC_ACCEPT_FLOOR,
                               "speedup_floor": floor,
                               "target_layers": TARGET_LAYERS,
                               "draft_layers": DRAFT_LAYERS,
                               "draft": "layer-spliced synthetic agreement "
                                        "(bit-identical logits)"}}
    rows: list[Row] = []
    for batching in ("continuous", "paged"):
        base = _best_of(repeats, lambda: _measure(
            model, params, batching=batching, n_instances=1,
            overlap=True, n_reqs=n_reqs, max_new=max_new))
        spec = _best_of(repeats, lambda: _measure(
            model, params, batching=batching, n_instances=1,
            overlap=True, n_reqs=n_reqs, max_new=max_new,
            speculate=spec_cfg, draft_params=dparams))
        speedup = spec["tokens_per_s"] / max(base["tokens_per_s"], 1e-9)
        expected = expected_tokens_per_round(SPEC_K, spec["acceptance"])
        report[batching] = {"fused_greedy": _strip(base),
                            "speculative": _strip(spec),
                            "effective_speedup": speedup,
                            "expected_tokens_per_round": expected}
        rows += [
            Row("spec", f"{batching}.effective_tokens_per_s",
                spec["tokens_per_s"]),
            Row("spec", f"{batching}.baseline_tokens_per_s",
                base["tokens_per_s"],
                note="PR-5 plain fused greedy round"),
            Row("spec", f"{batching}.effective_speedup", speedup,
                note=f"floor {floor}x at acceptance >= "
                     f"{SPEC_ACCEPT_FLOOR}"),
            Row("spec", f"{batching}.acceptance", spec["acceptance"],
                note="draft == target: greedy acceptance ~1.0"),
            Row("spec", f"{batching}.syncs_per_round",
                spec["syncs_per_round"],
                note="speculative round keeps the one-sync rule"),
            Row("spec", f"{batching}.tokens_per_slot_round",
                spec["acceptance"] * SPEC_K + 1,
                note=f"accepted drafts + 1 bonus per slot per verify "
                     f"round; <= k+1 = {SPEC_K + 1}"),
        ]
        # Hard acceptance checks.
        assert spec["syncs_per_round"] <= 1.0 + 1e-9, (
            f"{batching}: speculative path spent "
            f"{spec['syncs_per_round']:.2f} host syncs per round")
        assert spec["acceptance"] >= SPEC_ACCEPT_FLOOR, (
            f"{batching}: acceptance {spec['acceptance']:.2f} < "
            f"{SPEC_ACCEPT_FLOOR} with draft == target")
        assert spec["tokens_out"] == base["tokens_out"], (
            f"{batching}: speculative greedy stream diverged from the "
            f"non-speculative fused stream")
        assert speedup >= floor, (
            f"{batching}: effective speedup {speedup:.2f}x < {floor}x "
            f"(spec {spec['tokens_per_s']:.0f} vs base "
            f"{base['tokens_per_s']:.0f} tok/s)")
    write_report("BENCH_spec.json", report)
    return rows


if __name__ == "__main__":
    import sys

    argv = sys.argv[1:]
    entry = run_spec if "--speculate" in argv else run
    rows = entry(smoke="--smoke" in argv)
    for r in rows:
        print(r.csv())
