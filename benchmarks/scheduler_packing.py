"""Paper Fig. 11 + §5.4 — FaST-Scheduler node packing vs time sharing.

Workload: 4 ResNet pods (12% SM, 40% quota), 2 RNNT pods (24%, 40%),
2 BERT pods (50%, 60%) over a 4-GPU fleet.

* Time-sharing scheduling (KubeShare-style: no SM dimension, one racing
  pod's worth of compute per GPU) needs **4 GPUs**.
* FaST-Scheduler (Maximal Rectangles over the 2D resource plane) packs all
  8 pods onto **1 GPU** (sum of secondCores = 0.984 <= 1.0), lifting
  per-GPU utilization 1.34x and SM occupancy 3.13x.
"""

from __future__ import annotations

from benchmarks.common import Row
from repro.core.cluster import Cluster
from repro.core.scaling import ProfilePoint
from repro.core.workload import PAPER_ZOO, poisson_arrivals

DURATION = 40.0
WORKLOAD = [  # (fn, n_pods, sm, quota)
    ("resnet", 4, 0.12, 0.4),
    ("rnnt", 2, 0.24, 0.4),
    ("bert", 2, 0.50, 0.6),
]


def _drive(cluster: Cluster, scale: float = 0.9) -> None:
    for fn, n, sm, quota in WORKLOAD:
        rate = PAPER_ZOO[fn].rate(sm, quota) * n * scale
        cluster.submit_all(poisson_arrivals(fn, rate, DURATION,
                                            seed=hash(fn) % 1000))


def _fast_cluster() -> Cluster:
    # Largest-first deployment (standard best-fit-decreasing order).
    cluster = Cluster(n_nodes=4, sharing=True)
    for fn, n, sm, quota in sorted(WORKLOAD, key=lambda w: -w[2] * w[3]):
        cluster.register_function(fn, PAPER_ZOO[fn])
        for _ in range(n):
            assert cluster.deploy(
                fn, ProfilePoint(sm=sm, quota=quota, throughput=0.0)
            ) is not None
    return cluster


def _time_sharing_cluster() -> Cluster:
    """KubeShare-style: quota-only dimension, every pod racing at 100% SM.

    The scheduler can stack quotas up to 100% per GPU but has no spatial
    dimension, so each pod occupies its full quota at 100% SM.
    """
    cluster = Cluster(n_nodes=4, sharing=True)
    for fn, n, sm, quota in sorted(WORKLOAD, key=lambda w: -w[3]):
        cluster.register_function(fn, PAPER_ZOO[fn])
        for _ in range(n):
            assert cluster.deploy(
                fn, ProfilePoint(sm=1.0, quota=quota, throughput=0.0)
            ) is not None
    return cluster


def run() -> list[Row]:
    rows: list[Row] = []
    fast = _fast_cluster()
    ts = _time_sharing_cluster()
    rows.append(Row("fig11", "fast.nodes_used", fast.nodes_in_use(),
                    target=1, tol=0.0,
                    note="MRA packs all 8 pods on one GPU"))
    rows.append(Row("fig11", "time_sharing.nodes_used", ts.nodes_in_use(),
                    target=4, tol=0.0,
                    note="quota-only packing needs the whole fleet"))
    _drive(fast)
    _drive(ts)
    fast.run(DURATION + 5)
    ts.run(DURATION + 5)
    util_gain = fast.gpu_utilization(30) / max(ts.gpu_utilization(30), 1e-9)
    occ_gain = fast.sm_occupancy(30) / max(ts.sm_occupancy(30), 1e-9)
    rows.append(Row("fig11", "gpu_utilization_gain", util_gain,
                    target=1.34, tol=0.3,
                    note="FaST / time-sharing, per-GPU-in-use"))
    rows.append(Row("fig11", "sm_occupancy_gain", occ_gain,
                    target=3.13, tol=0.3))
    rows.append(Row("fig11", "fast.gpu_utilization",
                    fast.gpu_utilization(30)))
    rows.append(Row("fig11", "fast.sm_occupancy", fast.sm_occupancy(30)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
