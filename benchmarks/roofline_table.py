"""Roofline table — reads results/dryrun/*.json (written by
``repro.launch.dryrun``) and prints the per-(arch x shape x mesh) roofline
terms for EXPERIMENTS.md §Roofline.  Informational: no paper targets."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def load_cells() -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def table(cells: list[dict]) -> str:
    hdr = (f"{'arch':24s} {'case':12s} {'mesh':8s} {'C(s)':>9s} "
           f"{'M(s)':>9s} {'M_adj(s)':>9s} {'X(s)':>9s} {'dom':>10s} "
           f"{'useful':>7s} {'MFU_bnd':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        if c["status"] != "ok":
            lines.append(f"{c['arch']:24s} {c['case']:12s} {c['mesh']:8s} "
                         f"[{c['status']}] {c.get('error', '')[:60]}")
            continue
        lines.append(
            f"{c['arch']:24s} {c['case']:12s} {c['mesh']:8s} "
            f"{c['compute_s']:9.3f} {c['memory_s']:9.3f} "
            f"{c['memory_adj_s']:9.3f} {c['collective_s']:9.3f} "
            f"{c.get('dominant', '?'):>10s} {c.get('useful_ratio', 0):7.2f} "
            f"{c.get('mfu_bound', 0):8.3f}")
    return "\n".join(lines)


def run() -> list[Row]:
    cells = load_cells()
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    failed = [c for c in cells if c["status"] == "failed"]
    rows = [
        Row("roofline", "cells_ok", len(ok)),
        Row("roofline", "cells_skipped_by_design", len(skipped)),
        Row("roofline", "cells_failed", len(failed), target=0, tol=0.0),
    ]
    if ok:
        print(table(cells))
    return rows


if __name__ == "__main__":
    run_rows = run()
    for r in run_rows:
        print(r.csv())
