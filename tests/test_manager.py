"""Tests for FaST-Manager's multi-token scheduler (paper §3.3.2)."""

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core.manager import TokenScheduler, fair_share_baseline
from repro.core.resources import Alloc


def alloc(sm, q_req, q_lim=None):
    return Alloc(sm=sm, quota_request=q_req, quota_limit=q_lim or q_req)


def test_priority_by_q_miss_descending():
    ts = TokenScheduler(window=1.0)
    ts.register("low", alloc(0.2, 0.2))
    ts.register("high", alloc(0.2, 0.8))
    ts.register("mid", alloc(0.2, 0.5))
    for p in ("low", "high", "mid"):
        ts.request_token(p, 0.0)
    granted = [t.pod_id for t in ts.dispatch(0.0)]
    assert granted == ["high", "mid", "low"]  # descending Q_miss


def test_sm_global_limit_blocks_head_of_queue():
    ts = TokenScheduler(window=1.0)
    ts.register("a", alloc(0.6, 0.9))
    ts.register("b", alloc(0.5, 0.8))  # would exceed 100% with a
    ts.register("c", alloc(0.3, 0.7))  # would fit, but queue blocks at head
    for p in ("a", "b", "c"):
        ts.request_token(p, 0.0)
    granted = [t.pod_id for t in ts.dispatch(0.0)]
    # Paper: the adapter returns tokens "until it encounters
    # S_SMs + S_running > 100%" — head-of-line blocking, no skip-ahead.
    assert granted == ["a"]
    assert ts.sm_running() == pytest.approx(0.6)


def test_quota_limit_blocks_until_next_window():
    ts = TokenScheduler(window=1.0)
    ts.register("a", alloc(0.5, 0.3, 0.5))
    ts.request_token("a", 0.0)
    assert len(ts.dispatch(0.0)) == 1
    ts.complete("a", elapsed=0.55, now=0.55)  # Q_used 0.55 > Q_limit 0.5
    ts.request_token("a", 0.56)
    assert ts.dispatch(0.56) == []  # blocked: Q_remain <= 0
    ts.request_token("a", 1.01)  # next window: quota reset
    assert len(ts.dispatch(1.01)) == 1


def test_elastic_quota_between_request_and_limit():
    ts = TokenScheduler(window=1.0)
    ts.register("a", alloc(0.5, 0.3, 0.8))
    ts.request_token("a", 0.0)
    ts.dispatch(0.0)
    ts.complete("a", elapsed=0.4, now=0.4)  # past request, under limit
    ts.request_token("a", 0.4)
    assert len(ts.dispatch(0.4)) == 1  # elastic: still schedulable


def test_completion_without_token_raises():
    ts = TokenScheduler(window=1.0)
    ts.register("a", alloc(0.5, 0.5))
    with pytest.raises(RuntimeError):
        ts.complete("a", 0.1, 0.0)


def test_utilization_and_occupancy_accounting():
    ts = TokenScheduler(window=1.0)
    ts.register("a", alloc(0.25, 1.0))
    for w in range(4):
        ts.request_token("a", float(w))
        ts.dispatch(float(w))
        ts.complete("a", elapsed=0.5, now=w + 0.5)
    ts.dispatch(4.0)  # roll final window
    assert ts.utilization(last_n=4) == pytest.approx(0.5)
    assert ts.occupancy(last_n=4) == pytest.approx(0.5 * 0.25)


def test_fair_share_baseline_equal_slices():
    shares = fair_share_baseline({"a": alloc(0.2, 0.5), "b": alloc(0.9, 0.9)})
    assert shares == {"a": 0.5, "b": 0.5}


@settings(max_examples=50, deadline=None)
@given(
    st.lists(
        st.tuples(st.floats(0.05, 1.0), st.floats(0.05, 0.95)),
        min_size=1, max_size=8,
    )
)
def test_dispatch_never_exceeds_sm_global_limit(pods):
    """Property: at no point does Σ running SM shares exceed 100%."""
    ts = TokenScheduler(window=1.0)
    for i, (sm, q) in enumerate(pods):
        ts.register(f"p{i}", alloc(round(sm, 2), round(q, 2)))
        ts.request_token(f"p{i}", 0.0)
    ts.dispatch(0.0)
    assert ts.sm_running() <= 1.0 + 1e-9
    # Complete in arbitrary order and re-request; limit must still hold.
    t = 0.1
    for i in range(len(pods)):
        pid = f"p{i}"
        if ts.pods[pid].holding is not None:
            ts.complete(pid, 0.05, t)
            ts.request_token(pid, t)
            ts.dispatch(t)
            assert ts.sm_running() <= 1.0 + 1e-9
            t += 0.05


@settings(max_examples=30, deadline=None)
@given(st.floats(0.1, 0.9), st.integers(2, 6))
def test_quota_isolation_property(q_limit, n_windows):
    """Property: a pod can never consume more than Q_limit + one-step
    overshoot per window (token granularity = one step, like kernel bursts)."""
    q_limit = round(q_limit, 2)
    step = 0.05
    ts = TokenScheduler(window=1.0)
    ts.register("a", alloc(0.5, min(q_limit, 0.9), q_limit))
    now = 0.0
    per_window: dict[int, float] = {}
    while now < n_windows:
        ts.request_token("a", now)
        if ts.dispatch(now):
            per_window[int(now)] = per_window.get(int(now), 0.0) + step
            ts.complete("a", step, min(now + step, n_windows))
        now += step
        now = round(now, 10)
    for w, used in per_window.items():
        assert used <= q_limit + step + 1e-9
