"""Bucketed prefill padding: recompile containment + exact equivalence.

Chunked admission used to jit-compile the prefill once per distinct prompt
length; rounding prompts up to power-of-two buckets bounds compilations at
O(log max_len) while the length-masked prefill keeps the token stream
bit-identical to the exact-shape path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.resources import Alloc
from repro.serving import ServingEngine
from repro.serving.engine import _bucket_len

ALLOC = Alloc(sm=0.5, quota_request=0.8, quota_limit=0.8)


def test_bucket_len_rounds_up_to_power_of_two():
    assert [_bucket_len(n) for n in (1, 2, 3, 4, 5, 8, 9, 16, 17)] == \
        [1, 2, 4, 4, 8, 8, 16, 16, 32]


def test_prompts_in_one_bucket_share_one_compile(tiny_model, tiny_params):
    # Executors are shared per model across instances (and so across the
    # session-scoped fixture's tests): start from a fresh cache so the
    # lowering counts below are exact, not polluted by earlier tests.
    tiny_model.__dict__.pop("_jit_executors", None)
    engine = ServingEngine(window=0.1)
    (inst_id,) = engine.deploy("lm", tiny_model, tiny_params, ALLOC,
                               max_batch=2, max_len=32)
    inst = engine.instances[inst_id]
    assert inst.bucketed
    rng = np.random.default_rng(0)
    for n in (5, 6, 7, 8):  # all land in the 8-token bucket
        engine.submit("lm", rng.integers(0, 64, n, dtype=np.int32),
                      max_new_tokens=2)
    engine.pump(budget_s=30.0)
    assert inst._prefill_len._cache_size() == 1, \
        "4 distinct prompt lengths in one bucket must lower exactly once"
    for n in (9, 12):  # the 16-token bucket: exactly one more lowering
        engine.submit("lm", rng.integers(0, 64, n, dtype=np.int32),
                      max_new_tokens=2)
    engine.pump(budget_s=30.0)
    assert inst._prefill_len._cache_size() == 2


def test_bucketed_stream_matches_exact_prefill(tiny_model, tiny_params):
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 64, n, dtype=np.int32) for n in (3, 5, 7, 11)]

    def serve(prefill_buckets: bool) -> list[list[int]]:
        engine = ServingEngine(window=0.1)
        engine.deploy("lm", tiny_model, tiny_params, ALLOC, max_batch=2,
                      max_len=32, prefill_buckets=prefill_buckets)
        reqs = [engine.submit("lm", p, max_new_tokens=4) for p in prompts]
        engine.pump(budget_s=60.0)
        assert all(r.done for r in reqs)
        return [r.tokens_out for r in reqs]

    assert serve(True) == serve(False)


def test_length_masked_prefill_equals_exact(tiny_model, tiny_params):
    """Direct model-level check: padded prefill with ``length`` == exact."""
    prompt = np.arange(1, 6, dtype=np.int32)  # length 5 -> bucket 8
    padded = np.zeros((8,), np.int32)
    padded[:5] = prompt
    exact_logits, exact_cache = tiny_model.prefill(
        tiny_params, jnp.asarray(prompt[None]), max_len=16)
    lm_logits, lm_cache = tiny_model.prefill(
        tiny_params, jnp.asarray(padded[None]), max_len=16,
        length=jnp.int32(5))
    np.testing.assert_allclose(np.asarray(exact_logits),
                               np.asarray(lm_logits), rtol=1e-5, atol=1e-5)
    assert int(lm_cache["pos"]) == int(exact_cache["pos"]) == 5
    # Decode one token from each cache: identical argmax streams.
    tok = jnp.argmax(exact_logits, axis=-1).astype(jnp.int32)
    d1, _ = tiny_model.decode_step(tiny_params, tok, exact_cache)
    d2, _ = tiny_model.decode_step(tiny_params, tok, lm_cache)
    assert int(jnp.argmax(d1)) == int(jnp.argmax(d2))


def test_static_batching_keeps_exact_path(tiny_model, tiny_params):
    engine = ServingEngine(window=0.1)
    (inst_id,) = engine.deploy("lm", tiny_model, tiny_params, ALLOC,
                               max_batch=2, max_len=32, batching="static")
    assert not engine.instances[inst_id].bucketed
