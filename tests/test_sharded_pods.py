"""Tensor-parallel sharded pods: multi-device FunctionInstance with
link-aware multi-rectangle placement (the PR tentpole), end to end.

The load-bearing contracts:

* a sharded pod's token streams are **bit-identical** to the
  single-device reference (column-only exact TP; float32 params — see
  ``src/repro/distributed/README.md`` for why bf16 is excluded from the
  bit-identity claim);
* ``shards=1`` compiles to exactly today's single-device path (the
  executor cache gains no new entries — no re-trace);
* a dense KV reservation too big for ONE node's budget is admitted and
  served ONLY as a multi-rectangle pod;
* placement is link-aware (highest-bottleneck-bandwidth group wins) and
  member failures fold the whole pod with full rectangle rollback;
* the sim and live fleets make identical scale decisions with the shards
  axis and the link model enabled.

Everything runs on the 4 forced host devices conftest sets up.
"""

import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.control import (ControlPlane, FunctionSpec, LiveBackend,
                           SimBackend, decision_signature, ramp)
from repro.core.cluster import Cluster
from repro.core.links import NetworkLinks
from repro.core.resources import Alloc
from repro.core.scaling import ProfilePoint
from repro.core.workload import ServiceCurve
from repro.serving import ClusterFrontend, FleetModelStore, stage_params
from repro.serving.engine import per_device_bytes
from repro.serving.speculative import SamplingConfig, SpecConfig

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs 4 (forced host) devices")

ALLOC = Alloc(sm=0.25, quota_request=0.25, quota_limit=0.5)
PROMPTS = [np.array([3, 1, 4, 1, 5], dtype=np.int32),
           np.array([9, 2, 6, 5, 3, 5, 8, 9, 7], dtype=np.int32),
           np.array([2, 7, 1], dtype=np.int32)]


@pytest.fixture(scope="module")
def params32(tiny_model, tiny_params):
    # float32 weights: column-only TP is *exact*, but constraint-induced
    # codegen differences still wobble bf16 logits by one ulp, which can
    # flip near-tie argmax.  In f32 the wobble is ~1e-7 and token streams
    # are robustly bit-identical (the documented test/benchmark recipe).
    return jax.tree_util.tree_map(lambda x: x.astype(jnp.float32),
                                  tiny_params)


def serve(model, params, shards, *, links=None, n_nodes=4,
          batching="continuous", sampling=None, max_new=6):
    fe = ClusterFrontend(n_nodes=n_nodes, links=links)
    h = fe.place_instance("f", model, params, ALLOC, max_batch=4,
                          max_len=32, batching=batching, sampling=sampling,
                          shards=shards)
    assert h is not None, f"placement failed for shards={shards}"
    reqs = [fe.submit("f", p, max_new_tokens=max_new) for p in PROMPTS]
    fe.pump(budget_s=60.0)
    assert all(r.done for r in reqs)
    return fe, [list(r.tokens_out) for r in reqs]


# -------------------------------------------------------------------------
# Bit-identity: sharded == single-device reference
# -------------------------------------------------------------------------


@pytest.mark.parametrize("batching", ["continuous", "paged"])
@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_tokens_bit_identical_greedy(tiny_model, params32,
                                             batching, shards):
    _, ref = serve(tiny_model, params32, 1, batching=batching)
    fe, toks = serve(tiny_model, params32, shards, batching=batching)
    assert toks == ref
    p = fe.placements[0]
    assert len(p.member_nodes) == shards
    assert len(set(p.member_nodes)) == shards  # distinct devices
    # The pod's KV + weights really live across the member devices.
    inst = fe.engines[p.node].instances[p.inst_id]
    by_dev = per_device_bytes(inst.params, getattr(inst, "cache", None))
    assert set(by_dev) == set(p.member_nodes)


@pytest.mark.parametrize("batching", ["continuous", "paged"])
def test_sharded_tokens_bit_identical_sampled(tiny_model, params32,
                                              batching):
    # Same PRNG key stream on both sides: stochastic sampling must also
    # reproduce bit-identically under sharding.
    samp = SamplingConfig(temperature=0.8, top_k=8, seed=7)
    _, ref = serve(tiny_model, params32, 1, batching=batching,
                   sampling=samp)
    _, toks = serve(tiny_model, params32, 2, batching=batching,
                    sampling=samp)
    assert toks == ref
    assert len(set(map(tuple, ref))) > 1  # actually stochastic output


def test_shards1_reuses_single_device_executors(tiny_model, params32):
    # shards=1 must hit the EXACT executor cache entries of the
    # single-device path: the mesh key suffix is () and no new trace
    # happens.  A sharded deploy, by contrast, adds mesh-keyed entries.
    _, ref = serve(tiny_model, params32, 1)
    cache = tiny_model.__dict__["_jit_executors"]
    before = set(cache)
    _, again = serve(tiny_model, params32, 1)
    assert again == ref
    assert set(cache) == before, "shards=1 re-traced an executor"
    # A sharded pod's executors are extra, mesh-keyed entries — they
    # never collide with (or replace) the single-device ones.
    serve(tiny_model, params32, 2)
    assert any("tp" in str(k) for k in cache)
    assert before <= set(cache)


# -------------------------------------------------------------------------
# Admission: a KV reservation too big for one node needs a sharded pod
# -------------------------------------------------------------------------


def test_kv_overflow_admits_only_as_sharded_pod(tiny_model, params32):
    from repro.core.model_sharing import SERVER_CONTEXT_OVERHEAD

    kv = int(tiny_model.kv_cache_bytes(batching="continuous", max_batch=4,
                                       max_len=32))
    weights = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                  for l in jax.tree_util.tree_leaves(params32))
    # Budget fits weights + context + half the KV pool but not all of
    # it: a single-device pod must bounce, a 2-way pod must admit (each
    # member holds ~1/shards of the kv-head-sharded pool).
    mem = weights + SERVER_CONTEXT_OVERHEAD + (3 * kv) // 4

    def frontend():
        return ClusterFrontend(n_nodes=4, mem_bytes=mem)

    assert frontend().place_instance(
        "f", tiny_model, params32, ALLOC, max_batch=4, max_len=32,
        framework_bytes=0) is None
    fe = frontend()
    h = fe.place_instance("f", tiny_model, params32, ALLOC, max_batch=4,
                          max_len=32, framework_bytes=0, shards=2)
    assert h is not None
    req = fe.submit("f", PROMPTS[0], max_new_tokens=4)
    fe.pump(budget_s=60.0)
    assert req.done and len(req.tokens_out) == 4


# -------------------------------------------------------------------------
# Link-aware placement + member-failure rollback
# -------------------------------------------------------------------------


def test_placement_picks_highest_bandwidth_group(tiny_model, params32):
    links = NetworkLinks(4, default_bps=1e9)
    links.set_link(2, 3, 64e9)
    fe, toks = serve(tiny_model, params32, 2, links=links)
    assert fe.placements[0].member_nodes == (2, 3)
    _, ref = serve(tiny_model, params32, 1)
    assert toks == ref


def test_evict_releases_every_member_rectangle(tiny_model, params32):
    fe, _ = serve(tiny_model, params32, 2)
    [p] = fe.placements
    fe.evict(f"{p.node}:{p.inst_id}")
    fe.pump(budget_s=10.0)
    assert not fe.placements
    assert all(abs(v) < 1e-9 for v in fe.node_load().values())


def test_member_failure_folds_pod_and_heals(tiny_model, params32):
    fe, _ = serve(tiny_model, params32, 2)
    [p] = fe.placements
    primary, secondary = p.member_nodes
    stranded = fe.submit("f", PROMPTS[0], max_new_tokens=6)
    assert fe.fail_node(secondary) == 1
    assert not fe.placements
    assert p.inst_id not in fe.engines[primary].instances
    # The stranded request parked; a replacement pod on surviving nodes
    # drains it with reference-identical tokens.
    h = fe.place_instance("f", tiny_model, params32, ALLOC, max_batch=4,
                          max_len=32, shards=1)
    assert h is not None
    fe.pump(budget_s=60.0)
    _, ref = serve(tiny_model, params32, 1)
    assert stranded.done and list(stranded.tokens_out) == ref[0]


def test_primary_failure_releases_surviving_rectangles(tiny_model,
                                                       params32):
    fe, _ = serve(tiny_model, params32, 2)
    [p] = fe.placements
    fe.fail_node(p.member_nodes[0])
    assert not fe.placements
    # Surviving nodes can host a fresh 2-way pod straight away.
    assert fe.place_instance("f", tiny_model, params32, ALLOC, max_batch=4,
                             max_len=32, shards=2) is not None


def test_sharded_pod_refuses_speculation_and_migration(tiny_model,
                                                       params32):
    fe = ClusterFrontend(n_nodes=4)
    with pytest.raises(ValueError, match="speculate"):
        fe.place_instance("f", tiny_model, params32, ALLOC,
                          speculate=SpecConfig(draft_cfg=tiny_model.cfg, k=2),
                          shards=2)
    fe, _ = serve(tiny_model, params32, 2)
    [p] = fe.placements
    spare = next(n for n in range(4) if n not in p.member_nodes)
    assert fe.migrate("f", f"{p.node}:{p.inst_id}", tiny_model, params32,
                      spare) is None


# -------------------------------------------------------------------------
# Sim-vs-live: identical decisions with shards + links enabled
# -------------------------------------------------------------------------


def test_sim_vs_live_signature_with_shards_and_links(tiny_model, params32):
    profile = (ProfilePoint(sm=0.2, quota=0.3, throughput=2.0,
                            p99_latency=0.05),
               ProfilePoint(sm=0.4, quota=0.6, throughput=5.0,
                            p99_latency=0.03),)
    curve = ServiceCurve(name="f", r_max=5.0, sm_sat=0.4, p=1.0,
                         weight_bytes=1 << 20, framework_bytes=32 << 20,
                         allreduce_bytes=1 << 16)
    demand = ramp([(0.0, 2.0), (2.0, 9.0), (6.0, 2.0)])

    def make_spec(factory=None):
        return FunctionSpec(name="f", profile=profile, slo_latency=0.1,
                            target_rps=demand, min_instances=1,
                            max_instances=4, model_factory=factory,
                            max_batch=2, max_len=32,
                            framework_bytes=32 << 20, shards=2,
                            curve=curve)

    def run(plane):
        for tick in range(9):
            plane.reconcile(now=float(tick))

    def make_links():
        links = NetworkLinks(4, default_bps=8e9)
        links.set_link(0, 1, 64e9)
        return links

    frontend = ClusterFrontend(n_nodes=4, window=0.05, links=make_links())
    live = ControlPlane(LiveBackend(frontend))
    live.register(make_spec(lambda: (tiny_model, params32)))
    run(live)

    cluster = Cluster(n_nodes=4, sharing=True, links=make_links())
    sim = ControlPlane(SimBackend(cluster))
    sim.register(make_spec())
    run(sim)

    live_sig = decision_signature(live.log)
    assert live_sig and live_sig == decision_signature(sim.log)
    assert live.instances("f") == sim.instances("f")
    # Both fleets actually placed multi-rectangle pods on the fast pair,
    # and both expose the same link table through the backend verb.
    assert frontend.placements[0].member_nodes == (0, 1)
    assert cluster.pods[next(iter(cluster.pods))].member_nodes == (0, 1)
    assert live.backend.links() == sim.backend.links()


# -------------------------------------------------------------------------
# Satellite: bandwidth-aware peer selection in the fleet model store
# -------------------------------------------------------------------------


def test_fleet_store_picks_fastest_transfer_peer(tiny_model, tiny_params):
    def store_with(links):
        store = FleetModelStore(links=links)
        staged = stage_params(tiny_model, tiny_params)
        store.cache(1).put("f", staged)
        store.cache(3).put("f", staged)
        return store

    # Node 3 has the fat pipe to node 0 -> peer-warm transfer uses it.
    links = NetworkLinks(4, default_bps=1e9)
    links.set_link(0, 3, 64e9)
    store = store_with(links)
    params, event = store.acquire(0, "f", tiny_model)
    assert event.tier == "peer" and event.peer == 3
    # Without a links table the tie breaks to the lowest warm node id.
    store = store_with(None)
    _, event = store.acquire(0, "f", tiny_model)
    assert event.tier == "peer" and event.peer == 1


# -------------------------------------------------------------------------
# Model pieces: round_time collective term + per-point shards axis
# -------------------------------------------------------------------------


def test_round_time_folds_collective_cost():
    c = ServiceCurve(name="f", r_max=10.0, sm_sat=0.5, p=1.0,
                     allreduce_bytes=1 << 20)
    base = c.round_time(0.25, 3)
    t = c.round_time(0.25, 3, shards=2, link_bps=64e9)
    assert t == pytest.approx(
        base / 2 + 2 * (1 / 2) * c.allreduce_bytes * 3 / 64e9)
    # shards=1, or no link model, is bit-identical to the legacy value.
    assert c.round_time(0.25, 3, shards=1, link_bps=64e9) == base
    assert dataclasses.replace(c, allreduce_bytes=0).round_time(
        0.25, 3, shards=2, link_bps=64e9) == base / 2


def test_profile_point_shards_axis():
    with pytest.raises(ValueError, match="shards"):
        ProfilePoint(sm=0.2, quota=0.2, throughput=1.0, shards=0)
    single = ProfilePoint(sm=0.2, quota=0.2, throughput=2.0)
    wide = ProfilePoint(sm=0.2, quota=0.2, throughput=4.0, shards=2)
    # RPR divides by the whole multi-node footprint: 2x throughput over
    # 2x resources is NOT more efficient.
    assert wide.rpr == pytest.approx(single.rpr)


def test_spec_shards_validation():
    profile = (ProfilePoint(sm=0.2, quota=0.2, throughput=1.0),)
    assert FunctionSpec(name="f", profile=profile, shards=2).shards == 2
    with pytest.raises(ValueError, match="shards"):
        FunctionSpec(name="f", profile=profile, shards=0)
    with pytest.raises(ValueError, match="speculate"):
        FunctionSpec(name="f", profile=profile, shards=2,
                     speculate=SpecConfig(
                         draft_cfg=types.SimpleNamespace(vocab_size=64), k=2))


def test_network_links_queries():
    links = NetworkLinks(4, default_bps=1e9)
    links.set_link(1, 2, 4e9)
    assert links.bandwidth(2, 1) == 4e9  # symmetric
    assert links.bandwidth(3, 3) == float("inf")
    assert links.bottleneck([1, 2, 3]) == 1e9
    assert links.best_peer(1, [0, 2, 3]) == 2
    assert links.best_peer(1, [1]) is None  # no self-transfer
    assert links.best_groups([0, 1, 2, 3], 2)[0] == (1, 2)
    assert links.best_groups([0, 1], 3) == []
    with pytest.raises(ValueError):
        links.set_link(1, 1, 1e9)
