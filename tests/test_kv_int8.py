"""int8 KV-cache quantization (§Perf D): correctness vs the bf16 path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.attention import kv_dequantize, kv_int8_enabled, kv_quantize

# JAX-compile-heavy (full decode-path compiles): excluded from tier-1, run via `-m slow`.
pytestmark = pytest.mark.slow


def _run_decode(model, params, toks, forced, steps=5):
    """Teacher-forced decode: both paths see identical token histories, so
    logit differences isolate the cache quantization error (greedy feedback
    would diverge chaotically at the first argmax tie-flip)."""
    logits, cache = model.prefill(params, toks,
                                  max_len=toks.shape[1] + steps + 2)
    outs = [logits]
    for i in range(steps):
        logits, cache = model.decode_step(params, forced[:, i], cache)
        outs.append(logits)
    return outs, cache


def test_quantize_roundtrip_error_bounded():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 7, 3, 16)) * 3,
                    jnp.bfloat16)
    q, s = kv_quantize(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 7, 3, 1)
    back = kv_dequantize(q, s)
    rel = float(jnp.abs(back.astype(jnp.float32) - x.astype(jnp.float32)
                        ).max() / jnp.abs(x.astype(jnp.float32)).max())
    assert rel < 0.02  # <=1/127 + rounding


def test_int8_cache_matches_bf16_decode(monkeypatch):
    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)), jnp.int32)
    forced = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)

    monkeypatch.delenv("REPRO_KV_INT8", raising=False)
    fp, _ = _run_decode(model, params, toks, forced)
    monkeypatch.setenv("REPRO_KV_INT8", "1")
    assert kv_int8_enabled(cfg)
    q8, cache = _run_decode(model, params, toks, forced)

    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    for a, b in zip(fp, q8):
        d = float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        assert d < 0.25, d  # quantization-only error, small vs logit std ~1


def test_int8_gate_excludes_windowed_and_hybrid(monkeypatch):
    monkeypatch.setenv("REPRO_KV_INT8", "1")
    assert not kv_int8_enabled(get_config("mixtral-8x7b"))  # SWA
    assert not kv_int8_enabled(get_config("gemma3-27b"))  # local:global
    assert not kv_int8_enabled(get_config("hymba-1.5b"))  # hybrid
    assert kv_int8_enabled(get_config("qwen1.5-110b"))
    assert kv_int8_enabled(get_config("qwen2-moe-a2.7b"))
