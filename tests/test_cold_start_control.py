"""Cold-start tier through the control plane: sim semantics, warm-aware
placement and defrag, and sim-vs-live replay with ``cold_start_s``.

The contract under test: modeling cold starts changes WHEN capacity
comes online and WHERE pods land (warm-first node selection, warm-aware
defrag targets), but never WHAT the reconciler decides — the
``decision_signature`` of a live run must replay through the simulator
unchanged with the cold-start axis on.
"""

import numpy as np
import pytest

from repro.control import (ControlPlane, FunctionSpec, LiveBackend,
                           SimBackend, decision_signature, ramp)
from repro.core.cluster import Cluster
from repro.core.resources import Alloc
from repro.core.scaling import ProfilePoint
from repro.core.workload import ServiceCurve, poisson_arrivals
from repro.serving import ClusterFrontend, FleetModelStore, stage_params

PROFILE = (
    ProfilePoint(sm=0.25, quota=0.4, throughput=2.0, p99_latency=0.05),
    ProfilePoint(sm=0.45, quota=0.8, throughput=5.0, p99_latency=0.03),
)

RAMP = ramp([(0.0, 1.0), (2.0, 8.0), (6.0, 1.0)])


def tiny_curve() -> ServiceCurve:
    return ServiceCurve(name="chat", r_max=5.0, sm_sat=0.45, p=1.0,
                        weight_bytes=1 << 20, framework_bytes=32 << 20)


def make_spec(factory=None, **overrides) -> FunctionSpec:
    kw = dict(name="chat", profile=PROFILE, slo_latency=0.1,
              target_rps=RAMP, headroom=1.2, min_instances=1,
              max_instances=5, model_factory=factory, max_batch=2,
              max_len=32, framework_bytes=32 * 1024 * 1024,
              curve=tiny_curve())
    kw.update(overrides)
    return FunctionSpec(**kw)


# -------------------------------------------------------------------------
# Spec: the cold-start axis is validated declarative state
# -------------------------------------------------------------------------


def test_spec_rejects_negative_cold_start():
    with pytest.raises(ValueError, match="cold_start_s"):
        make_spec(cold_start_s=-0.1)
    assert make_spec(cold_start_s=2.5).cold_start_s == 2.5
    assert make_spec().cold_start_s == 0.0


# -------------------------------------------------------------------------
# Simulator semantics: tiers, delays, warm-first node selection
# -------------------------------------------------------------------------


def test_sim_deploy_tiers_and_warm_first_selection():
    cluster = Cluster(n_nodes=2, sharing=True)
    cluster.register_function("chat", tiny_curve(), slo_latency=0.1)
    # First deploy: nothing staged anywhere -> full cold delay.
    p0 = cluster.deploy("chat", PROFILE[0], cold_start_s=1.0)
    assert p0 is not None
    e0 = cluster.cold_events[-1]
    assert e0["tier"] == "cold" and e0["delay"] == 1.0
    assert cluster.warm_nodes("chat") == [e0["node"]]
    assert cluster.pods[p0].ready_at == pytest.approx(1.0)
    # Second deploy prefers the warm node: host tier, no delay.
    p1 = cluster.deploy("chat", PROFILE[0], cold_start_s=1.0)
    e1 = cluster.cold_events[-1]
    assert e1["tier"] == "host" and e1["delay"] == 0.0
    assert e1["node"] == e0["node"]
    assert cluster.pods[p1].ready_at == 0.0
    # Warm node cordoned -> the placement spills to the cold node but
    # pulls from its peer's host RAM: half the cold delay.
    cluster.pool.cordon(e0["node"])
    cluster.deploy("chat", PROFILE[0], cold_start_s=1.0)
    e2 = cluster.cold_events[-1]
    assert e2["tier"] == "peer" and e2["delay"] == pytest.approx(0.5)
    assert e2["node"] != e0["node"]
    # Both nodes staged now.
    assert cluster.warm_nodes("chat") == [0, 1]


def test_sim_cold_pod_serves_nothing_before_ready():
    """The ready gate holds the pod's first token grant until its
    weights 'land'; requests queued in the cold window survive it."""
    cluster = Cluster(n_nodes=1, sharing=True)
    cluster.register_function("chat", tiny_curve(), slo_latency=1.0)
    cluster.deploy("chat", PROFILE[1], cold_start_s=2.0)
    arrivals = poisson_arrivals("chat", rps=3.0, duration=1.5, seed=11)
    cluster.submit_all(arrivals)
    cluster.run(30.0)
    rec = cluster.recorders["chat"]
    assert rec.count() == len(arrivals) and cluster.dropped == 0
    assert min(rec.completion_times) >= 2.0, (
        "a request completed before the cold upload finished")


def test_sim_node_failure_loses_host_staging():
    cluster = Cluster(n_nodes=2, sharing=True)
    cluster.register_function("chat", tiny_curve(), slo_latency=0.1)
    cluster.deploy("chat", PROFILE[0], cold_start_s=1.0)
    warm = cluster.warm_nodes("chat")
    cluster.fail_node(warm[0])
    assert cluster.warm_nodes("chat") == []
    # The next placement is fully cold again.
    cluster.deploy("chat", PROFILE[0], cold_start_s=1.0)
    assert cluster.cold_events[-1]["tier"] == "cold"


def test_sim_zero_cold_start_records_nothing():
    cluster = Cluster(n_nodes=2, sharing=True)
    cluster.register_function("chat", tiny_curve(), slo_latency=0.1)
    cluster.deploy("chat", PROFILE[0])
    assert cluster.cold_events == []
    assert cluster.warm_nodes("chat") == []


# -------------------------------------------------------------------------
# Defrag prefers warm targets
# -------------------------------------------------------------------------


def test_defrag_moves_to_warm_target_over_lighter_cold_one():
    cluster = Cluster(n_nodes=3, sharing=True)
    plane = ControlPlane(SimBackend(cluster), defrag_threshold=-1.0)
    plane.register(make_spec(min_instances=2,
                             target_rps=ramp([(0.0, 0.0)])))
    # Both floor pods pack onto one node; among the two empty candidate
    # targets, staging node 2's host RAM must beat the (equally loaded,
    # lower-numbered) cold node 1.
    sources = {cluster.node_of(p) for p in plane.placed["chat"]}
    assert len(sources) == 1
    src = sources.pop()
    warm_target = [n for n in (1, 2) if n != src][-1]
    cluster.nodes[warm_target].warm_fns.add("chat")
    plane.reconcile(now=0.0)
    assert plane.migrations, "defrag pass did not move anything"
    move = plane.migrations[-1]
    assert move.source == src and move.target == warm_target


# -------------------------------------------------------------------------
# Live frontend: warm-first placement through the fleet store
# -------------------------------------------------------------------------


def test_frontend_places_on_host_warm_node_first(tiny_model, tiny_params):
    store = FleetModelStore()
    store.cache(1).put("chat", stage_params(tiny_model, tiny_params))
    fe = ClusterFrontend(n_nodes=2, window=0.05, model_store=store)
    alloc = Alloc(sm=0.3, quota_request=0.3, quota_limit=0.4)
    handle = fe.place_instance("chat", tiny_model, tiny_params, alloc,
                               max_batch=2, max_len=32)
    # MRA alone would pick node 0; warmth steers it to node 1.
    assert handle is not None and handle.startswith("1:")
    [event] = fe.cold_start_events()
    assert event.tier == "host" and event.node == 1
    # The placement pinned its host entry; a full pump lands tokens and
    # resolves the event's TTFT.
    assert store.cache(1).pins("chat") == 1
    req = fe.submit("chat", np.arange(5, dtype=np.int32),
                    max_new_tokens=3)
    fe.pump(budget_s=30.0)
    assert req.done
    [event] = fe.cold_start_events()  # re-read: TTFT resolves lazily
    assert event.ttft_s is not None and event.ttft_s > 0
    # Evicting the only instance releases the pin (weights evictable).
    fe.evict(handle)
    assert store.cache(1).pins("chat") == 0


# -------------------------------------------------------------------------
# Sim-vs-live replay with the cold-start axis on
# -------------------------------------------------------------------------


def test_sim_vs_live_signature_with_cold_start(tiny_model, tiny_params):
    """A live ramp placed through the fleet store replays through the
    simulator decision-for-decision with ``cold_start_s`` modeled —
    node choices and ready delays never leak into the signature."""

    def run(plane):
        for tick in range(9):
            plane.reconcile(now=float(tick))

    spec_kw = dict(min_instances=1, max_instances=5, cold_start_s=0.8)
    frontend = ClusterFrontend(n_nodes=2, window=0.05,
                               model_store=FleetModelStore())
    live = ControlPlane(LiveBackend(frontend))
    live.register(make_spec(lambda: (tiny_model, tiny_params), **spec_kw))
    run(live)

    cluster = Cluster(n_nodes=2, sharing=True)
    sim = ControlPlane(SimBackend(cluster))
    sim.register(make_spec(**spec_kw))
    run(sim)

    live_sig = decision_signature(live.log)
    assert live_sig and live_sig == decision_signature(sim.log)
    assert live.instances("chat") == sim.instances("chat")
    # Both fleets actually exercised the tier: the sim logged cold
    # events, the live path resolved store events, and scale-ups beyond
    # the first hit a warm tier (the first placement staged the weights).
    assert cluster.cold_events and cluster.cold_events[0]["tier"] == "cold"
    live_tiers = [e.tier for e in frontend.cold_start_events()]
    assert len(live_tiers) == len(cluster.cold_events)
    assert all(t in ("host", "device", "peer")
               for t in live_tiers[1:]), live_tiers
    sim_tiers = [e["tier"] for e in cluster.cold_events]
    assert all(t in ("host", "peer") for t in sim_tiers[1:]), sim_tiers
