"""Speculative decoding on the sync-free hot path, end to end.

The load-bearing invariant: every token a speculative round emits is the
TARGET's greedy continuation of the true prefix (scored by ``verify_step``
over a correct target cache), so the greedy output stream is bit-identical
to the non-speculative fused path for ANY draft — draft quality only moves
acceptance and throughput, never content.  Pinned here:

* spec-on == spec-off greedy streams, continuous AND paged planes, with
  draft == target (acceptance 1.0) and with a disagreeing draft;
* exactly one host sync per pump pass with speculation on (the draft-k /
  verify-1 loop adds zero host round-trips);
* sampled fused rounds replay bit-identically on the eager
  ``fused=False`` reference path from the same ``SamplingConfig.seed``;
* the generic-family fused wrapper (rwkv: no transformer KV cache at
  all) routes through the same shared sampler and keeps syncs == steps;
* ``FunctionSpec.speculate`` flows through profiler-shaped points to
  identical sim-vs-live ``decision_signature`` sequences.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from conftest import tiny_config
from repro.core.resources import Alloc
from repro.serving import ServingEngine
from repro.serving.speculative import SamplingConfig, SpecConfig

FULL = Alloc(sm=1.0, quota_request=0.9, quota_limit=0.9)


def _run(model, params, *, batching="continuous", fused=True,
         sampling=None, speculate=None, draft_params=None, n_reqs=4,
         max_new=9, prompt_len=6, seed=0):
    """Serve a deterministic workload; return (token streams, telemetry)."""
    engine = ServingEngine(window=0.1)
    engine.deploy("lm", model, params, FULL, n_instances=1, max_batch=2,
                  max_len=64, batching=batching, fused=fused,
                  sampling=sampling, speculate=speculate,
                  draft_params=draft_params)
    rng = np.random.default_rng(seed)
    reqs = [engine.submit(
        "lm", rng.integers(0, model.cfg.vocab_size, prompt_len,
                           dtype=np.int32), max_new_tokens=max_new)
        for _ in range(n_reqs)]
    done = engine.pump(budget_s=120.0)
    assert done == len(reqs)
    tele = list(engine.telemetry().values())[0]
    return [list(r.tokens_out) for r in reqs], tele


@pytest.mark.parametrize("batching", ["continuous", "paged"])
def test_spec_greedy_bit_identical_draft_equals_target(
        tiny_model, tiny_params, batching):
    """draft == target: acceptance 1.0, one sync per pass, identical
    greedy streams on both KV planes."""
    base, _ = _run(tiny_model, tiny_params, batching=batching)
    spec = SpecConfig(draft_cfg=tiny_config(), k=4)
    out, tele = _run(tiny_model, tiny_params, batching=batching,
                     speculate=spec, draft_params=tiny_params)
    assert out == base
    assert tele["syncs"] == tele["steps"], (
        f"speculative round broke the one-sync rule: {tele}")
    assert tele["spec_proposed"] > 0
    assert tele["spec_accepted"] == tele["spec_proposed"], (
        "draft == target must accept every proposal")


@pytest.mark.parametrize("batching", ["continuous", "paged"])
def test_spec_greedy_bit_identical_any_draft(tiny_model, tiny_params,
                                             batching):
    """A DISAGREEING draft (different init) still yields bit-identical
    greedy output — rejections cost throughput, never content."""
    base, _ = _run(tiny_model, tiny_params, batching=batching)
    draft_params = tiny_model.init(jax.random.key(999))  # disagrees
    spec = SpecConfig(draft_cfg=tiny_config(), k=3)
    out, tele = _run(tiny_model, tiny_params, batching=batching,
                     speculate=spec, draft_params=draft_params)
    assert out == base
    assert tele["syncs"] == tele["steps"]
    # a random draft must actually get rejected sometimes, or this test
    # would not be exercising the rollback path at all
    assert tele["spec_accepted"] < tele["spec_proposed"]


@pytest.mark.parametrize("batching", ["continuous", "paged"])
def test_sampled_fused_matches_host_reference(tiny_model, tiny_params,
                                              batching):
    """Stochastic fused rounds replay bit-identically on the eager
    ``fused=False`` path from the same seed (same key stream)."""
    sampling = SamplingConfig(temperature=0.8, top_k=12, top_p=0.9, seed=5)
    fused, tele = _run(tiny_model, tiny_params, batching=batching,
                       sampling=sampling)
    host, _ = _run(tiny_model, tiny_params, batching=batching,
                   fused=False, sampling=sampling)
    assert fused == host
    assert tele["syncs"] == tele["steps"]


def test_spec_off_reference_unchanged(tiny_model, tiny_params):
    """``speculate=None`` + ``fused=False`` still produces the same greedy
    stream as the fused path (the PR-5 reference contract)."""
    base, _ = _run(tiny_model, tiny_params)
    host, _ = _run(tiny_model, tiny_params, fused=False)
    assert base == host


def test_rwkv_generic_fused_sampler_sync_count():
    """Satellite: the generic-family wrapper (rwkv has no transformer KV
    cache) routes stochastic sampling through the shared fused sampler —
    syncs stay == steps, and the eager reference path bit-matches."""
    from repro.configs import get_config
    from repro.models import build_model

    model = build_model(get_config("rwkv6-1.6b", reduced=True))
    params = model.init(jax.random.key(3))
    sampling = SamplingConfig(temperature=0.9, top_k=8, seed=7)
    fused, tele = _run(model, params, n_reqs=2, max_new=6,
                       sampling=sampling)
    assert tele["syncs"] == tele["steps"], (
        f"generic-family fused sampled round added host syncs: {tele}")
    host, _ = _run(model, params, n_reqs=2, max_new=6, fused=False,
                   sampling=sampling)
    assert fused == host


def test_speculating_instance_refuses_export(tiny_model, tiny_params):
    """Migration of a speculating pod is unsupported by design (the draft
    side cache does not travel); the engine must refuse loudly."""
    engine = ServingEngine(window=0.1)
    engine.deploy("lm", tiny_model, tiny_params, FULL, n_instances=1,
                  max_batch=2, max_len=64, batching="paged",
                  speculate=SpecConfig(draft_cfg=tiny_config(), k=2),
                  draft_params=tiny_params)
    req = engine.submit("lm", np.arange(6, dtype=np.int32),
                        max_new_tokens=16)
    inst = list(engine.instances.values())[0]
    engine.pump(budget_s=0.2)
    if not req.done:
        slot = next(i for i, r in enumerate(inst.slots) if r is req)
        with pytest.raises(ValueError, match="speculating"):
            inst.export_slot(slot)


# -------------------------------------------------------------------------
# Control plane: the speculation axis yields identical sim/live decisions
# -------------------------------------------------------------------------


def test_sim_live_decision_signature_with_speculate(tiny_model, tiny_params):
    from repro.control import (ControlPlane, FunctionSpec, LiveBackend,
                               SimBackend, decision_signature, ramp)
    from repro.core.cluster import Cluster
    from repro.core.scaling import ProfilePoint
    from repro.core.workload import ServiceCurve
    from repro.serving import ClusterFrontend

    profile = (
        ProfilePoint(sm=0.25, quota=0.4, throughput=2.0, p99_latency=0.05,
                     spec_k=4, acceptance=0.8),
        ProfilePoint(sm=0.45, quota=0.8, throughput=5.0, p99_latency=0.03,
                     spec_k=4, acceptance=0.8),
    )
    curve = ServiceCurve(name="chat", r_max=5.0, sm_sat=0.45, p=1.0,
                         weight_bytes=1 << 20, framework_bytes=32 << 20)

    def spec_for(factory):
        return FunctionSpec(
            name="chat", profile=profile, slo_latency=0.1,
            target_rps=ramp([(0.0, 1.0), (2.0, 11.0), (5.0, 1.0)]),
            headroom=1.2, min_instances=1, max_instances=5,
            model_factory=factory, max_batch=2, max_len=32,
            framework_bytes=32 * 1024 * 1024, curve=curve,
            speculate=SpecConfig(draft_cfg=tiny_config(), k=4),
            draft_factory=lambda: tiny_params)

    frontend = ClusterFrontend(n_nodes=2, window=0.1)
    live = ControlPlane(LiveBackend(frontend))
    live.register(spec_for(lambda: (tiny_model, tiny_params)))

    sim = ControlPlane(SimBackend(Cluster(n_nodes=2, sharing=True)))
    sim.register(spec_for(None))

    for tick in range(8):
        live.reconcile(now=float(tick))
        sim.reconcile(now=float(tick))

    assert decision_signature(live.log) == decision_signature(sim.log)
    assert len(live.log) > 0
    # the live fleet actually speculates: serve a little traffic through it
    rng = np.random.default_rng(0)
    reqs = [frontend.submit("chat", rng.integers(0, 64, 6, dtype=np.int32),
                            max_new_tokens=4) for _ in range(3)]
    frontend.pump(budget_s=60.0)
    assert all(r.done for r in reqs)
    tele = [t for e in frontend.engines for t in e.telemetry().values()]
    assert sum(t["spec_proposed"] for t in tele) > 0


def test_spec_requires_slot_batching():
    from repro.control import FunctionSpec
    from repro.core.scaling import ProfilePoint

    with pytest.raises(ValueError):
        FunctionSpec(name="x",
                     profile=(ProfilePoint(sm=0.2, quota=0.2,
                                           throughput=1.0,
                                           p99_latency=0.01),),
                     slo_latency=0.1, batching="static",
                     speculate=SpecConfig(draft_cfg=tiny_config(), k=2))
