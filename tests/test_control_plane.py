"""Reconciler loop: one declarative spec, two backends, same decisions.

Covers the ``repro.control`` tentpole: a synthetic RPS ramp driven through
the simulator and the live JAX backend must yield identical
``ScaleDecision`` sequences; scale-down must drain in-flight slots before
releasing MRA rectangles and ModelStore refcounts; failed placements must
settle their provisional L_j reservations (no capacity drift).
"""

import jax
import numpy as np
import pytest

from repro.control import (ControlPlane, FunctionSpec, LiveBackend,
                           SimBackend, decision_signature, ramp)
from repro.core.cluster import Cluster
from repro.core.scaling import ProfilePoint
from repro.core.workload import ServiceCurve, poisson_arrivals
from repro.serving import ClusterFrontend

PROFILE = (
    ProfilePoint(sm=0.25, quota=0.4, throughput=2.0, p99_latency=0.05),
    ProfilePoint(sm=0.45, quota=0.8, throughput=5.0, p99_latency=0.03),
    # SLO-infeasible decoy: best throughput-per-resource if the filter broke.
    ProfilePoint(sm=0.1, quota=0.1, throughput=3.0, p99_latency=0.5),
)

RAMP = ramp([(0.0, 1.0), (2.0, 11.0), (5.0, 1.0)])


def tiny_curve() -> ServiceCurve:
    return ServiceCurve(name="chat", r_max=5.0, sm_sat=0.45, p=1.0,
                        weight_bytes=1 << 20, framework_bytes=32 << 20)


def make_spec(factory=None, **overrides) -> FunctionSpec:
    kw = dict(name="chat", profile=PROFILE, slo_latency=0.1, target_rps=RAMP,
              headroom=1.2, min_instances=1, max_instances=5,
              model_factory=factory, max_batch=2, max_len=32,
              framework_bytes=32 * 1024 * 1024, curve=tiny_curve())
    kw.update(overrides)
    return FunctionSpec(**kw)


def model_factory_from(tiny_model, tiny_params):
    return lambda: (tiny_model, tiny_params)


# -------------------------------------------------------------------------
# Identical decisions across backends
# -------------------------------------------------------------------------


def test_sim_and_live_identical_decision_sequences(tiny_model, tiny_params):
    frontend = ClusterFrontend(n_nodes=2, window=0.1)
    live = ControlPlane(LiveBackend(frontend))
    live.register(make_spec(model_factory_from(tiny_model, tiny_params)))

    cluster = Cluster(n_nodes=2, sharing=True)
    sim = ControlPlane(SimBackend(cluster))
    sim.register(make_spec())

    for tick in range(8):
        live.reconcile(now=float(tick))
        sim.reconcile(now=float(tick))

    assert decision_signature(live.log) == decision_signature(sim.log)
    assert len(live.log) > 0, "the ramp must trigger scaling"
    ups = [d for d in live.log if d.direction > 0]
    downs = [d for d in live.log if d.direction < 0]
    assert ups and downs, "ramp must scale out AND back in"
    # SLO filter: the infeasible decoy point must never be chosen.
    assert all(d.point.p99_latency <= 0.1 for d in live.log)
    # Both fleets converge to the same size.
    assert live.instances("chat") == sim.instances("chat") == 1


def test_max_instances_clamps_both_backends(tiny_model, tiny_params):
    frontend = ClusterFrontend(n_nodes=2, window=0.1)
    live = ControlPlane(LiveBackend(frontend))
    live.register(make_spec(model_factory_from(tiny_model, tiny_params),
                            max_instances=3))
    live.reconcile(now=3.0)  # burst: wants ~6 pods of the 2-rps point
    assert live.instances("chat") == 3
    # Aborted reservations must not leave phantom capacity in L_j.
    assert live.capacity("chat") == pytest.approx(
        sum(p.throughput for p in live.placed["chat"].values()))
    assert live.queues["chat"].provisional_ids() == set()


# -------------------------------------------------------------------------
# Scale-down: graceful drain, released rectangles, refcounts
# -------------------------------------------------------------------------


def test_live_scale_down_drains_and_releases(tiny_model, tiny_params):
    frontend = ClusterFrontend(n_nodes=2, window=0.05)
    plane = ControlPlane(LiveBackend(frontend))
    plane.register(make_spec(model_factory_from(tiny_model, tiny_params)))
    plane.reconcile(now=3.0)  # scale out for the burst
    n_burst = plane.instances("chat")
    assert n_burst > 1
    store_refs = sum(e.store.refcount("chat") for e in frontend.engines)
    assert store_refs == n_burst

    # Put real work in flight, then scale down while it is decoding.
    rng = np.random.default_rng(0)
    reqs = [frontend.submit("chat", rng.integers(0, 64, 6, dtype=np.int32),
                            max_new_tokens=4) for _ in range(6)]
    frontend.pump(budget_s=0.05)  # start decoding; do not finish
    plane.reconcile(now=6.0)      # ramp-down: evicts all but the floor
    assert plane.instances("chat") == 1
    frontend.pump(budget_s=60.0)  # drain retirees + finish survivors

    assert all(r.done for r in reqs), "eviction dropped in-flight requests"
    # Drained instances released their shared-weight refcounts...
    assert sum(e.store.refcount("chat") for e in frontend.engines) == 1
    # ...their scheduler registrations...
    assert sum(len(e.scheduler.pods) for e in frontend.engines) == 1
    # ...and their MRA rectangles.
    assert len(frontend.placements) == 1
    assert frontend.pool.total_used_area() == \
        frontend.placements[0].placement.rect.area


def test_live_scale_to_zero_zeroes_refcounts(tiny_model, tiny_params):
    frontend = ClusterFrontend(n_nodes=1, window=0.05)
    plane = ControlPlane(LiveBackend(frontend))
    plane.register(make_spec(model_factory_from(tiny_model, tiny_params),
                             min_instances=0,
                             target_rps=ramp([(0.0, 4.0), (1.0, 0.0)])))
    plane.reconcile(now=0.0)
    assert plane.instances("chat") >= 1
    plane.reconcile(now=1.0)  # zero demand: evict everything
    frontend.pump(budget_s=5.0)
    assert plane.instances("chat") == 0
    eng = frontend.engines[0]
    assert eng.store.refcount("chat") == 0
    assert eng.store.contains("chat"), "weights stay cached (evictable)"
    assert frontend.pool.total_used_area() == 0
    assert frontend.placements == [] and eng.instances == {}


def test_engine_retire_waits_for_occupied_slots(tiny_model, tiny_params):
    from repro.core.resources import Alloc
    from repro.serving import ServingEngine

    engine = ServingEngine(window=0.05)
    closed = []
    engine.on_instance_closed = closed.append
    (inst_id,) = engine.deploy("lm", tiny_model, tiny_params,
                               Alloc(sm=0.5, quota_request=0.8,
                                     quota_limit=0.8),
                               max_batch=2, max_len=32)
    inst = engine.instances[inst_id]
    req = engine.submit("lm", np.arange(6, dtype=np.int32), max_new_tokens=4)
    inst.run_step()  # occupy a slot mid-decode
    assert not req.done

    strays = engine.retire(inst_id)
    assert strays == [], "admitted requests are not strays"
    assert inst_id in engine.instances, "must drain before closing"
    assert engine.store.refcount("lm") == 1
    engine.pump(budget_s=30.0)
    assert req.done and len(req.tokens_out) == 4
    assert closed == [inst_id]
    assert inst_id not in engine.instances
    assert engine.store.refcount("lm") == 0
    assert engine.scheduler.pods == {}


def test_engine_retire_idle_instance_closes_immediately(tiny_model,
                                                        tiny_params):
    from repro.core.resources import Alloc
    from repro.serving import ServingEngine

    engine = ServingEngine(window=0.05)
    closed = []
    engine.on_instance_closed = closed.append
    (inst_id,) = engine.deploy("lm", tiny_model, tiny_params,
                               Alloc(sm=0.5, quota_request=0.8,
                                     quota_limit=0.8))
    assert engine.retire(inst_id) == []
    assert closed == [inst_id] and engine.instances == {}
    assert engine.store.refcount("lm") == 0


def test_sim_scale_down_drains_before_release():
    cluster = Cluster(n_nodes=2, sharing=True)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(make_spec())
    plane.reconcile(now=3.0)
    n_burst = plane.instances("chat")
    assert n_burst > 1
    arrivals = poisson_arrivals("chat", rps=8.0, duration=2.0, seed=3,
                                start=3.0)
    cluster.submit_all(arrivals)
    cluster.sim.at(6.0, lambda: plane.reconcile(now=6.0))
    cluster.run(20.0)
    assert plane.instances("chat") == 1
    assert cluster.recorders["chat"].count() == len(arrivals)
    assert cluster.dropped == 0
    # Retired pods fully torn down: one pod, one rectangle.
    assert len(cluster.pods) == 1
    assert cluster.pool.total_used_area() == \
        next(iter(cluster.pods.values())).placement.rect.area


def test_evict_last_replica_with_queued_requests(tiny_model, tiny_params):
    """Evicting the only replica while requests are queued (none admitted
    to slots yet) must drain them, not drop them."""
    frontend = ClusterFrontend(n_nodes=1, window=0.05)
    plane = ControlPlane(LiveBackend(frontend))
    plane.register(make_spec(model_factory_from(tiny_model, tiny_params),
                             min_instances=0,
                             target_rps=ramp([(0.0, 1.0), (1.0, 0.0)])))
    plane.reconcile(now=0.0)  # brings up the single replica
    assert plane.instances("chat") == 1
    rng = np.random.default_rng(2)
    reqs = [frontend.submit("chat", rng.integers(0, 64, 5, dtype=np.int32),
                            max_new_tokens=3) for _ in range(3)]
    plane.reconcile(now=1.0)  # zero demand: evict the only instance
    assert plane.instances("chat") == 0
    frontend.pump(budget_s=30.0)
    assert all(r.done for r in reqs), "last-replica eviction dropped work"
    eng = frontend.engines[0]
    assert eng.instances == {} and eng.store.refcount("chat") == 0
    assert frontend.pool.total_used_area() == 0


def test_sim_failure_reinjection_does_not_inflate_observed_rps():
    """Re-queued strays after a node failure are not new arrivals."""
    cluster = Cluster(n_nodes=2, sharing=True)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(make_spec(target_rps=None))
    arrivals = poisson_arrivals("chat", rps=4.0, duration=2.0, seed=7)
    cluster.submit_all(arrivals)
    cluster.run(2.0)
    before = cluster.observed_rps("chat", 2.0)
    victim = next(n.node_id for n in cluster.nodes if n.pods)
    cluster.fail_node(victim)  # re-injects every stranded request
    assert cluster.observed_rps("chat", 2.0) == pytest.approx(before)


# -------------------------------------------------------------------------
# Failed placement: reservations settle, capacity never drifts
# -------------------------------------------------------------------------


def test_failed_placement_aborts_reservation():
    # One node, fat rectangles: only two pods fit, the burst wants five.
    fat = (ProfilePoint(sm=0.45, quota=0.45, throughput=2.0,
                        p99_latency=0.05),)
    cluster = Cluster(n_nodes=1, sharing=True, allow_grow=False)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(make_spec(profile=fat, max_instances=16))
    plane.reconcile(now=3.0)  # demand 13.2 rps -> wants 7 pods, 4 fit
    placed = plane.instances("chat")
    assert placed < 7
    assert plane.capacity("chat") == pytest.approx(2.0 * placed)
    assert plane.queues["chat"].provisional_ids() == set()
    assert len(cluster.pods) == placed


def test_frontend_place_instance_returns_none_when_full(tiny_model,
                                                        tiny_params):
    frontend = ClusterFrontend(n_nodes=1, window=0.1)
    fat = (ProfilePoint(sm=0.6, quota=0.6, throughput=2.0,
                        p99_latency=0.05),)
    plane = ControlPlane(LiveBackend(frontend))
    plane.register(make_spec(model_factory_from(tiny_model, tiny_params),
                             profile=fat, max_instances=16))
    plane.reconcile(now=3.0)  # only ONE 0.6x0.6 rectangle fits per node
    assert plane.instances("chat") == 1
    assert plane.capacity("chat") == pytest.approx(2.0)
    assert plane.queues["chat"].provisional_ids() == set()


def test_frontend_deploy_rollback_on_engine_failure(tiny_model, tiny_params,
                                                    monkeypatch):
    from repro.serving.engine import ServingEngine

    frontend = ClusterFrontend(n_nodes=1, window=0.1)

    def boom(*a, **kw):
        raise RuntimeError("OOM compiling executor")

    monkeypatch.setattr(ServingEngine, "deploy", boom)
    from repro.core.resources import Alloc
    with pytest.raises(RuntimeError, match="OOM"):
        frontend.place_instance(
            "chat", tiny_model, tiny_params,
            Alloc(sm=0.5, quota_request=0.5, quota_limit=0.5))
    # The reserved rectangle and the provisional MemoryModel entry must
    # both be rolled back — a retry later must find a pristine pool.
    assert frontend.pool.total_used_area() == 0
    assert "chat" not in frontend._fn_mm
    assert frontend.placements == []


def test_register_rollback_on_capacity_starved_floor():
    # One node; the 0.45x0.45 rectangle fits at most 4 times: a floor of 9
    # cannot come up, and must leave no partial fleet behind.
    fat = (ProfilePoint(sm=0.45, quota=0.45, throughput=2.0,
                        p99_latency=0.05),)
    cluster = Cluster(n_nodes=1, sharing=True, allow_grow=False)
    plane = ControlPlane(SimBackend(cluster))
    with pytest.raises(RuntimeError, match="min_instances"):
        plane.register(make_spec(profile=fat, min_instances=9,
                                 max_instances=16))
    assert "chat" not in plane.specs
    cluster.run(5.0)  # let evicted bring-up pods tear down
    assert cluster.pods == {}
    # A corrected spec can re-register cleanly afterwards.
    plane.register(make_spec(profile=fat, min_instances=2, max_instances=16))
    assert plane.instances("chat") == 2


def test_reconcile_heals_fleet_back_to_floor():
    cluster = Cluster(n_nodes=2, sharing=True)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(make_spec(min_instances=2, target_rps=ramp([(0.0, 1.0)])))
    assert plane.instances("chat") == 2
    # A node failure kills a pod behind the reconciler's back.
    victim = next(iter(plane.placed["chat"]))
    cluster.retire(victim, drain=False)
    plane.placed["chat"].pop(victim)
    plane.queues["chat"].remove(victim)
    healed = plane.reconcile(now=0.0)
    assert plane.instances("chat") == 2
    assert any(d.direction > 0 for d in healed)


# -------------------------------------------------------------------------
# Spec validation
# -------------------------------------------------------------------------


def test_spec_rejects_empty_profile():
    with pytest.raises(ValueError, match="profile"):
        FunctionSpec(name="f", profile=())


def test_spec_slo_filter_degrades_gracefully():
    slow = (ProfilePoint(sm=0.2, quota=0.5, throughput=3.0, p99_latency=9.9),)
    spec = FunctionSpec(name="f", profile=slow, slo_latency=0.1)
    assert spec.feasible_points() == list(slow)


def test_sim_backend_requires_curve():
    plane = ControlPlane(SimBackend(Cluster(n_nodes=1)))
    with pytest.raises(ValueError, match="ServiceCurve"):
        plane.register(make_spec(curve=None))


def test_live_backend_requires_model_factory():
    plane = ControlPlane(LiveBackend(ClusterFrontend(n_nodes=1)))
    with pytest.raises(ValueError, match="model_factory"):
        plane.register(make_spec())
