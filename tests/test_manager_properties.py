"""Property-based invariants of the FaST-Manager ``TokenScheduler``.

Runs identically under real hypothesis or the deterministic shim in
``conftest.py`` (containers without the package).  Invariants:

1. Σ running SM shares never exceeds ``sm_global_limit`` — for *any*
   limit, not just 1.0.
2. A quota-blocked pod stays blocked for the remainder of its window: no
   grant until the window rolls, however often it asks.
3. Per-window ``busy_union`` (nvidia-smi-style utilization numerator)
   never exceeds the window length.
"""

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.manager import TokenScheduler
from repro.core.resources import Alloc


def alloc(sm, q_req, q_lim=None):
    return Alloc(sm=round(sm, 3), quota_request=round(q_req, 3),
                 quota_limit=round(q_lim if q_lim else q_req, 3))


pods_strategy = st.lists(
    st.tuples(st.floats(0.05, 1.0),    # sm
              st.floats(0.05, 0.6),    # quota_request
              st.floats(0.0, 0.35)),   # quota_limit headroom
    min_size=1, max_size=10)


@settings(max_examples=40, deadline=None)
@given(pods_strategy, st.floats(0.3, 1.0))
def test_sm_running_never_exceeds_global_limit(pods, limit):
    ts = TokenScheduler(window=1.0, sm_global_limit=limit)
    for i, (sm, q, extra) in enumerate(pods):
        ts.register(f"p{i}", alloc(sm, q, min(q + extra, 1.0)))
    now = 0.0
    for _ in range(6):  # several dispatch/complete rounds within a window
        for i in range(len(pods)):
            ts.request_token(f"p{i}", now)
        ts.dispatch(now)
        assert ts.sm_running() <= limit + 1e-9
        for i in range(len(pods)):
            pid = f"p{i}"
            if ts.pods[pid].holding is not None:
                ts.complete(pid, 0.02, now + 0.02)
        now += 0.05
        ts.dispatch(now)
        assert ts.sm_running() <= limit + 1e-9


@settings(max_examples=40, deadline=None)
@given(st.floats(0.1, 0.6), st.integers(1, 4))
def test_quota_blocked_pod_stays_blocked_within_window(q_limit, n_windows):
    """Once a pod exhausts Q_limit it receives NO token until the window
    rolls, no matter how many dispatch rounds it begs through."""
    q_limit = round(q_limit, 2)
    ts = TokenScheduler(window=1.0)
    ts.register("a", alloc(0.3, q_limit))
    now = 0.0
    for w in range(n_windows):
        window_end = (w + 1) * 1.0
        blocked_at = None
        while now < window_end - 1e-9:
            ts.request_token("a", now)
            granted = ts.dispatch(now)
            if granted:
                assert blocked_at is None, (
                    f"grant at {now} after quota block at {blocked_at}")
                # Burn exactly the remaining quota headroom sometimes, or a
                # fixed step — either way Q_used only grows.
                ts.complete("a", 0.15, now)
                if ts.pods["a"].q_remain(1.0) <= 0:
                    blocked_at = now
            now = round(now + 0.1, 10)
        # Window rolled: the pod must be eligible again.
        ts.request_token("a", now)
        assert ts.dispatch(now), f"pod still blocked after window {w} rolled"
        ts.complete("a", 0.05, now)


@settings(max_examples=40, deadline=None)
@given(pods_strategy, st.integers(2, 5))
def test_busy_union_never_exceeds_window(pods, n_windows):
    ts = TokenScheduler(window=1.0)
    for i, (sm, q, extra) in enumerate(pods):
        ts.register(f"p{i}", alloc(sm, q, min(q + extra, 1.0)))
    now = 0.0
    while now < n_windows:
        for i in range(len(pods)):
            ts.request_token(f"p{i}", now)
        ts.dispatch(now)
        for i in range(len(pods)):
            pid = f"p{i}"
            if ts.pods[pid].holding is not None:
                ts.complete(pid, 0.07, min(now + 0.07, float(n_windows)))
        now = round(now + 0.09, 10)
    ts.dispatch(float(n_windows))  # roll the final window
    assert ts.stats_history, "expected completed windows"
    for w in ts.stats_history:
        assert w.busy_union <= ts.window + 1e-9
        assert w.busy_area <= w.busy_time + 1e-9  # occ <= 1 per token


@settings(max_examples=25, deadline=None)
@given(st.floats(0.05, 0.5), st.floats(0.0, 1.0))
def test_complete_occ_override_bounds_busy_area(occ_base, fill):
    """busy_area accrues the *overridden* occupancy (slot-fill scaling)."""
    ts = TokenScheduler(window=1.0)
    ts.register("a", alloc(0.5, 0.9), occupied_sm=occ_base)
    ts.request_token("a", 0.0)
    assert ts.dispatch(0.0)
    ts.complete("a", 0.2, 0.2, occ=occ_base * fill)
    ts.dispatch(1.5)  # roll window
    w = ts.stats_history[0]
    assert abs(w.busy_area - 0.2 * occ_base * fill) < 1e-12
