"""Unit + property tests for the Maximal Rectangles Algorithm (paper Alg. 2)."""

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.maximal_rectangles import (MaxRectsNode, MaxRectsPool,
                                           _prune_contained, _subdivide)
from repro.core.resources import FULL_NODE, SCALE, Alloc, Rect, total_free_area


def alloc(sm=0.24, q=0.4):
    return Alloc(sm=sm, quota_request=q, quota_limit=q)


# -- subdivide -------------------------------------------------------------


def test_subdivide_no_overlap_yields_original():
    r = Rect(0, 0, 100, 100)
    assert _subdivide(r, Rect(200, 200, 10, 10)) == [r]


def test_subdivide_interior_hole_gives_four_maximal():
    r = Rect(0, 0, 100, 100)
    parts = _subdivide(r, Rect(40, 40, 20, 20))
    assert len(parts) == 4
    # Each part is maximal: strips keep full height/width of the parent.
    assert Rect(0, 0, 40, 100) in parts  # left, full height
    assert Rect(60, 0, 40, 100) in parts  # right, full height
    assert Rect(0, 0, 100, 40) in parts  # bottom, full width
    assert Rect(0, 60, 100, 40) in parts  # top, full width
    for p in parts:
        assert not p.intersects(Rect(40, 40, 20, 20))


def test_prune_contained_removes_subsets_keeps_duplicates_once():
    big = Rect(0, 0, 50, 50)
    small = Rect(10, 10, 5, 5)
    assert _prune_contained([big, small, big]) == [big]


# -- node-level placement ----------------------------------------------------


def test_first_placement_bottom_left_and_two_maximal_complements():
    node = MaxRectsNode(0)
    pod = node.place_in(FULL_NODE, "p", 400, 240)
    assert pod == Rect(0, 0, 400, 240)
    assert Rect(400, 0, SCALE - 400, SCALE) in node.free  # right strip
    assert Rect(0, 240, SCALE, SCALE - 240) in node.free  # top strip


def test_free_area_conservation_after_place_and_release():
    node = MaxRectsNode(0)
    r = node.best_fit(300, 300)
    node.place_in(r, "a", 300, 300)
    assert node.free_area() == SCALE * SCALE - 300 * 300
    node.release("a")
    assert node.free_area() == SCALE * SCALE


def test_restructure_triggered_and_preserves_placements():
    node = MaxRectsNode(0, restructure_threshold=3)
    for i in range(4):
        r = node.best_fit(200, 200)
        node.place_in(r, f"p{i}", 200, 200)
    live = dict(node.placements)
    node.release("p1")
    node.release("p2")  # free list growth forces a restructure eventually
    node.restructure()
    assert set(node.placements) == set(live) - {"p1", "p2"}
    # Free rects must not overlap any live pod.
    for fr in node.free:
        for pod in node.placements.values():
            assert not fr.intersects(pod)


# -- pool-level scheduling (Alg. 2 global best matching) ---------------------


def test_best_area_fit_prefers_occupied_node():
    pool = MaxRectsPool(3, allow_grow=False)
    p1 = pool.schedule(alloc(), "p1")
    p2 = pool.schedule(alloc(), "p2")
    # Second pod should co-locate: the split rectangles on node 0 are smaller
    # than a fresh node's full rectangle.
    assert p1.node == p2.node == 0
    assert pool.nodes_in_use() == 1


def test_no_fit_returns_none_without_growth():
    pool = MaxRectsPool(1, allow_grow=False)
    assert pool.schedule(Alloc(sm=1.0, quota_request=1.0, quota_limit=1.0),
                         "big") is not None
    assert pool.schedule(alloc(), "overflow") is None


def test_growth_adds_node_when_needed():
    pool = MaxRectsPool(1, allow_grow=True)
    pool.schedule(Alloc(sm=1.0, quota_request=1.0, quota_limit=1.0), "big")
    p = pool.schedule(alloc(), "next")
    assert p is not None and p.node == 1


def test_paper_fig11_packing_single_node():
    """§5.4: 4 resnet (12%,40%) + 2 rnnt (24%,40%) + 2 bert (50%,60%) pods
    fit on ONE node under MRA, versus 4 nodes with whole-GPU time sharing."""
    pool = MaxRectsPool(4, allow_grow=False)
    pods = (
        [("resnet", Alloc(0.12, 0.4, 0.4))] * 4
        + [("rnnt", Alloc(0.24, 0.4, 0.4))] * 2
        + [("bert", Alloc(0.5, 0.6, 0.6))] * 2
    )
    placements = pool.schedule_batch(
        [(a, f"{fn}-{i}") for i, (fn, a) in enumerate(pods)])
    assert all(p is not None for p in placements)
    # Σ secondCores = 4*.048 + 2*.096 + 2*.3 = 0.984 <= 1.0: packable, and
    # MRA must actually achieve it (time sharing would need 4 nodes).
    assert pool.nodes_in_use() == 1


# -- property tests -----------------------------------------------------------


@st.composite
def placement_sequences(draw):
    n_ops = draw(st.integers(2, 24))
    ops = []
    for i in range(n_ops):
        w = draw(st.integers(1, 20)) * 50  # 5%..100% in 5% steps
        h = draw(st.integers(1, 20)) * 50
        release_idx = draw(st.integers(-1, max(0, len(ops) - 1)))
        ops.append((w, h, release_idx))
    return ops


@settings(max_examples=60, deadline=None)
@given(placement_sequences())
def test_invariants_under_random_place_release(ops):
    node = MaxRectsNode(0, restructure_threshold=12)
    placed: list[str] = []
    for i, (w, h, rel) in enumerate(ops):
        if rel >= 0 and placed:
            victim = placed[rel % len(placed)]
            node.release(victim)
            placed.remove(victim)
        r = node.best_fit(w, h)
        if r is not None:
            node.place_in(r, f"p{i}", w, h)
            placed.append(f"p{i}")
        # Invariant 1: no free rectangle overlaps any placed pod.
        for fr in node.free:
            for pod_id in placed:
                assert not fr.intersects(node.placements[pod_id]), (
                    fr, node.placements[pod_id])
        # Invariant 2: placed pods are mutually disjoint.
        rects = [node.placements[p] for p in placed]
        for a in range(len(rects)):
            for b in range(a + 1, len(rects)):
                assert not rects[a].intersects(rects[b])
        # Invariant 3: free area + used area == total capacity.
        assert node.free_area() + node.used_area() == SCALE * SCALE
        # Invariant 4: everything stays in bounds.
        for fr in node.free + rects:
            assert 0 <= fr.x <= fr.x2 <= SCALE
            assert 0 <= fr.y <= fr.y2 <= SCALE


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 10), st.integers(1, 10)),
                min_size=1, max_size=30))
def test_pool_never_loses_capacity(sizes):
    pool = MaxRectsPool(2, allow_grow=False)
    placements = []
    for i, (wi, hi) in enumerate(sizes):
        a = Alloc(sm=hi / 10, quota_request=wi / 10, quota_limit=wi / 10)
        p = pool.schedule(a, f"p{i}")
        if p is not None:
            placements.append(p)
    for p in placements:
        pool.release(p)
    # After releasing everything, the exact free area must be fully restored
    # (keep-restructure keeps fragments verbatim), and a restructure must
    # re-coalesce each node into its single W x H rectangle.
    for node in pool.nodes:
        assert node.free_area() == SCALE * SCALE
        node.restructure()
        assert node.free == [FULL_NODE]
        assert node.best_fit(SCALE, SCALE) is not None
