"""Property-based fuzz of the refcounted prefix-sharing KV page plane.

Each property drives a random interleaving of the operations the engine
performs on ``KVPageAllocator`` + ``PageTable`` — admit (with content-hash
prefix matching and tail COW-spare reservation), decode append (through
``writable_block``, the single COW enforcement point), speculative
draft/verify rounds (window write + acceptance rollback, mirroring the
engine's ``_cow_round`` span of ``1 + k``), release, migrate
(import-then-release with full-block re-sharing, mirroring
``import_slot``), and defrag — against a pure-python mirror of what the
device would hold: per-block token contents and per-sequence token
histories.  The invariants checked after EVERY operation:

* a block's refcount equals the number of page-table rows mapping it
  (COW spares are refcount-1 blocks mapped by no row, tracked apart);
* ``blocks_in_use + free == capacity`` — no block is ever both live and
  free, none vanishes;
* while a mutable tail block is shared, ``spares[b]`` holds exactly
  ``refcount(b) - 1`` reserved blocks;
* the content registry only names live blocks;
* every sequence's tokens reconstruct bit-identically from its mapped
  blocks (shared blocks are never mutated — a divergent write would
  corrupt another sequence's history and fail this check).

Separate properties pin the failure modes: double/foreign frees are
rejected atomically (no partial state change), and a write aimed at a
refcount>1 block without a reserved spare raises instead of corrupting.

Runs under real hypothesis or the deterministic conftest shim; either
way ``--repro-seed`` replays a failing interleaving exactly.
"""

from __future__ import annotations

from collections import Counter

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.paging import (NULL_BLOCK, BlockExhausted,
                                  KVPageAllocator, PageTable, blocks_needed,
                                  prompt_digests)

BS = 4          # small blocks so partial tails and multi-block prompts
BYTES = 64      # are both common in short random prompts

# Three prompt families over a tiny alphabet: random prompts are prefixes
# of these (plus an optional unique suffix token), so independent draws
# collide often enough to exercise full-block AND exact-prompt sharing.
BASE = (tuple([1] * 16), tuple([2] * 16), tuple(range(16)))


class Driver:
    """Engine-shaped harness over one allocator + page table.

    Mirrors device state in ``content`` (block -> offset -> token) and
    request state in ``model`` (seq -> prompt/tokens/budget), applying
    the same admission, write, and migration rules as
    ``FunctionInstance`` so the bookkeeping invariants are tested under
    realistic interleavings.
    """

    def __init__(self, n_blocks: int):
        self.alloc = KVPageAllocator(n_blocks, BS, block_bytes=BYTES)
        self.pt = PageTable(self.alloc)
        self.content: dict[int, dict[int, int]] = {}
        self.model: dict[int, dict] = {}
        self.next_id = 0

    # -- operations ---------------------------------------------------------

    def admit(self, prompt, max_new: int):
        rows = len(prompt) + max_new
        full, tail = prompt_digests(prompt, BS)
        shared, tail_block = self.pt.match_prefix(full, tail)
        tail_shared = tail_block is not None
        shared_all = shared + ([tail_block] if tail_shared else [])
        before = (self.alloc.blocks_in_use, self.alloc.free_blocks())
        try:
            self.pt.allocate_shared(self.next_id, rows, shared_all,
                                    tail_shared=tail_shared)
        except BlockExhausted:
            # a rejected admission must not have touched the pool
            assert (self.alloc.blocks_in_use,
                    self.alloc.free_blocks()) == before
            return None
        seq = self.next_id
        self.next_id += 1
        self.pt.register_prefix(seq, full, tail)
        row = self.pt.blocks(seq)
        for b in row[len(shared_all):]:     # fresh private blocks: stale
            self.content[b] = {}            # reuse must not leak old rows
        n_shared_rows = len(prompt) if tail_shared else len(shared) * BS
        for pos, tok in enumerate(prompt):
            b = row[pos // BS]
            if pos < n_shared_rows:
                # drop-sentinel semantics: shared rows are never written;
                # the resident content must already be bit-identical
                assert self.content[b][pos % BS] == tok
            else:
                self.content[b][pos % BS] = tok
        self.model[seq] = dict(prompt=list(prompt), tokens=list(prompt),
                               budget=max_new, rows=rows)
        return seq

    def decode(self, seq: int) -> None:
        m = self.model[seq]
        if m["budget"] == 0:
            return
        pos = len(m["tokens"])
        tok = (pos * 7 + seq) % 64
        block, move = self.pt.writable_block(seq, pos)
        assert self.alloc.refcount(block) == 1, \
            "writable_block handed out a still-shared block"
        if move is not None:
            old, new = move
            assert new == block
            self.content[new] = dict(self.content.get(old, {}))
        self.content.setdefault(block, {})[pos % BS] = tok
        m["tokens"].append(tok)
        m["budget"] -= 1

    def speculate(self, seq: int, k: int, n_accept: int) -> None:
        """One speculative draft-k/verify-1 round mirrored at the
        page-table level: the whole window ``pos..pos+k`` is COW-resolved
        through ``writable_block`` BEFORE any row lands (the engine's
        ``_cow_round`` span is ``1 + k`` when speculating), then only the
        accepted prefix advances the history — a rejection is a position
        rollback, never a free and never a write to a still-shared block.
        The rejected tail rows stay in the sequence's private blocks and
        are overwritten by the next round before anything attends them.
        """
        m = self.model[seq]
        k = min(k, m["budget"] - 1, m["rows"] - len(m["tokens"]) - 1)
        if k < 1:
            return
        n_accept = min(n_accept, k)
        frees_before = self.alloc.n_frees
        pos0 = len(m["tokens"])
        toks = [(p * 11 + seq) % 64 for p in range(pos0, pos0 + k + 1)]
        for i, tok in enumerate(toks):
            pos = pos0 + i
            block, move = self.pt.writable_block(seq, pos)
            assert self.alloc.refcount(block) == 1, \
                "speculative window write aimed at a still-shared block"
            if move is not None:
                old, new = move
                assert new == block
                self.content[new] = dict(self.content.get(old, {}))
            self.content.setdefault(block, {})[pos % BS] = tok
        emitted = toks[:n_accept + 1]
        m["tokens"].extend(emitted)
        m["budget"] -= len(emitted)
        assert self.alloc.n_frees == frees_before, (
            "speculative rollback freed a block (rollback must be a "
            "position trim only)")

    def release(self, seq: int) -> None:
        self.pt.release(seq)
        del self.model[seq]

    def migrate(self, seq: int):
        """Import-then-release, like a live KV move: the target maps the
        source's FULL prompt blocks (tail holds decode rows in the
        gathered entry, so it stays private) and rewrites the rest."""
        m = self.model[seq]
        full, _ = prompt_digests(m["prompt"], BS)
        shared, _ = self.pt.match_prefix(full, None)
        try:
            self.pt.allocate_shared(self.next_id, m["rows"], shared)
        except BlockExhausted:
            return None                       # no room to land the import
        new_seq = self.next_id
        self.next_id += 1
        self.pt.register_prefix(new_seq, full, None)
        row = self.pt.blocks(new_seq)
        for b in row[len(shared):]:
            self.content[b] = {}
        n_shared_rows = len(shared) * BS
        for pos, tok in enumerate(m["tokens"]):
            b = row[pos // BS]
            if pos < n_shared_rows:
                assert self.content[b][pos % BS] == tok
            else:
                self.content[b][pos % BS] = tok
        self.model[new_seq] = dict(prompt=list(m["prompt"]),
                                   tokens=list(m["tokens"]),
                                   budget=m["budget"], rows=m["rows"])
        self.release(seq)                     # source side drops its refs
        return new_seq

    # -- invariants ---------------------------------------------------------

    def check_refcounts(self) -> None:
        counts: Counter[int] = Counter()
        for row in self.pt.seqs.values():
            for b in row:
                counts[b] += 1
        spare_blocks = [b for lst in self.pt.spares.values() for b in lst]
        assert len(spare_blocks) == len(set(spare_blocks))
        for b in spare_blocks:
            # a reserved spare is allocated, exclusive, and mapped nowhere
            assert self.alloc.refcount(b) == 1 and counts[b] == 0
        for b, r in list(self.alloc._ref.items()):
            expect = 1 if b in spare_blocks else counts[b]
            assert r == expect, (
                f"block {b}: refcount {r} != {expect} page-table rows")
        for b, lst in self.pt.spares.items():
            assert len(lst) == self.alloc.refcount(b) - 1, (
                f"tail block {b}: {len(lst)} spares for refcount "
                f"{self.alloc.refcount(b)}")
        assert (self.alloc.blocks_in_use + self.alloc.free_blocks()
                == self.alloc.capacity)
        free = self.alloc._free
        assert len(free) == len(set(free)) and NULL_BLOCK not in free
        for b in self.alloc._digest_to_block.values():
            assert self.alloc.refcount(b) > 0
        assert self.pt.saved_blocks() >= 0

    def check_tokens(self) -> None:
        for seq, m in self.model.items():
            row = self.pt.blocks(seq)
            got = [self.content[row[p // BS]].get(p % BS)
                   for p in range(len(m["tokens"]))]
            assert got == m["tokens"], (
                f"seq {seq} history diverged (a shared block was mutated)")


def _prompt(a: int, b: int):
    fam = BASE[a % len(BASE)]
    prompt = list(fam[:1 + b % 8])
    if a % 2:
        prompt.append(32 + a % 8)             # unique-ish divergent suffix
    return prompt


def _run(n_blocks: int, ops, *, check_each: bool = True) -> Driver:
    d = Driver(n_blocks)
    for kind, a, b in ops:
        live = sorted(d.model)
        if kind in (0, 1):                    # admit (double weight)
            d.admit(_prompt(a, b), max_new=1 + a % 4)
        elif kind == 2 and live:
            d.decode(live[a % len(live)])
        elif kind == 3 and live:
            d.release(live[a % len(live)])
        elif kind == 4 and live:
            d.migrate(live[a % len(live)])
        elif kind == 5:
            d.alloc.defrag()
        elif kind == 6 and live:
            seq = live[a % len(live)]
            k = 1 + b % 4
            d.speculate(seq, k, n_accept=a % (k + 1))
        if check_each:
            d.check_refcounts()
            d.check_tokens()
    return d


ops_st = st.lists(st.tuples(st.integers(0, 6), st.integers(0, 31),
                            st.integers(0, 31)),
                  min_size=1, max_size=40)


@settings(max_examples=200, deadline=None)
@given(st.integers(8, 28), ops_st)
def test_refcount_equals_mapping_rows(n_blocks, ops):
    """After every op: refcount == rows mapping the block, spares are
    exclusive and rowless, pool conservation holds."""
    _run(n_blocks, ops)


@settings(max_examples=200, deadline=None)
@given(st.integers(8, 28), ops_st)
def test_no_leaks_at_quiesce(n_blocks, ops):
    """Releasing every live sequence returns the pool to pristine: zero
    blocks in use, full free list, empty registry, no orphaned spares."""
    d = _run(n_blocks, ops, check_each=False)
    d.check_refcounts()
    for seq in sorted(d.model):
        d.release(seq)
    assert d.alloc.blocks_in_use == 0
    assert d.alloc.free_blocks() == d.alloc.capacity
    assert set(d.alloc._free) == set(range(1, n_blocks))
    assert d.alloc.registered_blocks == 0
    assert d.pt.n_spares == 0 and not d.pt.spares
    assert d.alloc.bytes_in_use == 0
    # alloc/free ledger balances: every physical alloc was physically freed
    assert d.alloc.n_allocs == d.alloc.n_frees


@settings(max_examples=200, deadline=None)
@given(st.integers(8, 28), ops_st, st.integers(0, 31))
def test_double_free_rejected_atomically(n_blocks, ops, pick):
    """Freeing a dead block, a foreign block, or the same block twice in
    one call raises — and a rejected free changes nothing."""
    d = _run(n_blocks, ops, check_each=False)
    snap = (dict(d.alloc._ref), list(d.alloc._free), d.alloc.n_frees)

    def unchanged():
        return (dict(d.alloc._ref), list(d.alloc._free),
                d.alloc.n_frees) == snap

    with pytest.raises(ValueError):
        d.alloc.free([NULL_BLOCK])            # never allocatable
    assert unchanged()
    if d.model:
        seq = sorted(d.model)[pick % len(d.model)]
        row = list(d.pt.blocks(seq))
        b = row[pick % len(row)]
        with pytest.raises(ValueError):
            d.alloc.free([b, b])              # duplicate within one call
        assert unchanged()
        d.release(seq)
        if d.alloc.refcount(b) == 0:          # physically freed: dead now
            with pytest.raises(ValueError):
                d.alloc.free([b])
    d.check_refcounts()


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 12), st.integers(1, 4), st.booleans())
def test_shared_block_write_impossible(plen, max_new, exact):
    """A write aimed at a refcount>1 block either COW-resolves through a
    reserved spare (exact-prompt tail share) or raises (full shared block
    / no spare) — it can never land in shared memory."""
    d = Driver(32)
    prompt = list(BASE[2][:plen])
    s1 = d.admit(prompt, max_new)
    p2 = list(prompt) if exact else prompt + [40]
    s2 = d.admit(p2, max_new)
    row2 = d.pt.blocks(s2)
    shared = [b for b in row2 if d.alloc.refcount(b) > 1]
    for b in shared:
        pos = row2.index(b) * BS
        if b in d.pt.spares:
            continue                          # tail share: COW path below
        with pytest.raises(RuntimeError):
            d.pt.writable_block(s2, pos)
        assert d.alloc.refcount(b) > 1        # refused, nothing changed
    # exact-match tail share: the divergent append must COW, not corrupt
    if exact and plen % BS:
        t1_before = list(d.model[s1]["tokens"])
        for _ in range(max_new):
            d.decode(s2)
        assert d.model[s1]["tokens"] == t1_before
        d.check_tokens()
    d.check_refcounts()
    # an artificially shared block with NO spare must refuse the write
    s3 = d.admit([50, 51, 52, 53, 54], 1)
    b3 = d.pt.blocks(s3)[0]
    d.alloc.incref(b3)
    with pytest.raises(RuntimeError):
        d.pt.writable_block(s3, 0)
    d.alloc.free([b3])                        # drop the artificial ref


@settings(max_examples=200, deadline=None)
@given(st.integers(8, 28), ops_st)
def test_histories_reconstruct_bit_identically(n_blocks, ops):
    """Every live sequence's token history reconstructs exactly from its
    mapped blocks after every op — shared blocks are never mutated, COW
    copies preserve content, migration re-lands every row."""
    d = _run(n_blocks, ops)                   # check_tokens runs per-op
    d.check_tokens()


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 12), st.integers(1, 4), st.integers(0, 4))
def test_speculative_rollback_never_frees_or_corrupts(plen, k, n_accept):
    """Speculative rejection on a prefix-shared pair: the window write
    COW-resolves first, the rollback frees nothing, and the sibling's
    shared history stays bit-identical."""
    d = Driver(32)
    prompt = list(BASE[2][:plen])
    d.admit(prompt, 8)
    s2 = d.admit(list(prompt), 8)
    frees = d.alloc.n_frees
    d.speculate(s2, k, n_accept)
    assert d.alloc.n_frees == frees, "rollback must not free blocks"
    d.check_refcounts()
    d.check_tokens()


def test_saved_blocks_accounting():
    """Sharing telemetry: extra_refs minus reserved spares, bytes forms
    consistent with block forms at the configured block_bytes."""
    d = Driver(32)
    prompt = list(BASE[0][:10])               # 2 full blocks + tail of 2
    d.admit(prompt, 2)
    d.admit(list(prompt), 2)                  # exact match: 2 full + tail
    # 3 extra refs (2 full + tail), 1 spare reserved -> 2 blocks saved
    assert d.alloc.extra_refs == 3
    assert d.pt.n_spares == 1
    assert d.pt.saved_blocks() == 2
    assert d.pt.bytes_saved(BYTES) == 2 * BYTES
    assert d.pt.bytes_in_use(BYTES) == d.alloc.blocks_in_use * BYTES
    assert d.alloc.stats()["extra_refs"] == 3
