"""Seasonal demand auto-tuning (satellite of the sharded-pod PR):
``autocorr_season`` finds the period of a diurnal trace from its
autocorrelation peaks, and ``fit_holt_winters`` grid-searches the
Holt-Winters smoothing parameters to beat untuned defaults on the same
trace — no hand-picked alpha/beta/gamma/season in operator configs.
"""

import math

import numpy as np
import pytest

from repro.control import (HoltWintersDemand, autocorr_season,
                           fit_holt_winters)
from repro.core.workload import diurnal_trace


def diurnal_series(base=2.0, peak=10.0, period=600.0, duration=1800.0,
                   step=10.0, noise=0.0, seed=0):
    """Observed-RPS samples of a sinusoidal day/night trace (one per
    reconciler tick), optionally with Poisson-ish observation noise."""
    xs = [rps for _, rps in diurnal_trace(base, peak, period, duration,
                                          step=step)]
    if noise:
        rng = np.random.default_rng(seed)
        xs = [max(x + rng.normal(0.0, noise), 0.0) for x in xs]
    return xs


def one_step_errors(forecaster, xs, warmup):
    err = 0.0
    for t, v in enumerate(xs):
        if t >= warmup:
            err += (forecaster(float(t)) - v) ** 2
        forecaster.observe(float(t), v)
    return err


def test_autocorr_finds_diurnal_period():
    # period=600s at 10s ticks -> season of ~60 ticks (the finite-sample
    # ACF estimator can land one lag off the true period).
    xs = diurnal_series()
    season = autocorr_season(xs)
    assert season is not None and abs(season - 60) <= 1, season


def test_autocorr_robust_to_noise():
    xs = diurnal_series(noise=0.5, seed=3)
    season = autocorr_season(xs)
    assert season is not None and abs(season - 60) <= 2


def test_autocorr_rejects_flat_and_trending_traffic():
    assert autocorr_season([5.0] * 100) is None  # zero variance
    assert autocorr_season(list(range(100))) is None  # monotone ramp
    assert autocorr_season([1.0, 2.0, 3.0]) is None  # too short


def test_fit_returns_fresh_seasonal_forecaster():
    xs = diurnal_series()
    hw = fit_holt_winters(xs)
    assert isinstance(hw, HoltWintersDemand)
    assert hw.season is not None and abs(hw.season - 60) <= 1
    assert hw.level is None  # unfed: ready for live observations
    for v in (hw.alpha, hw.beta, hw.gamma):
        assert 0.0 < v <= 1.0


def test_fit_beats_untuned_defaults_on_diurnal_trace():
    xs = diurnal_series(noise=0.3, seed=7)
    tuned = fit_holt_winters(xs)
    default = HoltWintersDemand()  # alpha=.5 beta=.3 gamma=.2, no season
    warmup = tuned.season or 1
    e_tuned = one_step_errors(tuned, xs, warmup)
    e_default = one_step_errors(default, xs, warmup)
    assert e_tuned < e_default, (e_tuned, e_default)


def test_fit_without_season_skips_gamma_axis():
    # Non-seasonal series: season detection yields None and gamma is
    # inert, so the fit still returns a valid level+trend forecaster.
    xs = [1.0 + 0.1 * t for t in range(40)]
    hw = fit_holt_winters(xs)
    assert hw.season is None
    # A forced season is honored as-is.
    hw = fit_holt_winters(diurnal_series(), season=30)
    assert hw.season == 30
    with pytest.raises(TypeError):
        fit_holt_winters(xs, season=12.5)


def test_fit_handles_short_grid():
    hw = fit_holt_winters(diurnal_series(duration=900.0),
                          grid=(0.3, 0.8))
    assert hw.alpha in (0.3, 0.8)
