"""Divisibility fallbacks of the logical sharding rules (satellite of the
sharded-pod PR): the dormant paths ``resolve_pspec`` / ``cache_pspec``
take when a dimension does NOT divide the mesh — head replication
(qwen2-style 28 heads vs model=16), GQA kv < TP, batch=1 context
parallelism — plus multi-axis ``used`` exclusivity, all under the forced
4-host-device mesh conftest sets up.
"""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import (SERVE_AXIS, cache_pspec,
                                        resolve_pspec, serve_pspec, tp_mesh)

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs 4 (forced host) devices")


def mesh_of(*axes: tuple) -> Mesh:
    """Mesh over the 4 forced host devices with the given (name, size)."""
    sizes = [s for _, s in axes]
    devs = np.asarray(jax.devices()[: int(np.prod(sizes))]).reshape(sizes)
    return Mesh(devs, tuple(n for n, _ in axes))


# -------------------------------------------------------------------------
# resolve_pspec divisibility fallbacks
# -------------------------------------------------------------------------


def test_non_divisible_heads_replicate_while_d_ff_shards():
    # qwen2-7b's situation scaled to this mesh: 7 heads on model=4 is the
    # same non-divisibility as 28 heads on model=16 — heads stay
    # replicated over TP while d_ff / vocab still shard.
    mesh = mesh_of(("model", 4))
    spec = resolve_pspec(("batch", "seq", "heads", None),
                         (2, 16, 7, 8), mesh)
    assert spec == P(None, None, None, None)
    spec = resolve_pspec(("d_ff",), (64,), mesh)
    assert spec == P("model")
    spec = resolve_pspec(("vocab", "d_model"), (128, 30), mesh)
    assert spec == P("model", None)


def test_divisible_heads_do_shard():
    mesh = mesh_of(("model", 4))
    assert resolve_pspec(("heads",), (8,), mesh) == P("model")


def test_gqa_kv_heads_below_tp_replicate():
    # kv_heads=2 < model=4: the standard GQA fallback — kv tensors
    # replicate over TP instead of splitting a head in half.
    mesh = mesh_of(("model", 4))
    assert resolve_pspec(("kv_heads",), (2,), mesh) == P(None)
    assert resolve_pspec(("kv_heads",), (4,), mesh) == P("model")


def test_multi_axis_used_exclusivity():
    # One dim takes BOTH preferred axes; a later dim with the same
    # preference list must not reuse them (a mesh axis shards exactly one
    # dim of a tensor).
    mesh = mesh_of(("model", 2), (SERVE_AXIS, 2))
    spec = resolve_pspec(("heads", "kv_heads"), (8, 8), mesh)
    assert spec == P(("model", SERVE_AXIS), None)
    # And partially: heads fits only the first axis, kv takes the second.
    spec = resolve_pspec(("heads", "kv_heads"), (2, 2), mesh)
    assert spec == P("model", SERVE_AXIS)


# -------------------------------------------------------------------------
# cache_pspec: every mesh axis must shard *something*
# -------------------------------------------------------------------------


def test_cache_pspec_batch1_context_parallel():
    # batch=1 (long_500k): the (pod,) axis moves from batch to seq —
    # context parallelism — instead of replicating the cache.
    mesh = mesh_of(("pod", 4))
    shape = (2, 1, 16, 4, 8)  # (layers, batch, seq, kv_heads, head_dim)
    spec = cache_pspec(shape, mesh)
    assert spec == P(None, None, "pod", None, None)
    # Divisible batch keeps the straight assignment.
    spec = cache_pspec((2, 4, 16, 4, 8), mesh)
    assert spec == P(None, "pod", None, None, None)


def test_cache_pspec_kv_below_tp_moves_model_to_seq():
    # GQA kv_heads=2 < model=4: model also moves to seq (flash-decoding
    # style sequence-sharded attention with a softmax combine).
    mesh = mesh_of(("model", 4))
    spec = cache_pspec((2, 4, 16, 2, 8), mesh)
    assert spec == P(None, None, "model", None, None)


def test_cache_pspec_seq_not_divisible_gives_up():
    # Fallback-of-the-fallback: seq can't absorb the axes either ->
    # plain resolve_pspec result (replicated kv, unsharded seq).
    mesh = mesh_of(("model", 4))
    spec = cache_pspec((2, 4, 6, 2, 8), mesh)
    assert spec == P(None, None, None, None, None)


# -------------------------------------------------------------------------
# serve_pspec: column-only exact TP
# -------------------------------------------------------------------------


def test_serve_pspec_shards_output_dims_only():
    mesh = tp_mesh(4)
    # Column-parallel projection: trailing "tp" shards.
    assert serve_pspec(("d_model", "tp"), (32, 64), mesh) == \
        P(None, SERVE_AXIS)
    # Row-parallel projection (wo / w_down): leading "tp" replicates —
    # the contraction must run fully on-device for exactness.
    assert serve_pspec(("tp", "d_model"), (64, 32), mesh) == P(None, None)
    # Vocab shards wherever it appears (embedding + lm head).
    assert serve_pspec(("vocab", "d_model"), (64, 32), mesh) == \
        P(SERVE_AXIS, None)
    # Non-divisible output dim: replicated, not an error.
    assert serve_pspec(("d_model", "tp"), (32, 30), mesh) == P(None, None)


def test_tp_mesh_shards1_is_none():
    assert tp_mesh(1) is None
    with pytest.raises(ValueError):
        tp_mesh(0)
    with pytest.raises(ValueError):
        tp_mesh(64)  # more shards than devices
