"""Shared test infrastructure.

Two jobs:

* **Hypothesis fallback** — property tests (`tests/test_manager.py`,
  `test_scaling.py`, `test_maximal_rectangles.py`, ...) are written against
  the real ``hypothesis`` API.  On containers without it, a minimal
  deterministic shim is installed into ``sys.modules`` *before* collection:
  each ``@given`` test runs ``max_examples`` seeded-random draws.  The shim
  covers only the strategy surface this repo uses (integers, floats,
  booleans, sampled_from, lists, tuples, composite); it does no shrinking,
  but failures reproduce exactly because every draw is seeded from the test
  name and example index.
* **Tiny model fixtures** — deterministic, CPU-cheap model configs
  (vocab 64, d_model 32) used by tier-1 serving/engine tests so one jit
  compile costs milliseconds, not minutes.
* **One seed to replay them all** — ``--repro-seed N`` (default 0) feeds
  every random source the suite owns: the shim's per-example draws, the
  real hypothesis profile (registered derandomized, so failures replay
  without a database), and the ``repro_rng`` fixture that seeds the
  random workload generators.  A tier-1 failure reproduces with the same
  ``--repro-seed`` it failed under.
"""

from __future__ import annotations

import functools
import os
import sys
import types
import zlib

import pytest

# Forced host devices: the sharded-pod / tensor-parallel tests build meshes
# over XLA host platform devices, which must exist before jax initializes.
# Appended (not overwritten) so an explicit user topology wins.
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4").strip()

# --------------------------------------------------------------------------
# Hypothesis shim (installed only when the real package is absent)
# --------------------------------------------------------------------------


def _install_hypothesis_shim() -> None:
    import numpy as np

    class Strategy:
        """A sampler: ``example(rng) -> value``."""

        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

        def map(self, fn):
            return Strategy(lambda rng: fn(self._sample(rng)))

        def filter(self, pred, _tries: int = 100):
            def sample(rng):
                for _ in range(_tries):
                    v = self._sample(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate too strict for shim")
            return Strategy(sample)

    def integers(min_value, max_value):
        return Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def floats(min_value, max_value, **_kw):
        return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def booleans():
        return Strategy(lambda rng: bool(rng.integers(0, 2)))

    def sampled_from(elements):
        seq = list(elements)
        return Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    def just(value):
        return Strategy(lambda rng: value)

    def lists(elements, *, min_size=0, max_size=None, **_kw):
        hi = max_size if max_size is not None else min_size + 10
        return Strategy(lambda rng: [
            elements.example(rng)
            for _ in range(int(rng.integers(min_size, hi + 1)))
        ])

    def tuples(*strategies):
        return Strategy(lambda rng: tuple(s.example(rng)
                                          for s in strategies))

    def one_of(*strategies):
        return Strategy(lambda rng: strategies[
            int(rng.integers(0, len(strategies)))].example(rng))

    def composite(fn):
        @functools.wraps(fn)
        def builder(*args, **kwargs):
            return Strategy(
                lambda rng: fn(lambda s: s.example(rng), *args, **kwargs))
        return builder

    def _seed(name: str, example: int) -> int:
        # REPRO_SEED is the module global set by --repro-seed; read at
        # call time so the option (parsed after this shim installs) wins.
        return zlib.crc32(f"{REPRO_SEED}:{name}:{example}".encode())

    def given(*strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples", 20)
                for i in range(n):
                    rng = np.random.default_rng(_seed(fn.__name__, i))
                    drawn = [s.example(rng) for s in strategies]
                    kw = {k: s.example(rng)
                          for k, s in kw_strategies.items()}
                    try:
                        fn(*args, *drawn, **kwargs, **kw)
                    except _ShimAssume:
                        continue  # assume() rejected this example
                    except Exception as e:
                        raise AssertionError(
                            f"property falsified on example {i} "
                            f"(seeded, reproducible): args={drawn!r} "
                            f"kwargs={kw!r}") from e
                wrapper.hypothesis_ran = n
            wrapper._shim_max_examples = 20
            wrapper.is_hypothesis_test = True
            # Strategy-supplied params must not look like pytest fixtures:
            # positional strategies fill the rightmost params, kw strategies
            # their named ones; anything left over (e.g. fixtures) stays.
            import inspect

            params = list(inspect.signature(fn).parameters.values())
            if strategies:
                params = params[:-len(strategies)]
            params = [p for p in params if p.name not in kw_strategies]
            wrapper.__signature__ = inspect.Signature(params)
            del wrapper.__wrapped__
            return wrapper
        return deco

    def settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._shim_max_examples = max_examples
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = lambda cond: None if cond else (_ for _ in ()).throw(
        _ShimAssume())
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)
    hyp.__is_repro_shim__ = True

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    st.just = just
    st.lists = lists
    st.tuples = tuples
    st.one_of = one_of
    st.composite = composite
    hyp.strategies = st

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


class _ShimAssume(Exception):
    pass


try:  # pragma: no cover - depends on container contents
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _install_hypothesis_shim()


# --------------------------------------------------------------------------
# One seed for every random source (--repro-seed)
# --------------------------------------------------------------------------

REPRO_SEED = 0


def pytest_addoption(parser):
    parser.addoption(
        "--repro-seed", action="store", type=int, default=0,
        help="Seed for the hypothesis shim, hypothesis profile, and the "
             "repro_rng workload-generator fixture (deterministic replay)")


def pytest_configure(config):
    global REPRO_SEED
    REPRO_SEED = int(config.getoption("--repro-seed"))
    hyp = sys.modules.get("hypothesis")
    if hyp is not None and not getattr(hyp, "__is_repro_shim__", False):
        # Real hypothesis: pin a derandomized profile so tier-1 runs are
        # reproducible without an example database; the seed feeds the
        # shim and repro_rng (hypothesis derives its own from the test).
        hyp.settings.register_profile(
            "repro", hyp.settings(derandomize=True, print_blob=True))
        hyp.settings.load_profile("repro")


@pytest.fixture
def repro_seed(request) -> int:
    """The suite-wide ``--repro-seed`` value."""
    return REPRO_SEED


@pytest.fixture
def repro_rng(request):
    """Per-test numpy Generator derived from ``--repro-seed`` and the
    test's node id — every random workload generator seeds from this so
    one command-line flag replays a failure exactly."""
    import numpy as np

    return np.random.default_rng(
        zlib.crc32(f"{REPRO_SEED}:{request.node.nodeid}".encode()))


# --------------------------------------------------------------------------
# Tiny deterministic model fixtures (tier-1 speed)
# --------------------------------------------------------------------------

TINY_VOCAB = 64
TINY_SEED = 1234


def tiny_config(**overrides):
    """Dense config small enough that jit compiles in milliseconds."""
    from repro.models.config import ModelConfig

    base = dict(
        name="tiny-dense",
        family="dense",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=TINY_VOCAB,
        vocab_pad_multiple=32,
        rope_theta=10_000.0,
    )
    base.update(overrides)
    return ModelConfig(**base)


@pytest.fixture(scope="session")
def tiny_model():
    from repro.models import build_model

    return build_model(tiny_config())


@pytest.fixture(scope="session")
def tiny_params(tiny_model):
    import jax

    return tiny_model.init(jax.random.key(TINY_SEED))
