"""Integration tests: the full FaST-GShare control plane in simulation."""

import pytest

from repro.core import (Cluster, PAPER_ZOO, ProfilePoint, poisson_arrivals,
                        simulate_trial)


def resnet_point(sm=0.24, quota=1.0):
    c = PAPER_ZOO["resnet"]
    return ProfilePoint(sm=sm, quota=quota, throughput=c.rate(sm, quota))


def test_single_pod_throughput_matches_service_curve():
    c = PAPER_ZOO["resnet"]
    tr = simulate_trial(c, sm=0.12, quota=0.6, duration=20.0)
    assert tr.throughput == pytest.approx(c.rate(0.12, 0.6), rel=0.1)


def test_temporal_throughput_proportionality():
    """Paper §5.2: throughput scales ~proportionally with time quota."""
    c = PAPER_ZOO["rnnt"]
    t40 = simulate_trial(c, sm=0.24, quota=0.4, duration=20.0).throughput
    t80 = simulate_trial(c, sm=0.24, quota=0.8, duration=20.0).throughput
    assert t80 / t40 == pytest.approx(2.0, rel=0.15)


def test_spatial_saturation():
    """Paper §5.2: beyond sm_sat, more SMs give no extra throughput."""
    c = PAPER_ZOO["resnet"]  # saturates at 24%
    t24 = simulate_trial(c, sm=0.24, quota=1.0, duration=20.0).throughput
    t50 = simulate_trial(c, sm=0.50, quota=1.0, duration=20.0).throughput
    assert t50 == pytest.approx(t24, rel=0.05)


def test_isolation_under_contention():
    """Paper Fig. 9: with spatial partitions, a greedy co-tenant cannot
    degrade a victim's throughput below its entitlement."""
    c = PAPER_ZOO["resnet"]
    # Victim alone at (0.24, 0.5).
    cluster = Cluster(n_nodes=1)
    cluster.register_function("victim", c)
    cluster.deploy("victim", resnet_point(0.24, 0.5))
    cluster.submit_all(poisson_arrivals("victim", c.rate(0.24, 0.5) * 2, 30.0))
    cluster.run(32.0)
    alone = cluster.recorders["victim"].throughput(5.0, 30.0)

    # Victim + aggressive co-tenant with its own partition.
    cluster2 = Cluster(n_nodes=1)
    cluster2.register_function("victim", c)
    cluster2.register_function("noisy", PAPER_ZOO["rnnt"])
    cluster2.deploy("victim", resnet_point(0.24, 0.5))
    noisy_c = PAPER_ZOO["rnnt"]
    cluster2.deploy("noisy", ProfilePoint(sm=0.24, quota=0.5,
                                          throughput=noisy_c.rate(0.24, 0.5)))
    cluster2.submit_all(poisson_arrivals("victim", c.rate(0.24, 0.5) * 2, 30.0))
    cluster2.submit_all(poisson_arrivals("noisy", noisy_c.rate(0.24, 0.5) * 3,
                                         30.0, seed=7))
    cluster2.run(32.0)
    contended = cluster2.recorders["victim"].throughput(5.0, 30.0)
    assert contended >= 0.9 * alone  # isolation: <=10% degradation


def test_spatial_sharing_beats_single_racing_pod():
    """Paper §5.3 headline: N partitioned pods >> one racing pod."""
    c = PAPER_ZOO["resnet"]
    # Racing: one pod with the whole node.
    racing = simulate_trial(c, sm=1.0, quota=1.0, duration=20.0).throughput
    # Spatial sharing: 8 pods at 12%.
    cluster = Cluster(n_nodes=1)
    cluster.register_function("f", c)
    for _ in range(8):
        assert cluster.deploy("f", resnet_point(0.12, 1.0)) is not None
    cluster.submit_all(poisson_arrivals("f", c.rate(0.12) * 8 * 1.3, 30.0))
    cluster.run(32.0)
    shared = cluster.recorders["f"].throughput(5.0, 30.0)
    assert shared / racing > 3.0  # paper: 3.15x for ResNet


def test_autoscaler_meets_slo_under_load_step():
    """Paper Fig. 12: heuristic autoscaling keeps violations ~<=1-5%.

    Profile points carry measured p99s (a pod with temporal quota q idles
    (1-q) of each window, bounding its tail latency from below), and the
    scheduler's SLO filter must avoid quota points incompatible with the SLO.
    """
    c = PAPER_ZOO["resnet"]
    slo = {"f": 0.5}
    profile = []
    for sm in (0.06, 0.12, 0.24):
        for q in (0.2, 0.4, 0.6, 0.8, 1.0):
            p99 = (1.0 - q) + 3.0 / c.rate(sm, 1.0)  # window gap + steps
            profile.append(ProfilePoint(sm=sm, quota=q,
                                        throughput=c.rate(sm, q),
                                        p99_latency=p99))
    cluster = Cluster(n_nodes=4, max_batch=1)
    cluster.register_function("f", c, slo_latency=slo["f"])
    # Initial deployment for 20 rps, then load steps to 60 rps at t=20.
    cluster.autoscale({"f": 20.0}, {"f": profile}, slo_latency=slo)
    cluster.submit_all(poisson_arrivals("f", 20.0, 20.0, seed=1))
    cluster.submit_all(poisson_arrivals("f", 60.0, 40.0, seed=2, start=20.0))

    def rescale():
        cluster.autoscale({"f": 60.0}, {"f": profile}, slo_latency=slo)

    cluster.sim.at(20.0, rescale)  # scaling reacts at the step
    cluster.run(62.0)
    rec = cluster.recorders["f"]
    # Steady-state after the scale event must meet the SLO.
    assert rec.violation_ratio(since=25.0) <= 0.05
    assert rec.throughput(25.0, 60.0) == pytest.approx(60.0, rel=0.15)


def test_scale_down_releases_nodes():
    c = PAPER_ZOO["resnet"]
    profile = [resnet_point(0.12, 1.0)]
    cluster = Cluster(n_nodes=4)
    cluster.register_function("f", c)
    cluster.autoscale({"f": 200.0}, {"f": profile})
    n_up = len(cluster.pods)
    cluster.autoscale({"f": -150.0 + 0.0}, {"f": profile})
    cluster.run(1.0)  # allow drains
    assert len(cluster.pods) < n_up


def test_node_failure_requeues_and_reconciler_replaces():
    """``fail_node`` only records the damage; the reconciler re-places the
    lost pods and the re-queued requests drain on the healed fleet."""
    from repro.control import ControlPlane, FunctionSpec, SimBackend, ramp

    c = PAPER_ZOO["resnet"]
    point = resnet_point(0.12, 1.0)
    cluster = Cluster(n_nodes=2)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(FunctionSpec(name="f", profile=(point,), curve=c,
                                target_rps=ramp([(0.0, 0.0)]),
                                min_instances=4, max_instances=8))
    cluster.submit_all(poisson_arrivals("f", 60.0, 30.0))

    def kill():
        lost = cluster.fail_node(0)
        # No self-redeploy left in the failure path itself.
        assert len(cluster.pods) == 4 - lost

    cluster.sim.at(10.0, kill)

    def heal():
        plane.reconcile()
        if cluster.sim.now < 35.0:
            cluster.sim.after(0.5, heal)

    cluster.sim.after(0.5, heal)
    cluster.run(40.0)
    rec = cluster.recorders["f"]
    # Service continues after the failure; no stranded requests.
    assert rec.throughput(12.0, 30.0) > 0.0
    assert all(not n.pods for n in cluster.nodes if not n.alive)
    assert len(cluster.pods) == 4, "reconciler must heal the floor"
    inflight = sum(len(p.queue) + len(p.in_flight) for p in cluster.pods.values())
    assert inflight == 0


def test_straggler_mitigation_moves_pods():
    c = PAPER_ZOO["resnet"]
    cluster = Cluster(n_nodes=3)
    cluster.register_function("f", c)
    cluster.deploy("f", resnet_point(0.12, 1.0))
    cluster.deploy("f", resnet_point(0.12, 1.0))
    cluster.nodes[0].slowdown = 4.0  # node 0 degrades
    stragglers = cluster.detect_stragglers(threshold=2.0)
    assert stragglers == [0]
    moved = cluster.mitigate_stragglers(threshold=2.0)
    assert moved >= 1
    assert all(p.placement.node != 0 for p in cluster.pods.values())


def test_memory_admission_blocks_overcommit():
    c = PAPER_ZOO["vit_huge"]
    cluster = Cluster(n_nodes=1, mem_bytes=6 * 1024**3, sharing=False)
    cluster.register_function("f", c)
    assert cluster.deploy("f", ProfilePoint(0.12, 1.0, c.rate(0.12))) is not None
    # Second instance exceeds 6G without sharing (2 x 4735M).
    assert cluster.deploy("f", ProfilePoint(0.12, 1.0, c.rate(0.12))) is None
    # With sharing it fits (weights stored once).
    cluster2 = Cluster(n_nodes=1, mem_bytes=8 * 1024**3, sharing=True)
    cluster2.register_function("f", c)
    assert cluster2.deploy("f", ProfilePoint(0.12, 1.0, c.rate(0.12))) is not None
    assert cluster2.deploy("f", ProfilePoint(0.12, 1.0, c.rate(0.12))) is not None
