"""Live serving engine: model sharing + token-gated dispatch end to end."""

import jax
import numpy as np
import pytest

from repro.core.resources import Alloc
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def served(tiny_model, tiny_params):
    # Tiny deterministic config (conftest) keeps this module tier-1-fast;
    # the full qwen2-7b-reduced engine path runs under `-m slow` in
    # test_smoke_archs / test_system coverage.
    return tiny_model, tiny_params


def test_end_to_end_generation_with_shared_weights(served):
    model, params = served
    engine = ServingEngine(window=0.1)
    alloc = Alloc(sm=0.5, quota_request=0.4, quota_limit=0.5)
    ids = engine.deploy("lm", model, params, alloc, n_instances=2,
                        max_batch=2, max_len=32)
    assert len(ids) == 2
    # Two instances, ONE stored copy (the paper's model sharing).
    assert engine.store.refcount("lm") == 2
    assert engine.memory_bytes() > 0

    rng = np.random.default_rng(0)
    reqs = [engine.submit("lm",
                          rng.integers(0, model.cfg.vocab_size, 8,
                                       dtype=np.int32),
                          max_new_tokens=4)
            for _ in range(4)]
    done = engine.pump(budget_s=30.0)
    assert done == 4
    for r in reqs:
        assert r.done and len(r.tokens_out) == 4
        assert all(0 <= t < model.cfg.vocab_size for t in r.tokens_out)
    rec = engine.recorders["lm"]
    assert rec.count() == 4 and rec.p99() > 0


def test_generation_matches_direct_decode(served):
    """Engine output == direct prefill+greedy decode (no scheduler effects)."""
    model, params = served
    engine = ServingEngine(window=0.1)
    engine.deploy("lm", model, params,
                  Alloc(sm=1.0, quota_request=0.9, quota_limit=0.9),
                  n_instances=1, max_batch=1, max_len=32)
    prompt = np.arange(8, dtype=np.int32) % model.cfg.vocab_size
    req = engine.submit("lm", prompt, max_new_tokens=4)
    engine.pump(budget_s=30.0)

    import jax.numpy as jnp
    logits, cache = jax.jit(
        lambda p, t: model.prefill(p, t, max_len=32))(
        params, jnp.asarray(prompt[None], jnp.int32))
    toks = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    toks.append(int(tok[0]))
    for _ in range(3):
        logits, cache = jax.jit(model.decode_step)(params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(int(tok[0]))
    assert req.tokens_out == toks


def test_quota_isolation_limits_step_rate(served):
    """A tiny quota must throttle an instance's token grants."""
    model, params = served
    engine = ServingEngine(window=0.05)
    engine.deploy("lm", model, params,
                  Alloc(sm=0.5, quota_request=0.1, quota_limit=0.1),
                  n_instances=1, max_batch=1, max_len=32)
    rng = np.random.default_rng(1)
    # Warm-up: first steps include jit compilation, which would dominate
    # Q_used; real deployments warm executors before admission.
    engine.submit("lm", rng.integers(0, model.cfg.vocab_size, 8,
                                     dtype=np.int32), max_new_tokens=2)
    engine.pump(budget_s=30.0)
    n_warm = len(engine.scheduler.stats_history)
    for _ in range(6):
        engine.submit("lm", rng.integers(0, model.cfg.vocab_size, 8,
                                         dtype=np.int32), max_new_tokens=4)
    engine.pump(budget_s=2.0)
    post = engine.scheduler.stats_history[n_warm:]
    assert post, "expected completed scheduling windows after warm-up"
    util = sum(w.busy_time for w in post) / (len(post)
                                             * engine.scheduler.window)
    # Utilization can exceed the 10% quota only by one-step overshoot
    # per window (steps are a few ms, window is 50 ms).
    assert util < 0.35, util
