"""Reconciler-owned pod lifecycle: failure healing, live KV migration,
and MRA defragmentation — identical semantics on both backends.

Covers the lifecycle seam: node-failure heal convergence (sim + live),
migration token/logit equivalence (a migrated paged/continuous pod
produces bit-identical streams to an unmigrated one), fragmentation-
triggered migration from the reconcile tick, sim-vs-live
``decision_signature`` equality under failure injection, and the
dead-pod capacity regression (L_j never counts phantom capacity).
"""

from collections import Counter

import numpy as np
import pytest

from repro.control import (ControlPlane, EWMADemand, FunctionSpec,
                           HoltWintersDemand, LiveBackend, SimBackend,
                           decision_signature, ramp)
from repro.core.cluster import Cluster
from repro.core.resources import Alloc
from repro.core.scaling import FunctionPodQueue, ProfilePoint
from repro.core.workload import ServiceCurve, poisson_arrivals
from repro.serving import ClusterFrontend

PROFILE = (
    ProfilePoint(sm=0.25, quota=0.4, throughput=2.0, p99_latency=0.05),
    ProfilePoint(sm=0.45, quota=0.8, throughput=5.0, p99_latency=0.03),
)

RAMP = ramp([(0.0, 1.0), (2.0, 8.0), (6.0, 1.0)])


def tiny_curve() -> ServiceCurve:
    return ServiceCurve(name="chat", r_max=5.0, sm_sat=0.45, p=1.0,
                        weight_bytes=1 << 20, framework_bytes=32 << 20)


def make_spec(factory=None, **overrides) -> FunctionSpec:
    kw = dict(name="chat", profile=PROFILE, slo_latency=0.1, target_rps=RAMP,
              headroom=1.2, min_instances=1, max_instances=5,
              model_factory=factory, max_batch=2, max_len=32,
              framework_bytes=32 * 1024 * 1024, curve=tiny_curve())
    kw.update(overrides)
    return FunctionSpec(**kw)


def busiest_node(plane: ControlPlane, backend) -> int:
    counts = Counter(backend.node_of(p) for p in plane.placed["chat"])
    return counts.most_common(1)[0][0]


# -------------------------------------------------------------------------
# fail_node: damage only, healing is the reconciler's
# -------------------------------------------------------------------------


def test_fail_node_does_not_self_redeploy():
    cluster = Cluster(n_nodes=2, sharing=True)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(make_spec(min_instances=3, target_rps=ramp([(0.0, 0.0)])))
    assert len(cluster.pods) == 3
    victim = busiest_node(plane, plane.backend)
    lost = cluster.fail_node(victim)
    assert lost >= 1
    # The failure path placed NOTHING: the fleet stays short until the
    # reconciler heals it.
    assert len(cluster.pods) == 3 - lost
    plane.reconcile(now=0.0)
    assert len(cluster.pods) == 3
    assert all(cluster.node_of(p) != victim for p in plane.placed["chat"])


def test_capacity_never_exceeds_live_pod_sum_after_fail_node():
    """Regression (SimBackend.evict dead-pod no-op): the reconciler —
    not the eviction path — is the dead-pod authority, so one tick after
    a failure L_j capacity equals the live-pod throughput sum exactly."""
    cluster = Cluster(n_nodes=2, sharing=True)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(make_spec(min_instances=4, max_instances=8,
                             target_rps=ramp([(0.0, 0.0)])))
    victim = busiest_node(plane, plane.backend)
    cluster.fail_node(victim)

    def live_sum() -> float:
        return sum(pt.throughput for pod, pt in plane.placed["chat"].items()
                   if cluster.alive(pod))

    # Phantom capacity exists right after the failure...
    assert plane.capacity("chat") > live_sum()
    plane.reconcile(now=0.0)
    # ...and is authoritatively pruned by the very next tick, after which
    # the invariant holds on every tick.
    for tick in range(1, 4):
        assert plane.capacity("chat") == pytest.approx(live_sum())
        assert all(cluster.alive(p) for p in plane.placed["chat"])
        plane.reconcile(now=float(tick))
    assert plane.instances("chat") == 4  # healed back to the floor


def test_evict_dead_pod_is_tolerated():
    cluster = Cluster(n_nodes=2, sharing=True)
    plane = ControlPlane(SimBackend(cluster))
    spec = make_spec(min_instances=2, target_rps=ramp([(0.0, 0.0)]))
    plane.register(spec)
    victim = next(iter(plane.placed["chat"]))
    cluster.fail_node(cluster.node_of(victim))
    plane.backend.evict(spec, victim)  # dead already: must not raise
    assert victim not in cluster.pods


def test_sim_heal_serves_parked_requests():
    """Every replica dies with the node; parked requests survive the
    outage and drain once the reconciler re-places the function."""
    cluster = Cluster(n_nodes=2, sharing=True)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(make_spec(min_instances=2, target_rps=ramp([(0.0, 0.0)])))
    # MRA best-area-fit packs both floor pods onto node 0.
    assert {cluster.node_of(p) for p in plane.placed["chat"]} == {0}
    arrivals = poisson_arrivals("chat", rps=3.0, duration=6.0, seed=5)
    cluster.submit_all(arrivals)
    cluster.sim.at(2.0, lambda: cluster.fail_node(0))

    def heal() -> None:
        plane.reconcile()
        if cluster.sim.now < 10.0:
            cluster.sim.after(0.5, heal)

    cluster.sim.after(0.5, heal)
    cluster.run(40.0)
    assert cluster.dropped == 0
    assert cluster.recorders["chat"].count() == len(arrivals)
    assert plane.instances("chat") == 2
    assert all(cluster.node_of(p) == 1 for p in plane.placed["chat"])


def test_live_node_failure_heals_to_floor_zero_drops(tiny_model,
                                                     tiny_params):
    frontend = ClusterFrontend(n_nodes=2, window=0.05)
    backend = LiveBackend(frontend)
    plane = ControlPlane(backend)
    plane.register(make_spec(lambda: (tiny_model, tiny_params),
                             min_instances=2,
                             target_rps=ramp([(0.0, 1.0)])))
    rng = np.random.default_rng(3)
    reqs = [frontend.submit("chat", rng.integers(0, 64, 5, dtype=np.int32),
                            max_new_tokens=3) for _ in range(4)]
    frontend.pump(budget_s=0.02)  # some requests mid-decode
    victim = busiest_node(plane, backend)
    lost = frontend.fail_node(victim)
    assert lost >= 1
    assert plane.instances("chat") == 2  # reconciler hasn't looked yet
    plane.reconcile(now=1.0)
    assert plane.instances("chat") == 2  # healed
    assert all(backend.alive(p) for p in plane.placed["chat"])
    assert all(backend.node_of(p) != victim for p in plane.placed["chat"])
    frontend.pump(budget_s=30.0)
    assert all(r.done for r in reqs), "failure dropped in-flight requests"
    assert all(len(r.tokens_out) == 3 for r in reqs)


def test_live_submit_during_podless_heal_window(tiny_model, tiny_params):
    """A submission between 'last replica died' and 'reconciler healed'
    parks (like the simulator's pending buffer) instead of raising — and
    is served once the heal places a replacement."""
    frontend = ClusterFrontend(n_nodes=2, window=0.05)
    plane = ControlPlane(LiveBackend(frontend))
    plane.register(make_spec(lambda: (tiny_model, tiny_params),
                             min_instances=1,
                             target_rps=ramp([(0.0, 1.0)])))
    rng = np.random.default_rng(7)
    frontend.fail_node(busiest_node(plane, plane.backend))
    # Podless window: no live instance anywhere.
    req = frontend.submit("chat", rng.integers(0, 64, 5, dtype=np.int32),
                          max_new_tokens=3)
    assert not req.done and frontend._pending["chat"] == [req]
    # Arrival was still observed (demand signal survives the outage)...
    assert frontend.observed_rps("chat", 60.0) > 0.0
    # ...oversized requests are still rejected, podless or not...
    with pytest.raises(ValueError, match="KV rows"):
        frontend.submit("chat", rng.integers(0, 64, 40, dtype=np.int32))
    with pytest.raises(KeyError):
        frontend.submit("ghost", rng.integers(0, 64, 5, dtype=np.int32))
    # ...and the reconciler's heal flushes the parked request.
    plane.reconcile(now=1.0)
    frontend.pump(budget_s=30.0)
    assert req.done and len(req.tokens_out) == 3


def test_sim_vs_live_signature_under_failure_injection(tiny_model,
                                                       tiny_params):
    """A live ramp with a mid-run node failure replays through the
    simulator decision-for-decision."""
    fail_tick = 3

    def run(plane, backend, fail):
        for tick in range(9):
            if tick == fail_tick:
                fail(busiest_node(plane, backend))
            plane.reconcile(now=float(tick))

    frontend = ClusterFrontend(n_nodes=2, window=0.05)
    lb = LiveBackend(frontend)
    live = ControlPlane(lb)
    live.register(make_spec(lambda: (tiny_model, tiny_params)))
    run(live, lb, frontend.fail_node)

    cluster = Cluster(n_nodes=2, sharing=True)
    sb = SimBackend(cluster)
    sim = ControlPlane(sb)
    sim.register(make_spec())
    run(sim, sb, cluster.fail_node)

    live_sig = decision_signature(live.log)
    assert live_sig == decision_signature(sim.log)
    # The failure forced extra scale-ups beyond the plain ramp.
    assert sum(1 for d in live.log if d.direction > 0) > \
        sum(1 for d in live.log if d.direction < 0)
    assert live.instances("chat") == sim.instances("chat") == 1


# -------------------------------------------------------------------------
# Migration: bit-identical streams, fragmentation trigger
# -------------------------------------------------------------------------


@pytest.mark.parametrize("batching", ["continuous", "paged"])
def test_migration_token_equivalence(tiny_model, tiny_params, batching):
    """A migrated pod (occupied decode slots and all) must produce
    bit-identical token streams to an unmigrated control run."""

    def run(migrate: bool):
        frontend = ClusterFrontend(n_nodes=2, window=0.05)
        alloc = Alloc(sm=0.5, quota_request=0.8, quota_limit=0.8)
        handle = frontend.place_instance(
            "chat", tiny_model, tiny_params, alloc, max_batch=2,
            max_len=32, batching=batching)
        assert handle is not None
        node = int(handle.split(":", 1)[0])
        rng = np.random.default_rng(0)
        reqs = [frontend.submit("chat",
                                rng.integers(0, 64, 4 + i, dtype=np.int32),
                                max_new_tokens=5) for i in range(4)]
        inst = next(iter(frontend.engines[node].instances.values()))
        inst.run_step()
        inst.run_step()  # slots occupied mid-decode, queue non-empty
        assert inst.n_active() > 0
        if migrate:
            new = frontend.migrate("chat", handle, tiny_model, tiny_params,
                                   1 - node)
            assert new is not None
            assert int(new.split(":", 1)[0]) == 1 - node
            # Source instance closed, rectangle released, queue re-routed.
            assert not frontend.engines[node].instances
            assert frontend.pool.nodes[node].placements == {}
            assert len(frontend.placements) == 1
        frontend.pump(budget_s=30.0)
        assert all(r.done for r in reqs), "migration dropped requests"
        return [tuple(r.tokens_out) for r in reqs]

    assert run(migrate=False) == run(migrate=True)


def test_fragmentation_triggered_migration_sim():
    cluster = Cluster(n_nodes=2, sharing=True)
    plane = ControlPlane(SimBackend(cluster), defrag_threshold=-1.0)
    plane.register(make_spec(min_instances=1, target_rps=ramp([(0.0, 1.0)])))
    (pod,) = plane.placed["chat"]
    src = cluster.node_of(pod)
    cap = plane.capacity("chat")
    plane.reconcile(now=0.0)
    assert len(plane.migrations) == 1
    ev = plane.migrations[0]
    assert ev.source == src and ev.target != src
    # placed/L_j re-keyed in place: same point, same capacity, new pod id.
    assert pod not in plane.placed["chat"]
    assert ev.new_pod in plane.placed["chat"]
    assert cluster.node_of(ev.new_pod) == ev.target
    assert plane.capacity("chat") == pytest.approx(cap)
    assert plane.instances("chat") == 1


def test_fragmentation_triggered_migration_live(tiny_model, tiny_params):
    frontend = ClusterFrontend(n_nodes=2, window=0.05)
    plane = ControlPlane(LiveBackend(frontend), defrag_threshold=-1.0)
    plane.register(make_spec(lambda: (tiny_model, tiny_params),
                             min_instances=1, batching="paged",
                             target_rps=ramp([(0.0, 1.0)])))
    (handle,) = plane.placed["chat"]
    src = int(handle.split(":", 1)[0])
    rng = np.random.default_rng(1)
    reqs = [frontend.submit("chat", rng.integers(0, 64, 6, dtype=np.int32),
                            max_new_tokens=4) for _ in range(3)]
    inst = next(iter(frontend.engines[src].instances.values()))
    inst.run_step()  # occupy paged slots mid-decode
    assert inst.n_active() > 0
    plane.reconcile(now=0.0)
    assert len(plane.migrations) == 1
    ev = plane.migrations[0]
    assert ev.source == src and ev.target == 1 - src
    assert ev.new_pod in plane.placed["chat"]
    assert not frontend.engines[src].instances
    frontend.pump(budget_s=30.0)
    assert all(r.done for r in reqs), "live migration dropped requests"
    assert all(len(r.tokens_out) == 4 for r in reqs)


def test_sim_migrate_defers_mid_step():
    cluster = Cluster(n_nodes=2, sharing=True, continuous=True)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(make_spec(min_instances=1, target_rps=ramp([(0.0, 0.0)])))
    (pod,) = plane.placed["chat"]
    cluster.submit_all(poisson_arrivals("chat", rps=4.0, duration=1.0,
                                        seed=2))
    # Advance into a decode step: the pod is mid-step (in_flight).
    cluster.run(0.3)
    runtime = cluster.pods[pod]
    if runtime.in_flight:
        assert cluster.migrate(pod, 1 - runtime.placement.node) is None
    # Between steps (after the run drains) the move succeeds.
    cluster.run(30.0)
    target = 1 - cluster.pods[pod].placement.node
    assert cluster.migrate(pod, target) is not None


def test_static_batches_cannot_migrate(tiny_model, tiny_params):
    frontend = ClusterFrontend(n_nodes=2, window=0.05)
    alloc = Alloc(sm=0.5, quota_request=0.8, quota_limit=0.8)
    handle = frontend.place_instance("chat", tiny_model, tiny_params, alloc,
                                     max_batch=2, max_len=32,
                                     batching="static")
    assert frontend.migrate("chat", handle, tiny_model, tiny_params, 1) \
        is None


def test_pod_queue_rekey():
    q = FunctionPodQueue()
    q.push("a", PROFILE[0])
    q.push("b", PROFILE[1])
    q.rekey("a", "a2")
    assert "a" not in q and "a2" in q
    assert q.capacity() == pytest.approx(
        PROFILE[0].throughput + PROFILE[1].throughput)
    # RPR ordering is preserved: "b" (lower RPR) stays the eviction front,
    # and the re-keyed entry keeps its original profile point.
    assert q.front().pod_id == "b"
    q.pop()
    assert q.front().point == PROFILE[0]
    with pytest.raises(KeyError):
        q.rekey("ghost", "x")


# -------------------------------------------------------------------------
# Predictive demand sources
# -------------------------------------------------------------------------


def test_ewma_demand_converges_faster_than_it_forgets():
    src = EWMADemand(alpha=0.5)
    assert src(0.0) == 0.0
    for t, obs in enumerate([1.0, 1.0, 10.0, 10.0, 10.0]):
        src.observe(float(t), obs)
    assert 8.5 < src(5.0) < 10.0  # near the step within 3 ticks


def test_holt_winters_extrapolates_a_ramp():
    src = HoltWintersDemand(alpha=0.6, beta=0.4)
    for t in range(8):
        src.observe(float(t), float(t))  # +1 rps per tick
    # Trend extrapolation: the forecast leads the last observation.
    assert src(8.0) > 7.0


def test_holt_winters_seasonal_cycle():
    src = HoltWintersDemand(alpha=0.4, beta=0.2, gamma=0.6, season=4)
    pattern = [2.0, 8.0, 2.0, 2.0]
    for t in range(24):
        src.observe(float(t), pattern[t % 4])
    # After six full cycles the seasonal term anticipates the burst phase.
    burst_phase = src._tick % 4 == 1
    forecasts = []
    for k in range(4):
        forecasts.append((src._tick % 4, src(float(24 + k))))
        src.observe(float(24 + k), pattern[src._tick % 4])
    by_phase = dict(forecasts)
    assert by_phase[1] > by_phase[2] + 2.0, by_phase


def test_demand_source_is_fed_from_backend_arrival_log():
    cluster = Cluster(n_nodes=2, sharing=True)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(make_spec(min_instances=1, max_instances=6,
                             target_rps=EWMADemand(alpha=0.7),
                             rps_window=2.0))
    arrivals = poisson_arrivals("chat", rps=9.0, duration=6.0, seed=4)
    cluster.submit_all(arrivals)
    for tick in range(1, 7):
        cluster.sim.at(float(tick),
                       lambda t=tick: plane.reconcile(now=float(t)))
    cluster.run(30.0)
    # The forecaster saw the arrival log and the plane scaled out on it.
    assert plane.instances("chat") > 1
    src = plane.specs["chat"].target_rps
    assert src.level is not None and src.level > 4.0
    assert cluster.recorders["chat"].count() == len(arrivals)


def test_demand_source_validation():
    with pytest.raises(ValueError):
        EWMADemand(alpha=0.0)
    with pytest.raises(ValueError):
        HoltWintersDemand(beta=1.5)
    with pytest.raises(ValueError):
        HoltWintersDemand(season=1)
