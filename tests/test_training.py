"""Training substrate tests: loss decreases, checkpoint round-trip,
microbatching equivalence, grad compression sanity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.training import (AdamW, TrainStepConfig, cross_entropy,
                            make_train_step, train)
from repro.training import checkpoint as ckpt
from repro.training.data import batch_iterator, make_batch

# JAX-compile-heavy (real optimizer/train-loop jit steps): excluded from tier-1, run via `-m slow`.
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def _tiny_shared():
    model = build_model(get_config("qwen2-7b", reduced=True))
    params = model.init(jax.random.key(0))
    return model, params


@pytest.fixture()
def tiny(_tiny_shared):
    # train() donates params; hand each test its own copy.
    model, params = _tiny_shared
    return model, jax.tree_util.tree_map(jnp.copy, params)


def test_loss_decreases_over_training(tiny):
    model, params = tiny
    batches = batch_iterator(model.cfg.vocab_size, batch=4, seq=32, seed=0)
    params, _, result = train(model, params, batches, steps=30,
                              opt=AdamW(lr=1e-2, warmup_steps=5,
                                        total_steps=30),
                              log_every=0)
    first = np.mean(result.losses[:5])
    last = np.mean(result.losses[-5:])
    assert last < first * 0.8, (first, last)


def test_microbatch_accumulation_matches_full_batch(tiny):
    model, params = tiny
    opt = AdamW(lr=1e-3)
    batch = make_batch(model.cfg.vocab_size, 8, 16, step=0)
    s1 = make_train_step(model, opt, TrainStepConfig(microbatches=1,
                                                     remat=False))
    s4 = make_train_step(model, opt, TrainStepConfig(microbatches=4,
                                                     remat=False))
    st = opt.init(params)
    p1, _, m1 = jax.jit(s1)(params, st, batch)
    st = opt.init(params)
    p4, _, m4 = jax.jit(s4)(params, st, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-3)
    l1 = jax.tree_util.tree_leaves(p1)
    l4 = jax.tree_util.tree_leaves(p4)
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-3)


def test_grad_compression_close_to_fp32(tiny):
    model, params = tiny
    opt = AdamW(lr=1e-3)
    batch = make_batch(model.cfg.vocab_size, 4, 16, step=1)
    sf = make_train_step(model, opt, TrainStepConfig(remat=False))
    sc = make_train_step(model, opt, TrainStepConfig(remat=False,
                                                     grad_compress=True))
    _, _, mf = jax.jit(sf)(params, opt.init(params), batch)
    _, _, mc = jax.jit(sc)(params, opt.init(params), batch)
    assert abs(float(mf["loss"]) - float(mc["loss"])) < 1e-3
    assert abs(float(mf["grad_norm"]) - float(mc["grad_norm"])) / \
        float(mf["grad_norm"]) < 0.05


def test_checkpoint_roundtrip_and_keep_n(tiny, tmp_path):
    model, params = tiny
    opt = AdamW()
    state = opt.init(params)
    d = str(tmp_path / "ckpts")
    for step in (10, 20, 30, 40):
        ckpt.save(d, step, params, state, keep=2)
    assert [s for s, _ in ckpt.list_checkpoints(d)] == [30, 40]
    step, p2, s2 = ckpt.restore_latest(d, params, state)
    assert step == 40
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restart_resumes_training(tiny, tmp_path):
    """Fault tolerance: kill training mid-run, restart, same trajectory."""
    model, params0 = tiny
    d = str(tmp_path / "ck")
    opt = AdamW(lr=1e-3, total_steps=20)

    def run(steps, params):
        params = jax.tree_util.tree_map(jnp.copy, params)  # train() donates
        batches = batch_iterator(model.cfg.vocab_size, 4, 16, seed=3)
        return train(model, params, batches, steps=steps, opt=opt,
                     checkpoint_dir=d, checkpoint_every=5, log_every=0)

    # "Crash" after 10 steps (checkpoint at 5 and 10 exist).
    p_crash, _, _ = run(10, params0)
    # Restart resumes from step 10 and continues to 20.
    p_final, _, result = run(20, params0)
    assert result.steps == 10  # only steps 10..20 re-run
    # Uninterrupted reference run.
    batches = batch_iterator(model.cfg.vocab_size, 4, 16, seed=3)
    p_ref, _, _ = train(model, jax.tree_util.tree_map(jnp.copy, params0),
                        batches, steps=20, opt=opt, log_every=0)
    for a, b in zip(jax.tree_util.tree_leaves(p_final),
                    jax.tree_util.tree_leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-2, atol=1e-2)


def test_elastic_restore_device_put(tiny, tmp_path):
    model, params = tiny
    d = str(tmp_path / "c2")
    ckpt.save(d, 1, params)
    _, arrays, _ = ckpt.restore_latest(d)
    assert any(k.lstrip("~bf16~").startswith("p") for k in arrays
               if not k.startswith("__"))
    # Re-shard onto the (single-device) default sharding.
    step, p2, _ = ckpt.restore_latest(d, params)
    dev = jax.devices()[0]
    placed = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, dev), p2)
    assert all(l.device == dev for l in jax.tree_util.tree_leaves(placed))


def test_cross_entropy_perfect_prediction_is_zero():
    logits = jnp.full((1, 4, 8), -30.0).at[0, :, 3].set(30.0)
    labels = jnp.full((1, 4), 3, jnp.int32)
    assert float(cross_entropy(logits, labels)) < 1e-5
