"""Unit tests for the trip-count-exact HLO roofline analyzer.

A hand-written miniature HLO module exercises every accounting rule:
while-trip multipliers, dot FLOPs from contracting dims, in-place
dynamic-update-slice windows, fused dynamic-slice reads, collective ring
costs, and the FloatNormalization bf16-width correction.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.roofline import (HloAnalysis, analyze_hlo, model_flops,
                                     parse_module, roofline_terms)
from repro.configs import get_config
from repro.models.model import SHAPE_CASES

MINI_HLO = """
HloModule mini

%add_f32 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add_f32
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ip, %ar)
}

%cond.1 (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (x0: f32[8,16]) -> f32[8,16] {
  %x0 = f32[8,16]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%c0, %x0)
  %w1 = (s32[], f32[8,16]) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w1), index=1
}
"""


def test_while_trip_multiplier_and_dot_flops():
    a = analyze_hlo(MINI_HLO)
    # dot: 2 * 8*16 (result) * 16 (contraction) = 4096 FLOPs, x4 trips.
    assert a.flops == pytest.approx(4 * 4096)
    assert a.flops_uncorrected == pytest.approx(4096)
    assert a.n_dots == 1
    assert a.unknown_trip_whiles == 0


def test_collective_ring_cost_and_trip_weighting():
    a = analyze_hlo(MINI_HLO)
    # all-reduce of f32[8,16] = 512 B -> ring cost 2x, x4 trips = 4096 B.
    assert a.collective_wire == {"all-reduce": pytest.approx(4 * 1024.0)}


def test_parse_module_structure():
    comps, entry, types = parse_module(MINI_HLO)
    assert entry == "main"
    assert {"add_f32", "body.1", "cond.1", "main"} <= set(comps)
    assert types["d"].startswith("f32[8,16]")


def test_bf16_width_correction():
    hlo = """
HloModule w
%add_f32 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}
ENTRY %main (x: bf16[32,32]) -> f32[32,32] {
  %x = bf16[32,32]{1,0} parameter(0)
  %cv = f32[32,32]{1,0} convert(%x)
  ROOT %ar = f32[32,32]{1,0} all-reduce(%cv), replica_groups={}, to_apply=%add_f32
}
"""
    a = analyze_hlo(hlo)
    # f32 payload 4096 B, ring 2x, but convert-from-bf16 producer -> x0.5.
    assert a.collective_wire["all-reduce"] == pytest.approx(4096.0)


def test_dus_counts_window_not_buffer():
    hlo = """
HloModule d
ENTRY %main (buf: f32[1024,64], upd: f32[1,64]) -> f32[1024,64] {
  %buf = f32[1024,64]{1,0} parameter(0)
  %upd = f32[1,64]{1,0} parameter(1)
  %z = s32[] constant(0)
  ROOT %w = f32[1024,64]{1,0} dynamic-update-slice(%buf, %upd, %z, %z)
}
"""
    a = analyze_hlo(hlo)
    # 2 x window (256 B), never the 256 KiB aliased buffer.
    assert a.hbm_bytes == pytest.approx(2 * 256.0)


def test_roofline_terms_and_dominance():
    a = HloAnalysis(flops=197e12, hbm_bytes=819e9 * 2,
                    collective_wire={"all-reduce": 50e9 * 3})
    r = roofline_terms(a, n_chips=4, model_flops=197e12 * 2)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(3.0)
    assert r.dominant == "collective"
    assert r.bound_s == pytest.approx(3.0)
    assert r.useful_ratio == pytest.approx(2.0 / 4.0)


def test_model_flops_sanity():
    cfg = get_config("qwen2-7b")
    train = model_flops(cfg, SHAPE_CASES["train_4k"])
    prefill = model_flops(cfg, SHAPE_CASES["prefill_32k"])
    decode = model_flops(cfg, SHAPE_CASES["decode_32k"])
    tokens = 256 * 4096
    n = cfg.active_param_count() - cfg.padded_vocab * cfg.d_model
    assert train > 6.0 * n * tokens  # 6ND plus attention
    assert prefill > 2.0 * n * tokens
    # decode: 2N per token x batch 128, plus attention reads.
    assert decode > 2.0 * n * 128
    assert decode < train / 100
    # MoE counts active params only.
    moe = get_config("mixtral-8x7b")
    assert moe.active_param_count() < 0.45 * moe.param_count()


# -- decode-round alpha calibration (ServiceCurve.round_time) ---------------


def test_decode_round_alpha_weight_vs_kv_bound():
    """qwen2-7b decode: weight-bound at short context (alpha -> 1),
    KV-bound as context grows (alpha monotonically decreasing)."""
    from repro.analysis.roofline import decode_round_alpha

    cfg = get_config("qwen2_7b")
    alphas = [decode_round_alpha(cfg, s) for s in (128, 2048, 32768, 524288)]
    assert alphas[0] > 0.9, "short-context decode must be weight-bound"
    assert all(a1 > a2 for a1, a2 in zip(alphas, alphas[1:])), \
        "alpha must fall monotonically with context length"
    assert all(0.0 < a < 1.0 for a in alphas)


def test_calibrated_alpha_preserves_single_slot_rates():
    """Calibration changes batching economics, not the paper-calibrated
    single-request service rates (round_time(sm, 1) == step_time(sm, 1))."""
    from repro.core.workload import PAPER_ZOO, calibrate_round_alpha

    cfg = get_config("qwen2_7b")
    base = PAPER_ZOO["rnnt"]
    cal = calibrate_round_alpha(base, cfg, seq_len=2048)
    assert cal.alpha != base.alpha
    for sm in (0.12, 0.24, 1.0):
        assert cal.round_time(sm, 1) == pytest.approx(base.step_time(sm, 1))
        assert cal.round_time(sm, 1) == pytest.approx(base.round_time(sm, 1))
    # More weight-bound than the 0.5 default => fuller batches are cheaper
    # per slot: the 8-slot round must cost LESS than the uncalibrated model.
    assert cal.alpha > 0.5
    assert cal.round_time(0.12, 8) < base.round_time(0.12, 8)


def test_cluster_uses_curve_alpha_by_default():
    """Cluster(batch_alpha=None) must dispatch rounds at each curve's own
    calibrated alpha; an explicit batch_alpha still overrides globally."""
    import dataclasses as _dc

    from repro.core.cluster import Cluster
    from repro.core.scaling import ProfilePoint
    from repro.core.workload import PAPER_ZOO, Request

    curve = _dc.replace(PAPER_ZOO["rnnt"], alpha=0.9)

    def run(**kw):
        cluster = Cluster(n_nodes=1, max_batch=4, continuous=True, **kw)
        cluster.register_function("f", curve)
        assert cluster.deploy(
            "f", ProfilePoint(sm=0.24, quota=1.0, throughput=0.0)) is not None
        for i in range(4):
            cluster.submit(Request(fn="f", arrival=0.01, req_id=i,
                                   n_tokens=8))
        cluster.run(60.0)
        rec = cluster.recorders["f"]
        assert rec.count() == 4
        return max(rec.latencies)

    # alpha=0.9: a 4-slot round costs (0.9 + 0.1*4)/rate = 1.3/rate, vs the
    # 0.5 default's 2.5/rate — the calibrated run must finish faster.
    assert run() < run(batch_alpha=0.5)
