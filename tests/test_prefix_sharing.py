"""Prefix sharing end to end: token equivalence, lifecycle seams, stats.

The sharing plane's correctness bar is *bit-identity*: with content-hash
prefix matching and COW on, every request must emit exactly the tokens
the unshared paged plane and the static reference batcher emit — per
model family (dense, MoE, int8-quantized KV), across mid-stream live
migration, and through retire-drain of one sharer.  The accounting bar:
``kv_bytes_peak`` charges a shared block once (allocator high-watermark
in bytes, updated at every allocation, not sampled per dispatch), and
the shared-fraction axis threads from ``FunctionSpec`` / ``ProfilePoint``
through ``paged_kv_capacity`` into frontend memory admission.
"""

import jax
import numpy as np
import pytest

from conftest import tiny_config
from repro.core.resources import Alloc
from repro.models import build_model
from repro.serving import ClusterFrontend, ServingEngine

FULL = Alloc(sm=1.0, quota_request=0.9, quota_limit=0.9)
HALF = Alloc(sm=0.4, quota_request=0.4, quota_limit=0.5)
MOE_KW = dict(name="tiny-moe", family="moe", n_experts=4, top_k=2)


def _shared_arrivals(n=4, prefix_len=12, suffix_len=4, seed=0, vocab=64,
                     max_new=(2, 6, 4, 5), rng=None):
    """n prompts sharing one prefix, each with a unique suffix and its own
    decode budget (staggered finishes exercise release-while-shared).
    Pass the ``repro_rng`` fixture as ``rng`` to put the workload under
    the suite-wide ``--repro-seed``."""
    rng = np.random.default_rng(seed) if rng is None else rng
    prefix = rng.integers(0, vocab, prefix_len, dtype=np.int32)
    return [(np.concatenate(
        [prefix, rng.integers(0, vocab, suffix_len, dtype=np.int32)]),
        max_new[i % len(max_new)]) for i in range(n)]


def _serve(model, params, batching, arrivals, *, prefix_sharing=True,
           max_batch=2, max_len=32, block_size=8):
    engine = ServingEngine(window=0.1)
    engine.deploy("f", model, params, FULL, max_batch=max_batch,
                  max_len=max_len, batching=batching, block_size=block_size,
                  prefix_sharing=prefix_sharing)
    reqs = [engine.submit("f", p, max_new_tokens=n) for p, n in arrivals]
    done = engine.pump(budget_s=120.0)
    assert done == len(reqs)
    inst = next(iter(engine.instances.values()))
    inst._engine_telemetry = next(iter(engine.telemetry().values()))
    return reqs, inst


# -- differential token equivalence, per family ----------------------------


@pytest.mark.parametrize("family", ["dense", "moe", "kv-int8"])
def test_shared_tokens_bit_identical_across_planes(family, monkeypatch,
                                                   repro_rng):
    """Shared-paged == unshared-paged == static token streams, exactly,
    while sharing actually engages and shrinks the physical peak.  The
    workload draws from ``repro_rng``: equivalence must hold for ANY
    prompt mix, so ``--repro-seed`` varies it (and replays failures)."""
    if family == "kv-int8":
        monkeypatch.setenv("REPRO_KV_INT8", "1")
        cfg = tiny_config()
    else:
        monkeypatch.delenv("REPRO_KV_INT8", raising=False)
        cfg = tiny_config(**(MOE_KW if family == "moe" else {}))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    arrivals = _shared_arrivals(rng=repro_rng)

    shared, inst_s = _serve(model, params, "paged", arrivals)
    unshared, inst_u = _serve(model, params, "paged", arrivals,
                              prefix_sharing=False)
    static, _ = _serve(model, params, "static", arrivals)

    toks = [r.tokens_out for r in shared]
    assert toks == [r.tokens_out for r in unshared]
    assert toks == [r.tokens_out for r in static]
    assert inst_s.shared_block_hits > 0, "trace must actually share"
    assert inst_u.shared_block_hits == 0
    assert inst_s.allocator.high_watermark < inst_u.allocator.high_watermark
    # staggered finishes released sharers mid-flight; nothing leaked
    assert inst_s.allocator.blocks_in_use == 0
    assert inst_s.pages.n_spares == 0


def test_exact_prompt_share_cow_resolves(tiny_model, tiny_params):
    """Bit-identical prompts share the partial tail block too; the first
    divergent decode append COWs through the reserved spare."""
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, 64, 20, dtype=np.int32)  # 2 full + tail of 4
    arrivals = [(prompt.copy(), 4) for _ in range(3)]
    shared, inst_s = _serve(tiny_model, tiny_params, "paged", arrivals,
                            max_batch=4)
    unshared, _ = _serve(tiny_model, tiny_params, "paged", arrivals,
                         max_batch=4, prefix_sharing=False)
    assert ([r.tokens_out for r in shared]
            == [r.tokens_out for r in unshared])
    assert inst_s.cow_count > 0, "tail share must COW on divergence"
    assert inst_s.allocator.blocks_in_use == 0
    assert inst_s.pages.n_spares == 0


# -- lifecycle seams: migration and retire-drain ---------------------------


def test_sharing_survives_midstream_migration(tiny_model, tiny_params):
    """Live-migrating sharers re-establishes sharing on the target (first
    import registers its full prompt blocks, later imports match them)
    with token streams identical to the unshared fleet's."""
    arrivals = _shared_arrivals(n=3, prefix_len=16, suffix_len=2,
                                max_new=(8,), seed=4)

    def run(prefix_sharing):
        fe = ClusterFrontend(n_nodes=2, window=0.1)
        [h0] = fe.deploy("f", tiny_model, tiny_params, HALF, max_batch=2,
                         max_len=32, batching="paged", block_size=8,
                         prefix_sharing=prefix_sharing)
        reqs = [fe.submit("f", p, max_new_tokens=n) for p, n in arrivals]
        # Fixed step count (not a wall-clock pump) so slots are still
        # mid-decode at migration even with warm shared executor caches.
        src_inst = next(iter(fe.engines[0].instances.values()))
        src_inst.run_step()
        src_inst.run_step()
        assert src_inst.n_active() > 0
        assert fe.migrate("f", h0, tiny_model, tiny_params,
                          target=1) is not None
        tgt = next(iter(fe.engines[1].instances.values()))
        done = fe.pump(budget_s=120.0)
        assert done == len(reqs) and all(r.done for r in reqs)
        assert fe.kv_bytes_in_use() == 0
        return [r.tokens_out for r in reqs], tgt

    shared_toks, tgt = run(True)
    unshared_toks, _ = run(False)
    assert shared_toks == unshared_toks
    # sharing re-engaged on the target: imported or re-admitted sharers
    # took extra references on resident prompt blocks
    assert tgt.allocator.n_increfs > 0


def test_retire_drain_of_sharers_releases_cleanly(tiny_model, tiny_params):
    """Retiring the instance mid-flight drains sharers to completion with
    unshared-identical tokens; refcounts and COW spares all unwind."""
    arrivals = _shared_arrivals(n=4, prefix_len=16, suffix_len=2,
                                max_new=(6, 6, 3, 4), seed=7)
    reference, _ = _serve(tiny_model, tiny_params, "paged", arrivals,
                          prefix_sharing=False)

    engine = ServingEngine(window=0.1)
    [iid] = engine.deploy("f", tiny_model, tiny_params, FULL, max_batch=2,
                          max_len=32, batching="paged", block_size=8)
    reqs = [engine.submit("f", p, max_new_tokens=n) for p, n in arrivals]
    # Fixed step count: slots must be mid-decode at retire even with
    # warm shared executor caches.
    inst = engine.instances[iid]
    inst.run_step()
    inst.run_step()
    alloc_ref, pages_ref = inst.allocator, inst.pages
    assert alloc_ref.blocks_in_use > 0, "test needs live paged slots"
    strays = engine.retire(iid, strip_queue=True)
    engine.pump(budget_s=120.0)
    assert iid not in engine.instances, "drained instance must close"
    assert alloc_ref.blocks_in_use == 0, "retire leaked shared KV blocks"
    assert pages_ref.n_spares == 0, "retire leaked COW spares"
    assert alloc_ref.registered_blocks == 0
    for r, ref in zip(reqs, reference):
        if r not in strays:
            assert r.done and r.tokens_out == ref.tokens_out


# -- stats: bytes-denominated, sharing-consistent (satellite fix) ----------


def test_kv_bytes_peak_charges_shared_blocks_once(tiny_model, tiny_params):
    """``kv_bytes_peak`` is the allocator's byte high-watermark: shared
    blocks count once, the peak survives the drain (every-alloc update,
    not per-dispatch sampling), and the stats dict reports blocks AND
    bytes consistently."""
    arrivals = _shared_arrivals(n=4, prefix_len=16, suffix_len=2,
                                max_new=(4,), seed=2)
    _, inst_s = _serve(tiny_model, tiny_params, "paged", arrivals,
                       max_batch=4)
    _, inst_u = _serve(tiny_model, tiny_params, "paged", arrivals,
                       max_batch=4, prefix_sharing=False)
    bb = tiny_model.kv_block_bytes(8)
    for inst in (inst_s, inst_u):
        stats = inst.allocator.stats()
        assert inst.kv_bytes_peak == inst.allocator.bytes_high_watermark
        assert stats["bytes_high_watermark"] == stats["high_watermark"] * bb
        assert stats["bytes_in_use"] == 0, "drained pool still charged"
        assert inst.kv_bytes_peak > 0, "peak must survive the drain"
    assert inst_s.kv_bytes_peak < inst_u.kv_bytes_peak, \
        "sharing must shrink the physical byte peak"
    assert inst_s._engine_telemetry["shared_hits"] == inst_s.shared_block_hits
    assert inst_s._engine_telemetry["cow"] == inst_s.cow_count


def test_frontend_reports_live_shared_fraction(tiny_model, tiny_params):
    """Fleet-wide sharing telemetry mid-flight: bytes saved > 0 and the
    observed shared fraction sits in (0, 1); both return to zero after
    the drain."""
    arrivals = _shared_arrivals(n=4, prefix_len=16, suffix_len=2,
                                max_new=(8,), seed=3)
    fe = ClusterFrontend(n_nodes=1, window=0.1)
    fe.deploy("f", tiny_model, tiny_params, FULL, max_batch=4, max_len=32,
              batching="paged", block_size=8)
    reqs = [fe.submit("f", p, max_new_tokens=n) for p, n in arrivals]
    # Fixed step count: the sharing must be observed mid-flight, before
    # the requests finish (warm executor caches make pumps fast).
    inst = next(iter(fe.engines[0].instances.values()))
    inst.run_step()
    assert fe.kv_bytes_saved() > 0
    assert 0.0 < fe.kv_shared_fraction() < 1.0
    done = fe.pump(budget_s=120.0)
    assert done == len(reqs) and all(r.done for r in reqs)
    assert fe.kv_bytes_saved() == 0 and fe.kv_shared_fraction() == 0.0


# -- shared-fraction admission axis (spec -> profiler -> frontend) ---------


def test_paged_kv_capacity_shared_fraction_axis(tiny_model):
    from repro.core.profiler import paged_kv_capacity

    bb = tiny_model.kv_block_bytes(8)
    assert paged_kv_capacity(10 * bb, bb) == 10
    # a 0.5 shared fraction stretches the same byte budget to 2x blocks
    assert paged_kv_capacity(10 * bb, bb, shared_frac=0.5) == 20
    with pytest.raises(ValueError, match="shared_frac"):
        paged_kv_capacity(10 * bb, bb, shared_frac=1.0)
    with pytest.raises(ValueError, match="shared_frac"):
        paged_kv_capacity(10 * bb, bb, shared_frac=-0.1)


def test_shared_frac_validation_on_spec_and_point():
    from repro.control.spec import FunctionSpec
    from repro.core.scaling import ProfilePoint

    point = ProfilePoint(sm=0.3, quota=0.3, throughput=1.0,
                         kv_shared_frac=0.3)
    assert point.kv_shared_frac == 0.3
    with pytest.raises(ValueError, match="kv_shared_frac"):
        ProfilePoint(sm=0.3, quota=0.3, throughput=1.0, kv_shared_frac=1.0)
    FunctionSpec(name="f", profile=(point,), batching="paged",
                 kv_shared_frac=0.5)
    with pytest.raises(ValueError, match="kv_shared_frac"):
        FunctionSpec(name="f", profile=(point,), kv_shared_frac=0.5)
    with pytest.raises(ValueError, match="kv_shared_frac"):
        FunctionSpec(name="f", profile=(point,), batching="paged",
                     prefix_sharing=False, kv_shared_frac=0.5)


def test_shared_frac_discounts_memory_admission(tiny_model, tiny_params):
    """A KV budget too small at frac=0 admits at frac=0.5 — the declared
    duplicate fraction is not double-charged by admission."""
    from repro.core.model_sharing import (SERVER_CONTEXT_OVERHEAD,
                                          pytree_nbytes)

    alloc = Alloc(sm=0.2, quota_request=0.2, quota_limit=0.3)
    paged_kv = tiny_model.kv_cache_bytes(batching="paged", max_batch=4,
                                         max_len=64, block_size=16,
                                         n_kv_blocks=8)
    base = pytree_nbytes(tiny_params) + SERVER_CONTEXT_OVERHEAD
    fw = 1024
    budget = base + fw + int(paged_kv * 0.5) + paged_kv // 4

    def place(frac):
        fe = ClusterFrontend(n_nodes=1, mem_bytes=budget)
        return fe.place_instance("f", tiny_model, tiny_params, alloc,
                                 batching="paged", n_kv_blocks=8,
                                 framework_bytes=fw, kv_shared_frac=frac)

    assert place(0.0) is None
    assert place(0.5) is not None
    with pytest.raises(ValueError, match="kv_shared_frac"):
        fe = ClusterFrontend(n_nodes=1)
        fe.place_instance("f", tiny_model, tiny_params, alloc,
                          kv_shared_frac=0.5)  # continuous: no sharing


def test_backend_places_with_profiled_shared_frac(tiny_model, tiny_params):
    """LiveBackend.place charges max(spec, point) shared fraction: a
    profile table carrying evidence of sharing admits where frac=0 does
    not."""
    from repro.control.backend import LiveBackend
    from repro.control.spec import FunctionSpec
    from repro.core.model_sharing import (SERVER_CONTEXT_OVERHEAD,
                                          pytree_nbytes)
    from repro.core.scaling import ProfilePoint

    paged_kv = tiny_model.kv_cache_bytes(batching="paged", max_batch=4,
                                         max_len=64, block_size=16,
                                         n_kv_blocks=8)
    budget = (pytree_nbytes(tiny_params) + SERVER_CONTEXT_OVERHEAD + 1024
              + int(paged_kv * 0.5) + paged_kv // 4)

    def place(frac):
        spec = FunctionSpec(
            name="f",
            profile=(ProfilePoint(sm=0.2, quota=0.2, throughput=1.0,
                                  kv_shared_frac=frac),),
            batching="paged", block_size=16, n_kv_blocks=8,
            framework_bytes=1024,
            model_factory=lambda: (tiny_model, tiny_params))
        backend = LiveBackend(ClusterFrontend(n_nodes=1, mem_bytes=budget))
        backend.register(spec)
        return backend.place(spec, spec.profile[0])

    assert place(0.0) is None
    assert place(0.5) is not None


def test_profiler_stamps_shared_frac_on_points(tiny_model):
    from repro.core.profiler import profile_points
    from repro.core.workload import ServiceCurve

    curve = ServiceCurve(name="chat", r_max=5.0, sm_sat=0.45, p=1.0,
                         weight_bytes=1 << 20, framework_bytes=32 << 20)
    bb = tiny_model.kv_block_bytes(8)
    pts = profile_points(curve, spatial=(0.3,), temporal=(1.0,),
                         duration=2.0, kv_budget_bytes=8 * bb,
                         kv_block_bytes=bb, kv_shared_frac=0.25)
    assert pts and all(p.kv_shared_frac == 0.25 for p in pts)
    # the stamped capacity is the stretched one: 8 / 0.75 -> 10 blocks
    assert all(p.kv_blocks == int(8 / 0.75) for p in pts)
