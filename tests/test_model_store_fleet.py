"""Fleet model store: host-RAM weight tier, staging, pipelined upload.

Covers the cold-start subsystem (``repro.serving.modelstore``) — the
per-node ``HostWeightCache`` (byte-budgeted LRU with refcount pinning),
``stage_params``/``upload_params`` (per-layer shards, blocking vs
overlapped upload bit-identity), and ``FleetModelStore`` tier
resolution (device/host/peer/cold) with pins, telemetry, and node-death
semantics — plus the per-node ``ModelStore`` eviction edge cases and
the Fig.-13 per-node storage-server accounting
(``node_shared_footprint``) the tier changes.
"""

import jax
import numpy as np
import pytest

from repro.core.model_sharing import (SERVER_CONTEXT_OVERHEAD, MemoryModel,
                                      ModelStore, node_shared_footprint,
                                      pytree_nbytes)
from repro.serving import (ClusterFrontend, FleetModelStore, HostWeightCache,
                           StagedWeights, stage_params, upload_params)
from repro.core.resources import Alloc

# -------------------------------------------------------------------------
# helpers
# -------------------------------------------------------------------------

_LIST_TREEDEF = jax.tree_util.tree_structure([0])


def fake_staged(nbytes: int) -> StagedWeights:
    """A StagedWeights of one flat uint8 leaf — cheap cache ballast."""
    arr = np.zeros(nbytes, dtype=np.uint8)
    return StagedWeights(_LIST_TREEDEF, [arr], [False], arr.nbytes)


# -------------------------------------------------------------------------
# HostWeightCache: LRU + pinning
# -------------------------------------------------------------------------


def test_cache_lru_evicts_oldest_unpinned_first():
    cache = HostWeightCache(capacity_bytes=100)
    cache.put("a", fake_staged(40))
    cache.put("b", fake_staged(40))
    cache.get("a")  # a is now most-recently-used
    cache.put("c", fake_staged(40))  # needs 20 bytes: evicts b, not a
    assert cache.keys() == ["a", "c"]
    assert cache.evictions == 1
    assert cache.used_bytes() == 80


def test_cache_refuses_to_evict_pinned_entries():
    cache = HostWeightCache(capacity_bytes=100)
    cache.put("a", fake_staged(60))
    cache.pin("a")
    with pytest.raises(MemoryError, match="pinned"):
        cache.put("b", fake_staged(60))
    # The failed put must not have dropped the pinned entry.
    assert cache.contains("a") and cache.pins("a") == 1
    # Unpinning makes it evictable and the same put succeeds.
    cache.unpin("a")
    cache.put("b", fake_staged(60))
    assert cache.keys() == ["b"]
    assert cache.evictions == 1


def test_cache_eviction_skips_pinned_evicts_next_lru():
    cache = HostWeightCache(capacity_bytes=100)
    cache.put("a", fake_staged(40))  # oldest, but pinned
    cache.put("b", fake_staged(40))
    cache.pin("a")
    cache.put("c", fake_staged(40))  # must step over a, evict b
    assert cache.keys() == ["a", "c"]


def test_cache_pin_unpin_bookkeeping():
    cache = HostWeightCache(capacity_bytes=100)
    cache.put("a", fake_staged(10))
    cache.pin("a")
    cache.pin("a")
    assert cache.pins("a") == 2
    cache.unpin("a")
    cache.unpin("a")
    cache.unpin("a")  # floor at zero, never negative
    assert cache.pins("a") == 0
    # pin/unpin of a missing key are no-ops, not errors.
    cache.pin("ghost")
    cache.unpin("ghost")
    assert cache.pins("ghost") == 0


def test_cache_put_existing_key_refreshes_recency_without_duplicating():
    cache = HostWeightCache(capacity_bytes=100)
    cache.put("a", fake_staged(40))
    cache.put("b", fake_staged(40))
    cache.put("a", fake_staged(40))  # refresh, no second copy
    assert cache.used_bytes() == 80
    cache.put("c", fake_staged(40))  # evicts b (a was refreshed)
    assert cache.keys() == ["a", "c"]


def test_cache_oversized_entry_and_bad_capacity():
    with pytest.raises(ValueError):
        HostWeightCache(capacity_bytes=0)
    cache = HostWeightCache(capacity_bytes=50)
    with pytest.raises(MemoryError):
        cache.put("big", fake_staged(60))


def test_cache_drop_and_clear():
    cache = HostWeightCache(capacity_bytes=100)
    cache.put("a", fake_staged(10))
    cache.put("b", fake_staged(10))
    cache.drop("a")
    cache.drop("ghost")  # idempotent
    assert cache.keys() == ["b"]
    cache.clear()
    assert cache.used_bytes() == 0 and not cache.contains("b")


# -------------------------------------------------------------------------
# stage_params / upload_params: per-layer shards, upload bit-identity
# -------------------------------------------------------------------------


def test_stage_splits_layer_stacked_leaves(tiny_model, tiny_params):
    staged = stage_params(tiny_model, tiny_params)
    assert staged.nbytes == pytree_nbytes(tiny_params)
    assert any(staged.stacked), "no per-layer shards were produced"
    n_layers = tiny_model.cfg.n_layers
    for leaf, stacked in zip(staged.leaves, staged.stacked):
        if stacked:
            assert isinstance(leaf, list) and len(leaf) == n_layers
            assert all(s.flags["C_CONTIGUOUS"] for s in leaf)
        else:
            assert isinstance(leaf, np.ndarray)


@pytest.mark.parametrize("mode", ["blocking", "overlap"])
def test_upload_roundtrips_bit_identical(tiny_model, tiny_params, mode):
    staged = stage_params(tiny_model, tiny_params)
    up = jax.block_until_ready(upload_params(staged, mode=mode))
    orig = jax.tree_util.tree_leaves(tiny_params)
    new = jax.tree_util.tree_leaves(up)
    assert len(orig) == len(new)
    for a, b in zip(orig, new):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_upload_rejects_unknown_mode(tiny_model, tiny_params):
    staged = stage_params(tiny_model, tiny_params)
    with pytest.raises(ValueError, match="unknown upload mode"):
        upload_params(staged, mode="streaming")


def test_staged_copy_is_deep():
    staged = fake_staged(16)
    clone = staged.copy()
    clone.leaves[0][:] = 7
    assert not np.any(staged.leaves[0]), "copy aliased the source shards"
    assert clone.nbytes == staged.nbytes


# -------------------------------------------------------------------------
# FleetModelStore: tier resolution, pins, telemetry
# -------------------------------------------------------------------------


def test_fleet_tier_order_cold_host_peer(tiny_model, tiny_params):
    store = FleetModelStore()
    # Cold miss on node 0: stages from params, uploads, pins.
    params, e = store.acquire(0, "fn", tiny_model, tiny_params)
    assert e.tier == "cold" and e.peer is None and e.nbytes > 0
    assert store.cache(0).pins("fn") == 1
    assert store.warm_nodes("fn") == [0]
    # Host hit on the same node.
    _, e = store.acquire(0, "fn", tiny_model)
    assert e.tier == "host"
    assert store.cache(0).pins("fn") == 2
    # Peer hit on node 1: copies node 0's shards, both warm after.
    _, e = store.acquire(1, "fn", tiny_model)
    assert e.tier == "peer" and e.peer == 0
    assert store.warm_nodes("fn") == [0, 1]
    t = store.telemetry()
    assert (t["cold_misses"], t["host_hits"], t["peer_hits"]) == (1, 1, 1)
    assert t["bytes_peer"] == e.nbytes
    assert t["bytes_staged"] == e.nbytes
    assert t["bytes_h2d"] == 3 * e.nbytes
    assert t["events"] == 3


def test_fleet_device_tier_passes_params_through(tiny_model, tiny_params):
    store = FleetModelStore()
    store.acquire(0, "fn", tiny_model, tiny_params)
    sentinel = object()
    out, e = store.acquire(0, "fn", tiny_model, sentinel, resident=True)
    assert out is sentinel and e.tier == "device" and e.nbytes == 0
    assert store.device_hits == 1
    assert store.cache(0).pins("fn") == 2  # device tier still pins


def test_fleet_release_unpins_and_drop_node_forgets(tiny_model, tiny_params):
    store = FleetModelStore()
    store.acquire(0, "fn", tiny_model, tiny_params)
    store.release(0, "fn")
    assert store.cache(0).pins("fn") == 0
    store.release(5, "fn")  # unknown node: no-op
    assert store.staged_nbytes("fn") == pytree_nbytes(tiny_params)
    store.drop_node(0)
    assert store.warm_nodes("fn") == []
    assert store.staged_nbytes("fn") is None


def test_fleet_cold_miss_without_source_raises(tiny_model):
    store = FleetModelStore()
    with pytest.raises(ValueError, match="cold miss"):
        store.acquire(0, "fn", tiny_model)
    # A loader-backed miss works and is only called once.
    calls = []

    def loader():
        calls.append(1)
        return tiny_model.init(jax.random.key(0))

    _, e = store.acquire(1, "fn", tiny_model, loader=loader)
    assert e.tier == "cold" and len(calls) == 1
    store.acquire(1, "fn", tiny_model)  # host hit: loader not re-run
    assert len(calls) == 1


def test_fleet_loader_preferred_only_on_missing_params(tiny_model,
                                                       tiny_params):
    store = FleetModelStore()
    _, e = store.acquire(0, "fn", tiny_model, tiny_params,
                         loader=lambda: pytest.fail("params given"))
    assert e.tier == "cold"


# -------------------------------------------------------------------------
# Frontend integration: shared executors make redeploys compile-free
# -------------------------------------------------------------------------


def test_instances_share_jit_executors(tiny_model, tiny_params):
    fe = ClusterFrontend(n_nodes=2, window=0.05)
    alloc = Alloc(sm=0.3, quota_request=0.3, quota_limit=0.4)
    h0 = fe.place_instance("f", tiny_model, tiny_params, alloc,
                           max_batch=2, max_len=32)
    h1 = fe.place_instance("f", tiny_model, tiny_params, alloc,
                           max_batch=2, max_len=32)
    assert h0 and h1
    insts = [i for eng in fe.engines for i in eng.instances.values()]
    assert len(insts) == 2
    a, b = insts
    # Same model => the jit wrappers (and their compile caches) are the
    # same objects; a redeploy never re-traces.
    assert a._prefill is b._prefill
    assert a._decode is b._decode
    assert a._decode_tok is b._decode_tok
    assert "_jit_executors" in tiny_model.__dict__


# -------------------------------------------------------------------------
# Per-node ModelStore eviction edge cases (paper §3.5 STORE/GET)
# -------------------------------------------------------------------------


def _tree(nbytes: int):
    return [np.zeros(nbytes, dtype=np.uint8)]


def test_model_store_refuses_evicting_referenced_entries():
    store = ModelStore(capacity_bytes=100)
    store.store("a", _tree(60))
    store.get("a")  # refcount 1: not evictable
    with pytest.raises(MemoryError, match="over capacity"):
        store.store("b", _tree(60))
    assert store.contains("a")
    # Releasing the reference makes the same store succeed.
    store.put_back("a")
    store.store("b", _tree(60))
    assert store.contains("b") and not store.contains("a")


def test_model_store_refcount_underflow_raises():
    store = ModelStore()
    store.store("a", _tree(8))
    store.get("a")
    store.put_back("a")
    with pytest.raises(RuntimeError, match="underflow"):
        store.put_back("a")


def test_model_store_overwrite_preserves_refcount():
    store = ModelStore()
    store.store("a", _tree(8))
    store.get("a")
    store.store("a", _tree(16))  # weight push while an instance holds it
    assert store.refcount("a") == 1
    assert store.used_bytes() == 16


def test_model_store_get_miss_without_loader_raises():
    store = ModelStore()
    with pytest.raises(KeyError):
        store.get("ghost")


# -------------------------------------------------------------------------
# Fig.-13 accounting: one storage-server context per NODE, not per fn
# -------------------------------------------------------------------------


def test_memory_model_share_slope_and_intercept():
    mm = MemoryModel(weight_bytes=500 << 20, framework_bytes=800 << 20)
    assert mm.footprint(0, sharing=True) == 0
    assert mm.footprint(3, sharing=False) == 3 * (mm.weight_bytes
                                                  + mm.framework_bytes)
    # share(n) = weights + overhead + n * framework: the slope is the
    # per-instance framework cost, the intercept the shared weight copy
    # plus the storage-server context (Fig. 13's hatched area).
    for n in range(1, 5):
        assert (mm.footprint(n + 1, sharing=True)
                - mm.footprint(n, sharing=True)) == mm.framework_bytes
    assert (mm.footprint(1, sharing=True) - mm.framework_bytes
            == mm.weight_bytes + SERVER_CONTEXT_OVERHEAD)
    # server=False drops exactly the context, nothing else.
    assert (mm.footprint(2, sharing=True)
            - mm.footprint(2, sharing=True, server=False)
            == SERVER_CONTEXT_OVERHEAD)


def test_node_shared_footprint_charges_one_context_per_node():
    a = MemoryModel(weight_bytes=100 << 20, framework_bytes=50 << 20)
    b = MemoryModel(weight_bytes=200 << 20, framework_bytes=80 << 20,
                    server_overhead=400 << 20)
    got = node_shared_footprint([(a, 2), (b, 1), (a, 0)])
    # Zero-instance entries are skipped; overhead charged once (the max),
    # not summed per function.
    expect = (a.footprint(2, sharing=True, server=False)
              + b.footprint(1, sharing=True, server=False)
              + max(a.server_overhead, b.server_overhead))
    assert got == expect
    per_fn = a.footprint(2, sharing=True) + b.footprint(1, sharing=True)
    assert per_fn - got == min(a.server_overhead, b.server_overhead)
    assert node_shared_footprint([]) == 0
    assert node_shared_footprint([(a, 0)]) == 0
