"""Block-paged KV cache: allocator, page table, engine, and equivalence.

Tier-1 tests on the tiny deterministic configs from ``conftest`` — this is
the CI smoke for the paged hot path.  Covers the ISSUE-3 edge cases:
block exhaustion under admission pressure, double-free rejection,
free-list reuse after retire, and paged-vs-dense decode equivalence per
model family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_config
from repro.core.resources import Alloc
from repro.models import build_model
from repro.serving import (NULL_BLOCK, BlockExhausted, ClusterFrontend,
                           KVPageAllocator, PageTable, ServingEngine,
                           blocks_needed)

FULL = Alloc(sm=1.0, quota_request=0.9, quota_limit=0.9)


def _prompts(spec, rng_seed=0, vocab=64):
    """spec: list of (prompt_len, max_new_tokens)."""
    rng = np.random.default_rng(rng_seed)
    return [(rng.integers(0, vocab, l, dtype=np.int32), n) for l, n in spec]


def _serve(model, params, batching, arrivals, *, max_batch=2, max_len=32,
           block_size=8, n_kv_blocks=None):
    engine = ServingEngine(window=0.1)
    engine.deploy("f", model, params, FULL, n_instances=1,
                  max_batch=max_batch, max_len=max_len, batching=batching,
                  block_size=block_size, n_kv_blocks=n_kv_blocks)
    reqs = [engine.submit("f", p, max_new_tokens=n) for p, n in arrivals]
    done = engine.pump(budget_s=120.0)
    assert done == len(reqs)
    return reqs, engine


def _only_instance(engine):
    return next(iter(engine.instances.values()))


# -- allocator units -------------------------------------------------------


def test_allocator_exhaustion_and_reuse():
    a = KVPageAllocator(n_blocks=5, block_size=8)  # 4 usable + null
    assert a.capacity == 4
    got = a.alloc(4)
    assert NULL_BLOCK not in got and len(set(got)) == 4
    assert not a.can_alloc(1)
    with pytest.raises(BlockExhausted):
        a.alloc(1)
    a.free(got[:2])
    # Freed blocks are recycled (appended, so reused in retire order).
    again = a.alloc(2)
    assert set(again) == set(got[:2])
    assert a.high_watermark == 4
    assert a.stats()["allocs"] == 6 and a.stats()["frees"] == 2


def test_allocator_rejects_double_and_foreign_free():
    a = KVPageAllocator(n_blocks=4, block_size=8)
    got = a.alloc(2)
    a.free(got)
    with pytest.raises(ValueError):
        a.free([got[0]])  # double free
    with pytest.raises(ValueError):
        a.free([NULL_BLOCK])  # the null block is never allocated
    # A rejected free must not have mutated the free list.
    assert a.free_blocks() == a.capacity and a.blocks_in_use == 0


def test_allocator_defrag_stats():
    a = KVPageAllocator(n_blocks=9, block_size=8)
    held = a.alloc(8)
    a.free(held[1::2])  # every other block -> maximally fragmented
    assert a.fragmentation() > 0.5
    a.free(held[0::2])
    assert a.defrag() == 0.0  # fully free list is one contiguous run
    assert a.stats()["defrags"] == 1


def test_page_table_rows_and_release():
    a = KVPageAllocator(n_blocks=8, block_size=4)
    t = PageTable(a)
    t.allocate(1, 9)  # 3 blocks
    t.allocate(2, 4)  # 1 block
    assert blocks_needed(9, 4) == 3 and len(t.blocks(1)) == 3
    row = t.row(1, max_blocks=5)
    assert row[:3] == t.blocks(1) and row[3:] == [NULL_BLOCK, NULL_BLOCK]
    with pytest.raises(ValueError):
        t.allocate(1, 4)  # id already live
    freed = t.release(1)
    assert a.blocks_in_use == 1 and len(freed) == 3
    assert t.release_all() == 1 and a.blocks_in_use == 0


# -- paged vs dense decode equivalence, per family -------------------------


MOE_KW = dict(name="tiny-moe", family="moe", n_experts=4, top_k=2)


@pytest.mark.parametrize("overrides", [{}, MOE_KW],
                         ids=["dense", "moe"])
def test_paged_matches_continuous_tokens(overrides):
    """Same mixed-length arrivals: the paged engine must emit exactly the
    dense slot-pool token streams (logit-path equivalence end to end)."""
    model = build_model(tiny_config(**overrides))
    params = model.init(jax.random.key(0))
    arrivals = _prompts([(4, 3), (12, 6), (7, 2), (20, 5), (5, 4), (16, 6)])
    cont, _ = _serve(model, params, "continuous", arrivals)
    paged, eng = _serve(model, params, "paged", arrivals)
    for rc, rp in zip(cont, paged):
        assert rc.done and rp.done
        assert rc.tokens_out == rp.tokens_out
    inst = _only_instance(eng)
    assert inst.refills > 0, "trace must exercise mid-flight admission"
    assert inst.allocator.blocks_in_use == 0, "drained engine leaked blocks"


def test_paged_decode_logits_match_dense(tiny_model, tiny_params):
    """Raw logits: decode_step_paged == decode_step within tolerance, with
    scrambled physical block order and an idle slot in the batch."""
    max_len, bs = 32, 8
    prompt = np.arange(9, dtype=np.int32) % tiny_model.cfg.vocab_size
    logits0, entry = jax.jit(
        lambda p, t: tiny_model.prefill(p, t, max_len=max_len))(
        tiny_params, jnp.asarray(prompt[None], jnp.int32))

    dense = dict(entry)
    cache = tiny_model.init_paged_cache(9, bs)
    row = jnp.asarray([3, 1, 4, 2], jnp.int32)  # scrambled physical order
    cache = tiny_model.append_paged(cache, entry, row)
    tables = jnp.zeros((2, max_len // bs), jnp.int32).at[0].set(row)
    pos = jnp.asarray([9, 0], jnp.int32)

    tok = jnp.argmax(logits0, -1).astype(jnp.int32)
    for _ in range(4):
        dl, dense = jax.jit(tiny_model.decode_step)(tiny_params, tok, dense)
        pl, cache = jax.jit(tiny_model.decode_step_paged)(
            tiny_params, jnp.asarray([int(tok[0]), 0], jnp.int32),
            cache, tables, pos)
        np.testing.assert_allclose(np.asarray(dl[0]), np.asarray(pl[0]),
                                   rtol=1e-4, atol=1e-4)
        pos = pos + 1
        tok = jnp.argmax(dl, -1).astype(jnp.int32)


def test_append_gather_pages_roundtrip(tiny_model, tiny_params):
    """gather_pages(append_paged(cache, entry, row), row) == entry."""
    prompt = np.arange(8, dtype=np.int32) % tiny_model.cfg.vocab_size
    _, entry = jax.jit(
        lambda p, t: tiny_model.prefill(p, t, max_len=32))(
        tiny_params, jnp.asarray(prompt[None], jnp.int32))
    cache = tiny_model.init_paged_cache(9, 8)
    row = jnp.asarray([5, 2, 7, 1], jnp.int32)
    cache = tiny_model.append_paged(cache, entry, row)
    back = tiny_model.gather_pages(cache, row, entry["pos"])
    for key in ("k", "v", "pos"):
        np.testing.assert_array_equal(
            np.asarray(back[key], np.float32),
            np.asarray(entry[key], np.float32), err_msg=key)


# -- engine: block budgeting, release, reuse -------------------------------


def test_block_exhaustion_under_admission_pressure(tiny_model, tiny_params):
    """A pool too small for two concurrent requests must serialize them —
    the queue waits for blocks, nothing is dropped, nothing leaks."""
    # Each request needs ceil((8 + 4 - 1) / 8) = 2 blocks; 3 usable blocks
    # admit exactly one at a time even though 2 decode slots are free.
    arrivals = _prompts([(8, 4)] * 4)
    reqs, eng = _serve(tiny_model, tiny_params, "paged", arrivals,
                       max_batch=2, n_kv_blocks=4)
    inst = _only_instance(eng)
    assert all(r.done and len(r.tokens_out) == 4 for r in reqs)
    assert inst.allocator.high_watermark <= 3
    assert inst.allocator.blocks_in_use == 0
    # Free-list reuse: 4 requests x 2 blocks through a 3-block pool is
    # only possible if freed blocks were recycled.
    assert inst.allocator.stats()["allocs"] == 8
    assert inst.allocator.stats()["frees"] == 8


def test_blocks_released_on_retire_drain(tiny_model, tiny_params):
    """Graceful scale-down: draining slots release their blocks into the
    free list as they finish; the closed instance leaves zero in use."""
    engine = ServingEngine(window=0.1)
    ids = engine.deploy("f", tiny_model, tiny_params, FULL, n_instances=1,
                        max_batch=2, max_len=32, batching="paged",
                        block_size=8)
    arrivals = _prompts([(8, 6), (8, 6), (8, 3)])
    reqs = [engine.submit("f", p, max_new_tokens=n) for p, n in arrivals]
    # Admit into slots, then retire mid-flight: queued strays come back,
    # occupied slots keep decoding under the token scheduler.  Step a
    # fixed count (not a wall-clock pump) so slots are still mid-decode
    # at retire even with warm shared executor caches.
    inst = engine.instances[ids[0]]
    inst.run_step()
    inst.run_step()
    alloc_ref = inst.allocator
    assert alloc_ref.blocks_in_use > 0, "test needs live paged slots"
    strays = engine.retire(ids[0], strip_queue=True)
    engine.pump(budget_s=120.0)
    assert ids[0] not in engine.instances, "drained instance must close"
    assert alloc_ref.blocks_in_use == 0, "retire leaked KV blocks"
    admitted = [r for r in reqs if r not in strays]
    assert all(r.done for r in admitted)
    assert alloc_ref.free_blocks() == alloc_ref.capacity


def test_paged_kv_bytes_strictly_below_dense_through_frontend(tiny_model,
                                                              tiny_params):
    """Acceptance: a mixed-length workload through ``ClusterFrontend`` with
    ``batching="paged"`` keeps per-step physical KV bytes-in-use strictly
    below the dense slot-pool reservation, with identical tokens out."""
    arrivals = _prompts([(4, 3), (14, 6), (6, 2), (22, 5), (5, 4),
                         (11, 3), (8, 6), (17, 2)], rng_seed=3)

    def run(batching):
        frontend = ClusterFrontend(n_nodes=2, window=0.1)
        frontend.deploy("lm", tiny_model, tiny_params,
                        Alloc(sm=0.45, quota_request=0.45, quota_limit=0.6),
                        n_instances=2, max_batch=4, max_len=32,
                        batching=batching, block_size=8)
        reqs = [frontend.submit("lm", p, max_new_tokens=n)
                for p, n in arrivals]
        done = frontend.pump(budget_s=120.0)
        assert done == len(reqs) and all(r.done for r in reqs)
        insts = [i for e in frontend.engines for i in e.instances.values()]
        return reqs, frontend, insts

    dense_reqs, dense_fe, _ = run("continuous")
    paged_reqs, paged_fe, insts = run("paged")
    # Same tokens out of both data planes (requests route identically:
    # same arrival order, same JSQ state evolution).
    assert ([r.tokens_out for r in paged_reqs]
            == [r.tokens_out for r in dense_reqs])
    # Per-step peak of every paged instance stays strictly below what the
    # dense pool reserves for the same slot capacity.
    for inst in insts:
        assert inst.kv_bytes_peak > 0
        assert inst.kv_bytes_peak < inst.dense_kv_reserved()
    assert paged_fe.kv_bytes_in_use() == 0  # all blocks back after drain
    assert paged_fe.dense_kv_reserved() == dense_fe.dense_kv_reserved()


def test_paged_admission_charges_block_budget_not_max_len():
    """Memory admission sees real block bytes: a paged deployment with a
    small block budget fits where the dense slot pool does not."""
    model = build_model(tiny_config())
    params = model.init(jax.random.key(0))
    alloc = Alloc(sm=0.2, quota_request=0.2, quota_limit=0.3)
    # Budget chosen so framework + dense KV overflows but framework +
    # 5-block paged KV fits (weights + server overhead dominate the rest).
    dense_kv = model.dense_kv_bytes(4, 64)
    paged_kv = model.kv_cache_bytes(batching="paged", max_batch=4,
                                    max_len=64, block_size=16, n_kv_blocks=5)
    assert paged_kv < dense_kv
    from repro.core.model_sharing import (SERVER_CONTEXT_OVERHEAD,
                                          pytree_nbytes)
    base = pytree_nbytes(params) + SERVER_CONTEXT_OVERHEAD
    fw = 1024
    budget = base + fw + paged_kv + (dense_kv - paged_kv) // 2
    fe_dense = ClusterFrontend(n_nodes=1, mem_bytes=budget)
    assert fe_dense.place_instance("f", model, params, alloc,
                                   framework_bytes=fw) is None
    fe_paged = ClusterFrontend(n_nodes=1, mem_bytes=budget)
    assert fe_paged.place_instance("f", model, params, alloc,
                                   batching="paged", n_kv_blocks=5,
                                   framework_bytes=fw) is not None


def test_profiled_kv_blocks_drive_paged_pool(tiny_model, tiny_params):
    """LiveBackend.place sizes the paged pool from the profile table's
    ``kv_blocks`` when the spec gives no explicit budget."""
    from repro.control.backend import LiveBackend
    from repro.control.spec import FunctionSpec
    from repro.core.profiler import paged_kv_capacity
    from repro.core.scaling import ProfilePoint

    block_bytes = tiny_model.kv_block_bytes(8)
    budget = 7 * block_bytes + block_bytes // 2
    kv_blocks = paged_kv_capacity(budget, block_bytes)
    assert kv_blocks == 7  # TOTAL pool size incl. the null block
    assert paged_kv_capacity(block_bytes, block_bytes) == 0  # null-only

    spec = FunctionSpec(
        name="f",
        profile=(ProfilePoint(sm=0.3, quota=0.3, throughput=1.0,
                              kv_blocks=kv_blocks),),
        batching="paged", block_size=8, max_len=32,
        model_factory=lambda: (tiny_model, tiny_params))
    frontend = ClusterFrontend(n_nodes=1)
    backend = LiveBackend(frontend)
    backend.register(spec)
    assert backend.place(spec, spec.profile[0]) is not None
    inst = next(iter(frontend.engines[0].instances.values()))
    assert inst.allocator.n_blocks == kv_blocks
    assert inst.allocator.capacity == kv_blocks - 1


def test_frontend_rejects_mixed_data_plane_configs(tiny_model, tiny_params):
    """One MemoryModel per function: a second placement with a different
    KV footprint must be rejected, not silently mis-accounted."""
    frontend = ClusterFrontend(n_nodes=2)
    alloc = Alloc(sm=0.2, quota_request=0.2, quota_limit=0.3)
    assert frontend.place_instance("f", tiny_model, tiny_params,
                                   alloc) is not None
    with pytest.raises(ValueError, match="different per-instance"):
        frontend.place_instance("f", tiny_model, tiny_params, alloc,
                                batching="paged", n_kv_blocks=4)
    # Same config again is fine.
    assert frontend.place_instance("f", tiny_model, tiny_params,
                                   alloc) is not None


def test_free_with_duplicate_ids_is_all_or_nothing():
    a = KVPageAllocator(n_blocks=6, block_size=8)
    got = a.alloc(3)
    with pytest.raises(ValueError):
        a.free([got[0], got[0]])  # duplicate WITHIN one free call
    # Nothing was lost: the rejected free left all three allocated.
    assert a.blocks_in_use == 3
    a.free(got)
    assert a.free_blocks() == a.capacity


def test_default_paged_pool_never_charges_more_than_dense(tiny_model):
    """The documented default (n_kv_blocks=None) must keep the paged
    admission charge at or below the dense slot-pool reservation."""
    for max_batch, max_len, bs in [(4, 64, 16), (2, 32, 8), (1, 32, 16)]:
        paged = tiny_model.kv_cache_bytes(batching="paged",
                                          max_batch=max_batch,
                                          max_len=max_len, block_size=bs)
        dense = tiny_model.dense_kv_bytes(max_batch, max_len)
        assert paged <= dense, (max_batch, max_len, bs)
    # Documented exception: a dense pool of ONE block still needs the null
    # page, so the 2-block minimum charges one extra block there.
    from repro.models.model import default_kv_blocks
    assert default_kv_blocks(1, 16, 16) == 2


def test_oversized_request_rejected_at_submit(tiny_model, tiny_params):
    """A request that cannot fit max_len is rejected up front instead of
    crashing the decode pump mid-admission (and leaking blocks)."""
    engine = ServingEngine(window=0.1)
    engine.deploy("f", tiny_model, tiny_params, FULL, max_batch=2,
                  max_len=16, batching="paged", block_size=8)
    ok = engine.submit("f", np.arange(8, dtype=np.int32), max_new_tokens=4)
    with pytest.raises(ValueError, match="KV rows"):
        engine.submit("f", np.arange(12, dtype=np.int32), max_new_tokens=8)
    # Service continues for well-formed requests; nothing leaked.
    assert engine.pump(budget_s=120.0) == 1 and ok.done
    inst = _only_instance(engine)
    assert inst.allocator.blocks_in_use == 0


def test_redeploy_after_full_drain_with_new_config(tiny_model, tiny_params):
    """Evicting a function's last replica clears its MemoryModel, so a
    redeploy may switch data-plane configs (continuous -> paged)."""
    frontend = ClusterFrontend(n_nodes=1, window=0.1)
    alloc = Alloc(sm=0.3, quota_request=0.3, quota_limit=0.4)
    [handle] = frontend.deploy("f", tiny_model, tiny_params, alloc,
                               batching="continuous")
    frontend.evict(handle)
    frontend.pump(budget_s=10.0)
    assert not frontend.placements
    # Different footprint (paged, tiny block budget) must now be accepted.
    assert frontend.place_instance("f", tiny_model, tiny_params, alloc,
                                   batching="paged",
                                   n_kv_blocks=4) is not None


def test_request_exceeding_pool_capacity_rejected_not_livelocked(
        tiny_model, tiny_params):
    """rows <= max_len but blocks > pool capacity (max_batch=1 default
    pool) must be rejected at submit, not spin _admit forever."""
    engine = ServingEngine(window=0.1)
    engine.deploy("f", tiny_model, tiny_params, FULL, max_batch=1,
                  max_len=32, batching="paged", block_size=8)
    inst = _only_instance(engine)
    assert inst.allocator.capacity == 3  # 4 total - null page
    with pytest.raises(ValueError, match="pool capacity"):
        engine.submit("f", np.arange(26, dtype=np.int32), max_new_tokens=7)
    ok = engine.submit("f", np.arange(20, dtype=np.int32), max_new_tokens=5)
    assert engine.pump(budget_s=120.0) == 1 and ok.done


def test_invalid_block_size_raises_value_error(tiny_model, tiny_params):
    from repro.control.spec import FunctionSpec
    from repro.core.scaling import ProfilePoint

    with pytest.raises(ValueError, match="block_size"):
        FunctionSpec(name="f",
                     profile=(ProfilePoint(sm=0.3, quota=0.3,
                                           throughput=1.0),),
                     batching="paged", block_size=0)
    engine = ServingEngine(window=0.1)
    with pytest.raises(ValueError, match="block_size"):
        engine.deploy("f", tiny_model, tiny_params, FULL,
                      batching="paged", block_size=0)
    # Non-paged specs stay exempt from block-size coupling.
    FunctionSpec(name="f",
                 profile=(ProfilePoint(sm=0.3, quota=0.3, throughput=1.0),),
                 max_len=24)


def test_paged_evict_reroute_across_nodes(tiny_model, tiny_params):
    """Evicting a paged instance re-routes its queued requests to another
    node whose local req-id space overlaps — sequences are keyed by slot,
    so the drain + re-route must complete without collisions or leaks."""
    frontend = ClusterFrontend(n_nodes=2, window=0.1)
    alloc = Alloc(sm=0.45, quota_request=0.45, quota_limit=0.6)
    h0, h1 = frontend.deploy("f", tiny_model, tiny_params, alloc,
                             n_instances=2, max_batch=2, max_len=32,
                             batching="paged", block_size=8)
    reqs = [frontend.submit("f", p, max_new_tokens=n)
            for p, n in _prompts([(8, 6)] * 6, rng_seed=9)]
    # Fixed step counts (not a wall-clock pump) so each node has slots
    # admitted AND requests still queued at evict time, regardless of
    # how warm the shared executor caches are.
    insts = [i for e in frontend.engines for i in e.instances.values()]
    assert len(insts) == 2
    for inst in insts:
        inst.run_step()
        inst.run_step()
        assert inst.n_active() > 0
    frontend.evict(h0)  # queued strays re-route to the other node
    done = frontend.pump(budget_s=120.0)
    assert done == len(reqs) and all(r.done for r in reqs)
    assert frontend.kv_bytes_in_use() == 0


def test_spec_rejects_undersized_kv_pool():
    from repro.control.spec import FunctionSpec
    from repro.core.scaling import ProfilePoint

    with pytest.raises(ValueError, match="n_kv_blocks"):
        FunctionSpec(name="f",
                     profile=(ProfilePoint(sm=0.3, quota=0.3,
                                           throughput=1.0),),
                     batching="paged", n_kv_blocks=1)
