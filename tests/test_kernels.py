"""Per-kernel tests: Pallas (interpret mode) and xla paths vs pure-jnp
oracles, swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas
from repro.kernels.wkv6 import wkv6_pallas

# JAX-compile-heavy (Pallas-interpret kernel sweeps): excluded from tier-1, run via `-m slow`.
pytestmark = pytest.mark.slow


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


FLASH_SHAPES = [
    # (B, Sq, Sk, H, K, D, bq, bk)
    (1, 16, 16, 4, 4, 16, 8, 8),     # MHA
    (2, 32, 32, 8, 2, 32, 8, 16),    # GQA, rectangular blocks
    (1, 64, 64, 4, 1, 64, 64, 32),   # MQA, single q block
    (2, 24, 24, 6, 3, 8, 24, 8),     # odd head count
]


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 9),
                                           (False, None)])
def test_flash_pallas_vs_ref(shape, dtype, causal, window):
    b, sq, sk, h, k, d, bq, bk = shape
    rng = np.random.default_rng(hash((shape, causal, window or 0)) % 2**32)
    q = _rand(rng, (b, sq, h, d), dtype)
    kk = _rand(rng, (b, sk, k, d), dtype)
    v = _rand(rng, (b, sk, k, d), dtype)
    out = flash_attention_pallas(q, kk, v, causal=causal, window=window,
                                 block_q=bq, block_k=bk)
    expected = ref.mha_reference(q, kk, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_xla_vs_ref_sweep(dtype):
    rng = np.random.default_rng(3)
    for (b, sq, h, k, d) in [(1, 16, 4, 2, 16), (2, 64, 8, 8, 32)]:
        q = _rand(rng, (b, sq, h, d), dtype)
        kk = _rand(rng, (b, sq, k, d), dtype)
        v = _rand(rng, (b, sq, k, d), dtype)
        out = ops.flash_attention(q, kk, v, causal=True, block_q=16,
                                  block_k=16, backend="xla")
        expected = ref.mha_reference(q, kk, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expected, np.float32),
                                   **_tol(dtype))


def test_flash_q_offset_matches_suffix():
    """q_offset positions queries at the cache tail (chunked prefill)."""
    rng = np.random.default_rng(5)
    b, s, h, k, d = 1, 32, 4, 2, 16
    q = _rand(rng, (b, s, h, d), jnp.float32)
    kk = _rand(rng, (b, s, k, d), jnp.float32)
    v = _rand(rng, (b, s, k, d), jnp.float32)
    full = ref.mha_reference(q, kk, v, causal=True)
    tail = ops.flash_attention(q[:, 16:], kk, v, causal=True, q_offset=16,
                               block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, 16:]),
                               rtol=1e-5, atol=1e-5)


DECODE_SHAPES = [
    # (B, S, H, K, D, bs)
    (2, 32, 8, 2, 16, 8),
    (1, 128, 4, 4, 32, 64),
    (3, 64, 4, 1, 64, 64),
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 17])
def test_decode_pallas_vs_ref(shape, dtype, window):
    b, s, h, k, d, bs = shape
    rng = np.random.default_rng(hash((shape, window or 0)) % 2**32)
    q = _rand(rng, (b, 1, h, d), dtype)
    kc = _rand(rng, (b, s, k, d), dtype)
    vc = _rand(rng, (b, s, k, d), dtype)
    cache_len = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
    out = decode_attention_pallas(q, kc, vc, cache_len, window=window,
                                  block_s=bs)
    expected = ref.decode_reference(q, kc, vc, cache_len, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("shape", DECODE_SHAPES)
def test_decode_quant_pallas_vs_dequant_ref(shape):
    """int8-KV decode kernel (§Perf D): pallas(int8) == ref(dequantized)."""
    from repro.kernels.decode_attention import decode_attention_quant_pallas
    from repro.models.attention import kv_quantize

    b, s, h, k, d, bs = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    q = _rand(rng, (b, 1, h, d), jnp.bfloat16)
    kc = _rand(rng, (b, s, k, d), jnp.bfloat16)
    vc = _rand(rng, (b, s, k, d), jnp.bfloat16)
    k8, ks = kv_quantize(kc)
    v8, vs = kv_quantize(vc)
    cache_len = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
    out = decode_attention_quant_pallas(q, k8, v8, ks, vs, cache_len,
                                        block_s=bs)
    # Oracle: dequantize, then the bf16 reference — isolates kernel math.
    deq = lambda c, sc: (c.astype(jnp.float32)
                         * sc.astype(jnp.float32)).astype(jnp.bfloat16)
    expected = ref.decode_reference(q, deq(k8, ks), deq(v8, vs), cache_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               **_tol(jnp.bfloat16))
    # And the ops wrapper dispatches both backends consistently.
    out_xla = ops.decode_attention_quant(q, k8, v8, ks, vs, cache_len,
                                         backend="xla")
    np.testing.assert_allclose(np.asarray(out_xla, np.float32),
                               np.asarray(expected, np.float32),
                               **_tol(jnp.bfloat16))


PAGED_SHAPES = [
    # (B, H, K, D, bs, M, N)  — M table slots/seq, N physical blocks
    (2, 8, 2, 16, 8, 4, 12),
    (3, 4, 4, 32, 16, 3, 16),
    (1, 4, 1, 64, 32, 2, 5),
]


def _paged_tables(rng, b, m, n, bs):
    """Disjoint per-sequence block lists + valid lengths, null-padded."""
    perm = rng.permutation(np.arange(1, n))  # never the null block 0
    tables = np.zeros((b, m), np.int32)
    cache_len = np.zeros((b,), np.int32)
    take = 0
    for i in range(b):
        used = int(rng.integers(1, m + 1))
        tables[i, :used] = perm[take:take + used]
        take += used
        cache_len[i] = rng.integers(max((used - 1) * bs, 1), used * bs + 1)
    return jnp.asarray(tables), jnp.asarray(cache_len)


@pytest.mark.parametrize("shape", PAGED_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_pallas_vs_gather_ref(shape, dtype):
    """Block-table walk == gather-then-dense-reference, ragged lengths."""
    b, h, k, d, bs, m, n = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    q = _rand(rng, (b, 1, h, d), dtype)
    kp = _rand(rng, (n, bs, k, d), dtype)
    vp = _rand(rng, (n, bs, k, d), dtype)
    tables, cache_len = _paged_tables(rng, b, m, n, bs)
    out = ops.paged_decode_attention(q, kp, vp, tables, cache_len,
                                     backend="pallas")
    gk = ops._gather_pages(kp, tables)
    gv = ops._gather_pages(vp, tables)
    expected = ref.decode_reference(q, gk, gv, cache_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               **_tol(dtype))
    out_xla = ops.paged_decode_attention(q, kp, vp, tables, cache_len,
                                         backend="xla")
    np.testing.assert_allclose(np.asarray(out_xla, np.float32),
                               np.asarray(expected, np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("shape", PAGED_SHAPES)
def test_paged_decode_quant_pallas_vs_dequant_ref(shape):
    """int8-KV paged kernel: pallas(int8 pages) == ref(dequantized gather)."""
    from repro.models.attention import kv_quantize

    b, h, k, d, bs, m, n = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    q = _rand(rng, (b, 1, h, d), jnp.bfloat16)
    kp = _rand(rng, (n, bs, k, d), jnp.bfloat16)
    vp = _rand(rng, (n, bs, k, d), jnp.bfloat16)
    k8, ks = kv_quantize(kp)
    v8, vs = kv_quantize(vp)
    tables, cache_len = _paged_tables(rng, b, m, n, bs)
    out = ops.paged_decode_attention_quant(q, k8, v8, ks, vs, tables,
                                           cache_len, backend="pallas")
    deq = lambda c, sc: (c.astype(jnp.float32)
                         * sc.astype(jnp.float32)).astype(jnp.bfloat16)
    expected = ref.decode_reference(
        q, ops._gather_pages(deq(k8, ks), tables),
        ops._gather_pages(deq(v8, vs), tables), cache_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               **_tol(jnp.bfloat16))


WKV_SHAPES = [
    # (B, S, H, D, bt)
    (2, 16, 2, 8, 8),
    (1, 32, 4, 16, 16),
    (2, 24, 1, 32, 24),
]


@pytest.mark.parametrize("shape", WKV_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_pallas_vs_ref(shape, dtype):
    b, s, h, d, bt = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    r = _rand(rng, (b, s, h, d), dtype)
    k = _rand(rng, (b, s, h, d), dtype)
    v = _rand(rng, (b, s, h, d), dtype)
    w = (-jnp.exp(_rand(rng, (b, s, h, d), jnp.float32) * 0.3) - 0.01
         ).astype(dtype)
    u = _rand(rng, (h, d), dtype)
    st = _rand(rng, (b, h, d, d), jnp.float32)
    out, s_t = wkv6_pallas(r, k, v, w, u, st, block_t=bt)
    eo, es = ref.wkv6_reference(r, k, v, w, u, st)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(eo, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(es),
                               **_tol(dtype))


def test_wkv6_chunking_invariance():
    """Chunked scan must be exactly associative across chunk boundaries."""
    rng = np.random.default_rng(11)
    b, s, h, d = 1, 32, 2, 8
    r = _rand(rng, (b, s, h, d), jnp.float32)
    k = _rand(rng, (b, s, h, d), jnp.float32)
    v = _rand(rng, (b, s, h, d), jnp.float32)
    w = -jnp.exp(_rand(rng, (b, s, h, d), jnp.float32) * 0.3) - 0.01
    u = _rand(rng, (h, d), jnp.float32)
    st = jnp.zeros((b, h, d, d), jnp.float32)
    o1, s1 = wkv6_pallas(r, k, v, w, u, st, block_t=32)
    o2, s2 = wkv6_pallas(r, k, v, w, u, st, block_t=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-6)


SSM_SHAPES = [
    # (B, S, H, D, N, bt)
    (2, 16, 2, 8, 4, 8),
    (1, 32, 4, 16, 8, 16),
]


@pytest.mark.parametrize("shape", SSM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_pallas_vs_ref(shape, dtype):
    b, s, h, d, n, bt = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    x = _rand(rng, (b, s, h, d), dtype)
    dt = jnp.abs(_rand(rng, (b, s, h), jnp.float32) * 0.1).astype(dtype)
    a_log = _rand(rng, (h, n), jnp.float32) * 0.2
    bm = _rand(rng, (b, s, h, n), dtype)
    cm = _rand(rng, (b, s, h, n), dtype)
    st = _rand(rng, (b, h, d, n), jnp.float32)
    y, s_t = ssm_scan_pallas(x, dt, a_log, bm, cm, st, block_t=bt)
    ey, es = ref.ssm_reference(x, dt, a_log, bm, cm, st)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ey, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(es), **_tol(dtype))


def test_state_carry_across_calls_matches_single_call():
    """Running the kernel on two halves with carried state == one call."""
    rng = np.random.default_rng(13)
    b, s, h, d, n = 1, 16, 2, 8, 4
    x = _rand(rng, (b, s, h, d), jnp.float32)
    dt = jnp.abs(_rand(rng, (b, s, h), jnp.float32) * 0.1)
    a_log = _rand(rng, (h, n), jnp.float32) * 0.2
    bm = _rand(rng, (b, s, h, n), jnp.float32)
    cm = _rand(rng, (b, s, h, n), jnp.float32)
    st = jnp.zeros((b, h, d, n), jnp.float32)
    y_full, s_full = ssm_scan_pallas(x, dt, a_log, bm, cm, st, block_t=8)
    y1, s1 = ssm_scan_pallas(x[:, :8], dt[:, :8], a_log, bm[:, :8],
                             cm[:, :8], st, block_t=8)
    y2, s2 = ssm_scan_pallas(x[:, 8:], dt[:, 8:], a_log, bm[:, 8:],
                             cm[:, 8:], s1, block_t=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-5, atol=1e-6)
