"""Tests for the model sharing store and memory model (paper §3.5, Fig. 13)."""

import numpy as np
import pytest

from repro.core.model_sharing import (SERVER_CONTEXT_OVERHEAD, MemoryModel,
                                      ModelStore, pytree_nbytes)

MB = 1024 * 1024


def make_params(kb=4):
    return {"w": np.zeros((kb * 256,), np.float32),  # kb KiB
            "b": {"x": np.zeros((4,), np.float32)}}


def test_get_returns_same_object_zero_copy():
    store = ModelStore()
    params = make_params()
    store.store("f", params)
    t1 = store.get("f")
    t2 = store.get("f")
    assert t1 is params and t2 is params  # by-reference, no copies
    assert store.refcount("f") == 2
    store.put_back("f")
    assert store.refcount("f") == 1


def test_get_miss_triggers_store_via_loader():
    store = ModelStore()
    calls = []

    def loader():
        calls.append(1)
        return make_params()

    t1 = store.get("f", loader)
    t2 = store.get("f", loader)
    assert t1 is t2 and len(calls) == 1  # STORE once, GET thereafter
    assert store.misses == 1 and store.hits == 1


def test_refcount_underflow_raises():
    store = ModelStore()
    store.store("f", make_params())
    with pytest.raises(RuntimeError):
        store.put_back("f")


def test_eviction_frees_unreferenced_largest_first():
    store = ModelStore(capacity_bytes=pytree_nbytes(make_params(8)) + 64)
    store.store("big", make_params(8))
    store.store("small", make_params(1))  # evicts "big"
    assert store.refcount("big") == 0
    with pytest.raises(KeyError):
        store.get("big")


def test_eviction_never_removes_referenced():
    params = make_params(8)
    store = ModelStore(capacity_bytes=pytree_nbytes(params) + 64)
    store.store("f", params)
    store.get("f")  # pin
    with pytest.raises(MemoryError):
        store.store("g", make_params(8))


def test_pytree_nbytes_counts_all_leaves():
    assert pytree_nbytes(make_params(4)) == 4 * 1024 + 16


# -- Fig. 13 memory model ----------------------------------------------------


def vit_huge():
    # Calibrated to the paper: 4735M no-share single pod; shared pod 2101M;
    # server = weights + 345M context ≈ 2979M.
    return MemoryModel(weight_bytes=2634 * MB, framework_bytes=2101 * MB,
                       server_overhead=345 * MB)


def test_vit_huge_paper_numbers():
    mm = vit_huge()
    assert mm.footprint(1, sharing=False) == 4735 * MB
    assert mm.footprint(3, sharing=False) == 14205 * MB
    shared3 = mm.footprint(3, sharing=True)
    assert shared3 == (2634 + 345 + 3 * 2101) * MB  # 9282M: paper §5.5
    # Paper: "resulting in a 4.8G reduction"
    assert (mm.footprint(3, False) - shared3) / MB == pytest.approx(4923, abs=1)


def test_sharing_reduction_grows_with_instances_and_model_size():
    mm = vit_huge()
    assert mm.reduction(3) > mm.reduction(2) > mm.reduction(1)
    small = MemoryModel(weight_bytes=98 * MB, framework_bytes=1427 * MB)
    assert mm.reduction(3) > small.reduction(3)  # larger models gain more


def test_single_instance_sharing_can_cost_memory():
    """Paper: with one pod, sharing may be slightly *higher* (server ctx)."""
    mm = vit_huge()
    assert mm.footprint(1, sharing=True) > mm.footprint(1, sharing=False)


def test_max_instances_resnext_7_vs_4():
    """Paper §5.5: 16G V100 fits 7 ResNeXt pods with sharing vs 4 without."""
    resnext = MemoryModel(weight_bytes=2100 * MB, framework_bytes=1900 * MB,
                          server_overhead=300 * MB)
    cap = 16 * 1024 * MB
    assert resnext.max_instances(cap, sharing=False) == 4
    assert resnext.max_instances(cap, sharing=True) == 7
