"""Fused on-device sampler vs host reference: statistical + edge cases.

``ops.sample_tokens`` is the device side of the sync-free sampled decode
round; the engine's ``fused=False`` path replays the same PRNG key stream
eagerly.  These tests pin the contract both paths share:

* ``temperature -> 0`` degenerates to ``greedy_sample`` (bit-identical
  argmax, vocab-clipped);
* a sampled id is always ``< vocab_size`` even when padded-vocab columns
  hold the largest logits (the clip runs before the filters);
* top-k draws land only inside the top-k set, top-p draws only inside
  the nucleus mass cutoff, and ``top_p -> 0`` keeps the argmax;
* device sampling is bit-reproducible from the key — same key, same
  token — which is what makes the engine's fused/non-fused paths diff
  bit-identically;
* draw frequencies match the host softmax distribution within a
  tolerance band (seeded via ``--repro-seed``: the ``repro_rng`` fixture
  generates the logits, so a failure replays exactly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

V = 48          # real vocab
VPAD = 64       # padded vocab (16 padding columns)


def _logits(rng, batch=4, scale=3.0, pad_high=False):
    """Random padded logits.  By default padding columns sit low (a real
    lm head never routes mass there); ``pad_high`` instead makes them the
    largest entries to probe the stochastic sampler's vocab clip.  Note
    ``greedy_sample`` argmaxes the PADDED logits by design (bit-parity
    with the engine's greedy fused path), so the ``temperature == 0``
    tests use the default low padding."""
    x = rng.normal(size=(batch, VPAD)).astype(np.float32) * scale
    x[:, V:] = 100.0 if pad_high else -1e9
    return jnp.asarray(x)


def _host_softmax(logits_np, temperature=1.0):
    x = logits_np[:, :V].astype(np.float64) / temperature
    x -= x.max(axis=-1, keepdims=True)
    p = np.exp(x)
    return p / p.sum(axis=-1, keepdims=True)


def test_temperature_zero_is_argmax(repro_rng):
    logits = _logits(repro_rng)
    tok = ops.sample_tokens(logits, jax.random.key(0), V, temperature=0.0)
    ref = np.argmax(np.asarray(logits)[:, :V], axis=-1)
    assert np.array_equal(np.asarray(tok), ref)
    # and bit-identical to the greedy kernel the plain fused path uses
    assert np.array_equal(np.asarray(tok),
                          np.asarray(ops.greedy_sample(logits, V)))


def test_vocab_clip_never_samples_padding(repro_rng):
    """Padding columns carry +100 logits; no draw may land there."""
    logits = _logits(repro_rng, pad_high=True)
    for i in range(32):
        tok = ops.sample_tokens(logits, jax.random.key(i), V,
                                temperature=1.5)
        assert np.all(np.asarray(tok) < V)


def test_top_k_draws_stay_in_top_k_set(repro_rng):
    logits = _logits(repro_rng)
    k = 5
    order = np.argsort(-np.asarray(logits)[:, :V], axis=-1)[:, :k]
    for i in range(32):
        tok = np.asarray(ops.sample_tokens(logits, jax.random.key(i), V,
                                           temperature=1.0, top_k=k))
        for b in range(tok.shape[0]):
            assert tok[b] in order[b], (
                f"top-k draw {tok[b]} outside top-{k} set {order[b]}")


def test_top_p_mass_cutoff(repro_rng):
    """Nucleus: draws only from the smallest prefix of sorted probs whose
    mass-before is < top_p (the argmax always survives)."""
    logits = _logits(repro_rng, scale=2.0)
    probs = _host_softmax(np.asarray(logits))
    top_p = 0.6
    allowed = []
    for b in range(probs.shape[0]):
        order = np.argsort(-probs[b])
        before = np.cumsum(probs[b][order]) - probs[b][order]
        n_keep = max(int((before < top_p).sum()), 1)
        allowed.append(set(order[:n_keep].tolist()))
    for i in range(32):
        tok = np.asarray(ops.sample_tokens(logits, jax.random.key(i), V,
                                           temperature=1.0, top_p=top_p))
        for b in range(tok.shape[0]):
            assert tok[b] in allowed[b], (
                f"top-p draw {tok[b]} outside nucleus {sorted(allowed[b])}")


def test_top_p_zero_keeps_argmax(repro_rng):
    """top_p -> 0 clamps the nucleus to >= 1 entry: pure argmax."""
    logits = _logits(repro_rng)
    ref = np.argmax(np.asarray(logits)[:, :V], axis=-1)
    for i in range(8):
        tok = ops.sample_tokens(logits, jax.random.key(i), V,
                                temperature=1.0, top_p=1e-9)
        assert np.array_equal(np.asarray(tok), ref)


def test_same_key_bit_reproducible(repro_rng):
    """The key fully determines the draw — the property the engine's
    fused and ``fused=False`` sampled paths rely on to diff
    bit-identically from one seed."""
    logits = _logits(repro_rng)
    for i in range(8):
        a = ops.sample_tokens(logits, jax.random.key(i), V,
                              temperature=0.8, top_k=7, top_p=0.9)
        b = ops.sample_tokens(logits, jax.random.key(i), V,
                              temperature=0.8, top_k=7, top_p=0.9)
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_distribution_matches_host_softmax(repro_rng, repro_seed):
    """Tolerance-banded frequency check vs the numpy softmax reference
    (small vocab, many draws, Gumbel-trick categorical)."""
    vocab = 8
    logits_np = np.zeros((1, vocab), np.float32)
    logits_np[0, :5] = repro_rng.normal(size=5).astype(np.float32) * 1.5
    logits_np[0, 5:] = -50.0          # ~zero mass tail
    logits = jnp.asarray(logits_np)
    n = 4000
    keys = jax.random.split(jax.random.key(repro_seed + 11), n)
    toks = np.asarray(jax.vmap(
        lambda key: ops.sample_tokens(logits, key, vocab,
                                      temperature=1.0))(keys)).ravel()
    freq = np.bincount(toks, minlength=vocab) / n
    x = logits_np[0].astype(np.float64)
    x -= x.max()
    p = np.exp(x) / np.exp(x).sum()
    # band: 5 sigma of the binomial sampling error per bucket, floor 0.02
    tol = np.maximum(5.0 * np.sqrt(p * (1 - p) / n), 0.02)
    assert np.all(np.abs(freq - p) <= tol), (
        f"freq {freq.round(3)} vs softmax {p.round(3)} (tol {tol.round(3)})")


def test_temperature_sharpens_distribution(repro_rng, repro_seed):
    """Lower temperature concentrates mass on the argmax (statistical,
    banded): P_hat[argmax | T=0.5] > P_hat[argmax | T=2.0]."""
    vocab = 8
    logits_np = repro_rng.normal(size=(1, vocab)).astype(np.float32)
    logits = jnp.asarray(logits_np)
    top = int(np.argmax(logits_np[0]))
    n = 2000
    keys = jax.random.split(jax.random.key(repro_seed + 13), n)

    def frac_top(temp):
        toks = np.asarray(jax.vmap(
            lambda key: ops.sample_tokens(logits, key, vocab,
                                          temperature=temp))(keys)).ravel()
        return float((toks == top).mean())

    assert frac_top(0.5) > frac_top(2.0) + 0.05
