"""Per-architecture smoke tests: reduced configs, one forward / prefill /
decode (+ a train-style grad step) on CPU; assert shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config
from repro.models import build_model

# JAX-compile-heavy (per-arch jit compiles dominate): excluded from tier-1, run via `-m slow`.
pytestmark = pytest.mark.slow

ARCHS = all_arch_ids()


def _data(model, batch=2, seq=16, key=0):
    rng = np.random.default_rng(key)
    tokens = jnp.asarray(
        rng.integers(0, model.cfg.vocab_size, (batch, seq)), jnp.int32)
    ctx = None
    if model.needs_ctx():
        ctx = jnp.asarray(
            rng.normal(size=(batch, model.cfg.n_context_tokens,
                             model.cfg.d_model)) * 0.02, jnp.bfloat16)
    return tokens, ctx


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch, reduced=True)
        m = build_model(cfg)
        out[arch] = (m, m.init(jax.random.key(0)))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(models, arch):
    model, params = models[arch]
    tokens, ctx = _data(model)
    logits, aux = model.forward(params, tokens, ctx=ctx)
    assert logits.shape == (2, 16, model.cfg.padded_vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step_finite(models, arch):
    model, params = models[arch]
    tokens, ctx = _data(model)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, aux = model.forward(p, tokens, ctx=ctx, remat=True)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    # Gradients reach the embedding table (end-to-end connectivity).
    gnorm = sum(float(jnp.abs(g).sum()) for g in flat)
    assert gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(models, arch):
    """Prefill + decode_step must reproduce the full-forward logits."""
    model, params = models[arch]
    tokens, ctx = _data(model, seq=12)
    max_len = 16
    logits_full, _ = model.forward(params, tokens, ctx=ctx, train=False)
    logits_pre, cache = model.prefill(params, tokens, max_len=max_len,
                                      ctx=ctx)
    assert logits_pre.shape == (2, model.cfg.padded_vocab)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)
    # One decode step == forward over seq+1 at the last position.
    next_tok = jnp.argmax(logits_pre, axis=-1).astype(jnp.int32)
    next_tok = jnp.minimum(next_tok, model.cfg.vocab_size - 1)
    logits_dec, cache2 = model.decode_step(params, next_tok, cache)
    tokens_ext = jnp.concatenate([tokens, next_tok[:, None]], axis=1)
    logits_full2, _ = model.forward(params, tokens_ext, ctx=ctx, train=False)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full2[:, -1]),
                               rtol=5e-2, atol=5e-2)
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_instantiates_abstractly(arch):
    """The FULL config must build specs + abstract params w/o allocation."""
    cfg = get_config(arch, reduced=False)
    model = build_model(cfg)
    tree = model.abstract_params()
    n = model.n_params()
    assert n > 1e8, f"{arch}: suspiciously few params {n}"
    leaves = jax.tree_util.tree_leaves(tree)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_param_counts_match_public_sizes():
    """Sanity: derived param counts are in range of the published sizes."""
    expect = {
        "qwen2-7b": (6e9, 9e9),
        "gemma3-27b": (24e9, 30e9),
        "starcoder2-15b": (13e9, 17e9),
        "qwen1.5-110b": (95e9, 120e9),
        "rwkv6-1.6b": (1.3e9, 2.2e9),
        "mixtral-8x7b": (42e9, 50e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),
        "hymba-1.5b": (1.1e9, 2.2e9),
        "llama-3.2-vision-11b": (9e9, 13e9),
        "seamless-m4t-large-v2": (1.2e9, 2.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = build_model(get_config(arch)).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_below_total():
    cfg = get_config("mixtral-8x7b")
    m = build_model(cfg)
    assert cfg.active_param_count() < m.n_params() * 0.45
