"""Tests for the Heuristic Scaling Algorithm (paper Alg. 1)."""

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core.scaling import (FunctionPodQueue, ProfilePoint,
                                heuristic_scale, processing_gap)


POINTS = [
    ProfilePoint(sm=0.06, quota=0.2, throughput=4.0),   # rpr 333
    ProfilePoint(sm=0.12, quota=0.4, throughput=15.0),  # rpr 312
    ProfilePoint(sm=0.12, quota=1.0, throughput=37.0),  # rpr 308
    ProfilePoint(sm=0.24, quota=1.0, throughput=71.0),  # rpr 296
    ProfilePoint(sm=0.50, quota=1.0, throughput=71.4),  # rpr 143 (saturated)
]


def rpr(p):
    return p.throughput / (p.sm * p.quota)


def test_scale_up_uses_p_eff_bulk_plus_p_ideal_residual():
    queues = {}
    decisions = heuristic_scale({"f": 11.0}, {"f": POINTS}, queues)
    ups = [d for d in decisions if d.direction > 0]
    p_eff = max(POINTS, key=rpr)
    # n = floor(11 / 4) = 2 pods of p_eff, residual 3 -> smallest point with
    # T > 3 minimizing T - r is p_eff itself (T=4).
    assert [d.point for d in ups] == [p_eff, p_eff, p_eff]
    assert queues["f"].capacity() == pytest.approx(12.0)


def test_scale_up_residual_picks_minimal_sufficient():
    queues = {}
    decisions = heuristic_scale({"f": 71.5}, {"f": POINTS}, queues)
    ups = [d for d in decisions if d.direction > 0]
    p_eff = max(POINTS, key=rpr)  # T=4
    assert ups[:-1] == [d for d in ups[:-1]]  # 17 p_eff pods (68 rps)
    assert len(ups) == 18
    assert all(d.point == p_eff for d in ups[:-1])
    # residual = 71.5 - 17*4 = 3.5 -> minimal sufficient is T=4 (p_eff).
    assert ups[-1].point.throughput == 4.0


def test_scale_down_pops_lowest_rpr_first():
    queues = {"f": FunctionPodQueue()}
    low = ProfilePoint(sm=0.5, quota=1.0, throughput=20.0)   # rpr 40
    high = ProfilePoint(sm=0.12, quota=0.4, throughput=15.0)  # rpr 312
    queues["f"].push("pod-low", low)
    queues["f"].push("pod-high", high)
    decisions = heuristic_scale({"f": -20.0}, {"f": POINTS}, queues)
    downs = [d for d in decisions if d.direction < 0]
    assert [d.pod_id for d in downs] == ["pod-low"]
    # Remaining capacity (15) still covers load (35 - 20 = 15 >= demand).
    assert queues["f"].capacity() == pytest.approx(15.0)


def test_scale_down_never_undershoots_capacity():
    queues = {"f": FunctionPodQueue()}
    p = ProfilePoint(sm=0.2, quota=0.5, throughput=10.0)
    for i in range(3):
        queues["f"].push(f"pod-{i}", p)
    # Gap of -5: removing any pod would drop capacity below demand (25).
    decisions = heuristic_scale({"f": -5.0}, {"f": POINTS}, queues)
    assert [d for d in decisions if d.direction < 0] == []
    assert queues["f"].capacity() == pytest.approx(30.0)


def test_slo_filter_excludes_slow_points():
    points = [
        ProfilePoint(sm=0.06, quota=0.2, throughput=4.0, p99_latency=0.5),
        ProfilePoint(sm=0.24, quota=1.0, throughput=71.0, p99_latency=0.05),
    ]
    queues = {}
    decisions = heuristic_scale({"f": 10.0}, {"f": points}, queues,
                                slo_latency={"f": 0.1})
    assert all(d.point.p99_latency <= 0.1 for d in decisions)


def test_processing_gap():
    queues = {"f": FunctionPodQueue()}
    queues["f"].push("p", ProfilePoint(sm=0.1, quota=0.5, throughput=30.0))
    gaps = processing_gap({"f": 50.0, "g": 7.0}, queues)
    assert gaps == {"f": 20.0, "g": 7.0}


def test_scale_up_entries_are_provisional_until_confirmed():
    queues = {}
    decisions = heuristic_scale({"f": 7.0}, {"f": POINTS}, queues)
    ups = [d for d in decisions if d.direction > 0]
    # Reserved capacity counts immediately (no double-provisioning)...
    assert queues["f"].provisional_ids() == {d.pod_id for d in ups}
    reserved = queues["f"].capacity()
    assert reserved >= 7.0
    # ...and the deployer settles each reservation: one placement succeeds,
    # the rest fail.
    queues["f"].confirm(ups[0].pod_id, "real-0")
    for d in ups[1:]:
        queues["f"].abort(d.pod_id)
    assert queues["f"].provisional_ids() == set()
    assert queues["f"].capacity() == pytest.approx(ups[0].point.throughput)
    assert len(queues["f"]) == 1


def test_abort_prevents_capacity_drift_across_passes():
    """A failed placement must re-trigger scale-up on the next pass."""
    queues = {}
    first = heuristic_scale({"f": 3.0}, {"f": POINTS}, queues)
    for d in first:
        queues["f"].abort(d.pod_id)  # deployer found no node
    assert queues["f"].capacity() == 0.0
    second = heuristic_scale({"f": 3.0}, {"f": POINTS}, queues)
    assert [d.point for d in second] == [d.point for d in first]


def test_confirm_unknown_reservation_raises():
    q = FunctionPodQueue()
    with pytest.raises(KeyError):
        q.confirm("nope", "real")
    with pytest.raises(KeyError):
        q.abort("nope")


def test_remove_of_unknown_pod_is_noop_and_leak_free():
    q = FunctionPodQueue()
    p = ProfilePoint(sm=0.2, quota=0.5, throughput=10.0)
    q.push("known", p)
    for i in range(100):  # untracked pods retired via a shared teardown path
        q.remove(f"never-pushed-{i}")
    assert q._dead == set()
    assert len(q) == 1 and q.capacity() == pytest.approx(10.0)
    q.remove("known")
    assert len(q) == 0 and q.front() is None and q._dead == set()


@settings(max_examples=60, deadline=None)
@given(st.floats(0.5, 500.0))
def test_scale_up_capacity_always_covers_gap(gap):
    """Property: after scale-up, Σ throughput of new pods >= ΔRPS."""
    queues = {}
    decisions = heuristic_scale({"f": gap}, {"f": POINTS}, queues)
    total = sum(d.point.throughput for d in decisions if d.direction > 0)
    assert total >= gap - 1e-9


@settings(max_examples=60, deadline=None)
@given(st.lists(st.sampled_from(POINTS), min_size=1, max_size=12),
       st.floats(-300.0, -0.5))
def test_scale_down_keeps_capacity_sufficient(running, gap):
    """Property: scale-down never removes so much that remaining < demand."""
    queues = {"f": FunctionPodQueue()}
    for i, p in enumerate(running):
        queues["f"].push(f"pod-{i}", p)
    demand = queues["f"].capacity() + gap  # current load implied by the gap
    heuristic_scale({"f": gap}, {"f": POINTS}, queues)
    assert queues["f"].capacity() >= max(demand, 0.0) - 1e-9
