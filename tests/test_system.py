"""End-to-end system tests: the full FaST-GShare loop wired together.

Each test exercises a multi-component path (profiler -> scheduler ->
manager -> SLO accounting; failures; elasticity), not a single unit.
"""

import dataclasses

import pytest

from repro.core.cluster import Cluster
from repro.core.profiler import ProfileDB, simulate_trial
from repro.core.scaling import ProfilePoint
from repro.core.workload import (PAPER_ZOO, diurnal_trace, poisson_arrivals,
                                 trace_arrivals)

SLO = 0.069


def _profile_resnet() -> ProfileDB:
    db = ProfileDB()
    for sm in (0.12, 0.24, 0.5):
        cap = simulate_trial(PAPER_ZOO["resnet"], sm, 1.0, duration=10.0)
        lat = simulate_trial(PAPER_ZOO["resnet"], sm, 1.0, duration=10.0,
                             overload_factor=0.8)
        db.add("resnet", dataclasses.replace(cap, p99=lat.p99))
    return db


def test_profile_scale_serve_slo_pipeline():
    """Profiler -> Alg.1 -> MRA -> token scheduler -> <=2% SLO violations."""
    db = _profile_resnet()
    profiles = {"resnet": db.table("resnet")}
    cluster = Cluster(n_nodes=4, sharing=True, max_batch=2)
    cluster.register_function("resnet", PAPER_ZOO["resnet"], slo_latency=SLO)
    cluster.deploy("resnet", db.best_rpr("resnet"), elastic_limit=1.0)
    trace = diurnal_trace(10.0, 120.0, 80.0, 80.0, 5.0) + [(80.0, 0.0)]
    arrivals = trace_arrivals("resnet", trace, seed=3)
    cluster.submit_all(arrivals)

    def control() -> None:
        now = cluster.sim.now
        recent = [r for r in arrivals if now - 2.0 <= r.arrival <= now]
        cluster.autoscale({"resnet": len(recent) / 2.0}, profiles,
                          slo_latency={"resnet": SLO}, headroom=1.8)
        if now < 80.0:
            cluster.sim.after(0.5, control)

    cluster.sim.after(0.5, control)
    cluster.run(90.0)
    rec = cluster.recorders["resnet"]
    assert rec.count() == len(arrivals), "every request served"
    assert rec.violation_ratio(since=5.0) <= 0.02
    assert cluster.rescheduled == 0
    assert cluster.gpu_utilization(20) > 0.0


def test_node_failure_no_request_loss():
    """Kill the only loaded node mid-run: the reconcile loop re-places the
    pods and every request survives the outage."""
    from repro.control import ControlPlane, FunctionSpec, SimBackend, ramp

    c = PAPER_ZOO["rnnt"]
    pt = ProfilePoint(sm=0.24, quota=1.0, throughput=c.rate(0.24, 1.0))
    cluster = Cluster(n_nodes=3, sharing=True)
    plane = ControlPlane(SimBackend(cluster))
    plane.register(FunctionSpec(name="rnnt", profile=(pt,), curve=c,
                                target_rps=ramp([(0.0, 0.0)]),
                                min_instances=2, max_instances=4))
    arrivals = poisson_arrivals("rnnt", 8.0, 30.0, seed=1)
    cluster.submit_all(arrivals)
    loaded_node = cluster.pods[next(iter(cluster.pods))].placement.node
    cluster.sim.at(10.0, lambda: cluster.fail_node(loaded_node))

    def heal() -> None:
        plane.reconcile()
        if cluster.sim.now < 50.0:
            cluster.sim.after(0.5, heal)

    cluster.sim.after(0.5, heal)
    cluster.run(60.0)
    rec = cluster.recorders["rnnt"]
    assert cluster.rescheduled >= 1
    assert rec.count() == len(arrivals), "failure must not drop requests"
    assert len(cluster.pods) == 2, "healed back to the declared floor"
    assert all(n.node_id != loaded_node or not n.pods
               for n in cluster.nodes)


def test_straggler_mitigation_moves_pods():
    cluster = Cluster(n_nodes=3, sharing=True)
    cluster.register_function("resnet", PAPER_ZOO["resnet"])
    pt = ProfilePoint(sm=0.12, quota=0.5, throughput=0.0)
    pod = cluster.deploy("resnet", pt)
    assert pod is not None
    nid = cluster.pods[pod].placement.node
    cluster.nodes[nid].slowdown = 4.0  # degraded node
    assert cluster.detect_stragglers(threshold=2.0) == [nid]
    moved = cluster.mitigate_stragglers(threshold=2.0)
    assert moved == 1
    new_node = cluster.pods[next(iter(cluster.pods))].placement.node
    assert new_node != nid


def test_elastic_quota_absorbs_bursts():
    """Q_limit > Q_request: the same load has a far better tail."""

    def p99_with(limit: float) -> float:
        cluster = Cluster(n_nodes=1, sharing=True)
        cluster.register_function("resnet", PAPER_ZOO["resnet"])
        cluster.deploy("resnet",
                       ProfilePoint(sm=0.24, quota=0.4, throughput=0.0),
                       elastic_limit=limit)
        # Load fits *within* Q_request on average, but is bursty.
        rate = PAPER_ZOO["resnet"].rate(0.24, 0.4) * 0.8
        cluster.submit_all(poisson_arrivals("resnet", rate, 30.0, seed=9))
        cluster.run(40.0)
        return cluster.recorders["resnet"].p99(since=3.0)

    capped = p99_with(0.4)
    elastic = p99_with(1.0)
    assert elastic < capped, (elastic, capped)
    assert elastic < 0.1, "elastic quota keeps the tail near service time"


def test_memory_pressure_blocks_then_sharing_admits():
    """The same fleet admits more pods with model sharing on."""
    gib = 1024**3
    cl_share = Cluster(n_nodes=1, mem_bytes=16 * gib, sharing=True)
    cl_plain = Cluster(n_nodes=1, mem_bytes=16 * gib, sharing=False)
    pt = ProfilePoint(sm=0.06, quota=0.25, throughput=1.0)
    for cl in (cl_share, cl_plain):
        cl.register_function("vit_huge", PAPER_ZOO["vit_huge"])
    n_share = sum(cl_share.deploy("vit_huge", pt) is not None
                  for _ in range(12))
    n_plain = sum(cl_plain.deploy("vit_huge", pt) is not None
                  for _ in range(12))
    assert n_share > n_plain
    assert n_plain == 3  # 16G / 4735M
    assert n_share == 6  # (2634+300) + n*2101 <= 16384


def test_scale_down_drains_before_teardown():
    """Retiring a pod with queued work must finish that work first."""
    cluster = Cluster(n_nodes=1, sharing=True)
    cluster.register_function("gnmt", PAPER_ZOO["gnmt"])
    pt = ProfilePoint(sm=0.5, quota=1.0, throughput=0.0)
    pod = cluster.deploy("gnmt", pt)
    # All arrivals land before the retire; the deep backlog must drain.
    arrivals = poisson_arrivals("gnmt", 30.0, 2.0, seed=2)
    cluster.submit_all(arrivals)
    cluster.sim.at(2.05, lambda: cluster.retire(pod))
    cluster.run(30.0)
    assert cluster.recorders["gnmt"].count() == len(arrivals)
    assert pod not in cluster.pods  # torn down after drain


def test_multi_function_packing_and_throughput():
    """Three functions share one GPU; each meets its own calibrated rate."""
    cluster = Cluster(n_nodes=2, sharing=True)
    alloc = {"resnet": (0.24, 0.4), "rnnt": (0.24, 0.4), "bert": (0.5, 0.5)}
    for fn, (sm, q) in alloc.items():
        cluster.register_function(fn, PAPER_ZOO[fn])
        assert cluster.deploy(
            fn, ProfilePoint(sm=sm, quota=q, throughput=0.0)) is not None
    assert cluster.nodes_in_use() == 1  # MRA packs all three on one node
    for fn, (sm, q) in alloc.items():
        rate = PAPER_ZOO[fn].rate(sm, q) * 0.8
        cluster.submit_all(poisson_arrivals(fn, rate, 30.0, seed=4))
    cluster.run(40.0)
    for fn, (sm, q) in alloc.items():
        rec = cluster.recorders[fn]
        served_rate = rec.throughput(6.0, 30.0)
        want = PAPER_ZOO[fn].rate(sm, q) * 0.8
        assert served_rate == pytest.approx(want, rel=0.25), fn
