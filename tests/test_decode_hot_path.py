"""Sync-free decode hot path: fused on-device sampling, donated buffers,
device-resident paged state, and overlapped dispatch.

Tier-1 tests on the tiny deterministic configs from ``conftest``:

* bit-identical token streams between the fused device sampler
  (``fused=True``, the default) and the old host-side argmax reference
  (``fused=False``) for dense, MoE, and paged instances — including
  across a mid-stream live migration and a retire-drain;
* exactly ONE host synchronisation per pump pass per instance
  (``FunctionInstance.sync_count`` via ``ServingEngine`` telemetry);
* donated KV / token / position buffers: the pre-round arrays are dead
  after dispatch (XLA updated the pool in place instead of copying);
* device-resident paged block tables / positions: uploads happen on
  admit/release events only, never per round.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_config
from repro.core.resources import Alloc
from repro.models import build_model
from repro.serving import ClusterFrontend, ServingEngine

FULL = Alloc(sm=1.0, quota_request=0.9, quota_limit=0.9)
MOE_KW = dict(name="tiny-moe", family="moe", n_experts=4, top_k=2)


def _prompts(spec, rng_seed=0, vocab=64):
    rng = np.random.default_rng(rng_seed)
    return [(rng.integers(0, vocab, l, dtype=np.int32), n) for l, n in spec]


ARRIVALS = [(4, 3), (12, 6), (7, 1), (20, 5), (5, 4), (16, 6), (6, 2)]


def _serve(model, params, batching, arrivals, *, fused, max_batch=2,
           max_len=32):
    engine = ServingEngine(window=0.1)
    engine.deploy("f", model, params, FULL, n_instances=1,
                  max_batch=max_batch, max_len=max_len, batching=batching,
                  block_size=8 if batching == "paged" else 16, fused=fused)
    reqs = [engine.submit("f", p, max_new_tokens=n) for p, n in arrivals]
    done = engine.pump(budget_s=120.0)
    assert done == len(reqs)
    return reqs, engine


# -- fused == host-argmax, all families and batching modes -----------------


@pytest.mark.parametrize("overrides,batching", [
    ({}, "continuous"), (MOE_KW, "continuous"),
    ({}, "paged"), (MOE_KW, "paged"),
], ids=["dense-continuous", "moe-continuous", "dense-paged", "moe-paged"])
def test_fused_matches_host_argmax(overrides, batching):
    """The on-device sampler (argmax + clip + slot update fused into the
    decode step) must emit exactly the host-side reference's tokens."""
    model = build_model(tiny_config(**overrides))
    params = model.init(jax.random.key(0))
    arrivals = _prompts(ARRIVALS)
    fused, eng_f = _serve(model, params, batching, arrivals, fused=True)
    host, eng_h = _serve(model, params, batching, arrivals, fused=False)
    for rf, rh in zip(fused, host):
        assert rf.done and rh.done
        assert rf.tokens_out == rh.tokens_out
    inst = next(iter(eng_f.instances.values()))
    assert inst.refills > 0, "trace must exercise mid-flight admission"


def test_free_slot_writes_dropped_not_aliased_to_last_block(tiny_model,
                                                            tiny_params):
    """Regression: the fused paged round drops free slots' writes via an
    OUT-OF-RANGE scatter index.  A negative sentinel would be normalized
    to the last physical block — which under a tight pool belongs to a
    live sequence (here request B's final block), silently corrupting its
    cached K/V and diverging from the host-argmax reference."""
    rng = np.random.default_rng(5)
    # 5-block pool (4 usable): A takes blocks [1,2], B takes [3,4] — the
    # LAST block.  A finishes after 3 tokens; B decodes 7 more rounds with
    # slot A free, each one a would-be garbage write.
    arrivals = [(rng.integers(0, 64, 8, dtype=np.int32), 3),
                (rng.integers(0, 64, 8, dtype=np.int32), 10)]

    def run(fused):
        engine = ServingEngine(window=0.1)
        engine.deploy("f", tiny_model, tiny_params, FULL, max_batch=2,
                      max_len=32, batching="paged", block_size=8,
                      n_kv_blocks=5, fused=fused)
        reqs = [engine.submit("f", p, max_new_tokens=n)
                for p, n in arrivals]
        assert engine.pump(budget_s=120.0) == len(reqs)
        return [r.tokens_out for r in reqs]

    assert run(True) == run(False)


def test_one_host_sync_per_pump_pass(tiny_model, tiny_params):
    """The fused hot path's budget: sync_count == steps, even on passes
    that admit prefills (their argmaxes share the round's single pull);
    the host-argmax reference spends strictly more."""
    # Same-length prompts: one prefill bucket, so the test measures sync
    # accounting rather than paying four bucket compiles per engine.
    arrivals = _prompts([(6, 4), (6, 1), (6, 3), (6, 5), (6, 2)])
    _, eng = _serve(tiny_model, tiny_params, "continuous", arrivals,
                    fused=True)
    (stats,) = eng.telemetry().values()
    assert stats["syncs"] == stats["steps"] > 0
    _, eng_p = _serve(tiny_model, tiny_params, "paged", arrivals,
                      fused=True)
    (pstats,) = eng_p.telemetry().values()
    assert pstats["syncs"] == pstats["steps"] > 0
    _, eng_h = _serve(tiny_model, tiny_params, "continuous", arrivals,
                      fused=False)
    (hstats,) = eng_h.telemetry().values()
    # 1 per decode round + 1 per admitted prompt.
    assert hstats["syncs"] > hstats["steps"]


def test_paged_state_uploaded_only_when_dirty(tiny_model, tiny_params):
    """Block tables / positions are device-resident: a long solo decode
    re-uploads them on admission/release events, not every round."""
    arrivals = _prompts([(4, 20)])  # one request, 19 decode rounds
    _, eng = _serve(tiny_model, tiny_params, "paged", arrivals, fused=True)
    (stats,) = eng.telemetry().values()
    assert stats["steps"] >= 19
    # One upload when the request was admitted; the release on its last
    # round dirties the state again but nothing decodes after it.
    assert stats["uploads"] == 1


def test_cache_and_token_buffers_are_donated(tiny_model, tiny_params):
    """After a fused round the pre-round KV pool and token vector are dead
    (donated to XLA, updated in place) — no per-round cache copy."""
    engine = ServingEngine(window=0.1)
    engine.deploy("f", tiny_model, tiny_params, FULL, max_batch=2,
                  max_len=32, batching="continuous")
    engine.submit("f", np.arange(8, dtype=np.int32), max_new_tokens=8)
    inst = next(iter(engine.instances.values()))
    inst.run_step()  # admit + first round
    cache_before = inst.cache
    tok_before = inst._slot_tok_dev
    inst.run_step()
    jax.block_until_ready(inst.cache["k"])
    assert cache_before["k"].is_deleted(), "KV pool was copied, not donated"
    assert tok_before.is_deleted(), "token vector was copied, not donated"
    assert not inst.cache["k"].is_deleted()
    engine.pump(budget_s=60.0)


def test_paged_pos_buffer_donated(tiny_model, tiny_params):
    engine = ServingEngine(window=0.1)
    engine.deploy("f", tiny_model, tiny_params, FULL, max_batch=2,
                  max_len=32, batching="paged", block_size=8)
    engine.submit("f", np.arange(8, dtype=np.int32), max_new_tokens=8)
    inst = next(iter(engine.instances.values()))
    inst.run_step()
    pos_before, cache_before = inst._pos_dev, inst.cache
    inst.run_step()  # clean state: no re-upload, pos donated in-jit
    jax.block_until_ready(inst.cache["k"])
    assert pos_before.is_deleted(), "pos vector was copied, not donated"
    assert cache_before["k"].is_deleted(), "paged pool copied, not donated"
    engine.pump(budget_s=60.0)


# -- migration + retire-drain keep working against device state ------------


@pytest.mark.parametrize("batching", ["continuous", "paged"])
def test_fused_migration_matches_host_path(tiny_model, tiny_params,
                                           batching):
    """Mid-stream live migration against the device-resident state: the
    fused fleet's token streams must equal the host-argmax fleet's."""
    arrivals = _prompts([(6, 8), (9, 8), (5, 8)], rng_seed=4)

    def run(fused):
        fe = ClusterFrontend(n_nodes=2, window=0.1)
        [h0] = fe.deploy("f", tiny_model, tiny_params,
                         Alloc(sm=0.4, quota_request=0.4, quota_limit=0.5),
                         max_batch=2, max_len=32, batching=batching,
                         block_size=8, fused=fused)
        reqs = [fe.submit("f", p, max_new_tokens=n) for p, n in arrivals]
        # A fixed number of steps (not a wall-clock pump budget) so slots
        # are mid-decode at migration regardless of how warm the shared
        # executor cache is.
        inst0 = next(iter(fe.engines[0].instances.values()))
        inst0.run_step()
        inst0.run_step()
        assert inst0.n_active() > 0
        new_handle = fe.migrate("f", h0, tiny_model, tiny_params, target=1)
        assert new_handle is not None
        tgt = next(iter(fe.engines[1].instances.values()))
        assert tgt.fused == fused, "migration must preserve sampling mode"
        done = fe.pump(budget_s=120.0)
        assert done == len(reqs) and all(r.done for r in reqs)
        return [r.tokens_out for r in reqs]

    assert run(True) == run(False)


@pytest.mark.parametrize("batching", ["continuous", "paged"])
def test_fused_retire_drain_matches_host_path(tiny_model, tiny_params,
                                              batching):
    """Retire mid-stream: draining slots decode on the device-resident
    state to completion, bit-identical to the host path, and release
    everything."""
    arrivals = _prompts([(8, 6), (8, 6), (8, 3), (6, 4)], rng_seed=9)

    def run(fused):
        engine = ServingEngine(window=0.1)
        [iid] = engine.deploy("f", tiny_model, tiny_params, FULL,
                              max_batch=2, max_len=32, batching=batching,
                              block_size=8, fused=fused)
        reqs = [engine.submit("f", p, max_new_tokens=n)
                for p, n in arrivals]
        # Step a fixed count (not a wall-clock pump) so slots are still
        # mid-decode at retire even with warm shared executor caches.
        inst = engine.instances[iid]
        inst.run_step()
        inst.run_step()
        assert inst.n_active() > 0, "test needs live decode slots"
        strays = engine.retire(iid, strip_queue=True)
        engine.pump(budget_s=120.0)
        assert iid not in engine.instances, "drained instance must close"
        if batching == "paged":
            assert inst.allocator.blocks_in_use == 0
        admitted = [r for r in reqs if r not in strays]
        assert admitted and all(r.done for r in admitted)
        return [(r.req_id in {s.req_id for s in strays}, r.tokens_out)
                for r in reqs]

    assert run(True) == run(False)


# -- overlapped multi-instance pump ----------------------------------------


def test_overlapped_pump_matches_serialized_tokens(tiny_model, tiny_params):
    """Co-located instances: the overlapped dispatch (round dispatched
    for every granted instance before any result is pulled) must serve
    the identical token streams the serialized pump serves."""
    arrivals = _prompts([(6, 5)] * 6 + [(6, 3)] * 3, rng_seed=2)

    def run(overlap):
        engine = ServingEngine(window=0.1)
        engine.deploy("f", tiny_model, tiny_params,
                      Alloc(sm=0.3, quota_request=0.9, quota_limit=0.9),
                      n_instances=3, max_batch=2, max_len=32)
        reqs = [engine.submit("f", p, max_new_tokens=n)
                for p, n in arrivals]
        done = engine.pump(budget_s=120.0, overlap=overlap)
        assert done == len(reqs)
        for inst in engine.instances.values():
            assert inst.sync_count == inst.steps
        return [r.tokens_out for r in reqs]

    assert run(True) == run(False)


def test_measured_profile_feeds_spec(tiny_model, tiny_params):
    """Live profiler wiring: ``measure_engine_profile`` duty-cycles the
    real jitted executors via ``measure_callable_trial`` and returns
    points a ``FunctionSpec`` accepts directly."""
    from repro.control.spec import FunctionSpec
    from repro.core.profiler import measure_engine_profile

    points = measure_engine_profile(
        tiny_model, tiny_params, spatial=(0.5,), temporal=(0.5, 1.0),
        max_batch=2, max_len=32, prompt_len=6, new_tokens=3,
        window=0.05, n_windows=2, sm_scale=lambda sm: sm)
    assert len(points) == 2
    assert all(p.throughput > 0 and p.p99_latency > 0 for p in points)
    # The higher temporal quota admits more wall-clock per window, so the
    # measured capacity must not shrink (monotone up to timer noise).
    assert points[1].throughput >= 0.5 * points[0].throughput
    spec = FunctionSpec(name="measured", profile=tuple(points),
                        slo_latency=10 * max(p.p99_latency for p in points),
                        model_factory=lambda: (tiny_model, tiny_params))
    assert spec.best_point() in points


def test_run_step_protocol_unchanged(tiny_model, tiny_params):
    """run_step (dispatch + sync chained) still returns the completions of
    exactly the step it ran — the synchronous seam migration relies on."""
    engine = ServingEngine(window=0.1)
    engine.deploy("f", tiny_model, tiny_params, FULL, max_batch=2,
                  max_len=32)
    engine.submit("f", np.arange(4, dtype=np.int32), max_new_tokens=3)
    inst = next(iter(engine.instances.values()))
    assert inst.run_step() == []          # admit (token 1) + round (token 2)
    [done] = inst.run_step()              # round 2 emits the final token
    assert done.done and len(done.tokens_out) == 3
    assert inst.n_active() == 0
