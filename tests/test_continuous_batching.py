"""Continuous (slot-level) batching: engine, slot cache, frontend, sim.

Tier-1 tests: everything here runs on the tiny deterministic config from
``conftest`` so jit compiles stay in the milliseconds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster import Cluster
from repro.core.resources import Alloc
from repro.core.scaling import ProfilePoint
from repro.core.workload import PAPER_ZOO, Request, poisson_arrivals
from repro.serving import ClusterFrontend, ServingEngine

FULL = Alloc(sm=1.0, quota_request=0.9, quota_limit=0.9)


def _prompts(n, rng_seed=0, length=8, vocab=64):
    rng = np.random.default_rng(rng_seed)
    return [rng.integers(0, vocab, length, dtype=np.int32) for _ in range(n)]


def _serve(model, params, batching, arrivals, max_batch=2, max_len=32):
    engine = ServingEngine(window=0.1)
    engine.deploy("f", model, params, FULL, n_instances=1,
                  max_batch=max_batch, max_len=max_len, batching=batching)
    reqs = [engine.submit("f", p, max_new_tokens=n) for p, n in arrivals]
    done = engine.pump(budget_s=120.0)
    assert done == len(reqs)
    return reqs, engine


# -- token-for-token equivalence ------------------------------------------


def test_continuous_matches_static_for_identical_arrivals(tiny_model,
                                                          tiny_params):
    """Same arrival trace, heterogeneous output lengths: continuous decode
    must emit exactly the tokens the static-batch reference emits."""
    arrivals = list(zip(_prompts(6), [3, 6, 4, 5, 2, 6]))
    cont, eng_c = _serve(tiny_model, tiny_params, "continuous", arrivals)
    stat, _ = _serve(tiny_model, tiny_params, "static", arrivals)
    for rc, rs in zip(cont, stat):
        assert rc.done and rs.done
        assert len(rc.tokens_out) == rc.max_new_tokens
        assert rc.tokens_out == rs.tokens_out
    inst = next(iter(eng_c.instances.values()))
    assert inst.refills > 0, "trace must exercise mid-flight admission"


def test_continuous_matches_direct_decode(tiny_model, tiny_params):
    """Single request through the slot pool == plain prefill+greedy loop."""
    prompt = np.arange(8, dtype=np.int32) % tiny_model.cfg.vocab_size
    reqs, _ = _serve(tiny_model, tiny_params, "continuous",
                     [(prompt, 5)], max_batch=4)

    logits, cache = jax.jit(
        lambda p, t: tiny_model.prefill(p, t, max_len=32))(
        tiny_params, jnp.asarray(prompt[None], jnp.int32))
    toks = [int(jnp.argmax(logits, axis=-1)[0])]
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    step = jax.jit(tiny_model.decode_step)
    for _ in range(4):
        logits, cache = step(tiny_params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(int(tok[0]))
    assert reqs[0].tokens_out == toks


# -- slot refill ----------------------------------------------------------


def test_slot_refilled_after_each_completion(tiny_model, tiny_params):
    """With max_batch=1 every completion must free the slot for the next
    queued request — queue drains even though the pool never grows."""
    arrivals = list(zip(_prompts(5), [2, 3, 2, 4, 2]))
    reqs, engine = _serve(tiny_model, tiny_params, "continuous", arrivals,
                          max_batch=1)
    inst = next(iter(engine.instances.values()))
    assert all(r.done for r in reqs)
    assert inst.n_active() == 0 and not inst.queue
    # 5 prefills + Σ(n-1) decodes can only fit in Σn slot-rounds if each
    # freed slot was reused; a retire-together batch would need more steps.
    assert inst.steps <= sum(n for _, n in arrivals) + len(arrivals)


def test_mid_flight_admission_counts_refills(tiny_model, tiny_params):
    arrivals = list(zip(_prompts(6), [6, 2, 2, 2, 2, 2]))
    reqs, engine = _serve(tiny_model, tiny_params, "continuous", arrivals,
                          max_batch=2)
    inst = next(iter(engine.instances.values()))
    # Short requests complete while the 6-token request holds its slot, so
    # every later admission joins a live decode batch.
    assert inst.refills >= 3


# -- KV-cache integrity on slot reuse -------------------------------------


def test_kv_cache_integrity_when_slot_reused(tiny_model, tiny_params):
    """A request admitted into a just-freed slot must decode exactly as if
    it had the cache to itself (stale rows fully overwritten)."""
    prompts = _prompts(3, rng_seed=7)
    # max_batch=1: request 1 and 2 decode in the SAME slot the previous
    # request just vacated.
    reqs, _ = _serve(tiny_model, tiny_params, "continuous",
                     list(zip(prompts, [4, 4, 4])), max_batch=1)
    for i, r in enumerate(reqs):
        solo, _ = _serve(tiny_model, tiny_params, "continuous",
                         [(prompts[i], 4)], max_batch=1)
        assert r.tokens_out == solo[0].tokens_out, f"slot reuse leaked (req {i})"


def test_merge_gather_slot_roundtrip(tiny_model, tiny_params):
    """gather_slot(merge_slot(cache, entry, s), s) == entry, all leaves."""
    prompt = _prompts(1)[0]
    logits, entry = jax.jit(
        lambda p, t: tiny_model.prefill(p, t, max_len=32))(
        tiny_params, jnp.asarray(prompt[None], jnp.int32))
    pool = tiny_model.init_slot_cache(4, 32)
    pool = tiny_model.merge_slot(pool, entry, jnp.int32(2))
    back = tiny_model.gather_slot(pool, jnp.int32(2))
    for key in entry:
        np.testing.assert_array_equal(
            np.asarray(back[key], np.float32),
            np.asarray(entry[key], np.float32), err_msg=key)
    # untouched slots stay zero
    other = tiny_model.gather_slot(pool, jnp.int32(0))
    assert float(jnp.abs(other["k"]).sum()) == 0.0


# -- ClusterFrontend: 2 functions x 2 nodes --------------------------------


def test_frontend_two_functions_two_nodes(tiny_model, tiny_params):
    frontend = ClusterFrontend(n_nodes=2, window=0.1)
    # 0.6-quota x 0.55-SM cannot pack twice per node -> chat spans both
    # nodes; code fills the leftover strips.
    frontend.deploy("chat", tiny_model, tiny_params,
                    Alloc(sm=0.55, quota_request=0.6, quota_limit=0.8),
                    n_instances=2, max_batch=2, max_len=32)
    frontend.deploy("code", tiny_model, tiny_params,
                    Alloc(sm=0.35, quota_request=0.6, quota_limit=0.8),
                    n_instances=2, max_batch=2, max_len=32)
    assert frontend.nodes_for("chat") == [0, 1]
    assert frontend.nodes_for("code") == [0, 1]
    # One stored weight copy per node, aliased by both functions' pytrees?
    # No — distinct functions store their own key; instances alias within.
    for engine in frontend.engines:
        assert engine.store.refcount("chat") == 1
        assert engine.store.refcount("code") == 1

    prompts = _prompts(12, rng_seed=3)
    reqs = [frontend.submit(fn, p, max_new_tokens=3 + (i % 3))
            for i, (fn, p) in enumerate(
                zip(["chat", "code"] * 6, prompts))]
    done = frontend.pump(budget_s=120.0)
    assert done == len(reqs) and all(r.done for r in reqs)
    # Both nodes actually served work.
    for engine in frontend.engines:
        assert sum(i.steps for i in engine.instances.values()) > 0


def test_frontend_memory_admission_excludes_full_node():
    """A node whose memory is exhausted is skipped even when its rectangle
    fits (mirrors core.cluster.Node.admits)."""
    from repro.models import build_model
    from conftest import tiny_config

    model = build_model(tiny_config())
    params = model.init(jax.random.key(0))
    small = Alloc(sm=0.2, quota_request=0.2, quota_limit=0.3)
    frontend = ClusterFrontend(n_nodes=2, mem_bytes=800 * 1024 * 1024)
    # Shared footprint = 300M server overhead + weights + n x 200M
    # framework: one function fits two instances on a node (~700M), but a
    # second function's server (+500M) does not.
    fb = 200 * 1024 * 1024
    frontend.deploy("a", model, params, small, n_instances=2,
                    framework_bytes=fb)
    assert frontend.nodes_for("a") == [0]
    frontend.deploy("b", model, params, small, n_instances=1,
                    framework_bytes=fb)
    assert frontend.nodes_for("b") == [1], "memory admission must spill b"


# -- simulator alignment ---------------------------------------------------


def _sim_occupancy(continuous: bool) -> tuple[float, int]:
    curve = PAPER_ZOO["rnnt"]
    cluster = Cluster(n_nodes=1, sharing=True, max_batch=8,
                      continuous=continuous)
    cluster.register_function("f", curve)
    for _ in range(8):
        assert cluster.deploy(
            "f", ProfilePoint(sm=0.12, quota=1.0, throughput=0.0)) is not None
    rps = curve.rate(0.12) * 8 / 8 * 1.6
    cluster.submit_all(poisson_arrivals("f", rps, 30.0, seed=11, n_tokens=8))
    cluster.run(35.0)
    refills = sum(p.refills for p in cluster.pods.values())
    return cluster.nodes[0].scheduler.occupancy(last_n=20), refills


def test_sim_continuous_occupancy_strictly_higher():
    """The sim mirrors the engine: slot-level batching keeps token-granted
    rounds full, so SM occupancy strictly exceeds the static-batch run."""
    occ_static, refills_static = _sim_occupancy(continuous=False)
    occ_cont, refills_cont = _sim_occupancy(continuous=True)
    assert refills_static == 0 and refills_cont > 0
    assert occ_cont > occ_static


def test_sim_single_shot_requests_unchanged():
    """n_tokens=1 + max_batch=1 is the paper's workload: continuous and
    static must behave identically (calibration preserved)."""
    curve = PAPER_ZOO["resnet"]
    out = []
    for continuous in (False, True):
        cluster = Cluster(n_nodes=1, continuous=continuous)
        cluster.register_function("f", curve)
        assert cluster.deploy(
            "f", ProfilePoint(sm=0.24, quota=1.0,
                              throughput=curve.rate(0.24))) is not None
        cluster.submit_all(poisson_arrivals("f", curve.rate(0.24) * 0.8,
                                            20.0, seed=3))
        cluster.run(25.0)
        out.append(cluster.recorders["f"].throughput(4.0, 20.0))
    assert out[0] == pytest.approx(out[1], rel=1e-9)


def test_sim_multi_token_requests_hold_slots():
    cluster = Cluster(n_nodes=1, max_batch=2, continuous=True)
    cluster.register_function("f", PAPER_ZOO["resnet"])
    pod_id = cluster.deploy(
        "f", ProfilePoint(sm=0.24, quota=1.0, throughput=0.0))
    assert pod_id is not None
    cluster.submit(Request(fn="f", arrival=0.1, req_id=0, n_tokens=50))
    cluster.run(0.2)
    pod = cluster.pods[pod_id]
    assert pod.slots and 0 < pod.slots[0].remaining < 50
    cluster.run(30.0)
    assert not pod.slots and cluster.recorders["f"].count() == 1
