"""Deadline lifecycle, gray-failure quarantine, and the chaos harness.

Covers the robustness seam end to end: seeded fault-schedule generation
and replay (``repro.core.chaos``), sim-vs-live ``decision_signature``
equality under identical chaos, the reconciler's health sweep (quarantine
+ heal on both backends), deadline shedding/expiry with typed outcomes,
bounded jittered-backoff retries (deterministic, guaranteed tier never
lost), the preemptible batch lane, and the unregister-rejects-parked
contract.
"""

from types import SimpleNamespace

import numpy as np
import pytest

from repro.control import (ControlPlane, FunctionSpec, LiveBackend,
                           SimBackend, decision_signature, ramp)
from repro.core.chaos import (ChaosInjector, ChaosSchedule, FaultEvent,
                              LiveChaosTarget, SimChaosTarget)
from repro.core.cluster import Cluster
from repro.core.links import DEFAULT_LINK_BPS
from repro.core.scaling import ProfilePoint
from repro.core.slo import (RetryPolicy, TIER_BATCH, TIER_BEST_EFFORT,
                            TIER_GUARANTEED, deadline_budget)
from repro.core.workload import Request, ServiceCurve, poisson_arrivals
from repro.serving import ClusterFrontend
from repro.serving.engine import ServeRequest, ServingEngine

PROFILE = (
    ProfilePoint(sm=0.25, quota=0.4, throughput=2.0, p99_latency=0.05),
    ProfilePoint(sm=0.45, quota=0.8, throughput=5.0, p99_latency=0.03),
)

RAMP = ramp([(0.0, 1.0), (2.0, 8.0), (6.0, 1.0)])


def tiny_curve() -> ServiceCurve:
    return ServiceCurve(name="chat", r_max=5.0, sm_sat=0.45, p=1.0,
                        weight_bytes=1 << 20, framework_bytes=32 << 20)


def make_spec(factory=None, **overrides) -> FunctionSpec:
    kw = dict(name="chat", profile=PROFILE, slo_latency=0.1, target_rps=RAMP,
              headroom=1.2, min_instances=1, max_instances=5,
              model_factory=factory, max_batch=2, max_len=32,
              framework_bytes=32 * 1024 * 1024, curve=tiny_curve())
    kw.update(overrides)
    return FunctionSpec(**kw)


# -------------------------------------------------------------------------
# Fault schedules: validation + seeded determinism
# -------------------------------------------------------------------------


def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(at=0.0, kind="meteor", node=0)
    with pytest.raises(ValueError, match=">= 0"):
        FaultEvent(at=-1.0, kind="kill", node=0)
    with pytest.raises(ValueError, match="magnitude"):
        FaultEvent(at=0.0, kind="straggler", node=0, magnitude=1.0)
    with pytest.raises(ValueError, match="duration"):
        FaultEvent(at=0.0, kind="link", node=0, duration=0.0)
    # kill ignores magnitude (it has none to speak of).
    FaultEvent(at=0.0, kind="kill", node=0, magnitude=0.5)


def test_schedule_generation_is_seed_deterministic():
    a = ChaosSchedule.generate(seed=11, duration=30.0, n_nodes=4)
    b = ChaosSchedule.generate(seed=11, duration=30.0, n_nodes=4)
    c = ChaosSchedule.generate(seed=12, duration=30.0, n_nodes=4)
    assert a.events == b.events  # byte-identical replay
    assert a.events != c.events
    assert list(a.events) == sorted(a.events, key=lambda e: e.at)
    assert all(0 <= e.node < 4 for e in a.events)
    assert all(0.0 <= e.at <= 30.0 for e in a.events)


def test_schedule_kill_budget_keeps_a_survivor():
    sched = ChaosSchedule.generate(seed=3, duration=10.0, n_nodes=3,
                                   n_events=8, kinds=("kill",))
    kills = [e for e in sched.events if e.kind == "kill"]
    assert len(kills) <= 2  # n_nodes - 1: at least one node survives
    assert len({e.node for e in kills}) == len(kills)  # no double-kill
    # The overflow degraded to stragglers instead of vanishing.
    assert len(sched.events) == 8
    assert all(e.kind == "straggler" for e in sched.events
               if e.kind != "kill")
    with pytest.raises(ValueError):
        ChaosSchedule.generate(seed=0, duration=1.0, n_nodes=0)


def test_sim_injector_applies_and_restores():
    cluster = Cluster(n_nodes=2, sharing=True)
    base_mem = cluster.nodes[0].mem_bytes
    sched = ChaosSchedule(seed=0, events=(
        FaultEvent(at=1.0, kind="straggler", node=0, magnitude=4.0,
                   duration=2.0),
        FaultEvent(at=1.0, kind="link", node=1, magnitude=2.0, duration=2.0),
        FaultEvent(at=1.0, kind="kv_pressure", node=0, magnitude=2.0,
                   duration=2.0),
        FaultEvent(at=5.0, kind="kill", node=1),
    ))
    inj = ChaosInjector(sched, SimChaosTarget(cluster))
    assert inj.advance(0.5) == 0 and inj.pending() == 4
    assert inj.advance(1.0) == 3
    assert cluster.nodes[0].slowdown == pytest.approx(4.0)
    assert cluster.nodes[0].mem_bytes == base_mem // 2
    assert cluster.links.bandwidth(0, 1) == pytest.approx(
        DEFAULT_LINK_BPS / 2)
    # All three bounded faults restore at t=3 — exactly what they changed.
    assert inj.advance(3.0) == 3
    assert cluster.nodes[0].slowdown == pytest.approx(1.0)
    assert cluster.nodes[0].mem_bytes == base_mem
    assert cluster.links.bandwidth(0, 1) == pytest.approx(DEFAULT_LINK_BPS)
    # The kill is permanent: applied once, nothing left to restore.
    assert inj.advance(10.0) == 1
    assert not cluster.nodes[1].alive and inj.pending() == 0
    assert [e.kind for _, e in inj.applied] == \
        ["straggler", "link", "kv_pressure", "kill"]


def test_live_target_straggler_and_kv_pressure():
    frontend = ClusterFrontend(n_nodes=2, window=0.05)
    target = LiveChaosTarget(frontend, straggler_unit_s=0.01)
    base_mem = frontend.mem_bytes
    undo = target.straggler(0, magnitude=3.0)
    assert frontend.engines[0].pump_delay_s == pytest.approx(0.02)
    undo()
    assert frontend.engines[0].pump_delay_s == 0.0
    undo = target.kv_pressure(0, magnitude=2.0)
    assert frontend.mem_bytes == base_mem // 2
    undo()
    assert frontend.mem_bytes == base_mem


# -------------------------------------------------------------------------
# Sim-vs-live decision parity under identical chaos
# -------------------------------------------------------------------------


def _parity_schedule() -> ChaosSchedule:
    # Node 0 is where MRA best-area-fit packs first, so the straggler and
    # the kill both hit loaded capacity on either backend.
    return ChaosSchedule(seed=0, events=(
        FaultEvent(at=1.0, kind="straggler", node=0, magnitude=3.0,
                   duration=4.0),
        FaultEvent(at=3.0, kind="kill", node=0),
        FaultEvent(at=4.0, kind="link", node=1, magnitude=2.0,
                   duration=2.0),
    ))


def test_sim_vs_live_signature_under_seeded_chaos(tiny_model, tiny_params):
    """One seeded fault schedule, two fleets, identical decisions."""

    def run(plane, injector):
        for tick in range(9):
            injector.advance(float(tick))
            plane.reconcile(now=float(tick))

    frontend = ClusterFrontend(n_nodes=2, window=0.05)
    live = ControlPlane(LiveBackend(frontend))
    live.register(make_spec(lambda: (tiny_model, tiny_params)))
    inj_live = ChaosInjector(_parity_schedule(), LiveChaosTarget(frontend))
    run(live, inj_live)

    cluster = Cluster(n_nodes=2, sharing=True)
    sim = ControlPlane(SimBackend(cluster))
    sim.register(make_spec())
    inj_sim = ChaosInjector(_parity_schedule(), SimChaosTarget(cluster))
    run(sim, inj_sim)

    assert decision_signature(live.log) == decision_signature(sim.log)
    # Both fleets saw the exact same fault history...
    assert [e for _, e in inj_live.applied] == [e for _, e in inj_sim.applied]
    # ...and both healed the kill: every surviving pod is off node 0.
    assert all(live.backend.node_of(p) == 1 for p in live.placed["chat"])
    assert all(sim.backend.node_of(p) == 1 for p in sim.placed["chat"])
    assert live.instances("chat") == sim.instances("chat")


def test_sim_vs_live_signature_under_explicit_quarantine(tiny_model,
                                                         tiny_params):
    """Quarantining the same node at the same tick heals through the same
    Alg.-1 path on both backends: the quarantine itself never enters the
    decision log, only the capacity gap it opens does."""

    def run(plane, backend):
        for tick in range(9):
            if tick == 3:
                assert backend.quarantine(0) >= 1
            plane.reconcile(now=float(tick))

    frontend = ClusterFrontend(n_nodes=2, window=0.05)
    lb = LiveBackend(frontend)
    live = ControlPlane(lb)
    live.register(make_spec(lambda: (tiny_model, tiny_params)))
    run(live, lb)

    cluster = Cluster(n_nodes=2, sharing=True)
    sb = SimBackend(cluster)
    sim = ControlPlane(sb)
    sim.register(make_spec())
    run(sim, sb)

    assert decision_signature(live.log) == decision_signature(sim.log)
    assert all(lb.node_of(p) == 1 for p in live.placed["chat"])
    assert all(sb.node_of(p) == 1 for p in sim.placed["chat"])
    # Idempotent: a second quarantine of the same node is a no-op.
    assert lb.quarantine(0) == 0 and sb.quarantine(0) == 0


# -------------------------------------------------------------------------
# Health signals + the reconciler's gray-failure sweep
# -------------------------------------------------------------------------


def test_sim_health_tracks_the_straggler_ewma():
    cluster = Cluster(n_nodes=2, sharing=True)
    cluster.register_function("chat", tiny_curve())
    assert cluster.deploy("chat", PROFILE[1]) is not None  # node 0
    assert cluster.health(0) == pytest.approx(1.0)
    SimChaosTarget(cluster).straggler(0, magnitude=5.0)
    cluster.submit_all(poisson_arrivals("chat", rps=3.0, duration=3.0,
                                        seed=1))
    cluster.run(60.0)
    # The EWMA converged toward the slowdown factor: health ~ 1/5.
    assert cluster.health(0) < 0.5
    cluster.fail_node(1)
    assert cluster.health(1) == 0.0  # dead reads zero


def test_live_engine_health_ratio():
    eng = ServingEngine(window=0.05)
    assert eng.health() == pytest.approx(1.0)  # no samples yet
    eng._lat_slow, eng._lat_fast = 0.5, 1.0  # recent passes 2x slower
    assert eng.health() == pytest.approx(0.5)
    eng._lat_slow, eng._lat_fast = 1.0, 0.8  # recovered: fast below slow
    assert eng.health() == pytest.approx(1.0)


def test_sim_sweep_quarantines_worst_first_and_keeps_one_node():
    cluster = Cluster(n_nodes=3, sharing=True)
    plane = ControlPlane(SimBackend(cluster), quarantine_threshold=0.6)
    plane.register(make_spec(min_instances=2,
                             target_rps=ramp([(0.0, 0.0)])))
    assert {cluster.node_of(p) for p in plane.placed["chat"]} == {0}
    # Every node degraded below threshold: the sweep must still keep one.
    cluster.nodes[0].lat_ewma = 5.0  # health 0.2 — worst
    cluster.nodes[1].lat_ewma = 3.0  # health 0.33
    cluster.nodes[2].lat_ewma = 2.0  # health 0.5 — least bad, survives
    plane.reconcile(now=1.0)
    assert [q.node for q in plane.quarantines] == [0, 1]
    assert [q.instances for q in plane.quarantines] == [2, 0]
    assert cluster.nodes[0].quarantined and cluster.nodes[1].quarantined
    assert not cluster.nodes[2].quarantined
    # Same tick healed the capacity onto the surviving node.
    assert plane.instances("chat") == 2
    assert all(cluster.node_of(p) == 2 for p in plane.placed["chat"])
    # Sweep is sticky: the next tick re-quarantines nothing.
    plane.reconcile(now=2.0)
    assert len(plane.quarantines) == 2
    # Health actions never touch the decision log's signature stream.
    assert all(d.function == "chat" for d in plane.log)


def test_live_sweep_quarantines_and_heals(tiny_model, tiny_params):
    frontend = ClusterFrontend(n_nodes=2, window=0.05)
    plane = ControlPlane(LiveBackend(frontend), quarantine_threshold=0.6)
    plane.register(make_spec(lambda: (tiny_model, tiny_params),
                             min_instances=2,
                             target_rps=ramp([(0.0, 1.0)])))
    assert all(int(p.split(":")[0]) == 0 for p in plane.placed["chat"])
    rng = np.random.default_rng(9)
    req = frontend.submit("chat", rng.integers(0, 64, 5, dtype=np.int32),
                          max_new_tokens=3)
    # Simulate a gray failure: recent passes twice as slow as the baseline.
    frontend.engines[0]._lat_slow = 0.01
    frontend.engines[0]._lat_fast = 0.02
    assert frontend.health(0) == pytest.approx(0.5)
    plane.reconcile(now=1.0)
    assert [q.node for q in plane.quarantines] == [0]
    assert frontend.engines[0].quarantined
    assert plane.instances("chat") == 2
    assert all(int(p.split(":")[0]) == 1 for p in plane.placed["chat"])
    # The quarantined node drains its occupants (unlike a crash) and new
    # submissions route around it.
    req2 = frontend.submit("chat", rng.integers(0, 64, 5, dtype=np.int32),
                           max_new_tokens=3)
    frontend.pump(budget_s=30.0)
    assert req.done and len(req.tokens_out) == 3
    assert req2.done and len(req2.tokens_out) == 3


# -------------------------------------------------------------------------
# Deadlines: shedding at admission, expiry in queue, typed outcomes
# -------------------------------------------------------------------------


def _burst(n: int, fn: str = "chat") -> list[Request]:
    return [Request(fn=fn, arrival=0.001 * i, req_id=i) for i in range(n)]


def test_sim_sheds_best_effort_that_cannot_make_deadline():
    cluster = Cluster(n_nodes=1, sharing=True)
    cluster.register_function("chat", tiny_curve(), slo_latency=0.1,
                              slo_tier=TIER_BEST_EFFORT, deadline_s=0.5)
    cluster.deploy("chat", PROFILE[0])  # 2 req/s: one fits the budget
    cluster.submit_all(_burst(10))
    cluster.run(30.0)
    rec = cluster.recorders["chat"]
    assert cluster.shed >= 1
    assert rec.shed == cluster.shed
    assert rec.count() + rec.shed == 10  # every request got an outcome
    assert cluster.dropped == 0 and cluster.expired == 0


def test_sim_never_sheds_or_expires_guaranteed():
    cluster = Cluster(n_nodes=1, sharing=True)
    cluster.register_function("vip", tiny_curve(), slo_latency=0.1,
                              slo_tier=TIER_GUARANTEED, deadline_s=0.5)
    cluster.deploy("vip", PROFILE[0])
    cluster.submit_all(_burst(8, fn="vip"))
    cluster.run(30.0)
    rec = cluster.recorders["vip"]
    assert cluster.shed == 0 and cluster.expired == 0 and cluster.lost == 0
    assert rec.count() == 8  # all served, even the deadline-missed tail
    assert rec.deadline_missed >= 1  # late, but never dropped


def test_sim_expires_queued_requests_after_gray_failure():
    """Admission said the deadline was makeable; a straggler then slowed
    the node — queued requests expire with a typed outcome instead of
    wasting a decode slot."""
    cluster = Cluster(n_nodes=1, sharing=True)
    cluster.register_function("chat", tiny_curve(), slo_latency=0.1,
                              slo_tier=TIER_BEST_EFFORT, deadline_s=2.0)
    cluster.deploy("chat", PROFILE[1])  # 5 req/s: the whole burst admits
    cluster.submit_all(_burst(8))
    cluster.sim.at(0.01, lambda: SimChaosTarget(cluster).straggler(
        0, magnitude=60.0))
    cluster.run(200.0)
    rec = cluster.recorders["chat"]
    assert cluster.shed == 0  # admission estimate predates the straggler
    assert cluster.expired >= 1
    assert rec.expired == cluster.expired
    assert rec.count() + rec.expired == 8


def test_live_sheds_with_typed_outcome(tiny_model, tiny_params):
    frontend = ClusterFrontend(n_nodes=1, window=0.05)
    plane = ControlPlane(LiveBackend(frontend))
    plane.register(make_spec(lambda: (tiny_model, tiny_params),
                             min_instances=1, max_batch=1,
                             target_rps=ramp([(0.0, 1.0)])))
    frontend.configure_slo("chat", tier=TIER_BEST_EFFORT, deadline_s=0.05,
                           est_rps=50.0)
    rng = np.random.default_rng(2)
    reqs = [frontend.submit("chat", rng.integers(0, 64, 4, dtype=np.int32),
                            max_new_tokens=2) for _ in range(8)]
    # (load + 1) / 50 exceeds the 50 ms budget once ~2 requests queue.
    shed = [r for r in reqs if r.outcome == "shed"]
    assert frontend.shed == len(shed) >= 1
    assert all(r.done and r.finished_at >= r.submitted_at for r in shed)
    frontend.pump(budget_s=30.0)
    assert all(r.done for r in reqs)
    served = [r for r in reqs if r.outcome is None]
    assert all(len(r.tokens_out) == 2 for r in served)


def test_live_expires_queued_requests_past_deadline(tiny_model,
                                                    tiny_params):
    frontend = ClusterFrontend(n_nodes=1, window=0.05)
    plane = ControlPlane(LiveBackend(frontend))
    plane.register(make_spec(lambda: (tiny_model, tiny_params),
                             min_instances=1, max_batch=1,
                             target_rps=ramp([(0.0, 1.0)])))
    # No est_rps: shedding stays off, expiry alone polices the deadline.
    frontend.configure_slo("chat", tier=TIER_BEST_EFFORT, deadline_s=0.001)
    rng = np.random.default_rng(4)
    reqs = [frontend.submit("chat", rng.integers(0, 64, 4, dtype=np.int32),
                            max_new_tokens=2) for _ in range(4)]
    import time
    time.sleep(0.02)  # every queued deadline is now in the past
    frontend.pump(budget_s=30.0)
    assert all(r.done for r in reqs)
    expired = [r for r in reqs if r.outcome == "expired"]
    assert len(expired) >= 1 and all(not r.tokens_out for r in expired)
    node0 = frontend.engines[0]
    assert sum(t["expired"] for t in node0.telemetry().values()) \
        == len(expired)


def test_deadline_budget_resolution():
    assert deadline_budget(TIER_BEST_EFFORT, 0.4, 0.1) == 0.4  # explicit
    assert deadline_budget(TIER_GUARANTEED, None, 0.1) == 0.1  # SLO falls in
    assert deadline_budget(TIER_BATCH, None, 0.1) == 0.1
    assert deadline_budget(TIER_BEST_EFFORT, None, 0.1) is None  # dormant
    spec = make_spec(slo_tier=TIER_GUARANTEED)
    assert spec.deadline_budget() == spec.slo_latency


# -------------------------------------------------------------------------
# Retries: seeded determinism, bounded loss, guaranteed never lost
# -------------------------------------------------------------------------


def test_retry_policy_validation_and_determinism():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(multiplier=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.5)
    a = RetryPolicy(max_attempts=3, base_s=0.1, seed=7)
    b = RetryPolicy(max_attempts=3, base_s=0.1, seed=7)
    c = RetryPolicy(max_attempts=3, base_s=0.1, seed=8)
    da = [a.delay(i) for i in range(1, 5)]
    assert da == [b.delay(i) for i in range(1, 5)]  # same seed, same jitter
    assert da != [c.delay(i) for i in range(1, 5)]
    # Backoff grows with the attempt and stays within the jitter envelope.
    assert all(0.1 * 2 ** (i - 1) <= d <= 0.1 * 2 ** (i - 1) * 1.5
               for i, d in enumerate(da, start=1))
    assert not a.exhausted(2) and a.exhausted(3)


@pytest.mark.parametrize("tier,expect_lost",
                         [(TIER_BEST_EFFORT, True), (TIER_GUARANTEED, False)])
def test_sim_retry_budget_after_repeated_failures(tier, expect_lost):
    """Two node kills in a row: best-effort requests exhaust the retry
    budget and record a typed loss; guaranteed requests never do."""
    cluster = Cluster(n_nodes=2, sharing=True,
                      retry=RetryPolicy(max_attempts=1, base_s=0.01, seed=0))
    plane = ControlPlane(SimBackend(cluster),
                         quarantine_threshold=None)
    plane.register(make_spec(min_instances=1, slo_tier=tier,
                             target_rps=ramp([(0.0, 0.0)])))
    cluster.submit_all(poisson_arrivals("chat", rps=8.0, duration=1.0,
                                        seed=3))
    cluster.sim.at(0.5, lambda: cluster.fail_node(0))
    cluster.sim.at(1.0, lambda: plane.reconcile(now=1.0))  # heal to node 1
    cluster.sim.at(1.2, lambda: cluster.fail_node(1))
    cluster.run(30.0)
    rec = cluster.recorders["chat"]
    if expect_lost:
        assert cluster.lost >= 1 and rec.lost == cluster.lost
    else:
        assert cluster.lost == 0 and rec.lost == 0
    assert cluster.dropped == 0
    parked = len(cluster._pending.get("chat", ()))
    # Every offered request is accounted for: served, lost, or parked
    # awaiting a heal that never comes (both nodes are dead).
    offered = rec.count() + cluster.lost + parked
    assert offered == len(poisson_arrivals("chat", rps=8.0, duration=1.0,
                                           seed=3))


def test_sim_retry_runs_are_reproducible():
    def trial() -> tuple:
        cluster = Cluster(n_nodes=2, sharing=True,
                          retry=RetryPolicy(max_attempts=3, base_s=0.02,
                                            seed=5))
        plane = ControlPlane(SimBackend(cluster))
        plane.register(make_spec(min_instances=1,
                                 target_rps=ramp([(0.0, 0.0)])))
        cluster.submit_all(poisson_arrivals("chat", rps=6.0, duration=2.0,
                                            seed=6))
        cluster.sim.at(0.7, lambda: cluster.fail_node(0))
        for t in range(1, 6):
            cluster.sim.at(float(t), lambda t=t: plane.reconcile(now=t))
        cluster.run(40.0)
        rec = cluster.recorders["chat"]
        return (rec.count(), cluster.lost, cluster.shed, cluster.expired,
                rec.p99(), decision_signature(plane.log))

    assert trial() == trial()


# -------------------------------------------------------------------------
# Batch lane: non-batch admissions preempt parked batch work
# -------------------------------------------------------------------------


def test_sim_batch_lane_ordering():
    cluster = Cluster(n_nodes=1, sharing=True)
    cluster.register_function("chat", tiny_curve())
    pod = cluster.pods[cluster.deploy("chat", PROFILE[0])]
    tiers = [TIER_BATCH, TIER_BATCH, TIER_BEST_EFFORT, TIER_GUARANTEED,
             TIER_BATCH]
    for i, t in enumerate(tiers):
        cluster._enqueue_pod(pod, Request(fn="chat", arrival=0.0, req_id=i,
                                          tier=t))
    assert [r.req_id for r in pod.queue] == [2, 3, 0, 1, 4]
    assert [r.tier for r in pod.queue] == [
        TIER_BEST_EFFORT, TIER_GUARANTEED, TIER_BATCH, TIER_BATCH,
        TIER_BATCH]


def test_live_batch_lane_ordering():
    inst = SimpleNamespace(queue=[])
    prompt = np.zeros(2, dtype=np.int32)
    for i, t in enumerate([TIER_BATCH, TIER_BEST_EFFORT, TIER_BATCH,
                           TIER_GUARANTEED]):
        ServingEngine.enqueue(inst, ServeRequest(req_id=i, prompt=prompt,
                                                 tier=t))
    assert [r.req_id for r in inst.queue] == [1, 3, 0, 2]


# -------------------------------------------------------------------------
# Unregister: parked requests get a typed rejection, never a leak
# -------------------------------------------------------------------------


def test_live_unregister_rejects_parked_requests(tiny_model, tiny_params):
    frontend = ClusterFrontend(n_nodes=2, window=0.05)
    plane = ControlPlane(LiveBackend(frontend))
    plane.register(make_spec(lambda: (tiny_model, tiny_params),
                             min_instances=1,
                             target_rps=ramp([(0.0, 1.0)])))
    rng = np.random.default_rng(8)
    frontend.fail_node(
        int(next(iter(plane.placed["chat"])).split(":")[0]))
    # Podless window: the submission parks, exactly like the sim buffer.
    req = frontend.submit("chat", rng.integers(0, 64, 5, dtype=np.int32),
                          max_new_tokens=3)
    assert not req.done and frontend._pending["chat"] == [req]
    rejected = frontend.unregister("chat")
    # The parked request terminated with a typed outcome — no leak.
    assert rejected == [req]
    assert req.done and req.outcome == "rejected"
    assert req.finished_at >= req.submitted_at
    assert frontend.rejected == 1
    assert "chat" not in frontend._pending
    # The function is gone for good: later submissions are a hard error.
    with pytest.raises(KeyError):
        frontend.submit("chat", rng.integers(0, 64, 5, dtype=np.int32))


def test_idle_sleep_knob_plumbs_through():
    eng = ServingEngine(window=0.05, idle_sleep_s=0.0)
    assert eng.idle_sleep_s == 0.0
    frontend = ClusterFrontend(n_nodes=2, window=0.05, idle_sleep_s=0.0)
    assert all(e.idle_sleep_s == 0.0 for e in frontend.engines)
    assert ClusterFrontend(n_nodes=1).engines[0].idle_sleep_s == 0.001
