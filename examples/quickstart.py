"""Quickstart: the FaST-GShare data plane in ~60 lines.

Builds a reduced qwen2-7b, deploys two weight-shared instances behind the
FaST-Manager token scheduler, serves a handful of batched requests, and
prints throughput / latency / sharing stats.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core.model_sharing import pytree_nbytes
from repro.core.resources import Alloc
from repro.models import build_model
from repro.serving.engine import ServingEngine


def main() -> None:
    # 1. A model is just a config + pure-JAX module set.
    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name} ({model.n_params() / 1e6:.1f}M params reduced)")

    # 2. One engine == one node: token scheduler + shared weight store.
    engine = ServingEngine(window=0.25)
    alloc = Alloc(sm=0.24, quota_request=0.5, quota_limit=1.0)
    engine.deploy("qwen2", model, params, alloc, n_instances=2, max_batch=4,
                  max_len=24)
    print(f"deployed 2 instances sharing "
          f"{pytree_nbytes(params) / 1e6:.1f} MB of weights; "
          f"store holds {engine.memory_bytes() / 1e6:.1f} MB total")

    # 3. Submit batched requests; every dispatched step is token-gated.
    rng = np.random.default_rng(1)
    reqs = [engine.submit("qwen2",
                          rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                          max_new_tokens=6)
            for _ in range(8)]
    done = engine.pump(budget_s=60.0)

    rec = engine.recorders["qwen2"]
    print(f"served {done} requests: p50={rec.p50():.3f}s p99={rec.p99():.3f}s")
    print(f"scheduler: utilization={engine.scheduler.utilization(50):.2f} "
          f"occupancy={engine.scheduler.occupancy(50):.2f}")
    print(f"first completion: {reqs[0].tokens_out}")
    assert done == len(reqs)


if __name__ == "__main__":
    main()
