"""Live isolation demo: two real models share one node without interference.

Two functions (reduced qwen2 + rwkv6) run on one ServingEngine.  First the
aggressor runs with an elastic quota next to the victim (time sharing
style) — the victim's step dispatch rate drops.  Then both get hard
spatio-temporal partitions — the victim's rate is unaffected by the
aggressor.  The live analogue of paper Fig. 9, on real JAX executors.

Run:  PYTHONPATH=src python examples/multi_tenant_isolation.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core.resources import Alloc
from repro.models import build_model
from repro.serving.engine import ServingEngine


def serve_victim(aggressor: bool, isolated: bool) -> float:
    """-> victim completed requests/s."""
    engine = ServingEngine(window=0.2)
    rng = np.random.default_rng(0)

    cfg_v = get_config("rwkv6-1.6b", reduced=True)
    victim = build_model(cfg_v)
    params_v = victim.init(jax.random.PRNGKey(0))
    # Victim: guaranteed 50%; isolated run caps everyone's elasticity.
    engine.deploy("victim", victim, params_v,
                  Alloc(sm=0.24 if isolated else 1.0, quota_request=0.5,
                        quota_limit=0.5 if isolated else 0.8),
                  n_instances=1, max_batch=2, max_len=20)
    if aggressor:
        cfg_a = get_config("qwen2-7b", reduced=True)
        model_a = build_model(cfg_a)
        params_a = model_a.init(jax.random.PRNGKey(1))
        engine.deploy("aggressor", model_a, params_a,
                      Alloc(sm=0.24 if isolated else 1.0, quota_request=0.5,
                            quota_limit=0.5 if isolated else 1.0),
                      n_instances=1, max_batch=2, max_len=20)
        for _ in range(40):
            engine.submit("aggressor",
                          rng.integers(0, cfg_a.vocab_size, 8).astype(np.int32),
                          max_new_tokens=8)
    n_victim = 30
    for _ in range(n_victim):
        engine.submit("victim",
                      rng.integers(0, cfg_v.vocab_size, 8).astype(np.int32),
                      max_new_tokens=4)
    engine.pump(budget_s=30.0)
    rec = engine.recorders["victim"]
    # Rate over the trailing 3/4 of completions: the head is dominated by
    # one-time jit compiles, which would swamp the isolation signal.
    times = sorted(rec.completion_times)
    if len(times) < 2:
        return 0.0
    k = len(times) // 4
    return (len(times) - 1 - k) / max(times[-1] - times[k], 1e-9)


def main() -> None:
    alone = serve_victim(aggressor=False, isolated=True)
    contended = serve_victim(aggressor=True, isolated=False)
    isolated = serve_victim(aggressor=True, isolated=True)
    print(f"victim rate alone       : {alone:6.1f} req/s")
    print(f"victim rate, time-shared: {contended:6.1f} req/s "
          f"({contended / alone:.0%} of alone — interference)")
    print(f"victim rate, isolated   : {isolated:6.1f} req/s "
          f"({isolated / alone:.0%} of alone)")
    # Isolation must recover most of the drop the aggressor causes.
    assert isolated >= contended * 0.9, "isolation should not be worse"


if __name__ == "__main__":
    main()
