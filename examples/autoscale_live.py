"""Live autoscaling: one declarative spec drives real JAX engines AND the
simulator, producing the identical scale-decision sequence.

A ``FunctionSpec`` declares a tiny chat model with a latency SLO, a
profile table, and a deterministic RPS ramp (1 -> burst -> 1 req/s).  The
``ControlPlane`` reconciles the live fleet (``ClusterFrontend`` over two
``ServingEngine`` nodes) once per virtual tick: Alg. 1 scales the function
from 1 instance up to several at the burst and back down to the floor,
placing via MRA + memory admission and evicting with graceful drain — the
run asserts **zero dropped in-flight requests**.  The same spec is then
replayed through the simulator backend and the two decision logs are
compared entry for entry.

With ``--fail-node`` the busiest node is killed mid-burst: its instances
(weights, KV) die instantly and every stranded request re-executes on the
healed fleet.  ``fail_node`` itself places nothing — the next reconcile
tick prunes the dead pods from L_j (``Backend.alive``) and the processing
gap + below-floor healing re-converge the fleet.  The run still asserts
zero dropped requests, and the simulator replay (same failure injected at
the same tick) still produces the identical decision sequence.

With ``--measured-profile`` the hand-written profile table is replaced by
one MEASURED on the real jitted executors
(``profiler.measure_engine_profile`` -> ``measure_callable_trial``: the
temporal quota duty-cycles actual wall-clock decode rounds), the RPS ramp
is re-scaled to the measured capacity so the burst still forces a
scale-out, and both the live fleet and the simulator replay reconcile the
same measured spec — the decision sequences must still match.

Run:  PYTHONPATH=src python examples/autoscale_live.py \
          [--fail-node] [--measured-profile]
"""

import argparse
from collections import Counter

import jax
import numpy as np

from repro.control import (ControlPlane, FunctionSpec, LiveBackend,
                           SimBackend, decision_signature, ramp)
from repro.core.cluster import Cluster
from repro.core.scaling import ProfilePoint
from repro.core.workload import ServiceCurve
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serving import ClusterFrontend

# Profile table for the tiny model: throughputs are in ticks of the
# reconcile loop, so the decision arithmetic is easy to follow by hand.
PROFILE = (
    ProfilePoint(sm=0.25, quota=0.4, throughput=2.0, p99_latency=0.05),
    ProfilePoint(sm=0.45, quota=0.8, throughput=5.0, p99_latency=0.03),
    ProfilePoint(sm=0.45, quota=0.8, throughput=4.0, p99_latency=0.30),  # SLO-infeasible
)

RAMP = ramp([(0.0, 1.0), (3.0, 12.0), (7.0, 1.0)])
TICKS = 11
FAIL_TICK = 5  # mid-burst, --fail-node only


def make_model():
    model = build_model(ModelConfig(
        name="tiny-chat", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab_size=64, vocab_pad_multiple=32))
    return model, model.init(jax.random.key(0))


def measured_profile_and_ramp():
    """Profile the real executors and scale the demand ramp to what they
    measured, so the burst still drives Alg. 1 past one instance."""
    from repro.core.profiler import measure_engine_profile

    model, params = make_model()
    # The analytic spatial factor stands in for SM partitioning (CPU has
    # none): capacity saturates at sm ~0.45 like the hand-written curve.
    points = measure_engine_profile(
        model, params, spatial=(0.25, 0.45), temporal=(0.4, 0.8),
        max_batch=2, max_len=32, prompt_len=8, new_tokens=3,
        window=0.1, n_windows=3, sm_scale=lambda sm: min(sm / 0.45, 1.0))
    cap = max(p.throughput for p in points)
    slo = 2.0 * max(p.p99_latency for p in points)
    profile = tuple(points)
    # Base load below one instance's capacity, burst past two of them.
    demand = ramp([(0.0, cap * 0.5), (3.0, cap * 2.2), (7.0, cap * 0.5)])
    return profile, slo, demand


def make_spec(profile=PROFILE, slo: float = 0.1,
              demand=RAMP) -> FunctionSpec:
    return FunctionSpec(
        name="chat", profile=profile, slo_latency=slo, target_rps=demand,
        headroom=1.2, min_instances=1, max_instances=6,
        model_factory=make_model, max_batch=2, max_len=32,
        framework_bytes=32 * 1024 * 1024,
        curve=ServiceCurve(name="chat", r_max=5.0, sm_sat=0.45, p=1.0,
                           weight_bytes=1 << 20, framework_bytes=32 << 20))


def busiest_node(plane: ControlPlane, backend) -> int:
    counts = Counter(backend.node_of(p) for p in plane.placed["chat"])
    return counts.most_common(1)[0][0]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--fail-node", action="store_true",
                        help="kill the busiest node mid-burst and let the "
                             "reconciler heal the fleet")
    parser.add_argument("--measured-profile", action="store_true",
                        help="measure the {<F,S,Q,T>} profile table on the "
                             "real jitted executors instead of the "
                             "hand-written one")
    args = parser.parse_args()

    if args.measured_profile:
        profile, slo, demand = measured_profile_and_ramp()
        print("[profiler] measured on live executors:")
        for p in profile:
            print(f"    sm={p.sm:.2f} quota={p.quota:.1f} "
                  f"T={p.throughput:7.1f} req/s  p99={p.p99_latency:.4f}s")
    else:
        profile, slo, demand = PROFILE, 0.1, RAMP
    spec_args = dict(profile=profile, slo=slo, demand=demand)

    # -- live fleet ------------------------------------------------------
    frontend = ClusterFrontend(n_nodes=2, window=0.1)
    backend = LiveBackend(frontend)
    live = ControlPlane(backend)
    live.register(make_spec(**spec_args))
    print(f"[live] registered: {live.instances('chat')} instance(s)")

    rng = np.random.default_rng(0)
    reqs = []
    n_base = None  # fleet size the base (pre-burst) demand settles at
    for tick in range(TICKS):
        if args.fail_node and tick == FAIL_TICK:
            victim = busiest_node(live, backend)
            lost = frontend.fail_node(victim)
            print(f"  t={tick:2d} node {victim} FAILED: {lost} instance(s) "
                  f"lost, stranded requests re-queued; reconcile heals")
        live.reconcile(now=float(tick))
        n_inst = live.instances("chat")
        if n_base is None:
            n_base = n_inst
        # Offer load matching the declared ramp; prompts of varying length
        # exercise the bucketed prefill (one compile per bucket).  The
        # measured-profile capacity can run to hundreds of req/s on this
        # container — cap the offered sample so the example stays short
        # (decisions follow the declared ramp, not the sampled arrivals).
        for _ in range(min(int(demand(float(tick))), 40)):
            prompt = rng.integers(0, 64, int(rng.integers(4, 12)),
                                  dtype=np.int32)
            reqs.append(frontend.submit("chat", prompt, max_new_tokens=3))
        frontend.pump(budget_s=5.0)
        print(f"  t={tick:2d} target={demand(float(tick)):7.1f} rps  "
              f"instances={n_inst}  inflight={frontend.inflight('chat')}")
    frontend.pump(budget_s=30.0)

    peak = max(e.instances_before for e in live.events)
    assert peak > n_base, "burst must scale the function out"
    assert live.instances("chat") == n_base, \
        "ramp-down must return to the pre-burst fleet size"
    done = sum(1 for r in reqs if r.done)
    assert done == len(reqs), f"dropped {len(reqs) - done} in-flight requests"
    if args.fail_node:
        healed = next(e for e in live.events if e.pruned)
        print(f"[live] t={healed.now:.0f}: reconcile pruned "
              f"{len(healed.pruned)} dead pod(s) and re-placed "
              f"{sum(1 for d in healed.applied if d.direction > 0)}")
    print(f"[live] served {done}/{len(reqs)} requests "
          f"(zero dropped across scale-up, {'node failure, ' if args.fail_node else ''}"
          f"AND drain-down), peak instances={peak}")

    # -- simulator replay of the same spec (same failure injected) --------
    cluster = Cluster(n_nodes=2, sharing=True)
    sim_backend = SimBackend(cluster)
    sim = ControlPlane(sim_backend)
    sim.register(make_spec(**spec_args))
    for tick in range(TICKS):
        if args.fail_node and tick == FAIL_TICK:
            cluster.fail_node(busiest_node(sim, sim_backend))
        sim.reconcile(now=float(tick))

    live_sig = decision_signature(live.log)
    sim_sig = decision_signature(sim.log)
    assert live_sig == sim_sig, (
        f"decision logs diverged:\n live={live_sig}\n  sim={sim_sig}")
    print(f"[replay] simulator produced the identical "
          f"{len(sim_sig)}-decision sequence: OK")
    for sig in live_sig:
        fn, direction, sm, quota = sig
        arrow = "up" if direction > 0 else "down"
        print(f"    {fn}: scale-{arrow} at (sm={sm}, quota={quota})")


if __name__ == "__main__":
    main()
