"""Train a small LM end to end: data -> AdamW -> checkpoints -> restart.

Exercises the training substrate (the serving paper still ships one):
microbatch gradient accumulation, atomic keep-N checkpoints, and a
simulated crash + restart that resumes mid-run from the latest checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 80]
"""

import argparse
import tempfile

import jax

from repro.launch.train import PRESETS
from repro.models import build_model
from repro.training import AdamW
from repro.training.data import batch_iterator
from repro.training.train_loop import TrainStepConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--preset", default="10m", choices=list(PRESETS))
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = build_model(cfg)
    print(f"training {cfg.name}: {model.n_params() / 1e6:.1f}M params")
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-4, total_steps=args.steps)
    step_cfg = TrainStepConfig(microbatches=2)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        half = args.steps // 2
        batches = batch_iterator(cfg.vocab_size, 4, 128, seed=0)
        params1, _, res1 = train(model, params, batches, opt=opt, steps=half,
                                 step_cfg=step_cfg, checkpoint_dir=ckpt_dir,
                                 checkpoint_every=10, log_every=10)
        print(f"[crash] simulated failure at step {half}; restarting from "
              f"the latest checkpoint in {ckpt_dir}")
        # Restart: train() restores step/params/optimizer from disk; the
        # data pipeline is seekable so batches replay deterministically.
        batches2 = batch_iterator(cfg.vocab_size, 4, 128, seed=0)
        params2, _, res2 = train(model, model.init(jax.random.PRNGKey(0)),
                                 batches2, opt=opt, steps=args.steps,
                                 step_cfg=step_cfg, checkpoint_dir=ckpt_dir,
                                 checkpoint_every=10, log_every=10)
    losses = res1.losses + res2.losses
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({args.steps} total steps, restart at {half})")
    assert losses[-1] < losses[0], "loss must improve end to end"


if __name__ == "__main__":
    main()
