"""Full control plane: profile -> autoscale -> schedule -> survive failures.

Reproduces the paper's serving story end to end on the discrete-event
cluster: FaST-Profiler sweeps two functions, Alg. 1 autoscales them under a
diurnal load with a latency SLO, MRA packs pods onto the fewest GPUs, a
node is killed mid-run (fault tolerance), and the run ends with utilization
/ occupancy / SLO numbers.  A final section replays the same stack on the
*live* JAX data plane: a ``ClusterFrontend`` places two functions across
two ``ServingEngine`` nodes (MRA + memory admission) and serves real
continuous-batching decodes through the per-node token schedulers.

Run:  PYTHONPATH=src python examples/serve_cluster.py
"""

from repro.core.cluster import Cluster
from repro.core.profiler import profile_points
from repro.core.workload import PAPER_ZOO, diurnal_trace, trace_arrivals

SLO = {"resnet": 0.069, "bert": 0.15}
DURATION = 120.0


def main() -> None:
    # 1. FaST-Profiler: Experiment -> Trial grid for each function,
    #    emitted as the spec-ready {<F, S, Q, T>} table.
    profiles = {fn: profile_points(PAPER_ZOO[fn]) for fn in SLO}
    for fn, pts in profiles.items():
        best = max(pts, key=lambda p: p.rpr)
        print(f"[profile] {fn}: best RPR at sm={best.sm} quota={best.quota} "
              f"-> {best.throughput:.1f} req/s")

    # 2. Cluster with autoscaling control loop.
    cluster = Cluster(n_nodes=6, sharing=True, max_batch=2)
    arrivals = []
    for i, fn in enumerate(SLO):
        cluster.register_function(fn, PAPER_ZOO[fn], slo_latency=SLO[fn])
        cluster.deploy(fn, max(profiles[fn], key=lambda p: p.rpr),
                       elastic_limit=1.0)
        trace = diurnal_trace(15.0, 150.0, DURATION, DURATION, 5.0) + [
            (DURATION, 0.0)]
        arrivals += trace_arrivals(fn, trace, seed=10 + i)
    cluster.submit_all(arrivals)

    def control() -> None:
        now = cluster.sim.now
        pred = {}
        for fn in SLO:
            recent = [r for r in arrivals
                      if r.fn == fn and now - 2.0 <= r.arrival <= now]
            pred[fn] = len(recent) / 2.0
        cluster.autoscale(pred, profiles, slo_latency=SLO, headroom=1.6)
        if now < DURATION:
            cluster.sim.after(0.5, control)

    cluster.sim.after(0.5, control)

    # 3. Kill a node mid-run: the failure path only records the damage
    #    (pods dead, requests re-queued); the 0.5 s Alg.-1 control loop
    #    above sees the lost L_j capacity and re-places on survivors.
    def failure() -> None:
        victim = next((n.node_id for n in cluster.nodes if n.pods), 0)
        lost = cluster.fail_node(victim)
        print(f"[t={cluster.sim.now:5.1f}] node {victim} FAILED; "
              f"{lost} pods lost — the autoscale loop heals the gap")

    cluster.sim.at(DURATION / 2, failure)
    cluster.run(DURATION + 10)

    # 4. Report.
    print(f"\n[cluster] nodes in use: {cluster.nodes_in_use()} / 6  "
          f"(dropped={cluster.dropped}, rescheduled={cluster.rescheduled})")
    print(f"[cluster] utilization={cluster.gpu_utilization(30):.2f}  "
          f"occupancy={cluster.sm_occupancy(30):.2f}")
    for fn in SLO:
        rec = cluster.recorders[fn]
        print(f"  {fn:8s} served={rec.count():5d}  p99={rec.p99(5.0):.3f}s  "
              f"SLO violations={rec.violation_ratio(5.0):.2%}")
        assert rec.violation_ratio(5.0) < 0.05, "SLO badly violated"

    # 5. The same stack, live: ClusterFrontend over real JAX engines.
    live_frontend_demo()


def live_frontend_demo() -> None:
    import jax
    import numpy as np

    from repro.core.resources import Alloc
    from repro.models import build_model
    from repro.models.config import ModelConfig
    from repro.serving import ClusterFrontend

    print("\n[live] ClusterFrontend: 2 functions x 2 engine nodes, "
          "continuous batching")
    cfg = dict(family="dense", n_layers=2, d_model=32, n_heads=4,
               n_kv_heads=2, d_ff=64, vocab_size=64, vocab_pad_multiple=32)
    fns = {"chat": build_model(ModelConfig(name="tiny-chat", **cfg)),
           "code": build_model(ModelConfig(name="tiny-code", **cfg))}
    frontend = ClusterFrontend(n_nodes=2, window=0.1)
    # A 0.6-quota x 0.55-SM rectangle cannot pack twice on one node, so
    # each function's two instances land on different nodes; the smaller
    # function then fills the leftover strips (the sim's MRA, live).
    allocs = {"chat": Alloc(sm=0.55, quota_request=0.6, quota_limit=0.8),
              "code": Alloc(sm=0.35, quota_request=0.6, quota_limit=0.8)}
    for i, (fn, model) in enumerate(fns.items()):
        params = model.init(jax.random.key(i))
        frontend.deploy(fn, model, params, allocs[fn], n_instances=2,
                        max_batch=4, max_len=32)
        print(f"  {fn}: instances on nodes {frontend.nodes_for(fn)}")
    rng = np.random.default_rng(0)
    reqs = [frontend.submit(fn, rng.integers(0, 64, 8, dtype=np.int32),
                            max_new_tokens=4 + i % 5)
            for i in range(24) for fn in fns]
    done = frontend.pump(budget_s=60.0)
    refills = sum(inst.refills for e in frontend.engines
                  for inst in e.instances.values())
    assert done == len(reqs) and all(r.done for r in reqs)
    print(f"  served {done} requests, {refills} mid-flight slot refills, "
          f"occupancy={frontend.occupancy():.2f}, "
          f"shared weights={frontend.memory_bytes() / 1024:.0f} KiB")


if __name__ == "__main__":
    main()
