"""End-to-end FaST-GShare serving driver (live data plane on this host).

Deploys N weight-shared instances of one or more architectures (reduced
configs — real JAX executors on CPU) onto a ServingEngine node, gates every
step through the FaST-Manager token scheduler, drives a batched request
load, and reports throughput / latency / utilization / occupancy and the
model-sharing memory ledger.

Usage:
  PYTHONPATH=src python -m repro.launch.serve \
      --arch qwen2-7b --arch rwkv6-1.6b --instances 2 --requests 32
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.model_sharing import pytree_nbytes
from repro.core.resources import Alloc
from repro.models import build_model
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="repeatable; reduced config of each arch is served")
    ap.add_argument("--instances", type=int, default=2,
                    help="instances per function (share one weight copy)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--sm", type=float, default=0.24,
                    help="spatial share per instance")
    ap.add_argument("--quota", type=float, default=0.5)
    ap.add_argument("--quota-limit", type=float, default=1.0)
    ap.add_argument("--window", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    archs = args.arch or ["qwen2-7b"]

    engine = ServingEngine(window=args.window)
    rng = np.random.default_rng(args.seed)
    alloc = Alloc(sm=args.sm, quota_request=args.quota,
                  quota_limit=args.quota_limit)

    unshared_total = 0
    for arch in archs:
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(args.seed))
        nbytes = pytree_nbytes(params)
        unshared_total += nbytes * args.instances
        engine.deploy(arch, model, params, alloc,
                      n_instances=args.instances,
                      max_batch=args.max_batch,
                      max_len=args.prompt_len + args.max_new_tokens + 1)
        print(f"[deploy] {arch}: {args.instances} instances sharing "
              f"{nbytes / 1e6:.1f} MB of weights "
              f"({cfg.n_layers}L d={cfg.d_model})")

    reqs = []
    for i in range(args.requests):
        fn = archs[i % len(archs)]
        prompt = rng.integers(
            0, get_config(fn, reduced=True).vocab_size,
            size=args.prompt_len).astype(np.int32)
        reqs.append(engine.submit(fn, prompt,
                                  max_new_tokens=args.max_new_tokens))

    t0 = time.perf_counter()
    done = engine.pump(budget_s=120.0)
    wall = time.perf_counter() - t0

    print(f"\n[serve] completed {done}/{len(reqs)} requests in {wall:.2f}s "
          f"({done / max(wall, 1e-9):.1f} req/s)")
    for fn, rec in engine.recorders.items():
        if rec.count():
            print(f"  {fn:24s} n={rec.count():4d}  p50={rec.p50():.3f}s  "
                  f"p99={rec.p99():.3f}s")
    sched = engine.scheduler
    print(f"[manager] utilization={sched.utilization(last_n=50):.2f}  "
          f"occupancy={sched.occupancy(last_n=50):.2f}  "
          f"(window={args.window}s)")
    shared = engine.memory_bytes()
    print(f"[model sharing] weights resident: {shared / 1e6:.1f} MB shared "
          f"vs {unshared_total / 1e6:.1f} MB unshared "
          f"({1 - shared / max(unshared_total, 1):.0%} saved)")
    sample = reqs[0]
    print(f"[sample] req0 prompt[:8]={sample.prompt[:8].tolist()} -> "
          f"tokens_out={sample.tokens_out}")


if __name__ == "__main__":
    main()
