import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (jax locks the
# device count on first init), which is why the docstring sits below them.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the jitted step (train_step / prefill /
serve_step) with explicit NamedSharding in/out shardings derived from the
logical rules, lowers it against ShapeDtypeStruct stand-ins (no
allocation), compiles, and records ``memory_analysis`` / ``cost_analysis``
plus the collective-bytes breakdown parsed from the optimized HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --case train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_bytes_from_hlo
from repro.analysis.roofline import (analyze_hlo, kernel_hbm_bytes,
                                     model_flops, roofline_terms)
from repro.configs import all_arch_ids, get_config
from repro.distributed.sharding import cache_pspec, resolve_pspec, use_mesh
from repro.launch.mesh import make_production_mesh
from repro.models import SHAPE_CASES, build_model, input_specs
from repro.models.layers import abstract_params, param_logical_names
from repro.training import AdamW, TrainStepConfig, make_train_step
from repro.training.optimizer import AdamWState

# Cells skipped by design (DESIGN.md §4): pure full-attention archs do not
# run the long-context decode cell.
LONG_CTX_SKIPS = {
    "qwen2-7b", "starcoder2-15b", "qwen1.5-110b", "seamless-m4t-large-v2",
    "llama-3.2-vision-11b", "qwen2-moe-a2.7b",
}


def _sharding_tree(names_tree: Any, shapes_tree: Any, mesh, *,
                   cache: bool = False) -> Any:
    """names/ShapeDtypeStruct trees -> NamedSharding tree."""

    def leaf(names, sds):
        if cache and "seq" in names and "batch" in names:
            spec = cache_pspec(sds.shape, mesh, names)
        else:
            spec = resolve_pspec(names, sds.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(
        leaf, names_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, (str, type(None))) for i in x))


def _param_shardings(model, mesh):
    return _sharding_tree(param_logical_names(model.specs),
                          abstract_params(model.specs), mesh)


@dataclasses.dataclass
class CellResult:
    arch: str
    case: str
    mesh: str
    status: str  # ok | skipped | failed
    seconds: float = 0.0
    # cost_analysis() raw numbers (while bodies counted once):
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    collective_bytes: float = 0.0
    memory: dict = dataclasses.field(default_factory=dict)
    # trip-count-corrected analysis (analysis/roofline.py):
    hlo_flops_per_device: float = 0.0
    hbm_bytes_per_device: float = 0.0
    kernel_internal_bytes: float = 0.0
    collective_wire: dict = dataclasses.field(default_factory=dict)
    # roofline terms (seconds / step) + bookkeeping:
    model_flops: float = 0.0
    compute_s: float = 0.0
    memory_s: float = 0.0
    memory_adj_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0
    mfu_bound: float = 0.0
    error: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _memory_dict(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def build_step(model, case, mesh):
    """Returns (fn, example_args tree of SDS, in_shardings, out_shardings,
    donate_argnums)."""
    cfg = model.cfg
    specs, names = input_specs(model, case)
    params_sds = abstract_params(model.specs)
    params_sh = _param_shardings(model, mesh)
    repl = NamedSharding(mesh, P())

    if case.kind == "train":
        opt = AdamW()
        opt_sds = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                params_sds),
            v=jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                params_sds))
        opt_sh = AdamWState(
            step=repl,
            m=jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s.spec), params_sh),
            v=jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s.spec), params_sh))
        batch_sh = _sharding_tree(names, specs, mesh)
        # Optimized mode ships bf16 gradient reduction (§Perf B2);
        # REPRO_BASELINE=1 keeps f32 grads for the paper-faithful baseline.
        compress = os.environ.get("REPRO_BASELINE", "") != "1"
        step = make_train_step(
            model, opt, TrainStepConfig(remat=True, grad_compress=compress))
        metrics_sh = {"grad_norm": repl, "lr": repl, "loss": repl}
        return (step, (params_sds, opt_sds, specs),
                (params_sh, opt_sh, batch_sh),
                (params_sh, opt_sh, metrics_sh), (0, 1))

    if case.kind == "prefill":
        batch_sh = _sharding_tree(names, specs, mesh)
        cache_sds = model.cache_shapes(case.global_batch, case.seq_len)
        cache_names = model.cache_names(case.global_batch, case.seq_len)
        cache_sh = _sharding_tree(cache_names, cache_sds, mesh, cache=True)
        logits_sh = NamedSharding(mesh, resolve_pspec(
            ("batch", "vocab"), (case.global_batch, cfg.padded_vocab), mesh))

        def fn(params, batch):
            return model.prefill(params, batch["tokens"],
                                 max_len=case.seq_len, ctx=batch.get("ctx"))

        return (fn, (params_sds, specs), (params_sh, batch_sh),
                (logits_sh, cache_sh), ())

    # decode
    cache_sds = specs["cache"]
    cache_sh = _sharding_tree(names["cache"], cache_sds, mesh, cache=True)
    token_sh = NamedSharding(mesh, resolve_pspec(
        ("batch",), (case.global_batch,), mesh))
    logits_sh = NamedSharding(mesh, resolve_pspec(
        ("batch", "vocab"), (case.global_batch, cfg.padded_vocab), mesh))

    def fn(params, token, cache):
        return model.decode_step(params, token, cache)

    return (fn, (params_sds, specs["token"], cache_sds),
            (params_sh, token_sh, cache_sh),
            (logits_sh, cache_sh), (2,))


def run_cell(arch: str, case_name: str, multi_pod: bool,
             timeout_note: Optional[str] = None) -> CellResult:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    case = SHAPE_CASES[case_name]
    if case_name == "long_500k" and arch in LONG_CTX_SKIPS:
        return CellResult(arch, case_name, mesh_name, "skipped",
                          error="pure full-attention arch (DESIGN.md §4)")
    t0 = time.perf_counter()
    try:
        cfg = get_config(arch)
        model = build_model(cfg)
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh, use_mesh(mesh):
            fn, args, in_sh, out_sh, donate = build_step(model, case, mesh)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            coll = collective_bytes_from_hlo(hlo)
            mem = _memory_dict(compiled)
            analysis = analyze_hlo(hlo)
            n_chips = mesh.devices.size
            mf = model_flops(cfg, case)
            kb = kernel_hbm_bytes(cfg, case)
            rl = roofline_terms(analysis, n_chips, mf,
                                kernel_bytes_global=kb)
        return CellResult(
            arch=arch, case=case_name, mesh=mesh_name, status="ok",
            seconds=time.perf_counter() - t0,
            flops_per_device=float(cost.get("flops", 0.0)),
            bytes_per_device=float(cost.get("bytes accessed", 0.0)),
            collectives={k: float(v) for k, v in coll.items()},
            collective_bytes=float(sum(coll.values())),
            memory=mem,
            hlo_flops_per_device=analysis.flops,
            hbm_bytes_per_device=analysis.hbm_bytes,
            kernel_internal_bytes=analysis.kernel_internal_bytes,
            collective_wire=dict(analysis.collective_wire),
            model_flops=mf,
            compute_s=rl.compute_s,
            memory_s=rl.memory_s,
            memory_adj_s=rl.memory_adj_s,
            collective_s=rl.collective_s,
            dominant=rl.dominant,
            useful_ratio=rl.useful_ratio,
            mfu_bound=rl.mfu_bound)
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        return CellResult(arch, case_name, mesh_name, "failed",
                          seconds=time.perf_counter() - t0,
                          error=f"{type(e).__name__}: {e}\n"
                                f"{traceback.format_exc(limit=8)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--case", default=None, choices=list(SHAPE_CASES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = all_arch_ids() if args.all or not args.arch else [args.arch]
    cases = list(SHAPE_CASES) if args.all or not args.case else [args.case]
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for case_name in cases:
            for mp in pods:
                tag = f"{arch}__{case_name}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                res = run_cell(arch, case_name, mp)
                with open(path, "w") as f:
                    json.dump(res.to_json(), f, indent=2)
                print(f"[{res.status:7s}] {tag}  {res.seconds:6.1f}s  "
                      f"C={res.compute_s:.3f}s M={res.memory_adj_s:.3f}s "
                      f"X={res.collective_s:.3f}s dom={res.dominant or '-'} "
                      f"useful={res.useful_ratio:.2f}"
                      + (f"  ERR {res.error.splitlines()[0]}"
                         if res.error else ""), flush=True)


if __name__ == "__main__":
    main()
