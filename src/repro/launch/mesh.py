"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.

Target hardware: TPU v5e pods; single pod = 16 x 16 = 256 chips
(data x model), multi-pod = 2 x 16 x 16 (pod x data x model).
"""

from __future__ import annotations

import jax

# v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist (1 on this container) as a flat data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
