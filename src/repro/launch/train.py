"""End-to-end training driver: reduced/custom config, checkpoint/restart.

The paper's contribution is a serving architecture (``serve.py`` is the
primary driver); this trainer exercises the substrate the framework also
ships — data pipeline, AdamW, microbatch accumulation, atomic checkpoints,
restart — at CPU-feasible scale.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --steps 60
  PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 300
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.training import AdamW, TrainStepConfig
from repro.training.data import batch_iterator
from repro.training.train_loop import TrainStepConfig, train

PRESETS = {
    # ~100M params: 12L x 768, GPT-2-small-ish with a swiglu MLP.
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                        vocab_size=32000),
    # ~10M: CPU-friendly demo scale.
    "10m": ModelConfig(name="lm-10m", family="dense", n_layers=6,
                       d_model=320, n_heads=8, n_kv_heads=4, d_ff=896,
                       vocab_size=8192),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="assigned arch id (reduced config is trained)")
    ap.add_argument("--preset", default=None, choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true",
                    help="bf16 gradient accumulation/reduction")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.preset:
        cfg = PRESETS[args.preset]
    else:
        cfg = get_config(args.arch or "qwen2-7b", reduced=True)
    model = build_model(cfg)
    print(f"[train] {cfg.name}: {model.n_params() / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    params = model.init(jax.random.PRNGKey(args.seed))
    ctx_shape = None
    if model.needs_ctx():
        ctx_shape = (args.batch, cfg.n_context_tokens, cfg.d_model)
    batches = batch_iterator(cfg.vocab_size, args.batch, args.seq,
                             seed=args.seed, ctx_shape=ctx_shape)
    opt = AdamW(lr=args.lr, total_steps=args.steps)
    step_cfg = TrainStepConfig(microbatches=args.microbatches,
                               grad_compress=args.grad_compress)
    params, opt_state, result = train(
        model, params, batches, opt=opt, steps=args.steps,
        step_cfg=step_cfg, checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every, log_every=10)
    first, last = result.losses[0], result.losses[-1]
    print(f"[train] done: loss {first:.3f} -> {last:.3f} over "
          f"{result.steps} steps in {result.wall_time:.1f}s "
          f"({result.steps / max(result.wall_time, 1e-9):.2f} steps/s)")
    if not (last < first):
        raise SystemExit("loss did not improve — training substrate broken")


if __name__ == "__main__":
    main()
