"""Backend protocol: the thin seam between the reconciler and a fleet.

The ``ControlPlane`` never touches nodes, rectangles, or engines directly —
it sees a fleet through a small verb set:

* ``place(spec, point)``   — deploy one instance at a profile point (MRA +
  memory admission with spillover happen inside); returns the concrete pod
  id, or None when no node can host it.
* ``evict(spec, pod_id)``  — gracefully retire an instance: stop routing,
  drain its in-flight decode slots, then release its rectangle and weight
  refcount.
* ``alive(pod_id)``        — whether a placed pod still exists on a live
  node; the reconciler prunes dead pods from L_j/``placed`` with this, so
  node failures heal through the ordinary processing gap.
* ``node_of(pod_id)``      — which node hosts a pod (defrag victim
  selection).
* ``fragmentation()``      — per-node MRA fragmentation telemetry over
  schedulable nodes.
* ``node_load()``          — per-node allocated-area fraction (defrag
  target selection).
* ``migrate(spec, pod_id, target)`` — move one running pod to a target
  node with its queue and occupied decode slots intact (a real KV move on
  the live path); returns the new pod id or None when it cannot move.
* ``observed_rps(fn, w)``  — trailing-window arrival rate (used when the
  spec declares no target-RPS source, and to feed predictive
  ``DemandSource``s).
* ``inflight(fn)``         — queued + slot-occupying requests (reported in
  reconcile telemetry).
* ``warm_nodes(fn)``       — nodes holding warm weights for ``fn`` (the
  cold-start tier; scale-up and defrag prefer them, empty when the tier
  is off).
* ``links()``              — the fleet's inter-node bandwidth table
  (every (a < b) pair, symmetric bytes/s): the link model behind sharded
  multi-rectangle placement and bandwidth-aware peer weight transfers.
* ``health(node)``         — gray-failure score in [0, 1]: 1.0 nominal,
  ~1/N for a node running N times slower than its own baseline, 0.0 dead.
* ``quarantine(node)``     — take a degraded-but-alive node out of
  routing and placement; occupants drain, the reconciler heals the lost
  capacity through the ordinary ``alive`` prune.  Returns the number of
  instances taken out of rotation.  Quarantine is a health action, never
  a scheduling decision: it is logged outside ``decision_signature``.

Two implementations ship: ``SimBackend`` over the discrete-event
``repro.core.cluster.Cluster`` and ``LiveBackend`` over the real JAX
``repro.serving.frontend.ClusterFrontend``.  Both are deliberately thin —
every scheduling decision lives in the shared ``ControlPlane``, which is
what lets a live fleet be replayed through the simulator decision-for-
decision, node failures included.
"""

from __future__ import annotations

from typing import Any, Optional, Protocol, runtime_checkable

from repro.control.spec import FunctionSpec
from repro.core.scaling import ProfilePoint


@runtime_checkable
class Backend(Protocol):
    """What a fleet must expose to be reconciled."""

    def register(self, spec: FunctionSpec) -> None: ...

    def place(self, spec: FunctionSpec,
              point: ProfilePoint) -> Optional[str]: ...

    def evict(self, spec: FunctionSpec, pod_id: str) -> None: ...

    def alive(self, pod_id: str) -> bool: ...

    def node_of(self, pod_id: str) -> Optional[int]: ...

    def fragmentation(self) -> dict[int, float]: ...

    def node_load(self) -> dict[int, float]: ...

    def migrate(self, spec: FunctionSpec, pod_id: str,
                target: int) -> Optional[str]: ...

    def observed_rps(self, fn: str, window: float) -> float: ...

    def inflight(self, fn: str) -> int: ...

    def warm_nodes(self, fn: str) -> list[int]: ...

    def links(self) -> dict[tuple[int, int], float]: ...

    def health(self, node: int) -> float: ...

    def quarantine(self, node: int) -> int: ...

    def now(self) -> float: ...


class SimBackend:
    """Adapter: the discrete-event ``Cluster`` as a reconciler backend.

    Pod ids are the cluster's own (``fn-N``); time is virtual
    (``cluster.sim.now``); observed RPS comes from the cluster's arrival
    log over virtual time.
    """

    def __init__(self, cluster: Any):
        self.cluster = cluster

    def register(self, spec: FunctionSpec) -> None:
        if spec.curve is None:
            raise ValueError(
                f"spec {spec.name!r} needs a ServiceCurve for the simulator")
        self.cluster.register_function(spec.name, spec.curve,
                                       slo_latency=spec.slo_latency,
                                       slo_tier=spec.slo_tier,
                                       deadline_s=spec.deadline_s)

    def place(self, spec: FunctionSpec,
              point: ProfilePoint) -> Optional[str]:
        # track=False: the ControlPlane owns the L_j capacity queue.
        # Effective tensor-parallel degree: the spec's fleet-wide setting
        # or the point's own profiled one, whichever is wider (same rule
        # as the live backend, so decision replays stay aligned).
        return self.cluster.deploy(spec.name, point,
                                   elastic_limit=spec.elastic_limit,
                                   track=False,
                                   cold_start_s=spec.cold_start_s,
                                   shards=max(spec.shards, point.shards))

    def evict(self, spec: FunctionSpec, pod_id: str) -> None:
        # Idempotent by design, but NOT the dead-pod authority: the
        # reconciler prunes pods that died behind its back via ``alive``
        # at the top of every tick, so a scale-down can only ever name a
        # pod that existed when the tick started (it may still lose a race
        # against a mid-tick failure, hence the tolerance here).
        if pod_id in self.cluster.pods:
            self.cluster.retire(pod_id, drain=True)

    def alive(self, pod_id: str) -> bool:
        return self.cluster.alive(pod_id)

    def node_of(self, pod_id: str) -> Optional[int]:
        return self.cluster.node_of(pod_id)

    def fragmentation(self) -> dict[int, float]:
        return self.cluster.fragmentation()

    def node_load(self) -> dict[int, float]:
        return self.cluster.node_load()

    def migrate(self, spec: FunctionSpec, pod_id: str,
                target: int) -> Optional[str]:
        return self.cluster.migrate(pod_id, target)

    def observed_rps(self, fn: str, window: float) -> float:
        return self.cluster.observed_rps(fn, window)

    def inflight(self, fn: str) -> int:
        return self.cluster.inflight(fn)

    def warm_nodes(self, fn: str) -> list[int]:
        return self.cluster.warm_nodes(fn)

    def links(self) -> dict[tuple[int, int], float]:
        return self.cluster.links.pairs()

    def health(self, node: int) -> float:
        return self.cluster.health(node)

    def quarantine(self, node: int) -> int:
        return self.cluster.quarantine(node)

    def now(self) -> float:
        return self.cluster.sim.now


class LiveBackend:
    """Adapter: the real JAX ``ClusterFrontend`` as a reconciler backend.

    Pod ids are ``node:inst_id`` handles; time is wall-clock.  Models are
    built once per spec at registration (``spec.model_factory``) and their
    params shared zero-copy across instances by the per-node ModelStore.
    """

    def __init__(self, frontend: Any):
        self.frontend = frontend
        self._models: dict[str, tuple[Any, Any]] = {}
        self._drafts: dict[str, Any] = {}

    def register(self, spec: FunctionSpec) -> None:
        if spec.model_factory is None:
            raise ValueError(
                f"spec {spec.name!r} needs a model_factory for live serving")
        self._models[spec.name] = spec.model_factory()
        if spec.speculate is not None:
            if spec.draft_factory is None:
                raise ValueError(
                    f"spec {spec.name!r} sets speculate but no "
                    f"draft_factory for the draft weights")
            # Draft weights built once per spec, shared by every placement
            # through the per-node store (same sharing as the target).
            self._drafts[spec.name] = spec.draft_factory()

    def place(self, spec: FunctionSpec,
              point: ProfilePoint) -> Optional[str]:
        model, params = self._models[spec.name]
        alloc = point.to_alloc(spec.elastic_limit)
        # Paged block budget: an explicit spec override wins; otherwise the
        # profiled capacity of this allocation (ProfilePoint.kv_blocks, via
        # profiler.paged_kv_capacity); otherwise the engine's dense-
        # equivalent default.
        n_kv_blocks = spec.n_kv_blocks
        if (n_kv_blocks is None and spec.batching == "paged"
                and point.kv_blocks >= 2):
            n_kv_blocks = point.kv_blocks
        # Shared-fraction axis: the spec declaration and the profiled
        # point both carry it; charge admission with the larger (the spec
        # is the operator's override, the point the profiler's evidence).
        shared_frac = max(spec.kv_shared_frac, point.kv_shared_frac)
        if spec.batching != "paged" or not spec.prefix_sharing:
            shared_frac = 0.0
        # Arm the frontend's deadline/shedding lifecycle here rather than
        # at register: the shed admission check needs a per-instance
        # service-rate estimate, and the profile point is the first place
        # one exists.  With a best-effort tier and no deadline this stores
        # (tier, None, rate) and the whole machinery stays dormant.
        self.frontend.configure_slo(spec.name, tier=spec.slo_tier,
                                    deadline_s=spec.deadline_budget(),
                                    est_rps=point.throughput)
        return self.frontend.place_instance(
            spec.name, model, params, alloc,
            max_batch=spec.max_batch, max_len=spec.max_len,
            batching=spec.batching, framework_bytes=spec.framework_bytes,
            block_size=spec.block_size, n_kv_blocks=n_kv_blocks,
            prefix_sharing=spec.prefix_sharing,
            kv_shared_frac=shared_frac,
            speculate=spec.speculate,
            draft_params=self._drafts.get(spec.name),
            shards=max(spec.shards, point.shards))

    def evict(self, spec: FunctionSpec, pod_id: str) -> None:
        # Same mid-tick failure tolerance as SimBackend.evict.
        if self.frontend.alive(pod_id):
            self.frontend.evict(pod_id)

    def alive(self, pod_id: str) -> bool:
        return self.frontend.alive(pod_id)

    def node_of(self, pod_id: str) -> Optional[int]:
        return self.frontend.node_of(pod_id)

    def fragmentation(self) -> dict[int, float]:
        return self.frontend.fragmentation()

    def node_load(self) -> dict[int, float]:
        return self.frontend.node_load()

    def migrate(self, spec: FunctionSpec, pod_id: str,
                target: int) -> Optional[str]:
        model, params = self._models[spec.name]
        return self.frontend.migrate(spec.name, pod_id, model, params,
                                     target)

    def observed_rps(self, fn: str, window: float) -> float:
        return self.frontend.observed_rps(fn, window)

    def inflight(self, fn: str) -> int:
        return self.frontend.inflight(fn)

    def warm_nodes(self, fn: str) -> list[int]:
        return self.frontend.warm_nodes(fn)

    def links(self) -> dict[tuple[int, int], float]:
        return self.frontend.links.pairs()

    def health(self, node: int) -> float:
        return self.frontend.health(node)

    def quarantine(self, node: int) -> int:
        return self.frontend.quarantine(node)

    def now(self) -> float:
        return self.frontend.now()
