"""Declarative per-function serving contract (``FunctionSpec``).

A spec is everything the control plane needs to serve one function — the
paper's per-function inputs to Alg. 1 gathered into a single declarative
object instead of imperative ``deploy()`` arguments:

* the **profile table** ``P_j = {<F_j, S_p, Q_p, T_p>}`` from the
  FaST-Profiler (``ProfilePoint``s, each with a measured p99),
* the **latency SLO** used to filter profile points to the feasible set,
* the **target-RPS source** ``R_j`` (a trace / predictor callable, or None
  to observe arrivals from the backend),
* data-plane options (model factory, batching mode, slot pool size) for
  the live backend, and the calibrated ``ServiceCurve`` for the simulator.

The same spec object drives both backends; that is what makes the
"replay the live fleet through the simulator" workflow possible.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.core.scaling import ProfilePoint
from repro.core.slo import SLO_TIERS, TIER_BEST_EFFORT, deadline_budget
from repro.core.workload import ServiceCurve

# A target-RPS source: virtual-or-wall time -> offered requests/second.
RPSSource = Callable[[float], float]

DEFAULT_FRAMEWORK_BYTES = 64 * 1024 * 1024


def ramp(steps: Sequence[tuple[float, float]]) -> RPSSource:
    """Piecewise-constant RPS schedule ``[(t_start, rps), ...]``.

    The canonical deterministic target-RPS source: both backends see the
    identical demand signal, so their scale-decision sequences can be
    compared bit-for-bit.
    """
    ordered = sorted(steps)

    def source(now: float) -> float:
        rps = 0.0
        for t0, r in ordered:
            if now >= t0:
                rps = r
            else:
                break
        return rps

    return source


class DemandSource:
    """A *predictive* target-RPS source fed from the backend arrival log.

    A plain ``RPSSource`` callable is an oracle (a declared trace); a
    ``DemandSource`` is a forecaster: the reconciler calls
    ``observe(now, rps)`` with the backend's trailing-window arrival rate
    at the top of every tick, then reads the one-tick-ahead forecast via
    ``__call__(now)``.  Construct one instance per control plane — the
    state is the forecast, so sharing a source between a live fleet and
    its simulator replay would double-feed it.
    """

    def observe(self, now: float, rps: float) -> None:
        raise NotImplementedError

    def __call__(self, now: float) -> float:
        raise NotImplementedError


class EWMADemand(DemandSource):
    """Exponentially-weighted moving average of observed RPS.

    ``level <- alpha * obs + (1 - alpha) * level``; the forecast is the
    level.  Reacts to an RPS step within ~``1/alpha`` ticks instead of
    waiting out the full trailing ``rps_window`` — shrinking the
    detection-lag SLO-violation window — while smoothing Poisson noise a
    raw last-window estimate passes straight through.
    """

    def __init__(self, alpha: float = 0.5):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("need 0 < alpha <= 1")
        self.alpha = alpha
        self.level: Optional[float] = None

    def observe(self, now: float, rps: float) -> None:
        self.level = (rps if self.level is None
                      else self.alpha * rps + (1 - self.alpha) * self.level)

    def __call__(self, now: float) -> float:
        return max(self.level or 0.0, 0.0)


class HoltWintersDemand(DemandSource):
    """Holt-Winters (triple-exponential) forecast of observed RPS.

    Level + trend (Holt's linear method), plus an optional additive
    seasonal component of ``season`` ticks (set ``season=None`` for
    non-periodic traffic).  The trend term *extrapolates* a ramp one tick
    ahead instead of trailing it, so capacity is provisioned before the
    arrivals land; ``horizon`` scales how far ahead the trend projects.
    """

    def __init__(self, alpha: float = 0.5, beta: float = 0.3,
                 gamma: float = 0.2, season: Optional[int] = None,
                 horizon: float = 1.0):
        for name, v in (("alpha", alpha), ("beta", beta), ("gamma", gamma)):
            if not 0.0 < v <= 1.0:
                raise ValueError(f"need 0 < {name} <= 1")
        if season is not None and season < 2:
            raise ValueError("season needs at least 2 ticks")
        self.alpha, self.beta, self.gamma = alpha, beta, gamma
        self.season = season
        self.horizon = horizon
        self.level: Optional[float] = None
        self.trend = 0.0
        self._seasonal: list[float] = [0.0] * (season or 0)
        self._tick = 0

    def observe(self, now: float, rps: float) -> None:
        if self.level is None:
            self.level = rps
            self._tick += 1
            return
        s = (self._seasonal[self._tick % self.season]
             if self.season else 0.0)
        prev_level = self.level
        self.level = (self.alpha * (rps - s)
                      + (1 - self.alpha) * (self.level + self.trend))
        self.trend = (self.beta * (self.level - prev_level)
                      + (1 - self.beta) * self.trend)
        if self.season:
            i = self._tick % self.season
            self._seasonal[i] = (self.gamma * (rps - self.level)
                                 + (1 - self.gamma) * self._seasonal[i])
        self._tick += 1

    def __call__(self, now: float) -> float:
        if self.level is None:
            return 0.0
        s = (self._seasonal[self._tick % self.season]
             if self.season else 0.0)
        return max(self.level + self.horizon * self.trend + s, 0.0)


def autocorr_season(series: Sequence[float], *, min_lag: int = 2,
                    threshold: float = 0.3) -> Optional[int]:
    """Dominant period of an RPS series via its autocorrelation peak.

    Returns the lag (in ticks) of the highest *interior* local maximum of
    the normalized autocorrelation function at or beyond ``min_lag``, or
    ``None`` when no peak clears ``threshold`` — flat, monotone, or
    noise-dominated traffic has no season worth modelling, and feeding
    Holt-Winters a spurious one is worse than level+trend alone.  Requiring
    a local maximum (not a bare argmax) rejects the smooth ACF decay every
    trending series produces at the ``min_lag`` boundary.
    """
    x = np.asarray(list(series), dtype=float)
    n = x.size
    if n < 3 * min_lag:
        return None
    x = x - x.mean()
    denom = float(x @ x)
    if denom <= 0.0:
        return None
    max_lag = n // 2
    acf = np.array([float(x[:-k] @ x[k:]) / denom
                    for k in range(1, max_lag + 1)])
    best_lag: Optional[int] = None
    best_val = threshold
    for k in range(max(min_lag, 2), max_lag):
        i = k - 1
        if acf[i] > acf[i - 1] and acf[i] >= acf[i + 1] and acf[i] >= best_val:
            best_lag, best_val = k, float(acf[i])
    return best_lag


def fit_holt_winters(series: Sequence[float], *,
                     season: int | str | None = "auto",
                     horizon: float = 1.0,
                     grid: Sequence[float] = (0.1, 0.3, 0.5, 0.7, 0.9),
                     ) -> HoltWintersDemand:
    """Auto-tune a ``HoltWintersDemand`` on an observed RPS series.

    Replays every ``(alpha, beta, gamma)`` combination from ``grid``
    through a fresh forecaster over ``series`` (one observation per tick)
    and scores the one-step-ahead squared forecast error, skipping the
    first season of warm-up ticks.  ``season="auto"`` detects the period
    with :func:`autocorr_season`; pass an int to force one or ``None``
    for level+trend only (then ``gamma`` is inert and not searched).

    Returns a **fresh, unfed** forecaster carrying the winning parameters
    — hand it to ``FunctionSpec.target_rps`` and let the reconciler feed
    it live observations; replaying the fit series into it would
    double-count history the real arrivals are about to repeat.
    """
    xs = [float(v) for v in series]
    if season == "auto":
        season = autocorr_season(xs)
    if season is not None and not isinstance(season, int):
        raise TypeError(f"season must be int, None or 'auto', got {season!r}")
    warmup = season if season else 1
    gammas = tuple(grid) if season else (tuple(grid)[0],)
    best: Optional[tuple[float, float, float, float]] = None
    for a in grid:
        for b in grid:
            for g in gammas:
                hw = HoltWintersDemand(alpha=a, beta=b, gamma=g,
                                       season=season, horizon=horizon)
                err = 0.0
                for t, v in enumerate(xs):
                    if t >= warmup:
                        err += (hw(float(t)) - v) ** 2
                    hw.observe(float(t), v)
                if best is None or err < best[0]:
                    best = (err, a, b, g)
    assert best is not None
    _, a, b, g = best
    return HoltWintersDemand(alpha=a, beta=b, gamma=g, season=season,
                             horizon=horizon)


@dataclasses.dataclass(frozen=True)
class FunctionSpec:
    """Declarative serving contract for one function.

    Attributes:
      name: function id ``F_j``.
      profile: FaST-Profiler table ``P_j`` — one ``ProfilePoint`` per
        profiled ``(S_p, Q_p)`` allocation, with measured throughput
        ``T_p`` and p99 latency.
      slo_latency: latency SLO ``L_j`` in seconds; profile points whose
        measured p99 exceeds it are infeasible for Alg. 1.  None =
        best-effort.
      target_rps: demand source ``R_j(t)``; None means the reconciler asks
        the backend for the observed trailing-window arrival rate.  A
        ``DemandSource`` (``EWMADemand`` / ``HoltWintersDemand``) is fed
        the backend's observed rate every tick and forecasts ahead.
      rps_window: trailing horizon (seconds) for observed-RPS estimation.
      headroom: capacity over-provisioning factor (target utilization
        ``1/headroom``) so queueing delay stays bounded at the SLO.
      min_instances / max_instances: fleet-size clamps enforced by the
        reconciler on top of Alg. 1's decisions.
      elastic_limit: ``Q_limit`` for scaled-up pods (§3.3.2 elastic quota);
        None keeps ``Q_limit == Q_request``.
      model_factory: live backend only — builds ``(model, params)`` once at
        registration; instances share the params via the node ModelStore.
      max_batch / max_len / batching: live instance decode-slot options
        (``batching="paged"`` runs the block-paged KV data plane).
      block_size / n_kv_blocks: paged mode only — tokens per KV block and
        the per-instance physical block budget (None = the dense pool's
        worst case, so paging can only reduce bytes-in-use).  Profile
        tables record the matching capacity in ``ProfilePoint.kv_blocks``.
      prefix_sharing: paged mode only — content-hash prefix matching with
        copy-on-write (default on; False deploys the unshared reference
        plane).
      kv_shared_frac: shared-fraction admission axis — the declared
        fraction of KV blocks expected to be prefix-shared duplicates.
        The live frontend discounts its KV admission charge by it (honest
        over-admission; the engine still enforces worst-case per-request
        block reservations).  Profile tables carry the same axis in
        ``ProfilePoint.kv_shared_frac``; the larger of the two wins at
        placement.
      framework_bytes: per-instance runtime footprint charged by memory
        admission on the live path.
      cold_start_s: estimated scale-from-zero cold-start latency (origin
        fetch + staging + full weight upload) — the cold-start axis.  The
        simulator delays a freshly placed pod's first token grant by it
        (scaled down for host-warm / peer-warm nodes); the live path
        measures the real thing through the fleet model store and reports
        it in ``ClusterFrontend.cold_start_events()``.  0 keeps the
        legacy instant-ready model.
      speculate: speculative-decoding axis — a
        ``repro.serving.speculative.SpecConfig`` (duck-typed here so the
        control plane stays import-free of the serving engine).  Live
        placements run the draft/verify round on the fused hot path; the
        profile table should carry the matching ``spec_k`` / ``acceptance``
        so Alg. 1 budgets *effective* tokens/s.  The simulator treats the
        axis as already folded into the profile throughputs, which keeps
        sim-vs-live decision signatures equal with the axis on.
      draft_factory: live backend only — builds the draft model's params
        once at registration (the draft config ships inside ``speculate``).
        Required when ``speculate`` is set on a live fleet; the weights are
        staged per node under ``"{fn}#draft"`` and admission charges them
        on top of the target weights.
      shards: tensor-parallel axis — devices each pod of this function
        spans.  1 (default) is today's single-device pod.  >1 makes every
        placement a multi-rectangle pod: the live backend acquires one MRA
        rectangle per member on the link-fastest device group, the
        simulator charges the same multi-node footprint and folds the
        collective cost into its round time.  A per-point
        ``ProfilePoint.shards`` may widen individual points further; the
        effective degree at placement is ``max(spec.shards,
        point.shards)``.  Mutually exclusive with ``speculate`` — the
        draft/verify round is not tensor-parallel.
      slo_tier: SLO tier of every request admitted under this spec —
        ``"guaranteed"`` (never shed or expired; retried without bound),
        ``"best_effort"`` (the default; sheddable once a deadline is
        configured), or ``"batch"`` (the preemptible lane: same shedding
        rules, but queued behind every non-batch request).
      deadline_s: per-request deadline budget in seconds from arrival.
        None (default) falls back to ``slo_latency`` for non-best-effort
        tiers and to *no deadline at all* for best-effort — so a spec that
        sets neither field runs the exact pre-SLO request lifecycle.
      curve: simulator backend only — the calibrated ``ServiceCurve``.
    """

    name: str
    profile: tuple[ProfilePoint, ...]
    slo_latency: Optional[float] = None
    slo_tier: str = TIER_BEST_EFFORT
    deadline_s: Optional[float] = None
    target_rps: Optional[RPSSource] = None
    rps_window: float = 2.0
    headroom: float = 1.2
    min_instances: int = 1
    max_instances: int = 32
    elastic_limit: Optional[float] = 1.0
    model_factory: Optional[Callable[[], tuple[Any, Any]]] = None
    max_batch: int = 4
    max_len: int = 64
    batching: str = "continuous"
    block_size: int = 16
    n_kv_blocks: Optional[int] = None
    prefix_sharing: bool = True
    kv_shared_frac: float = 0.0
    framework_bytes: int = DEFAULT_FRAMEWORK_BYTES
    cold_start_s: float = 0.0
    speculate: Optional[Any] = None
    draft_factory: Optional[Callable[[], Any]] = None
    shards: int = 1
    curve: Optional[ServiceCurve] = None

    def __post_init__(self) -> None:
        if not self.profile:
            raise ValueError(f"spec {self.name!r} needs a profile table")
        if not (0 <= self.min_instances <= self.max_instances):
            raise ValueError(
                f"need 0 <= min_instances <= max_instances, got "
                f"{self.min_instances}, {self.max_instances}")
        if self.batching not in ("continuous", "static", "paged"):
            raise ValueError(f"unknown batching mode {self.batching!r}")
        if self.batching == "paged":
            if self.block_size <= 0 or self.max_len % self.block_size:
                raise ValueError(
                    "block_size must be positive and divide max_len")
            if self.n_kv_blocks is not None and self.n_kv_blocks < 2:
                raise ValueError(
                    "n_kv_blocks needs the null page plus one usable "
                    "block (>= 2)")
        if not 0.0 <= self.kv_shared_frac < 1.0:
            raise ValueError(
                f"kv_shared_frac must be in [0, 1), got "
                f"{self.kv_shared_frac}")
        if self.kv_shared_frac > 0.0 and (self.batching != "paged"
                                          or not self.prefix_sharing):
            raise ValueError(
                "kv_shared_frac needs batching='paged' with prefix "
                "sharing enabled")
        if self.headroom < 1.0:
            raise ValueError("headroom < 1 provisions below offered load")
        if self.cold_start_s < 0.0:
            raise ValueError(
                f"cold_start_s must be >= 0, got {self.cold_start_s}")
        if self.speculate is not None:
            if self.batching == "static":
                raise ValueError(
                    "speculative decoding needs a slot batching mode "
                    "(continuous/paged)")
            if getattr(self.speculate, "k", 0) < 1:
                raise ValueError(
                    "speculate must be a SpecConfig-like object with k >= 1")
        if self.slo_tier not in SLO_TIERS:
            raise ValueError(
                f"slo_tier must be one of {SLO_TIERS}, got "
                f"{self.slo_tier!r}")
        if self.deadline_s is not None and self.deadline_s <= 0.0:
            raise ValueError(
                f"deadline_s must be positive, got {self.deadline_s}")
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.shards > 1 and self.speculate is not None:
            raise ValueError(
                "speculate cannot ride a sharded pod: the draft/verify "
                "round is not tensor-parallel")

    def feasible_points(self) -> list[ProfilePoint]:
        """Profile points meeting the SLO (all points when none do, so the
        scaler can degrade gracefully instead of dropping traffic)."""
        if self.slo_latency is None:
            return list(self.profile)
        ok = [p for p in self.profile if p.p99_latency <= self.slo_latency]
        return ok or list(self.profile)

    def best_point(self) -> ProfilePoint:
        """Most efficient SLO-feasible point: ``argmax_p RPR``."""
        return max(self.feasible_points(), key=lambda p: p.rpr)

    def deadline_budget(self) -> Optional[float]:
        """Seconds from arrival each request of this function has, or None
        (no deadline — the dormant default for best-effort specs)."""
        return deadline_budget(self.slo_tier, self.deadline_s,
                               self.slo_latency)
