"""Backend-agnostic reconciler: paper Alg. 1 converging a declared fleet.

One ``reconcile(now)`` tick is the paper's closed loop:

0. **Prune** — pods that died behind the reconciler's back (node failure)
   are dropped from ``placed`` and the L_j capacity queue via
   ``backend.alive``, so the gap below sees the real fleet.  This is the
   entire failure-recovery story: a dead pod is just missing capacity,
   and steps 1-4 re-converge it — identically on both backends.
1. **Demand** — per function, read ``R_j`` from the spec's target-RPS
   source (deterministic replay; predictive ``DemandSource``s are fed the
   backend's observed rate first) or the backend's observed trailing-
   window arrival rate, then inflate by the spec's headroom.
2. **Gap** — ``ΔRPS_j = R_j - Σ_i T_{j,i}`` over the L_j capacity queue
   (``processing_gap``).
3. **Decide** — ``heuristic_scale`` (Alg. 1) filtered to SLO-feasible
   profile points: bulk ``p_eff`` pods + one minimal-sufficient
   ``p_ideal`` on scale-up; lowest-RPR victims on scale-down.
4. **Converge** — scale-ups go through ``backend.place`` (MRA + memory
   admission with node spillover); each provisional L_j reservation is
   settled with ``confirm``/``abort`` so capacity never drifts above
   reality.  Scale-downs go through ``backend.evict``, which drains the
   victim's in-flight slots before releasing its rectangle and weight
   refcount.  ``min/max_instances`` clamps are applied here, on top of
   Alg. 1.
5. **Defragment** — when the worst node's MRA fragmentation exceeds
   ``defrag_threshold``, the lowest-RPR pod on that node migrates to the
   least-loaded node that admits it (``backend.migrate`` — a real KV move
   on the live path).  Migrations re-key L_j entries in place; they are
   capacity-neutral and logged separately (``migrations``), never in the
   decision log, so replay signatures stay backend-independent.

Because every decision is computed here — the backend only places,
evicts, and moves — the simulator and the live JAX data plane run
literally the same scheduler code, and a live run can be replayed through
the simulator decision-for-decision, node failures included.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable, Optional

from repro.control.backend import Backend
from repro.control.spec import DemandSource, FunctionSpec
from repro.core.scaling import (FunctionPodQueue, ProfilePoint, ScaleDecision,
                                heuristic_scale, processing_gap)


def decision_signature(decisions: Iterable[ScaleDecision]
                       ) -> list[tuple[str, int, float, float]]:
    """Backend-independent fingerprint of a decision sequence.

    Pod ids differ between backends (``fn-3`` vs ``1:fn/0``); what must
    match when replaying a live run through the simulator is *what* was
    scaled: (function, direction, S_p, Q_p) per decision, in order.
    """
    return [(d.function, d.direction, d.point.sm, d.point.quota)
            for d in decisions]


@dataclasses.dataclass
class ReconcileEvent:
    """Telemetry for one reconcile tick of one function."""

    now: float
    fn: str
    target_rps: float
    capacity_before: float
    instances_before: int
    inflight: int
    applied: list[ScaleDecision] = dataclasses.field(default_factory=list)
    pruned: list[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class MigrationEvent:
    """One defragmentation move applied by the reconciler."""

    now: float
    fn: str
    old_pod: str
    new_pod: str
    source: int
    target: int
    fragmentation: float  # source-node fragmentation that triggered it


@dataclasses.dataclass(frozen=True)
class QuarantineEvent:
    """One gray-failure quarantine applied by the reconciler."""

    now: float
    node: int
    score: float      # backend.health(node) that tripped the threshold
    instances: int    # instances taken out of rotation


class ControlPlane:
    """Declarative reconciler over any :class:`Backend`.

    ``history`` bounds the retained telemetry (``log`` / ``events``) so a
    long-lived control loop doesn't grow without bound.  ``defrag_threshold``
    arms the defragmentation pass: when any node's MRA fragmentation
    exceeds it, up to ``defrag_max_moves`` lowest-RPR pods migrate off the
    worst node per tick (None disables the pass).  ``quarantine_threshold``
    arms the gray-failure sweep: a node whose ``backend.health`` drops
    below it is quarantined — routing stops, occupants drain, and the
    reconciler's ordinary prune + processing gap heal the capacity exactly
    like a crash (None disables the sweep).
    """

    def __init__(self, backend: Backend, history: int = 10_000,
                 defrag_threshold: Optional[float] = None,
                 defrag_max_moves: int = 1,
                 quarantine_threshold: Optional[float] = None):
        self.backend = backend
        self.defrag_threshold = defrag_threshold
        self.defrag_max_moves = defrag_max_moves
        self.quarantine_threshold = quarantine_threshold
        self.specs: dict[str, FunctionSpec] = {}
        self.queues: dict[str, FunctionPodQueue] = {}
        # fn -> pod_id -> profile point, for every live instance we placed.
        self.placed: dict[str, dict[str, ProfilePoint]] = {}
        self.log: deque[ScaleDecision] = deque(maxlen=history)
        self.events: deque[ReconcileEvent] = deque(maxlen=history)
        self.migrations: deque[MigrationEvent] = deque(maxlen=history)
        self.quarantines: deque[QuarantineEvent] = deque(maxlen=history)
        self._quarantined: set[int] = set()

    # -- registration ------------------------------------------------------

    def register(self, spec: FunctionSpec) -> None:
        """Declare a function and bring up its ``min_instances`` floor at
        the most efficient SLO-feasible profile point.

        All-or-nothing: a failed bring-up evicts whatever it placed and
        unregisters the spec, so the caller can retry cleanly.
        """
        if spec.name in self.specs:
            raise ValueError(f"function {spec.name!r} already registered")
        self.backend.register(spec)
        self.specs[spec.name] = spec
        self.queues[spec.name] = FunctionPodQueue()
        self.placed[spec.name] = {}
        point = spec.best_point()
        for _ in range(spec.min_instances):
            if self._place(spec, point) is None:
                for pod_id in list(self.placed[spec.name]):
                    self.backend.evict(spec, pod_id)
                del self.specs[spec.name]
                del self.queues[spec.name]
                del self.placed[spec.name]
                raise RuntimeError(
                    f"cannot bring up min_instances={spec.min_instances} "
                    f"for {spec.name!r}: no node admits {point}")

    def _place(self, spec: FunctionSpec,
               point: ProfilePoint) -> Optional[str]:
        real = self.backend.place(spec, point)
        if real is not None:
            self.queues[spec.name].push(real, point)
            self.placed[spec.name][real] = point
        return real

    # -- introspection -----------------------------------------------------

    def instances(self, fn: str) -> int:
        return len(self.placed[fn])

    def capacity(self, fn: str) -> float:
        return self.queues[fn].capacity()

    # -- the loop ----------------------------------------------------------

    def reconcile(self, now: Optional[float] = None) -> list[ScaleDecision]:
        """One Alg.-1 tick over every registered function.

        ``now`` defaults to the backend clock; pass explicit ticks to make
        live and simulated runs comparable (their clocks differ, their
        decisions must not).
        """
        if now is None:
            now = self.backend.now()
        # Gray-failure sweep FIRST: a node quarantined here reads dead to
        # ``alive`` below, so the same tick's prune + gap already heal it.
        if self.quarantine_threshold is not None:
            self._sweep_health(now)
        # Prune pods that died behind our back (node failure): L_j and
        # ``placed`` are authoritative only over pods the backend still
        # reports alive, so the gap below re-provisions lost capacity.
        pruned: dict[str, list[str]] = {}
        for fn in self.specs:
            gone = [p for p in self.placed[fn]
                    if not self.backend.alive(p)]
            for pod_id in gone:
                self.placed[fn].pop(pod_id)
                self.queues[fn].remove(pod_id)
            if gone:
                pruned[fn] = gone
        demand: dict[str, float] = {}
        pre: dict[str, ReconcileEvent] = {}
        for fn, spec in self.specs.items():
            source = spec.target_rps
            if source is None:
                rps = self.backend.observed_rps(fn, spec.rps_window)
            else:
                if isinstance(source, DemandSource):
                    # Forecasters eat the arrival log, one tick at a time.
                    source.observe(
                        now, self.backend.observed_rps(fn, spec.rps_window))
                rps = source(now)
            demand[fn] = rps * spec.headroom
            pre[fn] = ReconcileEvent(
                now=now, fn=fn, target_rps=rps,
                capacity_before=self.queues[fn].capacity(),
                instances_before=len(self.placed[fn]),
                inflight=self.backend.inflight(fn),
                pruned=pruned.get(fn, []))
        gaps = processing_gap(demand, self.queues)
        # SLO feasibility is filtered once, by the spec (the same filter
        # best_point() used at registration) — heuristic_scale's own
        # slo_latency re-filter stays for legacy Cluster.autoscale callers.
        profiles = {fn: s.feasible_points() for fn, s in self.specs.items()}
        decisions = heuristic_scale(gaps, profiles, self.queues)
        applied: list[ScaleDecision] = []
        for d in decisions:
            spec = self.specs[d.function]
            queue = self.queues[d.function]
            live = self.placed[d.function]
            if d.direction > 0:
                if len(live) >= spec.max_instances:
                    queue.abort(d.pod_id)  # fleet-size ceiling
                    continue
                real = self.backend.place(spec, d.point)
                if real is None:
                    queue.abort(d.pod_id)  # no node admits it
                    continue
                queue.confirm(d.pod_id, real)
                live[real] = d.point
                applied.append(dataclasses.replace(d, pod_id=real))
            else:
                assert d.pod_id is not None
                if len(live) <= spec.min_instances:
                    # Alg. 1 popped the victim; fleet floor puts it back.
                    queue.push(d.pod_id, d.point)
                    continue
                self.backend.evict(spec, d.pod_id)
                live.pop(d.pod_id, None)
                applied.append(d)
        # Heal below-floor fleets (a pod died, or an earlier bring-up was
        # capacity-starved): the floor is declared state, not a one-shot.
        for fn, spec in self.specs.items():
            while len(self.placed[fn]) < spec.min_instances:
                point = spec.best_point()
                real = self._place(spec, point)
                if real is None:
                    break  # still no capacity; retry next tick
                applied.append(ScaleDecision(fn, point, +1, pod_id=real))
        # Defragmentation: heal the MRA rectangle space a long ramp
        # shattered by moving cheap pods off the worst node.
        if self.defrag_threshold is not None:
            self._defrag(now)
        for d in applied:
            pre[d.function].applied.append(d)
        self.events.extend(pre.values())
        self.log.extend(applied)
        return applied

    # -- gray-failure quarantine -------------------------------------------

    def _sweep_health(self, now: float) -> list[QuarantineEvent]:
        """Quarantine every schedulable node whose health score fell below
        the threshold, always keeping at least one node in rotation.

        Quarantine is a health action, not a scheduling decision: events
        go to ``self.quarantines``, never the decision log, so a replay's
        ``decision_signature`` is unaffected by WHICH backend detected the
        degradation — only by the capacity gap it opened, which both
        backends heal through the same Alg.-1 path.
        """
        swept: list[QuarantineEvent] = []
        in_rotation = sorted(set(self.backend.node_load())
                             - self._quarantined)
        scores = {n: self.backend.health(n) for n in in_rotation}
        # Worst node first, so the keep-one floor protects the healthiest.
        for node in sorted(in_rotation, key=lambda n: scores[n]):
            if scores[node] >= self.quarantine_threshold:
                break
            if len(in_rotation) - len(swept) <= 1:
                break  # never quarantine the last schedulable node
            n_inst = self.backend.quarantine(node)
            self._quarantined.add(node)
            event = QuarantineEvent(now=now, node=node,
                                    score=scores[node], instances=n_inst)
            self.quarantines.append(event)
            swept.append(event)
        return swept

    # -- defragmentation ---------------------------------------------------

    def _defrag(self, now: float) -> list[MigrationEvent]:
        """Migrate up to ``defrag_max_moves`` lowest-RPR pods off the most
        fragmented node to the least-loaded node that admits them.

        Migrations are capacity-neutral: the pod keeps its profile point,
        its L_j entry is re-keyed, and nothing enters the decision log —
        so a simulator replay's ``decision_signature`` is unaffected by
        how (or whether) the two fleets happened to defragment.
        """
        moved: list[MigrationEvent] = []
        for _ in range(self.defrag_max_moves):
            frag = self.backend.fragmentation()
            if not frag:
                break
            worst = max(sorted(frag), key=lambda n: frag[n])
            if frag[worst] <= self.defrag_threshold:
                break
            # Victim: the lowest-RPR pod we placed on the worst node (the
            # cheapest capacity to move, per Alg. 1's own eviction order).
            cands = [(point.rpr, pod_id, fn)
                     for fn, pods in self.placed.items()
                     for pod_id, point in pods.items()
                     if self.backend.node_of(pod_id) == worst]
            if not cands:
                break
            _, pod_id, fn = min(cands)
            spec = self.specs[fn]
            loads = self.backend.node_load()
            # Warm-aware targeting: among admitting nodes, prefer one that
            # already holds the function's weights (host-staged or device-
            # resident) so the move skips the cold upload.  getattr-guarded:
            # minimal test backends without the verb defrag as before.
            warm = set(getattr(self.backend, "warm_nodes",
                               lambda _fn: [])(fn))
            new_id = None
            for target in sorted((n for n in loads if n != worst),
                                 key=lambda n: (n not in warm,
                                                loads[n], n)):
                new_id = self.backend.migrate(spec, pod_id, target)
                if new_id is not None:
                    break
            if new_id is None:
                break  # nothing admits it (or the pod is mid-step): retry
            self.placed[fn][new_id] = self.placed[fn].pop(pod_id)
            self.queues[fn].rekey(pod_id, new_id)
            event = MigrationEvent(now=now, fn=fn, old_pod=pod_id,
                                   new_pod=new_id, source=worst,
                                   target=target,
                                   fragmentation=frag[worst])
            self.migrations.append(event)
            moved.append(event)
        return moved
