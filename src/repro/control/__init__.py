"""Declarative control plane: FunctionSpec + reconciler over both fleets.

Declare *what* to serve (``FunctionSpec``: profile table, SLO, demand
source); the ``ControlPlane`` reconciles the fleet with paper Alg. 1
through a thin ``Backend`` seam — ``SimBackend`` (discrete-event
simulator) and ``LiveBackend`` (real JAX engines) run the same scheduler
code.  See ``src/repro/control/README.md`` for the paper-symbol mapping.
"""

from repro.control.backend import Backend, LiveBackend, SimBackend
from repro.control.plane import (ControlPlane, MigrationEvent,
                                 ReconcileEvent, decision_signature)
from repro.control.spec import (DemandSource, EWMADemand, FunctionSpec,
                                HoltWintersDemand, RPSSource,
                                autocorr_season, fit_holt_winters, ramp)

__all__ = [
    "Backend",
    "ControlPlane",
    "DemandSource",
    "EWMADemand",
    "FunctionSpec",
    "HoltWintersDemand",
    "LiveBackend",
    "MigrationEvent",
    "RPSSource",
    "ReconcileEvent",
    "SimBackend",
    "autocorr_season",
    "decision_signature",
    "fit_holt_winters",
    "ramp",
]
