"""Mamba-style selective scan Pallas TPU kernel (Hymba SSM heads).

Grid = (batch, head, time-chunks) with the (D x N) state in VMEM scratch
across the sequential time axis.  Per step: elementwise decay
``exp(dt * A)`` on the (1 x N) row, a rank-1 (D x N) state update, and a
(D x N) x (N,) contraction for the output — all VPU-friendly shapes.

Validated in interpret mode against ``ref.ssm_reference``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x releases;
# accept either so the kernels run on whichever toolchain is baked in.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref, y_ref, sT_ref,
            state_ref, *, block_t: int, n_blocks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)    # (bt, d)
    dt = dt_ref[0, :, 0].astype(jnp.float32)     # (bt,)
    a = -jnp.exp(a_ref[0].astype(jnp.float32))   # (n,)
    bm = b_ref[0, :, 0, :].astype(jnp.float32)   # (bt, n)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)   # (bt, n)

    def step(t, _):
        xt = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)    # (1, d)
        dtt = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)  # (1,)
        bt_ = jax.lax.dynamic_slice_in_dim(bm, t, 1, 0)  # (1, n)
        ct = jax.lax.dynamic_slice_in_dim(cm, t, 1, 0)   # (1, n)
        da = jnp.exp(dtt[0] * a)  # (n,)
        dbx = xt.T @ (dtt[0] * bt_)  # (d, n) rank-1
        state_ref[...] = state_ref[...] * da[None, :] + dbx
        y = state_ref[...] @ ct[0][:, None]  # (d, 1)
        y_ref[0, t, 0, :] = y[:, 0].astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, block_t, step, ())

    @pl.when(it == n_blocks - 1)
    def _finalize():
        sT_ref[0, 0] = state_ref[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def ssm_scan_pallas(
    x: jax.Array,      # (B, S, H, D)
    dt: jax.Array,     # (B, S, H)
    a_log: jax.Array,  # (H, N)
    b: jax.Array,      # (B, S, H, N)
    c: jax.Array,      # (B, S, H, N)
    state: jax.Array,  # (B, H, D, N)
    *,
    block_t: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    bsz, s, h, d = x.shape
    n = a_log.shape[-1]
    block_t = min(block_t, s)
    if s % block_t:
        raise ValueError("sequence length must divide block_t")
    nt = s // block_t
    kernel = functools.partial(_kernel, block_t=block_t, n_blocks=nt)

    y, s_t = pl.pallas_call(
        kernel,
        grid=(bsz, h, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, 1, d), lambda ib, ih, it: (ib, it, ih, 0)),
            pl.BlockSpec((1, block_t, 1), lambda ib, ih, it: (ib, it, ih)),
            pl.BlockSpec((1, n), lambda ib, ih, it: (ih, 0)),
            pl.BlockSpec((1, block_t, 1, n), lambda ib, ih, it: (ib, it, ih, 0)),
            pl.BlockSpec((1, block_t, 1, n), lambda ib, ih, it: (ib, it, ih, 0)),
            pl.BlockSpec((1, 1, d, n), lambda ib, ih, it: (ib, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, 1, d), lambda ib, ih, it: (ib, it, ih, 0)),
            pl.BlockSpec((1, 1, d, n), lambda ib, ih, it: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, d), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, d, n), state.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((d, n), jnp.float32)],
        compiler_params=_CompilerParams(dimension_semantics=(
            "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a_log, b, c, state)
    return y, s_t
