"""Pure-jnp oracles for every kernel (naive, O(S^2) memory where applicable).

These are the ground truth for kernel tests: pallas (interpret mode) and the
xla chunked paths must match these within dtype tolerance.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  q_offset: int = 0) -> jax.Array:
    """Naive GQA attention. q: (B,Sq,H,D); k,v: (B,Sk,K,D)."""
    b, sq, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    g = h // n_kv
    qf = q.astype(jnp.float32) * d ** -0.5
    qf = qf.reshape(b, sq, n_kv, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def decode_reference(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *,
                     window: Optional[int] = None) -> jax.Array:
    b, _, h, d = q.shape
    _, s, n_kv, _ = k_cache.shape
    g = h // n_kv
    qf = q.astype(jnp.float32).reshape(b, 1, n_kv, g, d) * d ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, k_cache.astype(jnp.float32))
    pos = jnp.arange(s)
    valid = pos[None, :] < cache_len[:, None]
    if window is not None:
        valid &= pos[None, :] > cache_len[:, None] - 1 - window
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def wkv6_reference(r, k, v, w, u, state):
    """RWKV-6 recurrence, python loop over time (oracle)."""
    b, s, h, d = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    st = state.astype(jnp.float32)  # (B,H,Dk,Dv)
    outs = []
    for t in range(s):
        kv = kf[:, t, :, :, None] * vf[:, t, :, None, :]
        att = st + uf[None, :, :, None] * kv
        outs.append(jnp.einsum("bhk,bhkv->bhv", rf[:, t], att))
        st = jnp.exp(wf[:, t])[..., None] * st + kv
    out = jnp.stack(outs, axis=1)
    return out.astype(r.dtype), st.astype(state.dtype)


def ssm_reference(x, dt, a_log, b, c, state):
    """Selective scan, python loop over time (oracle)."""
    bsz, s, h, d = x.shape
    a = -jnp.exp(a_log.astype(jnp.float32))
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    bf, cf = b.astype(jnp.float32), c.astype(jnp.float32)
    st = state.astype(jnp.float32)  # (B,H,D,N)
    ys = []
    for t in range(s):
        da = jnp.exp(dtf[:, t][..., None] * a[None])  # (B,H,N)
        dbx = (dtf[:, t][..., None] * bf[:, t])[:, :, None, :] \
            * xf[:, t][..., None]
        st = da[:, :, None, :] * st + dbx
        ys.append(jnp.einsum("bhdn,bhn->bhd", st, cf[:, t]))
    y = jnp.stack(ys, axis=1)
    return y.astype(x.dtype), st.astype(state.dtype)
