"""FlashAttention Pallas TPU kernel (GQA, causal, sliding window).

TPU-native adaptation (DESIGN.md): the grid is (batch, q-head, q-blocks,
kv-blocks) with the kv dimension marked "arbitrary" (sequential) so the
online-softmax running state lives in VMEM scratch across kv steps — the
HBM->VMEM pipeline streams k/v blocks while the MXU consumes the previous
one.  Block shapes are (block_q x d_head) / (block_k x d_head) tiles,
MXU-aligned when block sizes are multiples of 128.

Causal/sliding-window masking is applied per element; fully-masked kv
blocks are skipped with ``pl.when`` so the causal lower triangle costs
~half the full-attention FLOPs.

Validated on CPU in interpret mode against ``ref.mha_reference``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x releases;
# accept either so the kernels run on whichever toolchain is baked in.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, window: Optional[int], q_offset: int,
            block_q: int, block_k: int, n_kv_blocks: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # Skip kv blocks that are entirely masked out.
    q_lo = q_offset + iq * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    live = jnp.asarray(True)
    if causal:
        live &= k_lo <= q_hi
    if window is not None:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k",
                     "interpret"))
def flash_attention_pallas(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, K, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = True,  # CPU container: interpret; on TPU pass False
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    g = h // n_kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError("sequence lengths must divide block sizes")
    nq, nk = sq // block_q, sk // block_k

    kernel = functools.partial(
        _kernel, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, n_kv_blocks=nk, scale=d ** -0.5)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda ib, ih, iq, ik, g=g: (ib, ik, ih // g, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda ib, ih, iq, ik, g=g: (ib, ik, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=(
            "parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
