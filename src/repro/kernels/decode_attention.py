"""Single-token GQA decode attention Pallas TPU kernel.

The serving hot-spot: one query token per sequence attends over a long
(padded) KV cache.  Grid = (batch, kv-head, kv-blocks); all G query heads of
a kv group are processed together as a (G x d) tile so the MXU sees a real
matmul instead of G matvecs — the TPU-native replacement for the GPU
warp-per-row reductions this kind of kernel uses on CUDA (DESIGN.md).
Online softmax state lives in VMEM scratch across the sequential kv-block
dimension; per-row cache lengths arrive via SMEM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x releases;
# accept either so the kernels run on whichever toolchain is baked in.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_s: int, n_blocks: int, window: Optional[int], scale: float):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cache_len = len_ref[0]
    blk_lo = ik * block_s
    live = blk_lo < cache_len
    if window is not None:
        live &= (blk_lo + block_s) > cache_len - 1 - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0, 0, :, :].astype(jnp.float32) * scale  # (G, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bs, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bs)
        pos = blk_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < cache_len
        if window is not None:
            mask &= pos > cache_len - 1 - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _kernel_q8(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
               acc_ref, m_ref, l_ref, *, block_s: int, n_blocks: int,
               scale: float):
    """int8-KV variant (§Perf D): codes dequantize in VMEM after the HBM
    load, so the cache streams at 1 byte/element + a scale row."""
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cache_len = len_ref[0]
    blk_lo = ik * block_s

    @pl.when(blk_lo < cache_len)
    def _compute():
        q = q_ref[0, 0, 0, :, :].astype(jnp.float32) * scale  # (G, d)
        ks = ks_ref[0, :, 0, :].astype(jnp.float32)  # (bs, 1)
        vs = vs_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32) * ks  # dequant in VMEM
        v = v_ref[0, :, 0, :].astype(jnp.float32) * vs
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bs)
        pos = blk_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < cache_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_kernel(len_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, block_s: int, n_blocks: int,
                  scale: float):
    """Block-table walk: grid dim 2 is the LOGICAL block index; the
    physical page each step streams was chosen by the scalar-prefetch
    index map (``tbl_ref[b, i]``), so only a sequence's own blocks ever
    leave HBM.  Past-the-end table entries point at the shared null block;
    its rows are masked by ``cache_len`` exactly like dense padding."""
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cache_len = len_ref[ib]
    blk_lo = ik * block_s  # logical token offset of this block-table slot

    @pl.when(blk_lo < cache_len)
    def _compute():
        q = q_ref[0, 0, 0, :, :].astype(jnp.float32) * scale  # (G, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bs, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bs)
        pos = blk_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < cache_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_kernel_q8(len_ref, tbl_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                     o_ref, acc_ref, m_ref, l_ref, *, block_s: int,
                     n_blocks: int, scale: float):
    """int8-KV paged variant: codes + per-row scales stream per physical
    block and dequantize in VMEM (1 byte/element over the wire)."""
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cache_len = len_ref[ib]
    blk_lo = ik * block_s

    @pl.when(blk_lo < cache_len)
    def _compute():
        q = q_ref[0, 0, 0, :, :].astype(jnp.float32) * scale  # (G, d)
        ks = ks_ref[0, :, 0, :].astype(jnp.float32)  # (bs, 1)
        vs = vs_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32) * ks  # dequant in VMEM
        v = v_ref[0, :, 0, :].astype(jnp.float32) * vs
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bs)
        pos = blk_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < cache_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(
    q: jax.Array,             # (B, 1, H, D)
    k_pages: jax.Array,       # (N, bs, K, D) physical KV blocks
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, M) int32
    cache_len: jax.Array,     # (B,) int32
    *,
    interpret: bool = True,
) -> jax.Array:
    """Paged single-token GQA decode: grid = (batch, kv-head, table slot).

    ``block_tables`` and ``cache_len`` ride in as scalar-prefetch operands
    (``pltpu.PrefetchScalarGridSpec``) so the K/V index maps can pick the
    PHYSICAL page for each logical slot before the DMA is issued — the
    TPU-native equivalent of vLLM's gather-free paged attention.
    """
    b, _, h, d = q.shape
    _, bs, n_kv, _ = k_pages.shape
    m = block_tables.shape[1]
    g = h // n_kv

    kernel = functools.partial(_paged_kernel, block_s=bs, n_blocks=m,
                               scale=d ** -0.5)
    qg = q.reshape(b, 1, n_kv, g, d)
    kv_spec = pl.BlockSpec(
        (1, bs, 1, d),
        lambda ib, ih, ik, len_ref, tbl_ref: (tbl_ref[ib, ik], 0, ih, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_kv, m),
        in_specs=[
            pl.BlockSpec((1, 1, 1, g, d),
                         lambda ib, ih, ik, *_: (ib, 0, ih, 0, 0)),
            kv_spec, kv_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ib, ih, ik, *_: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, d), q.dtype),
        compiler_params=_CompilerParams(dimension_semantics=(
            "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), block_tables.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(b, 1, h, d)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_quant_pallas(
    q: jax.Array,             # (B, 1, H, D)
    k_pages: jax.Array,       # (N, bs, K, D) int8 codes
    v_pages: jax.Array,
    k_scale: jax.Array,       # (N, bs, K, 1) bf16 scales
    v_scale: jax.Array,
    block_tables: jax.Array,  # (B, M) int32
    cache_len: jax.Array,     # (B,) int32
    *,
    interpret: bool = True,
) -> jax.Array:
    b, _, h, d = q.shape
    _, bs, n_kv, _ = k_pages.shape
    m = block_tables.shape[1]
    g = h // n_kv

    kernel = functools.partial(_paged_kernel_q8, block_s=bs, n_blocks=m,
                               scale=d ** -0.5)
    qg = q.reshape(b, 1, n_kv, g, d)
    kv_spec = pl.BlockSpec(
        (1, bs, 1, d),
        lambda ib, ih, ik, len_ref, tbl_ref: (tbl_ref[ib, ik], 0, ih, 0))
    sc_spec = pl.BlockSpec(
        (1, bs, 1, 1),
        lambda ib, ih, ik, len_ref, tbl_ref: (tbl_ref[ib, ik], 0, ih, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_kv, m),
        in_specs=[
            pl.BlockSpec((1, 1, 1, g, d),
                         lambda ib, ih, ik, *_: (ib, 0, ih, 0, 0)),
            kv_spec, kv_spec, sc_spec, sc_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ib, ih, ik, *_: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, d), q.dtype),
        compiler_params=_CompilerParams(dimension_semantics=(
            "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), block_tables.astype(jnp.int32),
      qg, k_pages, v_pages, k_scale, v_scale)
    return out.reshape(b, 1, h, d)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_quant_pallas(
    q: jax.Array,        # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, K, D) int8
    v_cache: jax.Array,  # (B, S, K, D) int8
    k_scale: jax.Array,  # (B, S, K, 1) bf16
    v_scale: jax.Array,
    cache_len: jax.Array,  # (B,) int32
    *,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, _, h, d = q.shape
    _, s, n_kv, _ = k_cache.shape
    g = h // n_kv
    block_s = min(block_s, s)
    if s % block_s:
        raise ValueError("cache length must divide block_s")
    ns = s // block_s

    kernel = functools.partial(_kernel_q8, block_s=block_s, n_blocks=ns,
                               scale=d ** -0.5)
    qg = q.reshape(b, 1, n_kv, g, d)
    kv_spec = pl.BlockSpec((1, block_s, 1, d),
                           lambda ib, ih, ik: (ib, ik, ih, 0))
    sc_spec = pl.BlockSpec((1, block_s, 1, 1),
                           lambda ib, ih, ik: (ib, ik, ih, 0))

    out = pl.pallas_call(
        kernel,
        grid=(b, n_kv, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih, ik: (ib,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, g, d), lambda ib, ih, ik: (ib, 0, ih, 0, 0)),
            kv_spec, kv_spec, sc_spec, sc_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=(
            "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), qg, k_cache, v_cache, k_scale, v_scale)
    return out.reshape(b, 1, h, d)


@functools.partial(jax.jit, static_argnames=("window", "block_s", "interpret"))
def decode_attention_pallas(
    q: jax.Array,        # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, K, D)
    v_cache: jax.Array,
    cache_len: jax.Array,  # (B,) int32
    *,
    window: Optional[int] = None,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, _, h, d = q.shape
    _, s, n_kv, _ = k_cache.shape
    g = h // n_kv
    block_s = min(block_s, s)
    if s % block_s:
        raise ValueError("cache length must divide block_s")
    ns = s // block_s

    kernel = functools.partial(_kernel, block_s=block_s, n_blocks=ns,
                               window=window, scale=d ** -0.5)
    qg = q.reshape(b, 1, n_kv, g, d)

    out = pl.pallas_call(
        kernel,
        grid=(b, n_kv, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih, ik: (ib,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, g, d), lambda ib, ih, ik: (ib, 0, ih, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda ib, ih, ik: (ib, ik, ih, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda ib, ih, ik: (ib, ik, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=(
            "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, 1, h, d)
