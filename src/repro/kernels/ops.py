"""Attention / scan ops: jit'd wrappers that dispatch to an implementation.

Backends:
  * ``pallas`` — the TPU kernels in this package (``pl.pallas_call``); on CPU
    they run in interpret mode (tests only — slow).
  * ``xla``    — pure-jnp *chunked* implementations with online softmax.
    Memory-bounded like the kernels (never materializes S x S), compiles to
    compact While-loop HLO, and is the default path inside the models.
  * ``ref``    — naive full-matrix oracles from ``ref.py`` (tests only).

The models call these wrappers; the dry-run therefore lowers the xla path,
and kernel tests assert pallas == xla == ref over shape/dtype sweeps.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

DEFAULT_BACKEND = "xla"

NEG_INF = -1e30


def _gqa_expand(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, S, H, D) -> (B, S, K, G, D) grouped by kv head."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _block_mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
                window: Optional[int]) -> jax.Array:
    """(bq, bk) validity mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "backend"))
def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, K, D)
    v: jax.Array,  # (B, Sk, K, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    backend: str = DEFAULT_BACKEND,
) -> jax.Array:
    """Multi-head GQA attention, O(S) memory. Returns (B, Sq, H, D)."""
    if backend == "ref":
        return _ref.mha_reference(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset)
    if backend == "pallas":
        from repro.kernels import flash_attention as _fa
        return _fa.flash_attention_pallas(q, k, v, causal=causal,
                                          window=window, q_offset=q_offset,
                                          block_q=block_q, block_k=block_k)
    return _xla_flash(q, k, v, causal=causal, window=window,
                      q_offset=q_offset, block_q=block_q, block_k=block_k)


def _xla_flash(q, k, v, *, causal, window, q_offset, block_q, block_k):
    orig_dtype = q.dtype
    b, sq, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    g = h // n_kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    # Pad ragged tails up to block multiples (hymba's +meta-token seqs,
    # vision cross-attention ctx lengths); padded keys are masked via
    # ``sk_valid`` below and padded query rows sliced off at the end.
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    sq_valid, sk_valid = sq, sk
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        sq, sk = sq + pad_q, sk + pad_k
    scale = d ** -0.5
    qg = _gqa_expand(q, n_kv).astype(jnp.float32) * scale  # (B,Sq,K,G,D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    nq, nk = sq // block_q, sk // block_k

    q_blocks = qg.reshape(b, nq, block_q, n_kv, g, d).transpose(1, 0, 3, 4, 2, 5)
    k_blocks = kf.reshape(b, nk, block_k, n_kv, d).transpose(1, 0, 3, 2, 4)
    v_blocks = vf.reshape(b, nk, block_k, n_kv, d).transpose(1, 0, 3, 2, 4)

    # Windowed attention only ever reaches a bounded, *contiguous* range of
    # KV blocks per Q block — scan that constant-length range from a
    # dynamic start instead of all nk blocks (16x fewer block-pairs for
    # hymba's W=1024 at 32k ctx; static trip count, exact HLO accounting).
    import os
    n_win = None
    if window is not None and os.environ.get("REPRO_BASELINE", "") != "1":
        n_win = min(nk, (window - 1 + block_q) // block_k + 2)

    def attend(carry, ik, kb, vb, q_pos, qb):
        acc, m, l = carry
        k_pos = ik * block_k + jnp.arange(block_k)
        s = jnp.einsum("bkgqd,bksd->bkgqs", qb, kb)  # (B,K,G,bq,bk)
        mask = _block_mask(q_pos, k_pos, causal, window)
        mask &= (k_pos < sk_valid)[None, :]  # padded keys are invalid
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bksd->bkgqd", p, vb)
        return acc_new, m_new, l_new

    def one_q_block(iq, qb):  # qb: (B, K, G, bq, D)
        q_pos = q_offset + iq * block_q + jnp.arange(block_q)
        acc0 = jnp.zeros((b, n_kv, g, block_q, d), jnp.float32)
        m0 = jnp.full((b, n_kv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, block_q), jnp.float32)

        if n_win is not None and n_win < nk:
            q_start = q_offset + iq * block_q
            start = jnp.clip((q_start - window + 1) // block_k,
                             0, nk - n_win)

            def kv_step_win(carry, j):
                ik = start + j
                kb = jax.lax.dynamic_index_in_dim(k_blocks, ik, 0, False)
                vb = jax.lax.dynamic_index_in_dim(v_blocks, ik, 0, False)
                return attend(carry, ik, kb, vb, q_pos, qb), None

            (acc, m, l), _ = jax.lax.scan(
                kv_step_win, (acc0, m0, l0), jnp.arange(n_win))
        else:
            def kv_step(carry, inputs):
                ik, kb, vb = inputs  # kb/vb: (B, K, bk, D)
                return attend(carry, ik, kb, vb, q_pos, qb), None

            (acc, m, l), _ = jax.lax.scan(
                kv_step, (acc0, m0, l0),
                (jnp.arange(nk), k_blocks, v_blocks))
        return acc / jnp.maximum(l, 1e-30)[..., None]  # (B,K,G,bq,D)

    out, = jax.lax.map(
        lambda args: (one_q_block(*args),),
        (jnp.arange(nq), q_blocks))
    # out: (nq, B, K, G, bq, D) -> (B, Sq, H, D)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, d)
    return out[:, :sq_valid].astype(orig_dtype)


@functools.partial(jax.jit, static_argnames=("vocab_size",))
def greedy_sample(logits: jax.Array, vocab_size: int) -> jax.Array:
    """Fused on-device greedy sampler: ``argmax`` over the (padded) vocab
    clipped to the real ``vocab_size``.  Returns int32 tokens with the
    leading batch shape of ``logits``.

    This is the device-side replacement for the serving engine's
    ``np.asarray(jnp.argmax(...))`` host round-trip: called inside the
    fused decode step it keeps the whole round on the accelerator (a
    (B,) int32 pull instead of a (B, V) logits pull), and XLA fuses the
    reduction into the lm-head consumer — no Pallas variant needed.
    """
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.minimum(tok, vocab_size - 1)


def _filtered_logits(logits: jax.Array, vocab_size: int, temperature: float,
                     top_k: int, top_p: float) -> jax.Array:
    """Vocab-clipped, temperature-scaled logits with top-k / top-p (nucleus)
    filtering applied; excluded entries sit at ``NEG_INF``.

    The padded-vocab mask runs *before* the filters so a top-k/top-p cutoff
    can never be consumed by padding columns, and the top-1 entry always
    survives (top-p keeps the head of the nucleus even when
    ``top_p -> 0``).  Shared by ``sample_tokens`` and
    ``speculative_verify`` so the draft-proposal and verify distributions
    are computed by the same code path.
    """
    v = logits.shape[-1]
    idx = jnp.arange(v)
    logits = jnp.where(idx < vocab_size, logits.astype(jnp.float32), NEG_INF)
    logits = logits / max(temperature, 1e-6)
    if top_k > 0 and top_k < vocab_size:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, NEG_INF, logits)
    if top_p < 1.0:
        sort = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sort, axis=-1)
        # mass strictly before each sorted entry; keep while < top_p so the
        # nucleus always includes the argmax.
        before = jnp.cumsum(probs, axis=-1) - probs
        cutoff = jnp.maximum(
            jnp.sum(jnp.where(before < top_p, 1, 0), axis=-1, keepdims=True),
            1)
        thresh = jnp.take_along_axis(sort, cutoff - 1, axis=-1)
        logits = jnp.where(logits < thresh, NEG_INF, logits)
    return logits


@functools.partial(jax.jit, static_argnames=("vocab_size", "temperature",
                                             "top_k", "top_p"))
def sample_tokens(logits: jax.Array, key: jax.Array, vocab_size: int, *,
                  temperature: float = 1.0, top_k: int = 0,
                  top_p: float = 1.0) -> jax.Array:
    """Fused on-device stochastic sampler (temperature / top-k / top-p).

    Categorical sampling via the Gumbel trick on the filtered logits —
    an argmax the compiler fuses into the lm-head consumer exactly like
    ``greedy_sample``, so the fused decode round still pulls only a (B,)
    int32 vector.  ``temperature == 0`` degenerates to ``greedy_sample``
    (bit-identical argmax).  Padded vocab columns are masked before the
    filters, so a sampled id is always ``< vocab_size``.
    """
    if temperature == 0.0:
        return greedy_sample(logits, vocab_size)
    filt = _filtered_logits(logits, vocab_size, temperature, top_k, top_p)
    g = jax.random.gumbel(key, filt.shape, dtype=jnp.float32)
    tok = jnp.argmax(filt + g, axis=-1).astype(jnp.int32)
    return jnp.minimum(tok, vocab_size - 1)


@functools.partial(jax.jit, static_argnames=("vocab_size", "temperature",
                                             "top_k", "top_p", "greedy"))
def speculative_verify(
    target_logits: jax.Array,  # (B, k+1, V) — scores of [t0, d_1..d_k]
    draft_logits: jax.Array,   # (B, k, Vd) — draft scores that proposed d_j
    draft_tokens: jax.Array,   # (B, k) int32 — proposed tokens d_1..d_k
    key: jax.Array,
    vocab_size: int,
    *,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 1.0,
    greedy: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """On-device speculative rejection sampling (Leviathan-style).

    Returns ``(out_tokens (B, k+1), n_accept (B,))``: the emitted token
    stream is ``out_tokens[:, :n_accept + 1]`` — the accepted draft prefix
    followed by one token drawn from the corrected residual distribution
    (or the bonus target sample when every draft was accepted).  Greedy
    mode (``greedy`` or ``temperature == 0``) accepts while the target
    argmax agrees with the draft, so draft == target yields the exact
    non-speculative greedy stream.  Both logit tensors are sliced to the
    shared real ``vocab_size`` so draft / target padding may differ.
    """
    b, kp1, _ = target_logits.shape
    k = kp1 - 1
    if greedy or temperature == 0.0:
        # argmax over the PADDED width + clip — exactly ``greedy_sample``
        # on the raw lm-head logits, so an accepted greedy stream is
        # bit-identical to the non-speculative fused path.
        g = greedy_sample(target_logits, vocab_size)            # (B, k+1)
        accept = (g[:, :k] == draft_tokens).astype(jnp.int32)   # (B, k)
        n_accept = jnp.cumprod(accept, axis=1).sum(axis=1)
        # accepted draft tokens equal the target argmax, so the emitted
        # stream *is* the target argmax over the window.
        return g, n_accept.astype(jnp.int32)
    tl = target_logits[..., :vocab_size]
    dl = draft_logits[..., :vocab_size]
    ukey, ckey = jax.random.split(key)
    p_t = jax.nn.softmax(
        _filtered_logits(tl, vocab_size, temperature, top_k, top_p), axis=-1)
    p_d = jax.nn.softmax(
        _filtered_logits(dl, vocab_size, temperature, top_k, top_p), axis=-1)
    d = draft_tokens[..., None]
    pt_d = jnp.take_along_axis(p_t[:, :k], d, axis=-1)[..., 0]  # (B, k)
    pd_d = jnp.take_along_axis(p_d, d, axis=-1)[..., 0]
    u = jax.random.uniform(ukey, (b, k), dtype=jnp.float32)
    accept = (u * pd_d < pt_d).astype(jnp.int32)
    n_accept = jnp.cumprod(accept, axis=1).sum(axis=1)          # (B,)
    # Residual distribution at the first rejected position; at position k
    # (all accepted) the draft contributes nothing and the residual is the
    # plain target distribution (bonus token).
    pad = jnp.zeros_like(p_t[:, :1])
    p_d_pad = jnp.concatenate([p_d, pad], axis=1)               # (B, k+1, V)
    at = n_accept[:, None, None]
    pt_at = jnp.take_along_axis(p_t, at, axis=1)[:, 0]          # (B, V)
    pd_at = jnp.take_along_axis(p_d_pad, at, axis=1)[:, 0]
    residual = jnp.maximum(pt_at - pd_at, 0.0)
    mass = residual.sum(axis=-1, keepdims=True)
    residual = jnp.where(mass > 0, residual, pt_at)
    logr = jnp.where(residual > 0, jnp.log(jnp.maximum(residual, 1e-30)),
                     NEG_INF)
    g = jax.random.gumbel(ckey, logr.shape, dtype=jnp.float32)
    corr = jnp.minimum(jnp.argmax(logr + g, axis=-1).astype(jnp.int32),
                       vocab_size - 1)                          # (B,)
    dpad = jnp.concatenate([draft_tokens, corr[:, None]], axis=1)
    out = jnp.where(jnp.arange(kp1)[None, :] < n_accept[:, None],
                    dpad, corr[:, None])
    return out, n_accept.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("window", "backend"))
def decode_attention(
    q: jax.Array,        # (B, 1, H, D) — one new token per sequence
    k_cache: jax.Array,  # (B, S, K, D)
    v_cache: jax.Array,  # (B, S, K, D)
    cache_len: jax.Array,  # (B,) int32 — valid prefix length (incl. new token)
    *,
    window: Optional[int] = None,
    backend: str = DEFAULT_BACKEND,
) -> jax.Array:
    """Single-token GQA attention against a (padded) KV cache."""
    if backend == "pallas":
        from repro.kernels import decode_attention as _da
        return _da.decode_attention_pallas(q, k_cache, v_cache, cache_len,
                                           window=window)
    if backend == "ref":
        return _ref.decode_reference(q, k_cache, v_cache, cache_len,
                                     window=window)
    b, _, h, d = q.shape
    _, s, n_kv, _ = k_cache.shape
    scale = d ** -0.5
    qg = _gqa_expand(q, n_kv).astype(jnp.float32) * scale  # (B,1,K,G,D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                        k_cache.astype(jnp.float32))  # (B,K,G,1,S)
    pos = jnp.arange(s)
    valid = pos[None, :] < cache_len[:, None]  # (B, S)
    if window is not None:
        valid &= pos[None, :] > cache_len[:, None] - 1 - window
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("backend",))
def verify_attention(
    q: jax.Array,          # (B, W, H, D) — W window tokens per sequence
    k_cache: jax.Array,    # (B, S, K, D) with rows pos..pos+W-1 written
    v_cache: jax.Array,
    cache_len: jax.Array,  # (B,) int32 — valid rows *before* the window
    *,
    backend: str = DEFAULT_BACKEND,
) -> jax.Array:
    """Multi-token verify attention for speculative decoding.

    ``decode_attention``'s (B, S) validity mask is shared by all query
    rows, which is wrong for W > 1: window query ``j`` (absolute position
    ``cache_len + j``) may attend rows ``< cache_len + j + 1`` only —
    earlier draft rows plus itself, never later ones.  Same einsum layout
    as the decode path with a per-query-row (B, W, S) mask.
    """
    b, w, h, d = q.shape
    _, s, n_kv, _ = k_cache.shape
    scale = d ** -0.5
    qg = _gqa_expand(q, n_kv).astype(jnp.float32) * scale  # (B,W,K,G,D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                        k_cache.astype(jnp.float32))  # (B,K,G,W,S)
    pos = jnp.arange(s)
    valid = (pos[None, None, :] <
             cache_len[:, None, None] + jnp.arange(w)[None, :, None] + 1)
    scores = jnp.where(valid[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, w, h, d).astype(q.dtype)


def _gather_pages(pages: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(N, bs, K, D) physical pages + (B, M) block table -> contiguous
    (B, M*bs, K, D) caches in logical order (reference materialization)."""
    b, m = block_tables.shape
    _, bs = pages.shape[:2]
    g = pages[block_tables]  # (B, M, bs, ...)
    return g.reshape(b, m * bs, *pages.shape[2:])


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_decode_attention(
    q: jax.Array,             # (B, 1, H, D) — one new token per sequence
    k_pages: jax.Array,       # (N, bs, K, D) physical KV blocks
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, M) int32 — physical block per logical slot
    cache_len: jax.Array,     # (B,) int32 — valid prefix length
    *,
    backend: str = DEFAULT_BACKEND,
) -> jax.Array:
    """Single-token GQA attention against a block-paged KV cache.

    The pallas backend walks the block table with scalar-prefetch index
    maps, streaming only each sequence's own blocks from HBM; the xla/ref
    fallback materializes the gather and reuses ``decode_attention``.
    Padded table entries (the null block) are masked by ``cache_len``.
    """
    if backend == "pallas":
        from repro.kernels import decode_attention as _da
        return _da.paged_decode_attention_pallas(q, k_pages, v_pages,
                                                 block_tables, cache_len)
    k = _gather_pages(k_pages, block_tables)
    v = _gather_pages(v_pages, block_tables)
    return decode_attention(q, k, v, cache_len, backend=backend)


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_verify_attention(
    q: jax.Array,             # (B, W, H, D)
    k_pages: jax.Array,       # (N, bs, K, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, M) int32
    cache_len: jax.Array,     # (B,) int32 — valid rows before the window
    *,
    backend: str = DEFAULT_BACKEND,
) -> jax.Array:
    """``verify_attention`` against a block-paged KV cache (gather
    materialization, same per-query-row causal mask)."""
    k = _gather_pages(k_pages, block_tables)
    v = _gather_pages(v_pages, block_tables)
    return verify_attention(q, k, v, cache_len, backend=backend)


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_decode_attention_quant(
    q: jax.Array,             # (B, 1, H, D)
    k_pages: jax.Array,       # (N, bs, K, D) int8 codes
    v_pages: jax.Array,
    k_scale: jax.Array,       # (N, bs, K, 1) bf16 per-(pos, kv-head) scales
    v_scale: jax.Array,
    block_tables: jax.Array,  # (B, M) int32
    cache_len: jax.Array,     # (B,) int32
    *,
    backend: str = DEFAULT_BACKEND,
) -> jax.Array:
    """Paged decode attention over int8 KV blocks (§Perf D x paging)."""
    if backend == "pallas":
        from repro.kernels import decode_attention as _da
        return _da.paged_decode_attention_quant_pallas(
            q, k_pages, v_pages, k_scale, v_scale, block_tables, cache_len)
    return decode_attention_quant(
        q, _gather_pages(k_pages, block_tables),
        _gather_pages(v_pages, block_tables),
        _gather_pages(k_scale, block_tables),
        _gather_pages(v_scale, block_tables),
        cache_len, backend=backend)


@functools.partial(jax.jit, static_argnames=("backend",))
def decode_attention_quant(
    q: jax.Array,        # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, K, D) int8 codes
    v_cache: jax.Array,
    k_scale: jax.Array,  # (B, S, K, 1) bf16 per-(pos, kv-head) scales
    v_scale: jax.Array,
    cache_len: jax.Array,
    *,
    backend: str = DEFAULT_BACKEND,
) -> jax.Array:
    """Decode attention over an int8 KV cache (§Perf D).

    The pallas backend streams int8 + scales and dequantizes in VMEM; the
    xla/ref backends dequantize then reuse the bf16 path (on CPU the
    dequant fuses into the consumer, so HBM reads stay int8-sized).
    """
    if backend == "pallas":
        from repro.kernels import decode_attention as _da
        return _da.decode_attention_quant_pallas(q, k_cache, v_cache,
                                                 k_scale, v_scale, cache_len)

    def deq(c, s):
        return (c.astype(jnp.float32) * s.astype(jnp.float32)).astype(
            jnp.bfloat16)

    return decode_attention(q, deq(k_cache, k_scale), deq(v_cache, v_scale),
                            cache_len, backend=backend)


@functools.partial(jax.jit, static_argnames=("backend",))
def wkv6_scan(
    r: jax.Array,  # (B, S, H, D) receptance
    k: jax.Array,  # (B, S, H, D)
    v: jax.Array,  # (B, S, H, D)
    w: jax.Array,  # (B, S, H, D) data-dependent decay (log-space, negative)
    u: jax.Array,  # (H, D) bonus for current token
    state: jax.Array,  # (B, H, D, D) recurrent state
    *,
    backend: str = DEFAULT_BACKEND,
) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 WKV recurrence. Returns (out (B,S,H,D), new state)."""
    if backend == "pallas":
        from repro.kernels import wkv6 as _wkv
        return _wkv.wkv6_pallas(r, k, v, w, u, state)
    if backend == "ref":
        return _ref.wkv6_reference(r, k, v, w, u, state)
    # xla path: lax.scan over time (compact HLO; sequential like the kernel).
    rf, kf, vf, wf = (x.astype(jnp.float32).transpose(1, 0, 2, 3)
                      for x in (r, k, v, w))

    def step(s, inputs):  # s: (B, H, D, D) maps k-dim x v-dim
        rt, kt, vt, wt = inputs  # each (B, H, D)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,Dk,Dv)
        # out_t = r . (u*kv + state)
        att = s + u.astype(jnp.float32)[None, :, :, None] * kv
        out = jnp.einsum("bhk,bhkv->bhv", rt, att)
        s_new = jnp.exp(wt)[..., None] * s + kv
        return s_new, out

    state_f, outs = jax.lax.scan(step, state.astype(jnp.float32),
                                 (rf, kf, vf, wf))
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), state_f.astype(state.dtype)


@functools.partial(jax.jit, static_argnames=("backend",))
def ssm_scan(
    x: jax.Array,      # (B, S, H, D) input per head
    dt: jax.Array,     # (B, S, H) step size (post-softplus)
    a_log: jax.Array,  # (H, N) state matrix (log of -A)
    b: jax.Array,      # (B, S, H, N) input matrix
    c: jax.Array,      # (B, S, H, N) output matrix
    state: jax.Array,  # (B, H, D, N)
    *,
    backend: str = DEFAULT_BACKEND,
) -> tuple[jax.Array, jax.Array]:
    """Mamba-style selective scan (Hymba SSM heads)."""
    if backend == "pallas":
        from repro.kernels import ssm_scan as _ssm
        return _ssm.ssm_scan_pallas(x, dt, a_log, b, c, state)
    if backend == "ref":
        return _ref.ssm_reference(x, dt, a_log, b, c, state)
    a = -jnp.exp(a_log.astype(jnp.float32))  # (H, N)
    xf = x.astype(jnp.float32).transpose(1, 0, 2, 3)   # (S,B,H,D)
    dtf = dt.astype(jnp.float32).transpose(1, 0, 2)    # (S,B,H)
    bf = b.astype(jnp.float32).transpose(1, 0, 2, 3)   # (S,B,H,N)
    cf = c.astype(jnp.float32).transpose(1, 0, 2, 3)

    def step(s, inputs):  # s: (B,H,D,N)
        xt, dtt, bt, ct = inputs
        da = jnp.exp(dtt[..., None] * a[None])          # (B,H,N)
        dbx = (dtt[..., None] * bt)[:, :, None, :] * xt[..., None]  # (B,H,D,N)
        s_new = da[:, :, None, :] * s + dbx
        yt = jnp.einsum("bhdn,bhn->bhd", s_new, ct)
        return s_new, yt

    state_f, ys = jax.lax.scan(step, state.astype(jnp.float32),
                               (xf, dtf, bf, cf))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), state_f.astype(state.dtype)
