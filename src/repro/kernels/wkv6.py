"""RWKV-6 WKV recurrence Pallas TPU kernel.

Grid = (batch, head, time-chunks); the (Dk x Dv) recurrent state lives in
VMEM scratch across the sequential time dimension, so HBM traffic is one
read of r/k/v/w and one write of the output per token — the recurrence
itself never round-trips state through HBM.  Inside a chunk the timestep
loop is a ``fori_loop`` over VMEM-resident tiles: each step is a (1 x D) x
(D x D) matvec plus two rank-1 updates, which the VPU/MXU handle natively —
this replaces the CUDA warp-per-channel formulation of the reference
implementation (DESIGN.md: hardware adaptation).

Validated in interpret mode against ``ref.wkv6_reference``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x releases;
# accept either so the kernels run on whichever toolchain is baked in.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
            state_ref, *, block_t: int, n_blocks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)  # (bt, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (d,)

    def step(t, _):
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)  # (1, d)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        kv = kt.T @ vt  # (dk, dv) rank-1
        att = state_ref[...] + u[:, None] * kv
        out = rt @ att  # (1, dv)
        o_ref[0, t, 0, :] = out[0].astype(o_ref.dtype)
        state_ref[...] = jnp.exp(wt[0])[:, None] * state_ref[...] + kv
        return ()

    jax.lax.fori_loop(0, block_t, step, ())

    @pl.when(it == n_blocks - 1)
    def _finalize():
        sT_ref[0, 0] = state_ref[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv6_pallas(
    r: jax.Array,  # (B, S, H, D)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # log-space decay (negative)
    u: jax.Array,  # (H, D)
    state: jax.Array,  # (B, H, D, D)
    *,
    block_t: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    b, s, h, d = r.shape
    block_t = min(block_t, s)
    if s % block_t:
        raise ValueError("sequence length must divide block_t")
    nt = s // block_t
    kernel = functools.partial(_kernel, block_t=block_t, n_blocks=nt)

    seq_spec = pl.BlockSpec((1, block_t, 1, d),
                            lambda ib, ih, it: (ib, it, ih, 0))
    out, s_t = pl.pallas_call(
        kernel,
        grid=(b, h, nt),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, d), lambda ib, ih, it: (ih, 0)),
            pl.BlockSpec((1, 1, d, d), lambda ib, ih, it: (ib, ih, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, d, d), lambda ib, ih, it: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), state.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=_CompilerParams(dimension_semantics=(
            "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, state)
    return out, s_t
