"""qwen2-moe-a2.7b [moe]: 24L d=2048 16H GQA(kv=16) V=151936.

MoE: 60 routed experts top-4 + 4 shared experts, expert d_ff=1408
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].  QKV bias; shared-expert sigmoid gate.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=151936,
        qkv_bias=True, mlp="swiglu", rope_theta=1e6,
        n_experts=60, top_k=4, n_shared_experts=4, moe_d_ff=1408,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab_size=256, vocab_pad_multiple=8,
        qkv_bias=True, n_experts=8, top_k=2, n_shared_experts=2, moe_d_ff=64,
        moe_cf_eval=8.0,
    )
