"""qwen1.5-110b [dense]: 80L d=8192 64H GQA(kv=8) d_ff=49152 V=152064.

QKV bias [hf:Qwen/Qwen1.5-110B family; hf].  The largest assigned arch —
FSDP over the data axis is mandatory for the train_4k cell to fit.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=49152, vocab_size=152064,
        qkv_bias=True, mlp="swiglu", rope_theta=1e6,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=192, vocab_size=256, vocab_pad_multiple=8,
        qkv_bias=True, mlp="swiglu",
    )
