"""seamless-m4t-large-v2 [audio]: enc-dec 24L d=1024 16H d_ff=8192 V=256206.

Enc-dec multimodal backbone [arXiv:2308.11596; hf].  The audio frontend is
a STUB per the assignment: input_specs() provides precomputed frame
embeddings (B, n_context_tokens=1024, d_model); the encoder-decoder
transformer backbone is fully implemented.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=24, encoder_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab_size=256206,
        mlp="gelu", n_context_tokens=1024,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-smoke", family="encdec",
        n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8,
        mlp="gelu", n_context_tokens=12,
    )
