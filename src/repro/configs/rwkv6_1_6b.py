"""rwkv6-1.6b [ssm]: 24L d=2048 attention-free, d_ff=7168 V=65536.

RWKV-6 "Finch" with data-dependent decay [arXiv:2404.05892; unverified].
O(1) decode state -> runs the long_500k cell.  Head size 64 (32 heads).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="rwkv",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=7168, vocab_size=65536,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="rwkv",
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
        d_ff=256, vocab_size=256, vocab_pad_multiple=8,
    )
