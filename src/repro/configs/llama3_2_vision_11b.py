"""llama-3.2-vision-11b [vlm]: 40L d=4096 32H GQA(kv=8) d_ff=14336 V=128256.

Gated cross-attention image layers after every 5 self layers (8 total)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].  The vision frontend is a
STUB: input_specs() provides precomputed patch embeddings
(B, 1600, d_model).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=128256,
        mlp="swiglu", rope_theta=5e5,
        cross_attn_every=5, n_context_tokens=1600,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke", family="vlm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8,
        mlp="swiglu", cross_attn_every=2, n_context_tokens=12,
    )
