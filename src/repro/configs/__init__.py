"""Assigned architecture configs. ``get_config(name, reduced=...)``."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "qwen2_7b",
    "gemma3_27b",
    "starcoder2_15b",
    "qwen1_5_110b",
    "seamless_m4t_large_v2",
    "rwkv6_1_6b",
    "llama3_2_vision_11b",
    "qwen2_moe_a2_7b",
    "mixtral_8x7b",
    "hymba_1_5b",
)

# CLI ids (--arch <id>) -> module names.
ALIASES = {
    "qwen2-7b": "qwen2_7b",
    "gemma3-27b": "gemma3_27b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen1.5-110b": "qwen1_5_110b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "hymba-1.5b": "hymba_1_5b",
}


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced_config() if reduced else mod.config()


def all_arch_ids() -> list[str]:
    return list(ALIASES)
