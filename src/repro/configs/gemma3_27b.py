"""gemma3-27b [dense]: 62L d=5376 32H GQA(kv=16) d_ff=21504 V=262144.

5:1 local:global attention interleave, sliding window 1024 on local layers,
128k context [hf:google/gemma-3-*; unverified].  head_dim fixed at 128 (the
published config; d_model/n_heads would give 168), GeGLU MLP, tied
embeddings with sqrt(d_model) embedding scale.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=21504, vocab_size=262144,
        mlp="geglu", rope_theta=1e6, tie_embeddings=True, embed_scale=True,
        sliding_window=1024, local_global_ratio=5,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-smoke", family="dense",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8,
        mlp="geglu", tie_embeddings=True, embed_scale=True,
        sliding_window=8, local_global_ratio=2,
    )
