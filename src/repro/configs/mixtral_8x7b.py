"""mixtral-8x7b [moe]: 32L d=4096 32H GQA(kv=8) expert d_ff=14336 V=32000.

8 routed experts top-2, sliding-window attention (W=4096)
[arXiv:2401.04088; hf].  SWA everywhere -> sub-quadratic cache, runs the
long_500k cell with a rolled W-sized cache.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        mlp="swiglu", rope_theta=1e6,
        n_experts=8, top_k=2, sliding_window=4096,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8,
        n_experts=4, top_k=2, sliding_window=16, moe_cf_eval=4.0,
    )
