"""hymba-1.5b [hybrid]: 32L d=1600 25H GQA(kv=5) d_ff=5504 V=32001 ssm=16.

Parallel attention + Mamba (SSM) heads fused per layer, 128 learnable meta
tokens [arXiv:2411.13676; hf].  All attention layers sliding-window (1024)
here — Hymba keeps 3 global layers; simplification noted in DESIGN.md.
25 heads / kv=5 are not divisible by TP=16 -> replicated over model axis.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab_size=32001,
        mlp="swiglu", ssm_state=16, sliding_window=1024,
        n_context_tokens=128,  # meta tokens
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8,
        ssm_state=8, sliding_window=8, n_context_tokens=4,
    )
