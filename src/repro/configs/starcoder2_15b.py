"""starcoder2-15b [dense]: 40L d=6144 48H GQA(kv=4) d_ff=24576 V=49152.

GQA + RoPE [arXiv:2402.19173; hf].  Full attention per the assignment row
(no window listed) -> long_500k skipped (DESIGN.md §4).  Simplification:
RMSNorm instead of LayerNorm, GELU MLP with biases kept.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
        d_ff=24576, vocab_size=49152,
        qkv_bias=True, mlp="gelu", rope_theta=1e5,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8,
        qkv_bias=True, mlp="gelu",
    )
