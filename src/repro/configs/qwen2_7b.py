"""qwen2-7b [dense]: 28L d=3584 28H GQA(kv=4) d_ff=18944 V=152064.

GQA with QKV bias [arXiv:2407.10671; hf].  28 query heads are not divisible
by the 16-way model axis — heads replicate over TP (see DESIGN.md §4); the
§Perf hillclimb pads heads to 32 and measures the win.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b", family="dense",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab_size=152064,
        qkv_bias=True, mlp="swiglu", rope_theta=1e6,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8,
        qkv_bias=True, mlp="swiglu", rope_theta=1e6,
    )
