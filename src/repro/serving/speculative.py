"""Speculative decoding on the sync-free hot path.

A draft model living in a sliver of the same MRA rectangle proposes ``k``
tokens; the target model scores the whole window ``[t0, d_1..d_k]`` in one
batched ``verify_step`` forward; on-device rejection sampling
(``ops.speculative_verify``) folds the accepted prefix plus one corrected /
bonus token back into the round.  Everything — the k draft steps, the
verify forward, acceptance folding, position advance, and the PRNG key
split — runs inside ONE jitted round, so the engine's pump pass still
spends exactly one host sync (pulling the (B, k+1) emitted-token window
and the (B,) acceptance counts instead of a (B,) token vector).

Cache discipline (the rollback invariants the property tests pin down):

* **Target cache** — the verify step writes the window's KV rows at
  ``pos..pos+k``; the engine advances ``pos`` by ``n_accept + 1`` only.
  Rejected rows beyond the new position are garbage the causal mask hides
  until the next round overwrites them (the bucketed-prefill argument), so
  rollback is a pure position trim: **no block is ever freed and no
  shared/COW block is ever written** — the engine pre-resolves
  copy-on-write for every block the window can touch before dispatch.
* **Draft cache** — a small dense slot-cache side pool (even under a paged
  target; the draft is tiny).  Each round starts by overwriting the draft
  position vector from the target's, then k+1 draft steps write rows
  ``pos..pos+k`` (the last step discards its logits and exists only to fill
  row ``pos+k``, which a fully-accepted round advances past); the accepted
  prefix leaves those rows *correct* for the next round and the rejected
  tail is overwritten before it is ever attended.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.scaling import expected_tokens_per_round  # noqa: F401 (re-export)
from repro.kernels import ops
from repro.models import transformer


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """On-device stochastic sampling knobs for the fused decode path.

    ``temperature == 0`` degenerates to greedy argmax (bit-identical to the
    default fused path).  ``seed`` feeds the engine's device-resident PRNG
    key; the ``fused=False`` reference path replays the exact same key
    stream eagerly, so fused and non-fused sampled runs diff bit-identical.
    """

    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self) -> None:
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0 < self.top_p <= 1 and not (self.top_p == 0 and
                                            self.temperature == 0):
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingConfig(temperature=0.0)


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative decoding configuration for a ``FunctionInstance``.

    ``draft_cfg`` is the draft model's ``ModelConfig`` (same tokenizer /
    real vocab as the target; padded vocab may differ).  ``k`` draft tokens
    are proposed per round, so each round emits between 1 (immediate
    rejection) and ``k + 1`` (full acceptance + bonus) tokens.
    """

    draft_cfg: Any
    k: int = 4
    seed: int = 0

    def __post_init__(self) -> None:
        if self.k < 1:
            raise ValueError(f"speculation depth k must be >= 1, got {self.k}")
        if getattr(self.draft_cfg, "vocab_size", None) is None:
            raise ValueError("draft_cfg must be a ModelConfig-like object "
                             "with a vocab_size")


def _draft_propose(draft_model, dparams, tok, dcache, keys, sampling,
                   k: int):
    """Run k fused draft steps; returns (draft_tokens (B,k),
    draft_logits (B,k,Vd), dcache).  The draft chain is the plain
    ``decode_step`` path, so with draft == target it is literally the
    non-speculative decode computation."""
    dcfg = draft_model.cfg
    t = tok
    toks, logits_list = [], []
    for j in range(k):
        logits, dcache = transformer.decode_step(dparams, t, dcache, dcfg)
        t = transformer.sampled_tokens(logits, dcfg, keys[j], sampling)
        toks.append(t)
        logits_list.append(logits)
    # One extra (logit-discarded) step consuming d_k so row pos+k holds its
    # KV: on full acceptance pos advances by k+1 and the next round's draft
    # would otherwise attend a never-written hole at the old pos+k.
    _, dcache = transformer.decode_step(dparams, t, dcache, dcfg)
    return (jnp.stack(toks, axis=1), jnp.stack(logits_list, axis=1), dcache)


def _verify_fold(cfg, tlogits, draft_logits, draft_tokens, vkey, sampling):
    """Rejection-sample the window on device; returns (out (B,k+1),
    n_emit (B,), tok_new (B,))."""
    out, n_accept = ops.speculative_verify(
        tlogits, draft_logits, draft_tokens, vkey, cfg.vocab_size,
        temperature=sampling.temperature, top_k=sampling.top_k,
        top_p=sampling.top_p, greedy=sampling.greedy)
    n_emit = n_accept + 1
    tok_new = jnp.take_along_axis(out, n_accept[:, None], axis=1)[:, 0]
    return out, n_emit, tok_new


def spec_round_continuous(model, draft_model, k: int,
                          sampling: SamplingConfig):
    """Build the fused continuous-batching speculative round.

    round(params, dparams, tok, cache, dcache, key) ->
        (tok_new, cache, dcache, out (B, k+1), n_emit (B,), new_key)

    One jitted call: k draft decode steps, one W=k+1 verify forward, the
    on-device accept/correct fold, and the per-slot position advance
    (``cache["pos"] += n_emit``).  ``tok``, both caches, and the key are
    donated by the engine exactly like the plain fused round.
    """
    cfg = model.cfg

    def round_fn(params, dparams, tok, cache, dcache, key):
        keys = jax.random.split(key, k + 2)
        new_key, vkey = keys[0], keys[k + 1]
        dcache = dict(dcache, pos=cache["pos"])
        draft_tokens, draft_logits, dcache = _draft_propose(
            draft_model, dparams, tok, dcache, keys[1:k + 1], sampling, k)
        window = jnp.concatenate([tok[:, None], draft_tokens], axis=1)
        tlogits, cache = transformer.verify_step(params, window, cache, cfg)
        out, n_emit, tok_new = _verify_fold(cfg, tlogits, draft_logits,
                                            draft_tokens, vkey, sampling)
        cache = dict(cache, pos=cache["pos"] + n_emit)
        return tok_new, cache, dcache, out, n_emit, new_key

    return round_fn


def spec_round_paged(model, draft_model, k: int, sampling: SamplingConfig):
    """Paged-plane speculative round.

    round(params, dparams, tok, cache, dcache, tables, pos, active, key) ->
        (tok_new, cache, dcache, new_pos, out, n_emit, new_key)

    The target writes through the per-position paged scatter (inactive
    slots drop, COW already resolved by the engine for the whole window);
    the draft keeps its dense side cache.  Free slots neither write nor
    advance (``pos + n_emit * active``).
    """
    cfg = model.cfg

    def round_fn(params, dparams, tok, cache, dcache, tables, pos, active,
                 key):
        keys = jax.random.split(key, k + 2)
        new_key, vkey = keys[0], keys[k + 1]
        active = jnp.asarray(active, jnp.int32)
        dcache = dict(dcache, pos=pos)
        draft_tokens, draft_logits, dcache = _draft_propose(
            draft_model, dparams, tok, dcache, keys[1:k + 1], sampling, k)
        window = jnp.concatenate([tok[:, None], draft_tokens], axis=1)
        tlogits, cache = transformer.verify_step_paged(
            params, window, cache, tables, pos, cfg, active)
        out, n_emit, tok_new = _verify_fold(cfg, tlogits, draft_logits,
                                            draft_tokens, vkey, sampling)
        n_emit = n_emit * active
        return tok_new, cache, dcache, pos + n_emit, out, n_emit, new_key

    return round_fn
