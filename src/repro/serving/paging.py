"""Block-paged KV cache bookkeeping (vLLM-style) for the live data plane.

The dense slot pool (`Model.init_slot_cache`) reserves a full ``max_len``
KV row per decode slot, so a short request strands most of the memory the
MRA/``MemoryModel`` admission charged for it — exactly the fragmentation
FaST-GShare's fine-grained accounting is supposed to prevent.  This module
is the host-side half of the paged replacement:

* ``KVPageAllocator`` — a free-list allocator over ``n_blocks`` physical
  KV blocks of ``block_size`` tokens each.  Block 0 is reserved as the
  **null block**: free decode slots and padded block-table entries all
  point at it, so their garbage writes land in a trash page instead of a
  live sequence's memory.  Double frees are rejected, alloc/free/defrag
  stats are tracked, and the free list is kept sorted (lowest id first)
  so reuse stays dense at the front of the pool.
* ``PageTable`` — per-sequence block lists: which physical blocks hold a
  sequence's KV rows, in logical order.  ``row`` pads a sequence's list
  to the fixed ``max_blocks`` width the jitted decode step expects.

The device-side half lives in ``repro.models``: paged cache layout
(``Model.init_paged_cache``), prefill scatter (``append_paged``), the
contiguous re-gather (``gather_pages``) and the block-table decode step
(``decode_step_paged``).  ``FunctionInstance(batching="paged")`` in
``repro.serving.engine`` ties the two together.
"""

from __future__ import annotations

import dataclasses

NULL_BLOCK = 0


def blocks_needed(n_tokens: int, block_size: int) -> int:
    """Physical blocks required to hold ``n_tokens`` KV rows."""
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // block_size)


class BlockExhausted(RuntimeError):
    """The pool has fewer free blocks than the allocation asked for."""


class KVPageAllocator:
    """Free-list allocator over a fixed pool of physical KV blocks.

    Block ``NULL_BLOCK`` (id 0) is never handed out: it is the shared
    trash page that free decode slots and block-table padding point at.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the null block)")
        if block_size <= 0:
            raise ValueError("block_size must be positive")
        self.n_blocks = n_blocks
        self.block_size = block_size
        # Free list: frees are appended (recently-freed blocks are reused
        # first); ``defrag`` re-sorts so allocation returns to preferring
        # the lowest ids and the live region re-packs at the pool front.
        self._free: list[int] = list(range(1, n_blocks))
        self._allocated: set[int] = set()
        self.n_allocs = 0
        self.n_frees = 0
        self.n_defrags = 0
        self.high_watermark = 0  # peak blocks_in_use over the pool lifetime

    # -- capacity ----------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Usable blocks (the null block is not allocatable)."""
        return self.n_blocks - 1

    @property
    def blocks_in_use(self) -> int:
        return len(self._allocated)

    def free_blocks(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    # -- alloc / free ------------------------------------------------------

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` blocks off the front of the free list."""
        if n < 0:
            raise ValueError("cannot allocate a negative block count")
        if n > len(self._free):
            raise BlockExhausted(
                f"need {n} blocks, only {len(self._free)} free "
                f"(capacity {self.capacity})")
        taken, self._free = self._free[:n], self._free[n:]
        self._allocated.update(taken)
        self.n_allocs += n
        self.high_watermark = max(self.high_watermark, self.blocks_in_use)
        return taken

    def free(self, blocks: list[int]) -> None:
        """Return blocks to the free list; rejects double/foreign frees.

        All-or-nothing: validation (including duplicates WITHIN the list)
        happens before any state changes, so a rejected free never loses
        blocks.
        """
        seen: set[int] = set()
        for b in blocks:
            if b not in self._allocated or b in seen:
                raise ValueError(
                    f"block {b} is not allocated (double free or foreign "
                    f"block)")
            seen.add(b)
        for b in blocks:
            self._allocated.remove(b)
        self._free.extend(blocks)
        self.n_frees += len(blocks)

    # -- stats -------------------------------------------------------------

    def fragmentation(self) -> float:
        """1 - (largest contiguous free run / free blocks); 0 = compact.

        Measured on the id-sorted view — it describes the free *address
        space*, not the reuse order of the list itself.
        """
        if not self._free:
            return 0.0
        ordered = sorted(self._free)
        best = run = 1
        for prev, cur in zip(ordered, ordered[1:]):
            run = run + 1 if cur == prev + 1 else 1
            best = max(best, run)
        return 1.0 - best / len(ordered)

    def defrag(self) -> float:
        """Re-sort the free list and report the remaining fragmentation.

        Frees append in retire order, so a long-lived pool drifts toward
        allocating scattered ids; defrag restores lowest-id-first reuse so
        the live region re-packs at the pool front.  Physical compaction
        (migrating live blocks) is the engine's job — it owns the device
        arrays.
        """
        self._free.sort()
        self.n_defrags += 1
        return self.fragmentation()

    def stats(self) -> dict[str, float]:
        return {
            "capacity": self.capacity,
            "in_use": self.blocks_in_use,
            "free": self.free_blocks(),
            "allocs": self.n_allocs,
            "frees": self.n_frees,
            "defrags": self.n_defrags,
            "high_watermark": self.high_watermark,
            "fragmentation": self.fragmentation(),
        }


@dataclasses.dataclass
class PageTable:
    """Per-sequence block lists over one ``KVPageAllocator``.

    Keys are caller-chosen sequence ids — the engine uses its decode-slot
    indices, NOT request ids (req-id counters are per-engine and collide
    when an evict re-routes queued requests across nodes; slots are unique
    within the instance and always released before reuse).  Values are the
    physical block ids holding the sequence's KV rows in logical order.
    """

    allocator: KVPageAllocator
    seqs: dict[int, list[int]] = dataclasses.field(default_factory=dict)

    def allocate(self, seq_id: int, n_tokens: int) -> list[int]:
        """Reserve enough blocks for ``n_tokens`` rows of sequence ``seq_id``."""
        if seq_id in self.seqs:
            raise ValueError(f"sequence {seq_id} already has pages")
        blocks = self.allocator.alloc(
            blocks_needed(n_tokens, self.allocator.block_size))
        self.seqs[seq_id] = blocks
        return blocks

    def blocks(self, seq_id: int) -> list[int]:
        return self.seqs[seq_id]

    def release(self, seq_id: int) -> list[int]:
        """Free a sequence's blocks back to the allocator."""
        blocks = self.seqs.pop(seq_id)
        self.allocator.free(blocks)
        return blocks

    def release_all(self) -> int:
        """Drop every sequence (instance teardown); returns blocks freed."""
        n = 0
        for seq_id in list(self.seqs):
            n += len(self.release(seq_id))
        return n

    def row(self, seq_id: int, max_blocks: int) -> list[int]:
        """Block-table row padded with the null block to ``max_blocks``."""
        blocks = self.seqs[seq_id]
        if len(blocks) > max_blocks:
            raise ValueError(
                f"sequence {seq_id} holds {len(blocks)} blocks > "
                f"max_blocks {max_blocks}")
        return blocks + [NULL_BLOCK] * (max_blocks - len(blocks))

    @property
    def n_seqs(self) -> int:
        return len(self.seqs)

    def bytes_in_use(self, block_bytes: int) -> int:
        """Physical KV bytes held by live sequences."""
        return self.allocator.blocks_in_use * block_bytes
