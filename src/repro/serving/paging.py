"""Block-paged KV cache bookkeeping (vLLM-style) for the live data plane.

The dense slot pool (`Model.init_slot_cache`) reserves a full ``max_len``
KV row per decode slot, so a short request strands most of the memory the
MRA/``MemoryModel`` admission charged for it — exactly the fragmentation
FaST-GShare's fine-grained accounting is supposed to prevent.  This module
is the host-side half of the paged replacement:

* ``KVPageAllocator`` — a free-list allocator over ``n_blocks`` physical
  KV blocks of ``block_size`` tokens each, now REFCOUNTED: a block handed
  out by ``alloc`` starts at refcount 1, ``incref`` lets several page
  tables map the same physical block (prefix sharing), and ``free``
  decrements — the block only returns to the free list when its last
  reference drops.  Block 0 is reserved as the **null block**: free
  decode slots and padded block-table entries all point at it, so their
  garbage writes land in a trash page instead of a live sequence's
  memory.  Double frees are rejected, alloc/free/defrag stats are tracked
  (in blocks AND bytes when ``block_bytes`` is given), and the free list
  is kept sorted (lowest id first) so reuse stays dense at the front of
  the pool.  The allocator also owns the **content-hash registry**
  (``register`` / ``lookup``): digest -> resident block, auto-unregistered
  when the block is physically freed.
* ``PageTable`` — per-sequence block lists: which physical blocks hold a
  sequence's KV rows, in logical order.  ``allocate_shared`` maps a new
  sequence onto resident prefix blocks (incref) plus freshly-allocated
  private blocks; ``writable_block`` enforces the copy-on-write rule at
  every write site.  ``row`` pads a sequence's list to the fixed
  ``max_blocks`` width the jitted decode step expects.

Prefix sharing, in one paragraph: ``prompt_digests`` hashes a prompt into
chained per-block digests (block ``i``'s digest commits to ALL tokens up
to and including block ``i``, so equal digests mean equal whole prefixes,
not just equal block contents).  Full prompt blocks are immutable once
written — every later write of any sequence lands at positions beyond
them — so they are shared freely at any refcount.  The *partial* tail
block of a prompt is shareable only on an exact full-prompt match (its
digest commits to the entire prompt) and IS written later (each sharer's
decode rows continue inside it), so sharing it reserves one copy-on-write
spare block per extra reference up front: the worst-case-reservation
admission invariant ("an admitted request can never exhaust the pool
mid-flight") survives sharing, and the first divergent append pops the
spare, copies the block, and re-points the writer — shared blocks are
never written (``PageTable.writable_block`` raises if they would be).

The device-side half lives in ``repro.models``: paged cache layout
(``Model.init_paged_cache``), prefill scatter (``append_paged``), the
COW block copy (``copy_block``), the contiguous re-gather
(``gather_pages``) and the block-table decode step (``decode_step_paged``).
``FunctionInstance(batching="paged")`` in ``repro.serving.engine`` ties
the two together.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

NULL_BLOCK = 0


def blocks_needed(n_tokens: int, block_size: int) -> int:
    """Physical blocks required to hold ``n_tokens`` KV rows."""
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // block_size)


def prompt_digests(prompt, block_size: int
                   ) -> tuple[list[bytes], Optional[bytes]]:
    """Chained content digests of a prompt's KV blocks.

    Returns ``(full, tail)``: one digest per FULL block of
    ``block_size`` tokens, plus a digest over the whole prompt when its
    length is not a block multiple (else None).  Digest ``i`` chains the
    previous digest into the hash, so two prompts produce the same digest
    at block ``i`` iff their first ``(i+1) * block_size`` tokens are
    identical — a match is always a whole-prefix match.  The tail digest
    commits to the entire prompt (chain + remainder), so a tail hit means
    the prompts are byte-for-byte equal.
    """
    toks = [int(t) for t in prompt]
    full: list[bytes] = []
    chain = b""
    n_full = len(toks) // block_size
    for i in range(n_full):
        h = hashlib.blake2b(digest_size=16)
        h.update(chain)
        h.update(b"".join(t.to_bytes(8, "little", signed=True)
                          for t in toks[i * block_size:(i + 1) * block_size]))
        chain = h.digest()
        full.append(chain)
    tail: Optional[bytes] = None
    rem = toks[n_full * block_size:]
    if rem:
        h = hashlib.blake2b(digest_size=16)
        h.update(chain)
        h.update(b"tail")  # a tail digest never collides with a full one
        h.update(b"".join(t.to_bytes(8, "little", signed=True)
                          for t in rem))
        tail = h.digest()
    return full, tail


class BlockExhausted(RuntimeError):
    """The pool has fewer free blocks than the allocation asked for."""


class KVPageAllocator:
    """Refcounted free-list allocator over a fixed pool of physical blocks.

    Block ``NULL_BLOCK`` (id 0) is never handed out: it is the shared
    trash page that free decode slots and block-table padding point at.
    ``block_bytes`` (optional) sizes the bytes-denominated stats; the
    block-count stats are always tracked.
    """

    def __init__(self, n_blocks: int, block_size: int, block_bytes: int = 0):
        if n_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the null block)")
        if block_size <= 0:
            raise ValueError("block_size must be positive")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.block_bytes = block_bytes
        # Free list: frees are appended (recently-freed blocks are reused
        # first); ``defrag`` re-sorts so allocation returns to preferring
        # the lowest ids and the live region re-packs at the pool front.
        self._free: list[int] = list(range(1, n_blocks))
        self._ref: dict[int, int] = {}  # allocated block -> reference count
        # Content-hash registry: prefix digest -> resident block (and the
        # inverse, for auto-unregistration on physical free).  One digest
        # per block, first registration wins.
        self._digest_to_block: dict[bytes, int] = {}
        self._block_digest: dict[int, bytes] = {}
        self.n_allocs = 0
        self.n_frees = 0       # physical frees (blocks returned to the list)
        self.n_increfs = 0     # sharing events (extra references taken)
        self.n_defrags = 0
        self.high_watermark = 0  # peak blocks_in_use over the pool lifetime

    # -- capacity ----------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Usable blocks (the null block is not allocatable)."""
        return self.n_blocks - 1

    @property
    def blocks_in_use(self) -> int:
        return len(self._ref)

    @property
    def extra_refs(self) -> int:
        """References beyond the first, over all blocks — the raw sharing
        win in blocks (before subtracting reserved COW spares)."""
        return sum(r - 1 for r in self._ref.values())

    @property
    def shared_blocks(self) -> int:
        """Blocks currently mapped by more than one sequence."""
        return sum(1 for r in self._ref.values() if r > 1)

    def free_blocks(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    # -- alloc / incref / free ---------------------------------------------

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` blocks off the front of the free list (refcount 1)."""
        if n < 0:
            raise ValueError("cannot allocate a negative block count")
        if n > len(self._free):
            raise BlockExhausted(
                f"need {n} blocks, only {len(self._free)} free "
                f"(capacity {self.capacity})")
        taken, self._free = self._free[:n], self._free[n:]
        for b in taken:
            self._ref[b] = 1
        self.n_allocs += n
        self.high_watermark = max(self.high_watermark, self.blocks_in_use)
        return taken

    def incref(self, block: int) -> int:
        """Take an extra reference on an allocated block (prefix sharing);
        returns the new refcount."""
        if block not in self._ref:
            raise ValueError(f"block {block} is not allocated")
        self._ref[block] += 1
        self.n_increfs += 1
        return self._ref[block]

    def refcount(self, block: int) -> int:
        """Current references on ``block`` (0 = not allocated)."""
        return self._ref.get(block, 0)

    def free(self, blocks: list[int]) -> None:
        """Drop one reference per listed block; rejects double/foreign
        frees.  A block whose count reaches zero returns to the free list
        (and its content-hash registration is dropped).

        All-or-nothing: validation (including duplicates WITHIN the list)
        happens before any state changes, so a rejected free never loses
        blocks.  A single call never names a block twice — each caller
        (one page-table row list) holds at most one reference per block.
        """
        seen: set[int] = set()
        for b in blocks:
            if b not in self._ref or b in seen:
                raise ValueError(
                    f"block {b} is not allocated (double free or foreign "
                    f"block)")
            seen.add(b)
        for b in blocks:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                self._free.append(b)
                self.n_frees += 1
                digest = self._block_digest.pop(b, None)
                if digest is not None:
                    del self._digest_to_block[digest]

    # -- content-hash registry (prefix sharing) -----------------------------

    def register(self, digest: bytes, block: int) -> bool:
        """Publish an allocated block's content digest for prefix matching.

        First registration wins (an equal digest means bit-identical
        content, so re-pointing would only churn the registry), and a
        block carries at most one digest.  Returns True iff registered.
        """
        if block not in self._ref:
            raise ValueError(f"cannot register free block {block}")
        if digest in self._digest_to_block or block in self._block_digest:
            return False
        self._digest_to_block[digest] = block
        self._block_digest[block] = digest
        return True

    def lookup(self, digest: bytes) -> Optional[int]:
        """Resident block holding this digest's content, or None."""
        return self._digest_to_block.get(digest)

    @property
    def registered_blocks(self) -> int:
        return len(self._digest_to_block)

    # -- stats -------------------------------------------------------------

    def fragmentation(self) -> float:
        """1 - (largest contiguous free run / free blocks); 0 = compact.

        Measured on the id-sorted view — it describes the free *address
        space*, not the reuse order of the list itself.
        """
        if not self._free:
            return 0.0
        ordered = sorted(self._free)
        best = run = 1
        for prev, cur in zip(ordered, ordered[1:]):
            run = run + 1 if cur == prev + 1 else 1
            best = max(best, run)
        return 1.0 - best / len(ordered)

    def defrag(self) -> float:
        """Re-sort the free list and report the remaining fragmentation.

        Frees append in retire order, so a long-lived pool drifts toward
        allocating scattered ids; defrag restores lowest-id-first reuse so
        the live region re-packs at the pool front.  Physical compaction
        (migrating live blocks) is the engine's job — it owns the device
        arrays.
        """
        self._free.sort()
        self.n_defrags += 1
        return self.fragmentation()

    @property
    def bytes_in_use(self) -> int:
        """Physical bytes held by allocated blocks (a shared block is
        charged ONCE — it occupies one physical block however many
        sequences map it)."""
        return self.blocks_in_use * self.block_bytes

    @property
    def bytes_high_watermark(self) -> int:
        """Physical peak in bytes — ``high_watermark`` (blocks) times
        ``block_bytes``, updated at every allocation rather than sampled,
        and consistent with refcounted sharing (charged once)."""
        return self.high_watermark * self.block_bytes

    def stats(self) -> dict[str, float]:
        return {
            "capacity": self.capacity,
            "in_use": self.blocks_in_use,
            "free": self.free_blocks(),
            "allocs": self.n_allocs,
            "frees": self.n_frees,
            "increfs": self.n_increfs,
            "defrags": self.n_defrags,
            # Block counts and their bytes forms, side by side: the
            # high-watermark/defrag stats used to be block-denominated
            # only, which silently under-reported on configs with large
            # ``block_bytes``.
            "high_watermark": self.high_watermark,
            "bytes_high_watermark": self.bytes_high_watermark,
            "bytes_in_use": self.bytes_in_use,
            "shared_blocks": self.shared_blocks,
            "extra_refs": self.extra_refs,
            "registered": self.registered_blocks,
            "fragmentation": self.fragmentation(),
        }


@dataclasses.dataclass
class PageTable:
    """Per-sequence block lists over one ``KVPageAllocator``.

    Keys are caller-chosen sequence ids — the engine uses its decode-slot
    indices, NOT request ids (req-id counters are per-engine and collide
    when an evict re-routes queued requests across nodes; slots are unique
    within the instance and always released before reuse).  Values are the
    physical block ids holding the sequence's KV rows in logical order.

    ``spares`` maps a *mutable shared* block (a prompt-tail block mapped
    by more than one sequence) to the copy-on-write blocks reserved for
    it: one spare per extra reference, allocated at share time so a COW
    can never hit pool exhaustion mid-flight.  Invariant: while block
    ``b`` is tail-shared, ``len(spares[b]) == refcount(b) - 1``; both
    sides step down together on every COW and on every sharer release.
    """

    allocator: KVPageAllocator
    seqs: dict[int, list[int]] = dataclasses.field(default_factory=dict)
    spares: dict[int, list[int]] = dataclasses.field(default_factory=dict)

    def allocate(self, seq_id: int, n_tokens: int) -> list[int]:
        """Reserve enough blocks for ``n_tokens`` rows of sequence ``seq_id``."""
        if seq_id in self.seqs:
            raise ValueError(f"sequence {seq_id} already has pages")
        blocks = self.allocator.alloc(
            blocks_needed(n_tokens, self.allocator.block_size))
        self.seqs[seq_id] = blocks
        return blocks

    # -- prefix sharing -----------------------------------------------------

    def match_prefix(self, full_digests: list[bytes],
                     tail_digest: Optional[bytes]
                     ) -> tuple[list[int], Optional[int]]:
        """Longest resident prefix for a prompt's digest chain.

        Walks the full-block digests until the first registry miss;
        the tail block joins the match only when EVERY full block hit
        (the tail digest commits to the whole prompt, so a partial chain
        can never legitimately reach it).  Returns
        ``(shared_full_blocks, tail_block_or_None)`` — nothing is
        increfed yet; ``allocate_shared`` takes the references.
        """
        shared: list[int] = []
        for digest in full_digests:
            block = self.allocator.lookup(digest)
            if block is None:
                break
            shared.append(block)
        tail = None
        if tail_digest is not None and len(shared) == len(full_digests):
            tail = self.allocator.lookup(tail_digest)
        return shared, tail

    def allocate_shared(self, seq_id: int, n_tokens: int,
                        shared: list[int], *,
                        tail_shared: bool = False) -> list[int]:
        """Map ``seq_id`` onto resident ``shared`` prefix blocks plus
        freshly-allocated private blocks for the rest of its lifetime.

        ``shared`` lists the matched blocks in logical order; when
        ``tail_shared``, its LAST entry is a mutable prompt-tail block and
        one COW spare is reserved against it up front (the admission
        charge is therefore ``blocks_needed - len(full_shared)``: tail
        sharing trades its block for a spare and saves bytes only until
        the first divergent append — what it really buys is the shared
        prefill write elision and the full-block wins in front of it).
        """
        if seq_id in self.seqs:
            raise ValueError(f"sequence {seq_id} already has pages")
        total = blocks_needed(n_tokens, self.allocator.block_size)
        if len(shared) > total:
            raise ValueError(
                f"matched {len(shared)} shared blocks > {total} needed")
        n_spare = 1 if tail_shared else 0
        fresh = self.allocator.alloc(total - len(shared) + n_spare)
        private, spare = (fresh[:-1], fresh[-1:]) if n_spare else (fresh, [])
        for b in shared:
            self.allocator.incref(b)
        self.seqs[seq_id] = list(shared) + private
        if tail_shared:
            self.spares.setdefault(shared[-1], []).extend(spare)
        return self.seqs[seq_id]

    def register_prefix(self, seq_id: int, full_digests: list[bytes],
                        tail_digest: Optional[bytes] = None) -> int:
        """Publish a sequence's prompt blocks in the content registry so
        later admissions can share them; returns how many registered anew
        (already-resident digests are skipped — first wins)."""
        blocks = self.seqs[seq_id]
        n = 0
        for i, digest in enumerate(full_digests):
            if i >= len(blocks):
                break
            n += self.allocator.register(digest, blocks[i])
        if tail_digest is not None and len(full_digests) < len(blocks):
            n += self.allocator.register(tail_digest,
                                         blocks[len(full_digests)])
        return n

    def writable_block(self, seq_id: int, pos: int
                       ) -> tuple[int, Optional[tuple[int, int]]]:
        """The block that may be WRITTEN at row ``pos`` — the single
        enforcement point of the COW rule.

        Exclusively-owned blocks pass through.  A shared (refcount > 1)
        block is swapped for one of its reserved COW spares: the spare
        replaces it in this sequence's row, the shared block loses one
        reference, and ``(old, new)`` is returned so the engine copies the
        device page before the write lands.  A shared block with no spare
        is an invariant violation (a write was about to corrupt another
        sequence's KV) and raises.
        """
        idx = pos // self.allocator.block_size
        block = self.seqs[seq_id][idx]
        if self.allocator.refcount(block) == 1:
            return block, None
        reserved = self.spares.get(block)
        if not reserved:
            raise RuntimeError(
                f"write at row {pos} would hit shared block {block} "
                f"(refcount {self.allocator.refcount(block)}) with no COW "
                f"spare reserved — shared blocks must never be written")
        new = reserved.pop()
        if not reserved:
            del self.spares[block]
        self.seqs[seq_id][idx] = new
        self.allocator.free([block])  # drop this sequence's reference
        return new, (block, new)

    # -- release ------------------------------------------------------------

    def blocks(self, seq_id: int) -> list[int]:
        return self.seqs[seq_id]

    def release(self, seq_id: int) -> list[int]:
        """Drop a sequence's references; blocks whose last reference this
        was return to the free list.  Releasing a sharer of a mutable
        tail block also returns one of its reserved COW spares (the
        ``spares`` invariant steps down with the refcount)."""
        blocks = self.seqs.pop(seq_id)
        for b in blocks:
            if self.allocator.refcount(b) > 1:
                reserved = self.spares.get(b)
                if reserved:
                    self.allocator.free([reserved.pop()])
                    if not reserved:
                        del self.spares[b]
            self.allocator.free([b])
        return blocks

    def release_all(self) -> int:
        """Drop every sequence (instance teardown); returns blocks
        released.  Any orphaned COW spares are returned too (there are
        none while the invariant holds, but teardown must not leak)."""
        n = 0
        for seq_id in list(self.seqs):
            n += len(self.release(seq_id))
        for block, reserved in list(self.spares.items()):
            self.allocator.free(reserved)
            del self.spares[block]
        return n

    # -- views --------------------------------------------------------------

    def row(self, seq_id: int, max_blocks: int) -> list[int]:
        """Block-table row padded with the null block to ``max_blocks``."""
        blocks = self.seqs[seq_id]
        if len(blocks) > max_blocks:
            raise ValueError(
                f"sequence {seq_id} holds {len(blocks)} blocks > "
                f"max_blocks {max_blocks}")
        return blocks + [NULL_BLOCK] * (max_blocks - len(blocks))

    @property
    def n_seqs(self) -> int:
        return len(self.seqs)

    @property
    def n_spares(self) -> int:
        """COW spare blocks currently reserved (allocated, not in any row)."""
        return sum(len(v) for v in self.spares.values())

    def saved_blocks(self) -> int:
        """Physical blocks sharing is saving RIGHT NOW: references beyond
        the first, minus the COW spares reserved against mutable shared
        blocks (a tail share is memory-neutral until its COW resolves)."""
        return self.allocator.extra_refs - self.n_spares

    def bytes_in_use(self, block_bytes: int) -> int:
        """Physical KV bytes held by live sequences (shared blocks charged
        once)."""
        return self.allocator.blocks_in_use * block_bytes

    def bytes_saved(self, block_bytes: int) -> int:
        """Bytes the unshared plane would additionally hold for the same
        live sequences."""
        return self.saved_blocks() * block_bytes
