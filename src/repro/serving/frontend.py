"""Multi-engine frontend: the live analogue of ``repro.core.cluster``.

``ClusterFrontend`` routes requests across N ``ServingEngine`` nodes so the
real JAX data plane exercises the simulator's full stack:

* **Placement** — function instances are bound to nodes by the same
  ``MaxRectsPool`` (paper Alg. 2) the simulator uses: each instance's
  ``Alloc`` rectangle is packed best-area-fit across the fleet, and a
  candidate node must also pass ``MemoryModel`` admission (model-sharing
  footprints, paper Fig. 13 / §3.5) before the engine deploys there.
* **Routing** — ``submit`` joins the shortest queue across all nodes
  hosting the function (queue depth + occupied decode slots), mirroring
  ``Cluster._arrive``.
* **Dispatch** — ``pump`` interleaves the per-node token schedulers
  (FaST-Manager, one per engine) until the fleet is idle.
* **Scale-down** — ``evict`` retires one instance: its queued requests are
  re-routed to surviving replicas, its occupied decode slots drain under
  the token scheduler, and only then are its MRA rectangle and weight
  refcount released (zero dropped in-flight requests).

The frontend is one of the two ``repro.control`` backends: the
``ControlPlane`` reconciler drives ``place_instance`` / ``evict`` /
``observed_rps`` / ``inflight`` so the live fleet and the simulator run
literally the same Alg.-1 scheduler code.

Weights are shared *per node*: deploying the same function on two nodes
stores one param pytree in each node's ``ModelStore``; instances within a
node alias it zero-copy.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.core.links import NetworkLinks
from repro.core.maximal_rectangles import MaxRectsPool, Placement
from repro.distributed.sharding import serve_pspec, tp_mesh
from repro.core.model_sharing import (MemoryModel, node_shared_footprint,
                                      pytree_nbytes)
from repro.core.resources import Alloc
from repro.core.slo import (TIER_BEST_EFFORT, TIER_GUARANTEED, RetryPolicy,
                            observed_rate, record_arrival)
from repro.models.model import Model
from repro.serving.engine import ServeRequest, ServingEngine
from repro.serving.modelstore import ColdStartEvent, FleetModelStore
from repro.serving.paging import blocks_needed

# Per-instance runtime footprint (jit executables, slot KV pool, host
# bookkeeping) charged by admission when the caller gives no measurement.
DEFAULT_FRAMEWORK_BYTES = 64 * 1024 * 1024


@dataclasses.dataclass
class InstancePlacement:
    """One live instance: which node it landed on and its MRA rectangle.

    A sharded (tensor-parallel) pod holds one rectangle on EVERY member
    node; ``node``/``placement`` are the primary's (the engine hosting the
    executors), ``member_nodes``/``member_placements`` list all of them
    (primary first).  Single-device pods leave the member tuples empty.
    """

    fn: str
    inst_id: str
    node: int
    placement: Placement
    member_nodes: tuple[int, ...] = ()
    member_placements: tuple[Placement, ...] = ()

    def all_nodes(self) -> tuple[int, ...]:
        return self.member_nodes or (self.node,)

    def all_placements(self) -> tuple[Placement, ...]:
        return self.member_placements or (self.placement,)


class ClusterFrontend:
    """Join-shortest-queue router over N token-scheduled engine nodes."""

    def __init__(self, n_nodes: int = 2, *,
                 mem_bytes: int = 16 * 1024**3, window: float = 0.2,
                 model_store: Optional[FleetModelStore] = None,
                 cold_start: str = "overlap",
                 links: Optional[NetworkLinks] = None,
                 idle_sleep_s: float = 0.001,
                 retry: Optional[RetryPolicy] = None):
        if n_nodes <= 0:
            raise ValueError("need at least one node")
        if cold_start not in ("overlap", "blocking"):
            raise ValueError(f"unknown cold_start mode {cold_start!r}")
        # Inter-node bandwidth graph: sharded pods co-locate their
        # rectangles on the highest-bottleneck-bandwidth group, and the
        # fleet store picks its transfer peer by link speed.
        self.links = links if links is not None else NetworkLinks(n_nodes)
        self.links.grow(n_nodes)
        # Optional fleet weight tier (serving/modelstore.py): placements
        # source their params through it (device -> host -> peer -> cold),
        # scale-up prefers warm nodes, and memory admission charges the
        # storage-server context once per node instead of per function.
        self.model_store = model_store
        if model_store is not None and getattr(model_store, "links",
                                               None) is None:
            # Bandwidth-aware peer selection for host-to-host transfers.
            model_store.links = self.links
        self.cold_start = cold_start
        # (event, node, inst_id): TTFT resolved lazily from the instance's
        # first landed token by cold_start_events().
        self._cold_instances: list[tuple[ColdStartEvent, int, str]] = []
        self.engines = [ServingEngine(window=window,
                                      idle_sleep_s=idle_sleep_s)
                        for _ in range(n_nodes)]
        for i, eng in enumerate(self.engines):
            eng.on_instance_closed = functools.partial(
                self._instance_closed, i)
        self.pool = MaxRectsPool(n_nodes, allow_grow=False)
        self.mem_bytes = mem_bytes
        self.placements: list[InstancePlacement] = []
        self._fn_mm: dict[str, MemoryModel] = {}
        self._pod_seq = itertools.count()
        self._arrival_log: dict[str, list[float]] = {}
        self._rps_horizon: dict[str, float] = {}
        # Requests stranded by a node failure while their function has zero
        # live instances: re-routed as soon as a replacement deploys.
        self._pending: dict[str, list[ServeRequest]] = {}
        # fn -> (max_len, block_size, paged block capacity or None, spec_k),
        # learned at placement so submissions during a podless heal window
        # can still be validated (and parked) instead of dropped.
        self._fn_limits: dict[str, tuple[int, int, Optional[int], int]] = {}
        # Functions whose placements pinned draft weights in the fleet
        # store (speculative decoding), so closing releases both keys.
        self._fn_draft: set[str] = set()
        # fn -> draft Model, built once for fleet-store staging (the engine
        # keeps its own per-node cache for the executors).
        self._draft_models: dict[str, Any] = {}
        self._req_seq = itertools.count()
        self._t0 = time.perf_counter()
        # SLO lifecycle (all dormant until ``configure_slo`` sets a
        # deadline): fn -> (tier, deadline budget seconds or None,
        # per-instance requests/s estimate for the shed admission check).
        self._fn_slo: dict[str, tuple[str, Optional[float], float]] = {}
        self.retry = retry
        # (not_before, fn, req): stranded requests waiting out their
        # jittered backoff; flushed by pump.
        self._retry_buf: list[tuple[float, str, ServeRequest]] = []
        self.shed = 0      # rejected at admission: could not make deadline
        self.lost = 0      # retry budget exhausted after failures
        self.rejected = 0  # parked requests whose function was unregistered

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def configure_slo(self, fn: str, tier: str = TIER_BEST_EFFORT,
                      deadline_s: Optional[float] = None,
                      est_rps: float = 0.0) -> None:
        """Arm the deadline/shedding lifecycle for ``fn``.

        ``deadline_s`` is the per-request budget from submission (None
        keeps the machinery dormant); ``est_rps`` is the per-instance
        service-rate estimate (the profile point's throughput) behind the
        queue-depth completion estimate that drives shedding."""
        self._fn_slo[fn] = (tier, deadline_s, est_rps)

    # -- memory admission (same closed form as core.cluster.Node) ---------

    def _fn_instances_on(self, node: int) -> dict[str, int]:
        counts: dict[str, int] = {}
        for p in self.placements:
            if node in p.all_nodes():
                counts[p.fn] = counts.get(p.fn, 0) + 1
        return counts

    def mem_used(self, node: int) -> int:
        counts = self._fn_instances_on(node)
        if self.model_store is not None:
            # The fleet store is the node's single storage server: its
            # context overhead is charged once per node, not per function.
            return node_shared_footprint(
                (self._fn_mm[fn], n) for fn, n in counts.items())
        return sum(self._fn_mm[fn].footprint(n, sharing=True)
                   for fn, n in counts.items() if n > 0)

    def admits(self, node: int, fn: str, mm: MemoryModel) -> bool:
        n = self._fn_instances_on(node).get(fn, 0)
        if self.model_store is not None:
            counts = self._fn_instances_on(node)
            counts[fn] = n + 1
            mms = {**self._fn_mm, fn: mm}
            projected = node_shared_footprint(
                (mms[f], c) for f, c in counts.items())
        else:
            projected = (self.mem_used(node)
                         - mm.footprint(n, sharing=True)
                         + mm.footprint(n + 1, sharing=True))
        return projected <= self.mem_bytes

    # -- warm-node lookup (cold-start tier) --------------------------------

    def warm_nodes(self, fn: str) -> list[int]:
        """Nodes that can serve ``fn``'s weights without a cold stage:
        device-resident (engine ModelStore) or host-staged (fleet store).
        Empty without a fleet store — warm-aware selection is then off."""
        if self.model_store is None:
            return []
        warm = set(self.model_store.warm_nodes(fn))
        warm |= {i for i, eng in enumerate(self.engines)
                 if eng.alive and eng.store.contains(fn)}
        return sorted(warm)

    # -- deployment --------------------------------------------------------

    def place_instance(self, fn: str, model: Model, params: Any,
                       alloc: Alloc, *, max_batch: int = 4, max_len: int = 64,
                       batching: str = "continuous",
                       framework_bytes: int = DEFAULT_FRAMEWORK_BYTES,
                       block_size: int = 16,
                       n_kv_blocks: Optional[int] = None,
                       fused: bool = True, prefix_sharing: bool = True,
                       kv_shared_frac: float = 0.0,
                       weights_loader: Optional[Any] = None,
                       sampling: Optional[Any] = None,
                       speculate: Optional[Any] = None,
                       draft_params: Optional[Any] = None,
                       shards: int = 1
                       ) -> Optional[str]:
        """Place ONE instance via MRA + memory admission with spillover.

        Returns a ``node:inst_id`` handle, or None when no node has both a
        free rectangle and the memory headroom.  On engine failure after a
        successful rectangle reservation, the rectangle (and a freshly
        created ``MemoryModel`` entry) is rolled back instead of leaking.

        Admission charges the instance's REAL decode-cache layout on top of
        ``framework_bytes``: ``n_kv_blocks x block_bytes`` for a paged
        instance, the dense ``max_batch x max_len`` slot pool otherwise —
        so a paged deployment with a tight block budget admits more
        replicas per node than its dense equivalent.

        ``kv_shared_frac`` is the shared-fraction admission axis: the
        declared fraction of KV blocks expected to be prefix-shared
        duplicates of resident blocks (profiled, or observed via
        ``kv_shared_fraction``).  The KV charge is discounted to
        ``(1 - frac)`` of the physical pool — honest over-admission, in
        HAS-GPU's sense of charging what is actually used: the engine
        enforces the worst case per request at block granularity, and the
        observed ``kv_bytes_saved`` telemetry validates the declared
        fraction.  ``prefix_sharing=False`` deploys the unshared
        reference plane (and such a function must declare frac 0).

        With a fleet ``model_store`` attached, placement prefers warm
        nodes (host-staged or device-resident weights) over cold ones,
        sources the params through the tier (device -> host -> peer ->
        cold), and records a ``ColdStartEvent``.  ``params=None`` is
        then allowed: a host/peer hit re-uploads the staged shards, and
        a true cold miss calls ``weights_loader()`` — the origin fetch
        is paid inside the measured cold-start window.

        ``speculate`` (a ``SpecConfig``) deploys the speculative
        draft/verify hot path: the draft weights (``draft_params``)
        charge the same MRA rectangle and memory admission as the target
        (their bytes fold into the function's weight footprint), and
        with a fleet ``model_store`` they ride the identical warm tier
        under the ``"{fn}#draft"`` key — a scale-up on a node that
        staged the draft before re-uploads it host->device instead of
        paying the origin path.  ``sampling`` (a ``SamplingConfig``)
        turns on fused on-device stochastic sampling.

        ``shards > 1`` deploys ONE tensor-parallel pod spanning that many
        nodes: a rectangle is acquired on every member of the best-linked
        node group (``NetworkLinks.best_groups``), the KV charge divides
        by ``shards`` per node, and the primary member's engine runs the
        executors under a ``tp_mesh`` over the members' devices.
        """
        t_start = time.perf_counter()
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if shards > 1 and speculate is not None:
            raise ValueError(
                "speculate cannot ride a sharded pod: the draft/verify "
                "round is not tensor-parallel")
        if not 0.0 <= kv_shared_frac < 1.0:
            raise ValueError(
                f"kv_shared_frac must be in [0, 1), got {kv_shared_frac}")
        if kv_shared_frac > 0.0 and (batching != "paged"
                                     or not prefix_sharing):
            raise ValueError(
                "kv_shared_frac needs batching='paged' with prefix "
                "sharing enabled — nothing else can share KV blocks")
        kv_bytes = int(model.kv_cache_bytes(
            batching=batching, max_batch=max_batch, max_len=max_len,
            block_size=block_size, n_kv_blocks=n_kv_blocks)
            * (1.0 - kv_shared_frac))
        if shards > 1:
            # Per-member charge: the KV pool shards its kv-heads over the
            # pod's tensor axis, so each member node holds ~1/shards of
            # it — this is what lets a dense reservation too big for ONE
            # node's budget admit as a multi-rectangle pod.  Weights stay
            # charged in full per node: column-only exact TP replicates
            # the row-parallel projections, so full bytes is the honest
            # upper bound.
            kv_bytes //= shards
        if params is None:
            if self.model_store is None:
                raise ValueError(
                    "params=None requires a fleet model_store")
            weight_bytes = self.model_store.staged_nbytes(fn)
            if weight_bytes is None:
                if weights_loader is None:
                    raise ValueError(
                        f"function {fn!r} has no staged weights and no "
                        "weights_loader — nothing to place")
                # Origin fetch: genuinely cold, and charged to this
                # placement's cold-start window.
                params = weights_loader()
                weight_bytes = pytree_nbytes(params)
        else:
            weight_bytes = pytree_nbytes(params)
        if speculate is not None:
            # The draft charges the same rectangle and admission as the
            # target: its bytes fold into the function's weight footprint
            # (shared per node through the store exactly like the target).
            if draft_params is not None:
                weight_bytes += pytree_nbytes(draft_params)
            else:
                staged = (self.model_store.staged_nbytes(f"{fn}#draft")
                          if self.model_store is not None else None)
                if staged is None:
                    raise ValueError(
                        f"function {fn!r} sets speculate but has no draft "
                        f"weights (pass draft_params or stage them in the "
                        f"fleet store)")
                weight_bytes += staged
        created_mm = fn not in self._fn_mm
        mm = self._fn_mm.setdefault(
            fn, MemoryModel(weight_bytes=weight_bytes,
                            framework_bytes=framework_bytes + kv_bytes))
        if mm.framework_bytes != framework_bytes + kv_bytes:
            # The per-function MemoryModel is shared by all replicas; a
            # placement with a different data-plane config would silently
            # mis-account every node's footprint.
            raise ValueError(
                f"function {fn!r} already placed with a different "
                f"per-instance footprint ({mm.framework_bytes} vs "
                f"{framework_bytes + kv_bytes} bytes); one data-plane "
                f"config per function")

        def rollback_mm() -> None:
            if created_mm and not any(p.fn == fn for p in self.placements):
                del self._fn_mm[fn]

        if shards > 1:
            return self._place_sharded(
                fn, model, params, alloc, mm, rollback_mm, shards,
                max_batch=max_batch, max_len=max_len, batching=batching,
                block_size=block_size, n_kv_blocks=n_kv_blocks,
                fused=fused, prefix_sharing=prefix_sharing,
                sampling=sampling, weights_loader=weights_loader,
                t_start=t_start)

        pod_id = f"{fn}-{next(self._pod_seq)}"
        # Warm-first phases: with a fleet store attached, the MRA search
        # first restricts itself to warm nodes (host-staged or
        # device-resident weights) and only then falls back to the whole
        # fleet — warm-aware selection riding next to the existing fit.
        all_nodes = {n.node_id for n in self.pool.nodes}
        phases: list[set[int]] = []
        warm = set(self.warm_nodes(fn))
        if warm and warm != all_nodes:
            phases.append(all_nodes - warm)
        phases.append(set())
        placement = None
        for base_exclude in phases:
            excluded = set(base_exclude)
            while True:
                placement = self.pool.schedule(alloc, pod_id,
                                               exclude=excluded)
                if placement is None:
                    break
                if self.admits(placement.node, fn, mm):
                    break
                # Spillover: rectangle fit but memory admission failed on
                # this node — release and retry the remaining nodes.
                self.pool.release(placement)
                excluded.add(placement.node)
            if placement is not None:
                break
        if placement is None:
            rollback_mm()
            return None
        event = None
        deploy_params = params
        deploy_draft = draft_params
        draft_acquired = False
        if self.model_store is not None:
            resident = self.engines[placement.node].store.contains(fn)
            deploy_params, event = self.model_store.acquire(
                placement.node, fn, model, params=params,
                loader=weights_loader, resident=resident,
                mode=self.cold_start)
            event.placed_at = t_start  # TTFT window opens at call entry
            if speculate is not None:
                # Draft weights ride the same warm tier under "{fn}#draft":
                # device-resident engine copy > host-staged shards > peer >
                # cold stage from draft_params.
                dkey = f"{fn}#draft"
                if fn not in self._draft_models:
                    from repro.models.model import build_model
                    self._draft_models[fn] = build_model(speculate.draft_cfg)
                resident_d = self.engines[placement.node].store.contains(
                    dkey)
                deploy_draft, _ = self.model_store.acquire(
                    placement.node, dkey, self._draft_models[fn],
                    params=draft_params, resident=resident_d,
                    mode=self.cold_start)
                draft_acquired = True
                self._fn_draft.add(fn)
        try:
            inst_id = self.engines[placement.node].deploy(
                fn, model, deploy_params, alloc, n_instances=1,
                max_batch=max_batch, max_len=max_len, batching=batching,
                block_size=block_size, n_kv_blocks=n_kv_blocks,
                fused=fused, prefix_sharing=prefix_sharing,
                sampling=sampling, speculate=speculate,
                draft_params=deploy_draft)[0]
        except Exception:
            # The rectangle was reserved before the engine ran; a failed
            # deploy must not leak it (or a provisional memory-model entry,
            # or a host-cache pin).
            self.pool.release(placement)
            if self.model_store is not None:
                self.model_store.release(placement.node, fn)
                if draft_acquired:
                    self.model_store.release(placement.node, f"{fn}#draft")
            rollback_mm()
            raise
        if event is not None:
            self._cold_instances.append((event, placement.node, inst_id))
        self.placements.append(InstancePlacement(
            fn=fn, inst_id=inst_id, node=placement.node,
            placement=placement))
        inst = self.engines[placement.node].instances[inst_id]
        self._fn_limits[fn] = (max_len, block_size,
                               inst.allocator.capacity
                               if batching == "paged" else None,
                               speculate.k if speculate is not None else 0)
        # Requests parked while the function had zero live instances.
        for req in self._pending.pop(fn, []):
            self._enqueue(fn, req)
        return f"{placement.node}:{inst_id}"

    def _place_sharded(self, fn: str, model: Model, params: Any,
                       alloc: Alloc, mm: MemoryModel, rollback_mm: Any,
                       shards: int, *, max_batch: int, max_len: int,
                       batching: str, block_size: int,
                       n_kv_blocks: Optional[int], fused: bool,
                       prefix_sharing: bool, sampling: Optional[Any],
                       weights_loader: Optional[Any],
                       t_start: float) -> Optional[str]:
        """Acquire ``shards`` MRA rectangles — one per member node — on
        the best-connected node group and deploy ONE tensor-parallel
        instance across them.

        Link-aware placement (Helix-style): candidate groups are walked
        in ``NetworkLinks.best_groups`` order — highest bottleneck
        bandwidth first, so the pod's per-round all-gathers ride the
        fastest links available.  Every member must fit the rectangle AND
        pass memory admission; a group that fails anywhere rolls back the
        rectangles it acquired and the next-best group is tried.  The
        primary (first member) hosts the executors; the mesh spans one
        jax device per member node.
        """
        devices = jax.devices()
        all_nodes = {n.node_id for n in self.pool.nodes}
        candidates = sorted(n for n in all_nodes
                            if n < len(devices) and self.engines[n].alive)
        pod_id = f"{fn}-{next(self._pod_seq)}"
        group: Optional[list[int]] = None
        rects: list[Placement] = []
        for cand in self.links.best_groups(candidates, shards):
            acquired: list[Placement] = []
            ok = True
            for member in cand:
                rect = self.pool.schedule(alloc, f"{pod_id}@{member}",
                                          exclude=all_nodes - {member})
                if rect is None or not self.admits(member, fn, mm):
                    if rect is not None:
                        self.pool.release(rect)
                    ok = False
                    break
                acquired.append(rect)
            if ok:
                group, rects = list(cand), acquired
                break
            for rect in acquired:
                self.pool.release(rect)
        if group is None:
            rollback_mm()
            return None
        primary = group[0]
        mesh = tp_mesh(shards, devices=[devices[n] for n in group])
        event = None
        deploy_params = params
        acquired_store = False
        try:
            if self.model_store is not None:
                # The fleet tier stages on the primary's host cache but
                # uploads each layer shard STRAIGHT to its owning device
                # (sharding_for); the engine's shard_put re-place is then
                # a no-op and warm scale-ups skip the origin fetch.
                from jax.sharding import NamedSharding
                resident = self.engines[primary].store.contains(
                    f"{fn}@tp{shards}")
                deploy_params, event = self.model_store.acquire(
                    primary, fn, model, params=params,
                    loader=weights_loader, resident=resident,
                    mode=self.cold_start,
                    sharding_for=lambda nm, shp: NamedSharding(
                        mesh, serve_pspec(nm, shp, mesh)))
                acquired_store = True
                event.placed_at = t_start
            inst_id = self.engines[primary].deploy(
                fn, model, deploy_params, alloc, n_instances=1,
                max_batch=max_batch, max_len=max_len, batching=batching,
                block_size=block_size, n_kv_blocks=n_kv_blocks,
                fused=fused, prefix_sharing=prefix_sharing,
                sampling=sampling, mesh=mesh)[0]
        except Exception:
            for rect in rects:
                self.pool.release(rect)
            if acquired_store:
                self.model_store.release(primary, fn)
            rollback_mm()
            raise
        if event is not None:
            self._cold_instances.append((event, primary, inst_id))
        self.placements.append(InstancePlacement(
            fn=fn, inst_id=inst_id, node=primary, placement=rects[0],
            member_nodes=tuple(group), member_placements=tuple(rects)))
        inst = self.engines[primary].instances[inst_id]
        self._fn_limits[fn] = (max_len, block_size,
                               inst.allocator.capacity
                               if batching == "paged" else None, 0)
        for req in self._pending.pop(fn, []):
            self._enqueue(fn, req)
        return f"{primary}:{inst_id}"

    def deploy(self, fn: str, model: Model, params: Any, alloc: Alloc, *,
               n_instances: int = 1, max_batch: int = 4, max_len: int = 64,
               batching: str = "continuous",
               framework_bytes: int = DEFAULT_FRAMEWORK_BYTES,
               block_size: int = 16,
               n_kv_blocks: Optional[int] = None,
               fused: bool = True, prefix_sharing: bool = True,
               kv_shared_frac: float = 0.0,
               sampling: Optional[Any] = None,
               speculate: Optional[Any] = None,
               draft_params: Optional[Any] = None,
               shards: int = 1) -> list[str]:
        """Place ``n_instances`` of ``fn`` across the fleet via MRA +
        memory admission; returns ``node:inst_id`` handles."""
        handles = []
        for _ in range(n_instances):
            handle = self.place_instance(
                fn, model, params, alloc, max_batch=max_batch,
                max_len=max_len, batching=batching,
                framework_bytes=framework_bytes,
                block_size=block_size, n_kv_blocks=n_kv_blocks, fused=fused,
                prefix_sharing=prefix_sharing,
                kv_shared_frac=kv_shared_frac, sampling=sampling,
                speculate=speculate, draft_params=draft_params,
                shards=shards)
            if handle is None:
                raise RuntimeError(
                    f"no node can host {fn} at alloc {alloc} "
                    f"(rectangles or memory exhausted)")
            handles.append(handle)
        return handles

    def nodes_for(self, fn: str) -> list[int]:
        return sorted({p.node for p in self.placements if p.fn == fn})

    # -- request path ------------------------------------------------------

    def _fn_load(self, node: int, fn: str) -> int:
        eng = self.engines[node]
        return sum(inst.load() for key, inst in eng.instances.items()
                   if key.startswith(fn + "/"))

    def _live_nodes(self, fn: str) -> list[int]:
        """Nodes with at least one routable (non-retired, non-paused)
        instance of ``fn``."""
        out = []
        for node in self.nodes_for(fn):
            eng = self.engines[node]
            if eng.alive and not eng.quarantined and any(
                    k.startswith(fn + "/") and not inst.retired
                    and not inst.paused
                    for k, inst in eng.instances.items()):
                out.append(node)
        return out

    def _pick_node(self, fn: str) -> int:
        """Join-shortest-queue node selection over live instances."""
        nodes = self._live_nodes(fn)
        if not nodes:
            raise KeyError(f"function {fn} is not deployed")
        return min(nodes, key=lambda n: self._fn_load(n, fn))

    def _enqueue(self, fn: str, req: ServeRequest) -> None:
        """Route an EXISTING request (drain re-route) the same way submit
        routes new ones: JSQ node, then JSQ live instance."""
        eng = self.engines[self._pick_node(fn)]
        cands = [v for k, v in eng.instances.items()
                 if k.startswith(fn + "/") and not v.retired
                 and not v.paused]
        ServingEngine.enqueue(min(cands, key=lambda i: i.load()), req)

    def submit(self, fn: str, prompt: np.ndarray, max_new_tokens: int = 8
               ) -> ServeRequest:
        tier, budget, est_rps = self._fn_slo.get(
            fn, (TIER_BEST_EFFORT, None, 0.0))
        deadline = None if budget is None else self.now() + budget
        if not self._live_nodes(fn):
            # Podless window (a failure killed the last replica, or the
            # fleet scaled to zero): park the request — mirroring the
            # simulator's pending buffer — and let the reconciler's next
            # placement flush it.  Functions never placed here stay a hard
            # error: there is no config to validate against.
            if fn not in self._fn_limits:
                raise KeyError(f"function {fn} is not deployed")
            max_len, block_size, blocks_cap, spec_k = self._fn_limits[fn]
            rows = (int(prompt.shape[0]) + max_new_tokens - 1
                    + (spec_k if max_new_tokens > 1 else 0))
            if rows > max_len:
                raise ValueError(
                    f"request needs {rows} KV rows > max_len {max_len} "
                    f"of function {fn}")
            if (blocks_cap is not None and max_new_tokens > 1
                    and blocks_needed(rows, block_size) > blocks_cap):
                raise ValueError(
                    f"request needs {blocks_needed(rows, block_size)} KV "
                    f"blocks > pool capacity {blocks_cap} of function {fn}")
            record_arrival(self._arrival_log, self._rps_horizon, fn,
                           self.now())
            req = ServeRequest(req_id=next(self._req_seq), prompt=prompt,
                               max_new_tokens=max_new_tokens,
                               submitted_at=self.now(), deadline=deadline,
                               tier=tier)
            self._pending.setdefault(fn, []).append(req)
            return req
        node = self._pick_node(fn)
        record_arrival(self._arrival_log, self._rps_horizon, fn, self.now())
        # Deadline shedding ("reject fast"): estimate completion from the
        # chosen node's queue depth x the configured per-instance service
        # rate and reject a non-guaranteed request that cannot make its
        # deadline with a typed outcome instead of queuing it to die.
        if (deadline is not None and tier != TIER_GUARANTEED
                and est_rps > 0.0):
            est = (self._fn_load(node, fn) + 1) / est_rps
            if self.now() + est > deadline:
                self.shed += 1
                eng = self.engines[node]
                if fn in eng.recorders:
                    eng.recorders[fn].record_shed()
                return ServeRequest(req_id=next(self._req_seq),
                                    prompt=prompt,
                                    max_new_tokens=max_new_tokens,
                                    submitted_at=self.now(),
                                    deadline=deadline, tier=tier,
                                    done=True, outcome="shed",
                                    finished_at=self.now())
        # Second JSQ level across the chosen node's instances happens in
        # ServingEngine.submit.
        return self.engines[node].submit(fn, prompt, max_new_tokens,
                                         deadline=deadline, tier=tier)

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.engines)

    def pump(self, budget_s: float = 1.0, slice_s: float = 0.02) -> int:
        """Interleave the per-node schedulers until idle or out of budget."""
        completed = 0
        deadline = time.perf_counter() + budget_s
        self._flush_retries()
        while ((time.perf_counter() < deadline)
               and (self.has_work() or self._retry_buf)):
            for eng in self.engines:
                if eng.has_work():
                    completed += eng.pump(budget_s=slice_s)
            self._flush_retries()
            if not self.has_work() and self._retry_buf:
                # Only backoff timers outstanding: wait one out instead of
                # spinning the whole budget.
                wake = min(t for t, _, _ in self._retry_buf)
                wait = min(wake - self.now(), deadline - time.perf_counter())
                if wait > 0:
                    time.sleep(wait)
                self._flush_retries()
        return completed

    def _flush_retries(self) -> None:
        """Re-route stranded requests whose jittered backoff has elapsed."""
        if not self._retry_buf:
            return
        now = self.now()
        due = [e for e in self._retry_buf if e[0] <= now]
        if not due:
            return
        self._retry_buf = [e for e in self._retry_buf if e[0] > now]
        for _, fn, req in due:
            if self._live_nodes(fn):
                self._enqueue(fn, req)
            elif fn in self._fn_limits:
                self._pending.setdefault(fn, []).append(req)
            else:
                req.done = True
                req.outcome = "rejected"
                req.finished_at = now
                self.rejected += 1

    # -- scale-down --------------------------------------------------------

    def evict(self, handle: str) -> None:
        """Gracefully retire the instance behind ``node:inst_id``.

        Queued (not yet admitted) requests are immediately re-routed to the
        function's surviving instances; occupied decode slots keep decoding
        until they finish.  The MRA rectangle and weight refcount are only
        released once the instance has fully drained (``on_instance_closed``
        fires from the engine pump)."""
        node_s, inst_id = handle.split(":", 1)
        node = int(node_s)
        fn = inst_id.split("/")[0]
        victim = self.engines[node].instances[inst_id]
        survivors = any(
            inst is not victim and not inst.retired
            for eng in self.engines for k, inst in eng.instances.items()
            if k.startswith(fn + "/"))
        # Last replica: keep its queue — it drains everything (queued AND
        # in-flight) before closing, so nothing is dropped.
        strays = self.engines[node].retire(inst_id,
                                           strip_queue=survivors)
        for req in strays:
            self._enqueue(fn, req)

    # -- lifecycle: failure + live KV migration ----------------------------

    def alive(self, handle: str) -> bool:
        """Whether the instance behind ``node:inst_id`` is still running on
        a non-quarantined node (failed nodes lose all their instances
        instantly; a quarantined node's instances read as not-alive so the
        reconciler prunes and heals them exactly like a crash)."""
        node_s, inst_id = handle.split(":", 1)
        node = int(node_s)
        if not 0 <= node < len(self.engines):
            return False
        eng = self.engines[node]
        if not eng.alive or eng.quarantined or inst_id not in eng.instances:
            return False
        # A sharded pod reads dead when ANY member node is quarantined.
        for p in self.placements:
            if p.node == node and p.inst_id == inst_id:
                return not any(self.engines[m].quarantined
                               for m in p.all_nodes())
        return True

    def health(self, node: int) -> float:
        """Node health score in (0, 1]: the engine's slow/fast pass-latency
        EWMA ratio (1.0 nominal; a node running Nx slower scores ~1/N)."""
        if not 0 <= node < len(self.engines):
            return 0.0
        return self.engines[node].health()

    def quarantine(self, node: int) -> int:
        """Gray-failure quarantine: stop routing and placement to the node,
        let occupants drain through pump.  One-way, like death — but the
        engine keeps serving what it already holds, and the reconciler
        heals the capacity through the ordinary ``alive`` prune +
        processing gap.  Returns the number of instances taken out of
        rotation."""
        eng = self.engines[node]
        if eng.quarantined or not eng.alive:
            return 0
        eng.quarantined = True
        self.pool.cordon(node)
        return sum(1 for p in self.placements if node in p.all_nodes())

    def unregister(self, fn: str) -> list[ServeRequest]:
        """Delete a function: evict its live instances and reject every
        parked request with the typed outcome ``"rejected"`` — a parked
        request must never outlive its function's registration.  Returns
        the rejected requests; subsequent submits raise ``KeyError``."""
        for p in [p for p in self.placements if p.fn == fn]:
            handle = f"{p.node}:{p.inst_id}"
            if self.alive(handle):
                self.evict(handle)
        rejected = self._pending.pop(fn, [])
        self._retry_buf, orphans = (
            [e for e in self._retry_buf if e[1] != fn],
            [e[2] for e in self._retry_buf if e[1] == fn])
        rejected.extend(orphans)
        now = self.now()
        for req in rejected:
            req.done = True
            req.outcome = "rejected"
            req.finished_at = now
        self.rejected += len(rejected)
        self._fn_limits.pop(fn, None)
        self._fn_slo.pop(fn, None)
        return rejected

    def node_of(self, handle: str) -> Optional[int]:
        node = int(handle.split(":", 1)[0])
        return node if 0 <= node < len(self.engines) else None

    def fragmentation(self) -> dict[int, float]:
        """Per-node MRA fragmentation over schedulable (alive) nodes."""
        return self.pool.fragmentation()

    def node_load(self) -> dict[int, float]:
        """Per-node allocated-area fraction over schedulable nodes."""
        return self.pool.node_load()

    def fail_node(self, node: int) -> int:
        """Crash one engine node: its instances, weights, and KV die.

        Mirrors ``Cluster.fail_node``: the node is cordoned, its
        rectangles dropped, and every stranded unfinished request (queued
        AND slot-occupying — partial output reset, since the KV died with
        the node) is re-routed to surviving replicas or parked until the
        reconciler re-places the function.  No self-healing here:
        ``ControlPlane.reconcile`` prunes the dead pods via ``alive`` and
        re-converges the fleet.  Returns the number of instances lost.
        """
        eng = self.engines[node]
        strays = eng.fail()
        if self.model_store is not None:
            # Host RAM died with the node; peer caches stay warm.
            self.model_store.drop_node(node)
        self.pool.drain_node(node)
        # A sharded pod dies with ANY member: one KV shard and one weight
        # shard lived on the dead node.  A secondary-member death must
        # also kill the (still running) instance on the primary engine;
        # rectangles on surviving member nodes are released explicitly
        # (drain_node only dropped the dead node's).
        lost = [p for p in self.placements if node in p.all_nodes()]
        self.placements = [p for p in self.placements
                           if node not in p.all_nodes()]
        for p in lost:
            if p.node != node:
                strays.extend(self._kill_remote_member(p))
            for n_, rect in zip(p.all_nodes(), p.all_placements()):
                if n_ != node and self.engines[n_].alive:
                    self.pool.release(rect)
        for fn in {p.fn for p in lost}:
            if not any(p.fn == fn for p in self.placements):
                # No replica left anywhere: drop the per-function
                # MemoryModel so the healing redeploy may re-create it.
                self._fn_mm.pop(fn, None)
        for fn, req in strays:
            self._reinject(fn, req)
        return len(lost)

    def _reinject(self, fn: str, req: ServeRequest) -> None:
        """Re-route one stranded request — immediately (legacy, no retry
        policy) or through the bounded jittered-backoff retry buffer."""
        if self.retry is None:
            if self._live_nodes(fn):
                self._enqueue(fn, req)
            else:
                self._pending.setdefault(fn, []).append(req)
            return
        if (req.tier != TIER_GUARANTEED
                and self.retry.exhausted(req.attempts)):
            # Best-effort/batch: retry budget spent — typed loss, not an
            # eternal park.  Guaranteed requests retry without bound.
            req.done = True
            req.outcome = "failed"
            req.finished_at = self.now()
            self.lost += 1
            for eng in self.engines:
                if eng.alive and fn in eng.recorders:
                    eng.recorders[fn].record_lost()
                    break
            return
        req.attempts += 1
        self._retry_buf.append(
            (self.now() + self.retry.delay(req.attempts), fn, req))

    def _kill_remote_member(self, p: InstancePlacement
                            ) -> list[tuple[str, ServeRequest]]:
        """Tear down a sharded pod whose SECONDARY member died: the
        primary engine is alive but the pod's mesh lost a device, so the
        instance dies crash-style (no drain — its KV shard is gone) and
        its unfinished requests strand for re-routing; slot occupants
        restart from the prompt exactly like a primary crash."""
        eng = self.engines[p.node]
        inst = eng.instances.pop(p.inst_id, None)
        if inst is None:
            return []
        eng.scheduler.deregister(p.inst_id)
        strays: list[tuple[str, ServeRequest]] = []
        occupants = (inst.active if inst.batching == "static"
                     else inst.slots)
        for req in occupants:
            if req is None or req.done:
                continue
            req.tokens_out = []  # KV shard lost: re-execute from scratch
            strays.append((p.fn, req))
        strays.extend((p.fn, req) for req in inst.queue)
        inst.queue.clear()
        inst.close()  # drops the engine-store weight refcount
        if self.model_store is not None:
            self.model_store.release(p.node, p.fn)
        return strays

    def migrate(self, fn: str, handle: str, model: Model, params: Any,
                target: int) -> Optional[str]:
        """Live KV migration: move the instance behind ``handle`` to node
        ``target`` with zero dropped in-flight requests.

        The protocol is pause -> gather -> merge -> re-route: admission and
        decode pause on the source, a fresh instance (same data-plane
        config) deploys into a reserved rectangle on the target, every
        occupied decode slot's cache entry is gathered
        (``Model.gather_slot`` / ``gather_pages``) and merged into the same
        slot of the target (``merge_slot`` / page re-append), queued
        requests re-route, and only then does the source close and release
        its rectangle.  Remaining decode rounds produce bit-identical
        tokens.  Prefix sharing re-establishes on the target as the slots
        import: the first cohort member to land registers its full prompt
        blocks, later members map them read-only instead of re-writing
        them (``import_slot``).  Returns the new ``node:inst_id`` handle,
        or None when the
        instance cannot move (static batch, retired, target full or dead).
        """
        node_s, inst_id = handle.split(":", 1)
        src = int(node_s)
        if target == src or not 0 <= target < len(self.engines):
            return None
        if not self.engines[target].alive:
            return None
        eng = self.engines[src]
        inst = eng.instances.get(inst_id)
        if inst is None or inst.retired or inst.batching == "static":
            return None
        if getattr(inst, "mesh", None) is not None:
            # Sharded pods don't migrate: the KV lives as one shard per
            # member device and a target would need an identical link
            # group — the reconciler re-places instead.
            return None
        if inst.speculate is not None:
            # Mid-flight speculative state (draft side cache, device PRNG
            # key stream) does not export; speculating pods scale, they
            # don't migrate.
            return None
        mm = self._fn_mm.get(fn)
        # Copy-then-delete: the target must admit the instance while the
        # source still holds its memory.
        if mm is None or not self.admits(target, fn, mm):
            return None
        pod_id = f"{fn}-{next(self._pod_seq)}"
        exclude = {n.node_id for n in self.pool.nodes} - {target}
        placement = self.pool.schedule(inst.alloc, pod_id, exclude=exclude)
        if placement is None:
            return None
        if placement.node != target:
            self.pool.release(placement)
            return None
        event = None
        deploy_params = params
        if self.model_store is not None:
            resident = self.engines[target].store.contains(fn)
            deploy_params, event = self.model_store.acquire(
                target, fn, model, params=params, resident=resident,
                mode=self.cold_start)
        inst.paused = True  # pause admission + decode while the KV moves
        try:
            new_inst_id = self.engines[target].deploy(
                fn, model, deploy_params, inst.alloc, n_instances=1,
                max_batch=inst.max_batch, max_len=inst.max_len,
                batching=inst.batching,
                block_size=getattr(inst, "block_size", 16),
                n_kv_blocks=(inst.allocator.n_blocks
                             if inst.batching == "paged" else None),
                fused=inst.fused,
                prefix_sharing=inst.prefix_sharing)[0]
        except Exception:
            self.pool.release(placement)
            if self.model_store is not None:
                self.model_store.release(target, fn)
            inst.paused = False
            raise
        if event is not None:
            self._cold_instances.append((event, target, new_inst_id))
        new_inst = self.engines[target].instances[new_inst_id]
        # Gather -> merge, slot by slot: same slot index on the target, so
        # the decode batch resumes exactly where it paused.
        for slot, req in enumerate(inst.slots):
            if req is None:
                continue
            new_inst.import_slot(slot, *inst.export_slot(slot))
            inst.slots[slot] = None
            if inst.batching == "paged":
                inst._release_paged(slot)
        # Re-route queued (not yet admitted) requests to the new instance.
        new_inst.queue.extend(inst.queue)
        inst.queue.clear()
        self.placements.append(InstancePlacement(
            fn=fn, inst_id=new_inst_id, node=target, placement=placement))
        # The source is now empty: retiring it closes immediately and
        # releases its rectangle + weight refcount via on_instance_closed.
        eng.retire(inst_id)
        return f"{target}:{new_inst_id}"

    def _instance_closed(self, node: int, inst_id: str) -> None:
        """Engine callback: a retired instance finished draining."""
        for p in self.placements:
            if p.node == node and p.inst_id == inst_id:
                for rect in p.all_placements():
                    self.pool.release(rect)
                self.placements.remove(p)
                if self.model_store is not None:
                    # The pod's hold on its host-staged weights ends here;
                    # the entry stays cached (evictable) for the next
                    # scale-up to hit warm.
                    self.model_store.release(node, p.fn)
                    if p.fn in self._fn_draft:
                        self.model_store.release(node, f"{p.fn}#draft")
                if not any(q.fn == p.fn for q in self.placements):
                    # Fully drained: drop the per-function MemoryModel so a
                    # redeploy may use a different data-plane config.
                    self._fn_mm.pop(p.fn, None)
                    self._fn_draft.discard(p.fn)
                return

    # -- metrics -----------------------------------------------------------

    def observed_rps(self, fn: str, window: float) -> float:
        """Submit rate over the trailing wall-clock ``window`` seconds."""
        return observed_rate(self._arrival_log, self._rps_horizon,
                             fn, window, self.now())

    def inflight(self, fn: str) -> int:
        """Queued + slot-occupying requests across the function's
        instances (draining ones included)."""
        return sum(self._fn_load(node, fn) for node in self.nodes_for(fn))

    def occupancy(self, last_n: int = 10) -> float:
        live = [e for e in self.engines if e.instances]
        if not live:
            return 0.0
        return sum(e.scheduler.occupancy(last_n) for e in live) / len(live)

    def utilization(self, last_n: int = 10) -> float:
        live = [e for e in self.engines if e.instances]
        if not live:
            return 0.0
        return sum(e.scheduler.utilization(last_n) for e in live) / len(live)

    def memory_bytes(self) -> int:
        return sum(e.memory_bytes() for e in self.engines)

    def kv_bytes_in_use(self) -> int:
        """Physical KV bytes live requests hold across the fleet."""
        return sum(e.kv_bytes_in_use() for e in self.engines)

    def dense_kv_reserved(self) -> int:
        """Dense slot-pool reservation for the fleet's current capacity."""
        return sum(e.dense_kv_reserved() for e in self.engines)

    def kv_bytes_saved(self) -> int:
        """Bytes prefix sharing is saving fleet-wide right now (extra
        block references minus reserved COW spares, in bytes)."""
        return sum(e.kv_bytes_saved() for e in self.engines)

    def cold_start_events(self) -> list[ColdStartEvent]:
        """Every placement's trip through the weight tier, with
        time-to-first-token resolved lazily: ``ttft_s`` fills in once the
        placed instance lands its first token (``first_token_at``)."""
        out = []
        for event, node, inst_id in self._cold_instances:
            if event.ttft_s is None:
                inst = self.engines[node].instances.get(inst_id)
                first = inst.first_token_at if inst is not None else None
                if first is not None:
                    event.ttft_s = first - event.placed_at
            out.append(event)
        return out

    def kv_shared_fraction(self) -> float:
        """Observed shared fraction: saved / (in_use + saved) — the honest
        value to feed back into ``kv_shared_frac`` / profile tables."""
        saved = self.kv_bytes_saved()
        live = self.kv_bytes_in_use()
        return saved / (saved + live) if saved + live > 0 else 0.0

    def recorder(self, fn: str):
        """Merged view is unnecessary: latency records live per node."""
        return [e.recorders[fn] for e in self.engines if fn in e.recorders]
