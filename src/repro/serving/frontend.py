"""Multi-engine frontend: the live analogue of ``repro.core.cluster``.

``ClusterFrontend`` routes requests across N ``ServingEngine`` nodes so the
real JAX data plane finally exercises the simulator's full stack:

* **Placement** — function instances are bound to nodes by the same
  ``MaxRectsPool`` (paper Alg. 2) the simulator uses: each instance's
  ``Alloc`` rectangle is packed best-area-fit across the fleet, and a
  candidate node must also pass ``MemoryModel`` admission (model-sharing
  footprints, paper Fig. 13 / §3.5) before the engine deploys there.
* **Routing** — ``submit`` joins the shortest queue across all nodes
  hosting the function (queue depth + occupied decode slots), mirroring
  ``Cluster._arrive``.
* **Dispatch** — ``pump`` interleaves the per-node token schedulers
  (FaST-Manager, one per engine) until the fleet is idle.

Weights are shared *per node*: deploying the same function on two nodes
stores one param pytree in each node's ``ModelStore``; instances within a
node alias it zero-copy.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional

import numpy as np

from repro.core.maximal_rectangles import MaxRectsPool, Placement
from repro.core.model_sharing import MemoryModel, pytree_nbytes
from repro.core.resources import Alloc
from repro.models.model import Model
from repro.serving.engine import ServeRequest, ServingEngine

# Per-instance runtime footprint (jit executables, slot KV pool, host
# bookkeeping) charged by admission when the caller gives no measurement.
DEFAULT_FRAMEWORK_BYTES = 64 * 1024 * 1024


@dataclasses.dataclass
class InstancePlacement:
    """One live instance: which node it landed on and its MRA rectangle."""

    fn: str
    inst_id: str
    node: int
    placement: Placement


class ClusterFrontend:
    """Join-shortest-queue router over N token-scheduled engine nodes."""

    def __init__(self, n_nodes: int = 2, *,
                 mem_bytes: int = 16 * 1024**3, window: float = 0.2):
        if n_nodes <= 0:
            raise ValueError("need at least one node")
        self.engines = [ServingEngine(window=window) for _ in range(n_nodes)]
        self.pool = MaxRectsPool(n_nodes, allow_grow=False)
        self.mem_bytes = mem_bytes
        self.placements: list[InstancePlacement] = []
        self._fn_mm: dict[str, MemoryModel] = {}
        self._pod_seq = itertools.count()

    # -- memory admission (same closed form as core.cluster.Node) ---------

    def _fn_instances_on(self, node: int) -> dict[str, int]:
        counts: dict[str, int] = {}
        for p in self.placements:
            if p.node == node:
                counts[p.fn] = counts.get(p.fn, 0) + 1
        return counts

    def mem_used(self, node: int) -> int:
        return sum(self._fn_mm[fn].footprint(n, sharing=True)
                   for fn, n in self._fn_instances_on(node).items() if n > 0)

    def admits(self, node: int, fn: str, mm: MemoryModel) -> bool:
        n = self._fn_instances_on(node).get(fn, 0)
        projected = (self.mem_used(node)
                     - mm.footprint(n, sharing=True)
                     + mm.footprint(n + 1, sharing=True))
        return projected <= self.mem_bytes

    # -- deployment --------------------------------------------------------

    def deploy(self, fn: str, model: Model, params: Any, alloc: Alloc, *,
               n_instances: int = 1, max_batch: int = 4, max_len: int = 64,
               batching: str = "continuous",
               framework_bytes: int = DEFAULT_FRAMEWORK_BYTES) -> list[str]:
        """Place ``n_instances`` of ``fn`` across the fleet via MRA +
        memory admission; returns ``node:inst_id`` handles."""
        mm = self._fn_mm.setdefault(
            fn, MemoryModel(weight_bytes=pytree_nbytes(params),
                            framework_bytes=framework_bytes))
        handles = []
        for _ in range(n_instances):
            pod_id = f"{fn}-{next(self._pod_seq)}"
            excluded: set[int] = set()
            while True:
                placement = self.pool.schedule(alloc, pod_id,
                                               exclude=excluded)
                if placement is None:
                    raise RuntimeError(
                        f"no node can host {fn} at alloc {alloc} "
                        f"(rectangles or memory exhausted)")
                if self.admits(placement.node, fn, mm):
                    break
                self.pool.release(placement)
                excluded.add(placement.node)
            inst_id = self.engines[placement.node].deploy(
                fn, model, params, alloc, n_instances=1,
                max_batch=max_batch, max_len=max_len, batching=batching)[0]
            self.placements.append(InstancePlacement(
                fn=fn, inst_id=inst_id, node=placement.node,
                placement=placement))
            handles.append(f"{placement.node}:{inst_id}")
        return handles

    def nodes_for(self, fn: str) -> list[int]:
        return sorted({p.node for p in self.placements if p.fn == fn})

    # -- request path ------------------------------------------------------

    def _fn_load(self, node: int, fn: str) -> int:
        eng = self.engines[node]
        return sum(inst.load() for key, inst in eng.instances.items()
                   if key.startswith(fn + "/"))

    def submit(self, fn: str, prompt: np.ndarray, max_new_tokens: int = 8
               ) -> ServeRequest:
        nodes = self.nodes_for(fn)
        if not nodes:
            raise KeyError(f"function {fn} is not deployed")
        # Join-shortest-queue across nodes, then again across the chosen
        # node's instances (ServingEngine.submit).
        node = min(nodes, key=lambda n: self._fn_load(n, fn))
        return self.engines[node].submit(fn, prompt, max_new_tokens)

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.engines)

    def pump(self, budget_s: float = 1.0, slice_s: float = 0.02) -> int:
        """Interleave the per-node schedulers until idle or out of budget."""
        import time

        completed = 0
        deadline = time.perf_counter() + budget_s
        while time.perf_counter() < deadline and self.has_work():
            for eng in self.engines:
                if eng.has_work():
                    completed += eng.pump(budget_s=slice_s)
        return completed

    # -- metrics -----------------------------------------------------------

    def occupancy(self, last_n: int = 10) -> float:
        live = [e for e in self.engines if e.instances]
        if not live:
            return 0.0
        return sum(e.scheduler.occupancy(last_n) for e in live) / len(live)

    def utilization(self, last_n: int = 10) -> float:
        live = [e for e in self.engines if e.instances]
        if not live:
            return 0.0
        return sum(e.scheduler.utilization(last_n) for e in live) / len(live)

    def memory_bytes(self) -> int:
        return sum(e.memory_bytes() for e in self.engines)

    def recorder(self, fn: str):
        """Merged view is unnecessary: latency records live per node."""
        return [e.recorders[fn] for e in self.engines if fn in e.recorders]
