"""Multi-engine frontend: the live analogue of ``repro.core.cluster``.

``ClusterFrontend`` routes requests across N ``ServingEngine`` nodes so the
real JAX data plane exercises the simulator's full stack:

* **Placement** — function instances are bound to nodes by the same
  ``MaxRectsPool`` (paper Alg. 2) the simulator uses: each instance's
  ``Alloc`` rectangle is packed best-area-fit across the fleet, and a
  candidate node must also pass ``MemoryModel`` admission (model-sharing
  footprints, paper Fig. 13 / §3.5) before the engine deploys there.
* **Routing** — ``submit`` joins the shortest queue across all nodes
  hosting the function (queue depth + occupied decode slots), mirroring
  ``Cluster._arrive``.
* **Dispatch** — ``pump`` interleaves the per-node token schedulers
  (FaST-Manager, one per engine) until the fleet is idle.
* **Scale-down** — ``evict`` retires one instance: its queued requests are
  re-routed to surviving replicas, its occupied decode slots drain under
  the token scheduler, and only then are its MRA rectangle and weight
  refcount released (zero dropped in-flight requests).

The frontend is one of the two ``repro.control`` backends: the
``ControlPlane`` reconciler drives ``place_instance`` / ``evict`` /
``observed_rps`` / ``inflight`` so the live fleet and the simulator run
literally the same Alg.-1 scheduler code.

Weights are shared *per node*: deploying the same function on two nodes
stores one param pytree in each node's ``ModelStore``; instances within a
node alias it zero-copy.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Any, Optional

import numpy as np

from repro.core.maximal_rectangles import MaxRectsPool, Placement
from repro.core.model_sharing import MemoryModel, pytree_nbytes
from repro.core.resources import Alloc
from repro.core.slo import observed_rate, record_arrival
from repro.models.model import Model
from repro.serving.engine import ServeRequest, ServingEngine

# Per-instance runtime footprint (jit executables, slot KV pool, host
# bookkeeping) charged by admission when the caller gives no measurement.
DEFAULT_FRAMEWORK_BYTES = 64 * 1024 * 1024


@dataclasses.dataclass
class InstancePlacement:
    """One live instance: which node it landed on and its MRA rectangle."""

    fn: str
    inst_id: str
    node: int
    placement: Placement


class ClusterFrontend:
    """Join-shortest-queue router over N token-scheduled engine nodes."""

    def __init__(self, n_nodes: int = 2, *,
                 mem_bytes: int = 16 * 1024**3, window: float = 0.2):
        if n_nodes <= 0:
            raise ValueError("need at least one node")
        self.engines = [ServingEngine(window=window) for _ in range(n_nodes)]
        for i, eng in enumerate(self.engines):
            eng.on_instance_closed = functools.partial(
                self._instance_closed, i)
        self.pool = MaxRectsPool(n_nodes, allow_grow=False)
        self.mem_bytes = mem_bytes
        self.placements: list[InstancePlacement] = []
        self._fn_mm: dict[str, MemoryModel] = {}
        self._pod_seq = itertools.count()
        self._arrival_log: dict[str, list[float]] = {}
        self._rps_horizon: dict[str, float] = {}
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    # -- memory admission (same closed form as core.cluster.Node) ---------

    def _fn_instances_on(self, node: int) -> dict[str, int]:
        counts: dict[str, int] = {}
        for p in self.placements:
            if p.node == node:
                counts[p.fn] = counts.get(p.fn, 0) + 1
        return counts

    def mem_used(self, node: int) -> int:
        return sum(self._fn_mm[fn].footprint(n, sharing=True)
                   for fn, n in self._fn_instances_on(node).items() if n > 0)

    def admits(self, node: int, fn: str, mm: MemoryModel) -> bool:
        n = self._fn_instances_on(node).get(fn, 0)
        projected = (self.mem_used(node)
                     - mm.footprint(n, sharing=True)
                     + mm.footprint(n + 1, sharing=True))
        return projected <= self.mem_bytes

    # -- deployment --------------------------------------------------------

    def place_instance(self, fn: str, model: Model, params: Any,
                       alloc: Alloc, *, max_batch: int = 4, max_len: int = 64,
                       batching: str = "continuous",
                       framework_bytes: int = DEFAULT_FRAMEWORK_BYTES,
                       block_size: int = 16,
                       n_kv_blocks: Optional[int] = None) -> Optional[str]:
        """Place ONE instance via MRA + memory admission with spillover.

        Returns a ``node:inst_id`` handle, or None when no node has both a
        free rectangle and the memory headroom.  On engine failure after a
        successful rectangle reservation, the rectangle (and a freshly
        created ``MemoryModel`` entry) is rolled back instead of leaking.

        Admission charges the instance's REAL decode-cache layout on top of
        ``framework_bytes``: ``n_kv_blocks x block_bytes`` for a paged
        instance, the dense ``max_batch x max_len`` slot pool otherwise —
        so a paged deployment with a tight block budget admits more
        replicas per node than its dense equivalent.
        """
        kv_bytes = model.kv_cache_bytes(
            batching=batching, max_batch=max_batch, max_len=max_len,
            block_size=block_size, n_kv_blocks=n_kv_blocks)
        created_mm = fn not in self._fn_mm
        mm = self._fn_mm.setdefault(
            fn, MemoryModel(weight_bytes=pytree_nbytes(params),
                            framework_bytes=framework_bytes + kv_bytes))
        if mm.framework_bytes != framework_bytes + kv_bytes:
            # The per-function MemoryModel is shared by all replicas; a
            # placement with a different data-plane config would silently
            # mis-account every node's footprint.
            raise ValueError(
                f"function {fn!r} already placed with a different "
                f"per-instance footprint ({mm.framework_bytes} vs "
                f"{framework_bytes + kv_bytes} bytes); one data-plane "
                f"config per function")

        def rollback_mm() -> None:
            if created_mm and not any(p.fn == fn for p in self.placements):
                del self._fn_mm[fn]

        pod_id = f"{fn}-{next(self._pod_seq)}"
        excluded: set[int] = set()
        while True:
            placement = self.pool.schedule(alloc, pod_id, exclude=excluded)
            if placement is None:
                rollback_mm()
                return None
            if self.admits(placement.node, fn, mm):
                break
            # Spillover: rectangle fit but memory admission failed on this
            # node — release and retry the remaining nodes.
            self.pool.release(placement)
            excluded.add(placement.node)
        try:
            inst_id = self.engines[placement.node].deploy(
                fn, model, params, alloc, n_instances=1,
                max_batch=max_batch, max_len=max_len, batching=batching,
                block_size=block_size, n_kv_blocks=n_kv_blocks)[0]
        except Exception:
            # The rectangle was reserved before the engine ran; a failed
            # deploy must not leak it (or a provisional memory-model entry).
            self.pool.release(placement)
            rollback_mm()
            raise
        self.placements.append(InstancePlacement(
            fn=fn, inst_id=inst_id, node=placement.node,
            placement=placement))
        return f"{placement.node}:{inst_id}"

    def deploy(self, fn: str, model: Model, params: Any, alloc: Alloc, *,
               n_instances: int = 1, max_batch: int = 4, max_len: int = 64,
               batching: str = "continuous",
               framework_bytes: int = DEFAULT_FRAMEWORK_BYTES,
               block_size: int = 16,
               n_kv_blocks: Optional[int] = None) -> list[str]:
        """Place ``n_instances`` of ``fn`` across the fleet via MRA +
        memory admission; returns ``node:inst_id`` handles."""
        handles = []
        for _ in range(n_instances):
            handle = self.place_instance(
                fn, model, params, alloc, max_batch=max_batch,
                max_len=max_len, batching=batching,
                framework_bytes=framework_bytes,
                block_size=block_size, n_kv_blocks=n_kv_blocks)
            if handle is None:
                raise RuntimeError(
                    f"no node can host {fn} at alloc {alloc} "
                    f"(rectangles or memory exhausted)")
            handles.append(handle)
        return handles

    def nodes_for(self, fn: str) -> list[int]:
        return sorted({p.node for p in self.placements if p.fn == fn})

    # -- request path ------------------------------------------------------

    def _fn_load(self, node: int, fn: str) -> int:
        eng = self.engines[node]
        return sum(inst.load() for key, inst in eng.instances.items()
                   if key.startswith(fn + "/"))

    def _live_nodes(self, fn: str) -> list[int]:
        """Nodes with at least one non-retired instance of ``fn``."""
        out = []
        for node in self.nodes_for(fn):
            eng = self.engines[node]
            if any(k.startswith(fn + "/") and not inst.retired
                   for k, inst in eng.instances.items()):
                out.append(node)
        return out

    def _pick_node(self, fn: str) -> int:
        """Join-shortest-queue node selection over live instances."""
        nodes = self._live_nodes(fn)
        if not nodes:
            raise KeyError(f"function {fn} is not deployed")
        return min(nodes, key=lambda n: self._fn_load(n, fn))

    def _enqueue(self, fn: str, req: ServeRequest) -> None:
        """Route an EXISTING request (drain re-route) the same way submit
        routes new ones: JSQ node, then JSQ live instance."""
        eng = self.engines[self._pick_node(fn)]
        cands = [v for k, v in eng.instances.items()
                 if k.startswith(fn + "/") and not v.retired]
        min(cands, key=lambda i: i.load()).queue.append(req)

    def submit(self, fn: str, prompt: np.ndarray, max_new_tokens: int = 8
               ) -> ServeRequest:
        node = self._pick_node(fn)
        record_arrival(self._arrival_log, self._rps_horizon, fn, self.now())
        # Second JSQ level across the chosen node's instances happens in
        # ServingEngine.submit.
        return self.engines[node].submit(fn, prompt, max_new_tokens)

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.engines)

    def pump(self, budget_s: float = 1.0, slice_s: float = 0.02) -> int:
        """Interleave the per-node schedulers until idle or out of budget."""
        completed = 0
        deadline = time.perf_counter() + budget_s
        while time.perf_counter() < deadline and self.has_work():
            for eng in self.engines:
                if eng.has_work():
                    completed += eng.pump(budget_s=slice_s)
        return completed

    # -- scale-down --------------------------------------------------------

    def evict(self, handle: str) -> None:
        """Gracefully retire the instance behind ``node:inst_id``.

        Queued (not yet admitted) requests are immediately re-routed to the
        function's surviving instances; occupied decode slots keep decoding
        until they finish.  The MRA rectangle and weight refcount are only
        released once the instance has fully drained (``on_instance_closed``
        fires from the engine pump)."""
        node_s, inst_id = handle.split(":", 1)
        node = int(node_s)
        fn = inst_id.split("/")[0]
        victim = self.engines[node].instances[inst_id]
        survivors = any(
            inst is not victim and not inst.retired
            for eng in self.engines for k, inst in eng.instances.items()
            if k.startswith(fn + "/"))
        # Last replica: keep its queue — it drains everything (queued AND
        # in-flight) before closing, so nothing is dropped.
        strays = self.engines[node].retire(inst_id,
                                           strip_queue=survivors)
        for req in strays:
            self._enqueue(fn, req)

    def _instance_closed(self, node: int, inst_id: str) -> None:
        """Engine callback: a retired instance finished draining."""
        for p in self.placements:
            if p.node == node and p.inst_id == inst_id:
                self.pool.release(p.placement)
                self.placements.remove(p)
                if not any(q.fn == p.fn for q in self.placements):
                    # Fully drained: drop the per-function MemoryModel so a
                    # redeploy may use a different data-plane config.
                    self._fn_mm.pop(p.fn, None)
                return

    # -- metrics -----------------------------------------------------------

    def observed_rps(self, fn: str, window: float) -> float:
        """Submit rate over the trailing wall-clock ``window`` seconds."""
        return observed_rate(self._arrival_log, self._rps_horizon,
                             fn, window, self.now())

    def inflight(self, fn: str) -> int:
        """Queued + slot-occupying requests across the function's
        instances (draining ones included)."""
        return sum(self._fn_load(node, fn) for node in self.nodes_for(fn))

    def occupancy(self, last_n: int = 10) -> float:
        live = [e for e in self.engines if e.instances]
        if not live:
            return 0.0
        return sum(e.scheduler.occupancy(last_n) for e in live) / len(live)

    def utilization(self, last_n: int = 10) -> float:
        live = [e for e in self.engines if e.instances]
        if not live:
            return 0.0
        return sum(e.scheduler.utilization(last_n) for e in live) / len(live)

    def memory_bytes(self) -> int:
        return sum(e.memory_bytes() for e in self.engines)

    def kv_bytes_in_use(self) -> int:
        """Physical KV bytes live requests hold across the fleet."""
        return sum(e.kv_bytes_in_use() for e in self.engines)

    def dense_kv_reserved(self) -> int:
        """Dense slot-pool reservation for the fleet's current capacity."""
        return sum(e.dense_kv_reserved() for e in self.engines)

    def recorder(self, fn: str):
        """Merged view is unnecessary: latency records live per node."""
        return [e.recorders[fn] for e in self.engines if fn in e.recorders]
