from repro.serving.engine import (FunctionInstance, ServeRequest,
                                  ServingEngine)

__all__ = ["ServingEngine", "FunctionInstance", "ServeRequest"]
