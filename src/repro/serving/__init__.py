from repro.serving.engine import (FunctionInstance, ServeRequest,
                                  ServingEngine)
from repro.serving.frontend import ClusterFrontend, InstancePlacement

__all__ = ["ServingEngine", "FunctionInstance", "ServeRequest",
           "ClusterFrontend", "InstancePlacement"]
