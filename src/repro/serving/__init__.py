from repro.serving.engine import (FunctionInstance, ServeRequest,
                                  ServingEngine)
from repro.serving.frontend import ClusterFrontend, InstancePlacement
from repro.serving.modelstore import (ColdStartEvent, FleetModelStore,
                                      HostWeightCache, StagedWeights,
                                      stage_params, upload_params)
from repro.serving.paging import (NULL_BLOCK, BlockExhausted,
                                  KVPageAllocator, PageTable, blocks_needed,
                                  prompt_digests)

__all__ = ["ServingEngine", "FunctionInstance", "ServeRequest",
           "ClusterFrontend", "InstancePlacement", "KVPageAllocator",
           "PageTable", "BlockExhausted", "NULL_BLOCK", "blocks_needed",
           "prompt_digests", "FleetModelStore", "HostWeightCache",
           "ColdStartEvent", "StagedWeights", "stage_params",
           "upload_params"]
