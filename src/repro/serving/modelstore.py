"""Fleet-level model store: a weight-swap cold-start tier.

The per-node :class:`repro.core.model_sharing.ModelStore` shares one
device-resident copy of a function's weights between co-located
instances (paper §3.5).  This module generalizes that into a *fleet*
tier, the Torpor/FaaSTube direction from the roadmap:

    device HBM  →  host RAM  →  peer node's host RAM  →  init from scratch

* ``FleetModelStore`` keeps a per-node host-RAM cache of *staged*
  weights (numpy shards, LRU with refcount pinning — a pod's live
  weights can never be evicted) and resolves every placement through
  the tier order above, counting hits, misses, and bytes moved.
* ``stage_params`` splits a param pytree into per-layer host shards
  (leaves stacked under a leading ``"layers"`` axis become one shard
  per layer); ``upload_params`` re-assembles them on device either
  ``"blocking"`` (full pytree resident before returning — the
  reference mode tests diff against) or ``"overlap"`` (one
  asynchronous ``jax.device_put`` per layer shard, left in flight, so
  instance creation and the first chunked-prefill admissions overlap
  the upload).  Both modes produce bit-identical values by
  construction.

The live frontend sources weights through ``acquire`` at placement
time; the control plane reads ``warm_nodes`` for warm-aware scale-up
and defrag targeting.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from time import perf_counter
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "StagedWeights",
    "stage_params",
    "upload_params",
    "HostWeightCache",
    "ColdStartEvent",
    "FleetModelStore",
]


def _name_leaves(model) -> Optional[list]:
    """Leaf-aligned logical names for ``model``'s params, or None."""
    try:
        names = model.param_names()
    except Exception:
        return None
    return jax.tree_util.tree_leaves(
        names, is_leaf=lambda x: isinstance(x, tuple)
    )


@dataclass
class StagedWeights:
    """A param pytree staged as host numpy shards.

    ``leaves[i]`` is either one ndarray (unstacked leaf) or, when
    ``stacked[i]``, a list of per-layer ndarrays split along the
    leading ``"layers"`` axis — the unit of pipelined upload.
    """

    treedef: Any
    leaves: List[Any]
    stacked: List[bool]
    nbytes: int

    def copy(self) -> "StagedWeights":
        """Deep host-to-host copy (the peer-transfer payload)."""
        leaves = [
            [shard.copy() for shard in leaf] if stacked else leaf.copy()
            for leaf, stacked in zip(self.leaves, self.stacked)
        ]
        return StagedWeights(self.treedef, leaves, list(self.stacked), self.nbytes)


def stage_params(model, params) -> StagedWeights:
    """Stage a device param pytree into per-layer host shards."""
    dev_leaves, treedef = jax.tree_util.tree_flatten(params)
    names = _name_leaves(model)
    if names is not None and len(names) != len(dev_leaves):
        names = None
    leaves: List[Any] = []
    stacked: List[bool] = []
    nbytes = 0
    for i, leaf in enumerate(dev_leaves):
        host = np.asarray(leaf)
        name = names[i] if names is not None else ()
        if name and name[0] == "layers" and host.ndim > 0 and host.shape[0] > 0:
            shards = [np.ascontiguousarray(host[j]) for j in range(host.shape[0])]
            leaves.append(shards)
            stacked.append(True)
            nbytes += sum(s.nbytes for s in shards)
        else:
            host = np.ascontiguousarray(host)
            leaves.append(host)
            stacked.append(False)
            nbytes += host.nbytes
    return StagedWeights(treedef, leaves, stacked, nbytes)


def _layer_sharding(s):
    """Sharding of one layer slice of a stacked leaf: drop the leading
    ``"layers"`` dim from the full leaf's PartitionSpec (it is never a
    sharded dim on the serving path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    if not isinstance(s, NamedSharding):
        return None
    return NamedSharding(s.mesh, P(*tuple(s.spec)[1:]))


def upload_params(staged: StagedWeights, *, mode: str = "overlap",
                  shardings: Optional[List[Any]] = None):
    """Re-assemble staged shards on device.

    ``"blocking"`` stacks layer shards on host and blocks until the
    full pytree is resident; ``"overlap"`` dispatches one asynchronous
    ``jax.device_put`` per layer shard (plus a device-side stack) and
    returns with the transfers still in flight — downstream jit
    tracing and the first prefill dispatch overlap the upload.  Values
    are identical either way.

    ``shardings`` (optional, leaf-aligned with ``staged.leaves``) gives
    each leaf's final ``jax.sharding.Sharding``: the upload then places
    every leaf — and in overlap mode every LAYER shard of a stacked
    leaf — straight onto its owning device(s), so a tensor-parallel
    pod's weights never round-trip the full tree through one device.
    """
    if mode not in ("blocking", "overlap"):
        raise ValueError(f"unknown upload mode {mode!r}")
    if shardings is not None and len(shardings) != len(staged.leaves):
        raise ValueError(
            f"shardings must align with staged leaves "
            f"({len(shardings)} vs {len(staged.leaves)})")
    out = []
    for i, (leaf, stacked) in enumerate(zip(staged.leaves, staged.stacked)):
        s = shardings[i] if shardings is not None else None
        if stacked:
            if mode == "blocking":
                # Re-assemble on host, then one synchronous transfer.
                host = np.stack(leaf)
                out.append(jnp.asarray(host) if s is None
                           else jax.device_put(host, s))
            else:
                # One async device_put per layer shard; the device-side
                # stack is dispatched, not executed, so the call returns
                # with the whole pipeline in flight.  With a sharding,
                # each layer shard is sliced host-side and lands on its
                # owning devices directly.
                layer_s = _layer_sharding(s) if s is not None else None
                parts = [jax.device_put(x) if layer_s is None
                         else jax.device_put(x, layer_s) for x in leaf]
                out.append(jnp.stack(parts))
        elif mode == "blocking":
            out.append(jnp.asarray(leaf) if s is None
                       else jax.device_put(leaf, s))
        else:
            out.append(jax.device_put(leaf) if s is None
                       else jax.device_put(leaf, s))
    params = jax.tree_util.tree_unflatten(staged.treedef, out)
    if mode == "blocking":
        params = jax.block_until_ready(params)
    return params


@dataclass
class _CacheEntry:
    staged: StagedWeights
    nbytes: int
    pins: int = 0


class HostWeightCache:
    """One node's host-RAM weight cache: byte-budgeted LRU with pinning.

    ``pin``/``unpin`` track live pods whose weights came from this
    entry; eviction only ever considers unpinned entries and refuses
    (raises ``MemoryError``) rather than evict a pinned one.
    """

    def __init__(self, capacity_bytes: int = 4 << 30):
        if capacity_bytes <= 0:
            raise ValueError("capacity_bytes must be positive")
        self.capacity_bytes = int(capacity_bytes)
        self._entries: "OrderedDict[str, _CacheEntry]" = OrderedDict()
        self.evictions = 0

    def contains(self, key: str) -> bool:
        return key in self._entries

    def keys(self):
        return list(self._entries)

    def used_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def pins(self, key: str) -> int:
        e = self._entries.get(key)
        return e.pins if e is not None else 0

    def get(self, key: str) -> StagedWeights:
        entry = self._entries[key]
        self._entries.move_to_end(key)
        return entry.staged

    def peek(self, key: str) -> Optional[StagedWeights]:
        entry = self._entries.get(key)
        return entry.staged if entry is not None else None

    def put(self, key: str, staged: StagedWeights) -> None:
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        self._evict_for(staged.nbytes)
        self._entries[key] = _CacheEntry(staged, staged.nbytes)

    def pin(self, key: str) -> None:
        entry = self._entries.get(key)
        if entry is not None:
            entry.pins += 1

    def unpin(self, key: str) -> None:
        entry = self._entries.get(key)
        if entry is not None and entry.pins > 0:
            entry.pins -= 1

    def drop(self, key: str) -> None:
        self._entries.pop(key, None)

    def clear(self) -> None:
        self._entries.clear()

    def _evict_for(self, need_bytes: int) -> None:
        free = self.capacity_bytes - self.used_bytes()
        if free >= need_bytes:
            return
        # LRU order: oldest unpinned first.
        for key in list(self._entries):
            if free >= need_bytes:
                break
            entry = self._entries[key]
            if entry.pins > 0:
                continue
            del self._entries[key]
            self.evictions += 1
            free += entry.nbytes
        if free < need_bytes:
            raise MemoryError(
                f"host weight cache over capacity: need {need_bytes - free} "
                "more bytes but remaining entries are pinned"
            )


@dataclass
class ColdStartEvent:
    """One placement's trip through the weight tier."""

    fn: str
    node: int
    tier: str  # "device" | "host" | "peer" | "cold"
    mode: str  # "blocking" | "overlap"
    nbytes: int
    upload_s: float  # host-side dispatch time of the upload
    peer: Optional[int] = None
    ttft_s: Optional[float] = None  # resolved by the frontend
    placed_at: float = field(default=0.0, repr=False)


class FleetModelStore:
    """The fleet weight tier: per-node host caches + warm lookup.

    ``acquire`` resolves one placement: device-resident weights are
    reused as-is; a host hit re-uploads from the node's own cache; a
    peer hit copies the staged shards from another node's cache first
    (counted in ``bytes_peer``); a cold miss stages from ``params`` or
    ``loader()``.  Every non-device tier pins the host entry until
    ``release`` — live pods' weights are never evictable.
    """

    def __init__(self, host_budget_bytes: int = 4 << 30, *,
                 links: Optional[Any] = None):
        self.host_budget_bytes = int(host_budget_bytes)
        # Optional NetworkLinks graph: peer selection then prefers the
        # candidate with the fastest link to the acquiring node instead
        # of the lowest node id (the frontend wires its own graph in).
        self.links = links
        self._caches: Dict[int, HostWeightCache] = {}
        self._lock = threading.Lock()
        self.device_hits = 0
        self.host_hits = 0
        self.peer_hits = 0
        self.cold_misses = 0
        self.bytes_h2d = 0
        self.bytes_peer = 0
        self.bytes_staged = 0
        self.events: List[ColdStartEvent] = []

    def _cache_for(self, node: int) -> HostWeightCache:
        cache = self._caches.get(node)
        if cache is None:
            cache = self._caches[node] = HostWeightCache(self.host_budget_bytes)
        return cache

    def warm_nodes(self, key: str) -> List[int]:
        """Nodes whose host cache holds ``key`` (ascending id)."""
        with self._lock:
            return sorted(n for n, c in self._caches.items() if c.contains(key))

    def staged_nbytes(self, key: str) -> Optional[int]:
        """Byte size of ``key``'s staged weights, from any node's cache."""
        with self._lock:
            for cache in self._caches.values():
                staged = cache.peek(key)
                if staged is not None:
                    return staged.nbytes
        return None

    def acquire(
        self,
        node: int,
        key: str,
        model,
        params=None,
        loader: Optional[Callable[[], Any]] = None,
        *,
        resident: bool = False,
        mode: str = "overlap",
        sharding_for: Optional[Callable[[tuple, tuple], Any]] = None,
    ):
        """Source ``key``'s weights for a placement on ``node``.

        Returns ``(device_params, ColdStartEvent)`` and pins the host
        entry backing them (pair with :meth:`release`).

        ``sharding_for(names, shape) -> Sharding`` (optional) resolves
        each param leaf's final placement — a sharded pod passes its
        mesh resolver here so the upload streams every layer shard
        straight to its owning device.
        """
        with self._lock:
            cache = self._cache_for(node)
            if resident:
                # Device tier: the node's engine ModelStore already holds
                # the pytree — ``params`` is returned untouched (it may be
                # None; the engine deploy ignores it on a store hit).
                self.device_hits += 1
                cache.pin(key)
                event = ColdStartEvent(key, node, "device", mode, 0, 0.0,
                                       placed_at=perf_counter())
                self.events.append(event)
                return params, event

            peer = None
            if cache.contains(key):
                tier = "host"
                self.host_hits += 1
                staged = cache.get(key)
            else:
                cands = [n for n in sorted(self._caches)
                         if n != node and self._caches[n].contains(key)]
                if self.links is not None and cands:
                    # Bandwidth-aware: pull from the warm peer with the
                    # fastest link to this node (ties to lowest id).
                    peer = self.links.best_peer(node, cands)
                else:
                    peer = cands[0] if cands else None
                if peer is not None:
                    tier = "peer"
                    self.peer_hits += 1
                    staged = self._caches[peer].peek(key).copy()
                    self.bytes_peer += staged.nbytes
                    cache.put(key, staged)
                else:
                    tier = "cold"
                    self.cold_misses += 1
                    if params is None and loader is None:
                        raise ValueError(
                            f"cold miss for {key!r} with neither params "
                            "nor a loader")
                    source = params if params is not None else loader()
                    staged = stage_params(model, source)
                    self.bytes_staged += staged.nbytes
                    cache.put(key, staged)
            cache.pin(key)

        shardings = None
        if sharding_for is not None:
            names = _name_leaves(model)
            if names is not None and len(names) == len(staged.leaves):
                shardings = [
                    sharding_for(nm, ((len(leaf),) + leaf[0].shape)
                                 if st else leaf.shape)
                    for nm, leaf, st in zip(names, staged.leaves,
                                            staged.stacked)
                ]
        t0 = perf_counter()
        device_params = upload_params(staged, mode=mode,
                                      shardings=shardings)
        upload_s = perf_counter() - t0
        with self._lock:
            self.bytes_h2d += staged.nbytes
            event = ColdStartEvent(key, node, tier, mode, staged.nbytes,
                                   upload_s, peer=peer, placed_at=perf_counter())
            self.events.append(event)
        return device_params, event

    def release(self, node: int, key: str) -> None:
        """Unpin one placement's hold on ``key``'s host entry."""
        with self._lock:
            cache = self._caches.get(node)
            if cache is not None:
                cache.unpin(key)

    def drop_node(self, node: int) -> None:
        """A node died: its host RAM (and every pin on it) is gone."""
        with self._lock:
            cache = self._caches.pop(node, None)
            if cache is not None:
                cache.clear()

    def cache(self, node: int) -> HostWeightCache:
        with self._lock:
            return self._cache_for(node)

    def telemetry(self) -> dict:
        with self._lock:
            return {
                "device_hits": self.device_hits,
                "host_hits": self.host_hits,
                "peer_hits": self.peer_hits,
                "cold_misses": self.cold_misses,
                "bytes_h2d": self.bytes_h2d,
                "bytes_peer": self.bytes_peer,
                "bytes_staged": self.bytes_staged,
                "host_used_bytes": {
                    n: c.used_bytes() for n, c in self._caches.items()
                },
                "events": len(self.events),
            }
