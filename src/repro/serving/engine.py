"""Live serving engine: FaST-GShare data plane over real JAX executors.

This is the paper's serving stack made real on this container, one engine
per node:

* **Model sharing (§3.5)** — N instances of a function share ONE param
  pytree through the ``ModelStore``; the runtime never copies weights.
* **FaST-Manager (§3.3)** — every instance's dispatch loop is gated by the
  node's ``TokenScheduler``; wall-clock step times feed ``Q_used`` exactly
  as the paper's CUDA-event accounting does (DESIGN.md §2).
* **Continuous (slot-level) batching** — each ``FunctionInstance`` owns a
  fixed pool of ``max_batch`` decode slots backed by a persistent per-slot
  KV cache (``Model.init_slot_cache``).  A finished request frees its slot
  *immediately*; queued requests are admitted mid-flight by prefilling
  them individually and merging their cache entries into the live decode
  batch at the freed slot index (``Model.merge_slot``).  Token-granted
  decode steps therefore stay full whenever there is queued work — the
  property the paper's throughput wins depend on.  ``batching="static"``
  keeps the old retire-together semantics as a reference implementation
  (the equivalence tests decode both ways and compare token streams).
* **Block-paged KV (``batching="paged"``)** — the slot pool's dense
  ``max_len`` rows are replaced by physical blocks of ``block_size``
  tokens handed out by a ``KVPageAllocator``; admission budgets FREE
  BLOCKS (a request needs ``ceil((prompt + new_tokens - 1)/block_size)``)
  instead of just free slots, and a finished/drained request releases its
  blocks immediately, so short requests stop stranding the memory the
  MRA/``MemoryModel`` admission charged for them.  Decode walks per-slot
  block tables (``Model.decode_step_paged``); token streams are
  bit-identical to the dense path.  See ``serving/README.md`` for the
  block-table layout.
* **Sync-free decode hot path (``fused=True``, the default)** — one decode
  round is a single donated, fused jitted call: the greedy sampler runs on
  device (``Model.decode_step_tokens`` returns ``(B,)`` int32 tokens, the
  ``(B, V)`` logits never cross to the host), the KV pool / token vector /
  paged position vector are donated so XLA updates them in place instead
  of copying the cache every round, and the paged block tables + positions
  stay device-resident (host mirrors are only touched on admit / release /
  migrate and re-uploaded once when dirty).  Each instance splits a step
  into ``dispatch_step`` (enqueue the round, no host pull) and
  ``sync_step`` (ONE host synchronisation for everything the pass
  dispatched), which lets ``ServingEngine.pump`` dispatch every co-located
  instance's round before pulling any of their results — N pods pipeline
  on one device instead of ping-ponging through Python.  ``fused=False``
  keeps the old host-side argmax path as the bit-identical reference.

Topology: a ``ServingEngine`` is one node; ``repro.serving.frontend``
routes requests across several engines (join-shortest-queue) and places
functions onto nodes with the same MRA + memory-model admission the
simulator uses, so the live path mirrors ``repro.core.cluster`` end to
end.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.manager import TokenScheduler
from repro.core.model_sharing import ModelStore
from repro.core.resources import Alloc
from repro.core.slo import SLORecorder
from repro.distributed.sharding import serve_pspec, shard_put, use_mesh
from repro.models.model import Model, default_kv_blocks
from repro.serving.paging import (NULL_BLOCK, KVPageAllocator, PageTable,
                                  blocks_needed, prompt_digests)
from repro.serving.speculative import (GREEDY, SamplingConfig, SpecConfig,
                                       spec_round_continuous,
                                       spec_round_paged)


def _bucket_len(n: int) -> int:
    """Smallest power of two >= n (prefill padding bucket)."""
    return 1 << max(n - 1, 0).bit_length()


def _executor(model: Model, key: tuple, build) -> Any:
    """Per-model shared jit wrapper: ``jax.jit`` caches compiled
    executables per *wrapper object*, so per-instance wrappers would pay
    a fresh trace + compile on every deployment.  Sharing them across
    instances (keyed on the model, stored on it so the cache dies with
    it) is what makes a warm node warm in the cold-start sense: it holds
    the function's compiled executors, not just its weights.  Donation
    is per-call semantics, so shared donated wrappers are safe.

    ``build`` must jit a FRESH function object (a lambda), never a bound
    method directly: jax shares its trace cache across jit wrappers of
    the same underlying function, and a sharded pod's mesh constraints
    are baked into the jaxpr at trace time — a bound-method trace from
    one device group would silently serve every other group's executor
    and fail on the first mismatched device set."""
    cache = model.__dict__.setdefault("_jit_executors", {})
    fn = cache.get(key)
    if fn is None:
        fn = cache[key] = build()
    return fn


# Model-independent: scatter one sampled token into the donated vector.
_SET_TOK = jax.jit(lambda t, s, v: t.at[s].set(v), donate_argnums=(0,))


def per_device_bytes(*trees: Any) -> dict[int, int]:
    """Resident bytes per device id across ``trees`` (``None`` entries are
    skipped), via each leaf's ``addressable_shards`` — so a tensor-parallel
    leaf charges each device only its shard, while a replicated leaf
    charges its full size on every device.  The benchmark's per-shard HBM
    high-watermark accounting."""
    out: dict[int, int] = {}
    for tree in trees:
        if tree is None:
            continue
        for leaf in jax.tree_util.tree_leaves(tree):
            if not isinstance(leaf, jax.Array):
                continue
            for shard in leaf.addressable_shards:
                d = int(shard.device.id)
                out[d] = out.get(d, 0) + int(shard.data.nbytes)
    return out


@dataclasses.dataclass
class ServeRequest:
    req_id: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 8
    submitted_at: float = 0.0
    tokens_out: list = dataclasses.field(default_factory=list)
    done: bool = False
    finished_at: float = 0.0
    # SLO lifecycle (inert by default): absolute deadline on the engine
    # clock (None = no deadline), the tier it was admitted under, a typed
    # outcome when the request terminates without completing
    # (shed/expired/rejected/failed — see repro.core.slo), and how many
    # times it has been re-routed after a failure.
    deadline: Optional[float] = None
    tier: str = "best_effort"
    outcome: Optional[str] = None
    attempts: int = 0


class FunctionInstance:
    """One FaSTPod-equivalent: jitted prefill/decode with shared weights.

    ``batching="continuous"`` (default): a fixed pool of ``max_batch``
    decode slots; every step first admits queued requests into free slots
    (chunked prefill + slot merge), then advances all occupied slots one
    token.  ``batching="static"``: the legacy batch that only re-fills
    once every member finishes — kept as the reference semantics.

    ``fused=True`` (default for the slot modes) runs the sync-free hot
    path: a step is dispatched by ``dispatch_step`` (no host round-trip)
    and completed by ``sync_step`` (one blocking pull for the whole pass);
    ``run_step`` chains the two for callers that want the old synchronous
    protocol.  ``fused=False`` restores the host-side argmax reference —
    token streams are bit-identical either way.
    """

    def __init__(self, inst_id: str, model: Model, store: ModelStore,
                 weights_key: str, alloc: Alloc, *, max_batch: int = 4,
                 max_len: int = 64, batching: str = "continuous",
                 prefill_buckets: bool = True, block_size: int = 16,
                 n_kv_blocks: Optional[int] = None, fused: bool = True,
                 prefix_sharing: bool = True,
                 sampling: Optional[SamplingConfig] = None,
                 speculate: Optional[SpecConfig] = None,
                 draft_model: Optional[Model] = None,
                 draft_key: Optional[str] = None,
                 mesh: Optional[Any] = None):
        if batching not in ("continuous", "static", "paged"):
            raise ValueError(f"unknown batching mode {batching!r}")
        if sampling is not None and batching == "static":
            raise ValueError("stochastic sampling requires a slot batching "
                             "mode (continuous/paged)")
        if mesh is not None and speculate is not None:
            raise ValueError(
                "speculate cannot ride a sharded pod: the draft/verify "
                "round is not tensor-parallel (FunctionSpec forbids it)")
        # Tensor-parallel pod: every executor runs under this mesh so the
        # models' named() constraints bind at trace time, and the executor
        # cache key gets a mesh suffix.  ``key + ()`` IS ``key``, so a
        # shards=1 instance hits the exact single-device cache entries —
        # no re-trace, byte-identical dispatch.
        self.mesh = mesh
        self._mkey = (() if mesh is None else
                      ("tp", tuple(int(d.id) for d in mesh.devices.flat)))

        def _jit(owner: Model, key: tuple, build) -> Any:
            fn = _executor(owner, key + self._mkey, build)
            if mesh is None:
                return fn

            def sharded(*a, _fn=fn, **kw):
                with use_mesh(mesh):
                    return _fn(*a, **kw)
            return sharded

        self.inst_id = inst_id
        self.model = model
        self.alloc = alloc
        self.max_batch = max_batch
        self.max_len = max_len
        self.batching = batching
        self.fused = fused and batching != "static"
        self.store = store
        self.weights_key = weights_key
        self.params = store.get(weights_key)  # shared, zero-copy
        self.queue: deque[ServeRequest] = deque()
        self._prefill = _jit(model, ("prefill", max_len), lambda:
                                  jax.jit(lambda p, t: model.prefill(
                                      p, t, max_len=max_len)))
        # Bucketed chunked admission: prompts are right-padded to power-of-
        # two buckets so the jitted prefill sees O(log max_len) distinct
        # shapes instead of one per prompt length (each a recompile).
        self.bucketed = (batching in ("continuous", "paged")
                         and prefill_buckets
                         and model.supports_bucketed_prefill())
        self._prefill_len = _jit(model, ("prefill_len", max_len),
                                      lambda: jax.jit(
                                          lambda p, t, n: model.prefill(
                                              p, t, max_len=max_len,
                                              length=n))
                                      ) if self.bucketed else None
        self._decode = _jit(model, ("decode",),
                                 lambda: jax.jit(lambda *a: model.decode_step(*a)))
        # Fused executors: the decode round samples on device and returns
        # (B,) int32 tokens; the token vector and the whole KV pool are
        # DONATED — after dispatch the old buffers are dead and XLA writes
        # the new round in place (no per-round cache copy).  Never alias a
        # donated buffer after dispatch (serving/README.md "Hot path").
        self._decode_tok = _jit(model, ("decode_tok",), lambda:
                                     jax.jit(lambda *a: model.decode_step_tokens(*a),
                                             donate_argnums=(1, 2)))
        self._greedy = _jit(model, ("greedy",),
                                 lambda: jax.jit(lambda *a: model.sample_greedy(*a)))
        self._set_tok = _SET_TOK
        # The slot pool is donated on merge/append too: admitting a request
        # scatters its prefill entry into the pool in place.
        self._merge = _jit(model, ("merge",), lambda:
                                jax.jit(lambda *a: model.merge_slot(*a),
                                        donate_argnums=(0,)))
        self.steps = 0
        self.retired = False  # draining: no new routing, slots finish
        self.paused = False   # migrating: no admission, no decode
        # Wall-clock of the FIRST token this instance ever landed on a
        # request — the cold-start tier's time-to-first-token anchor.
        self.first_token_at: Optional[float] = None
        # continuous state: slot i holds the request decoding in cache row i.
        self.slots: list[Optional[ServeRequest]] = [None] * max_batch
        self._slot_tok = np.zeros((max_batch,), np.int32)
        self.cache: Optional[Any] = None  # slot pool / static batch cache
        # static state
        self.active: list[ServeRequest] = []
        self.refills = 0  # mid-flight slot admissions (continuous only)
        self.last_fill = 0  # slots that did work in the latest step
        # Prefix sharing (paged only): admission matches prompt-block
        # digests against resident pages; divergence resolves by COW.
        self.prefix_sharing = prefix_sharing and batching == "paged"
        self.shared_block_hits = 0  # resident blocks mapped, not re-written
        self.cow_count = 0          # divergent appends resolved by a copy
        # -- sync-free hot-path state (fused modes) -------------------------
        self.sync_count = 0  # host synchronisation points (telemetry)
        self.uploads = 0     # paged table/pos uploads (dirty-flag telemetry)
        self._slot_tok_dev: Optional[jax.Array] = None  # (B,) device tokens
        # Deferred results of the in-flight pass: (req, (1,) device token,
        # slot or None for done-at-prefill) plus the decode round's
        # ((B,) device tokens, active-slot snapshot).
        self._pending_prefill: list[tuple[ServeRequest, Any,
                                          Optional[int]]] = []
        self._round: Optional[tuple[Any, list[int]]] = None
        self._host_finished: list[ServeRequest] = []  # non-fused stash
        # paged state: host-side block tables + positions are the MIRRORS;
        # the jitted decode consumes device-resident copies that are only
        # re-uploaded when admit/release/migrate dirtied the host side.
        if batching == "paged":
            if not model.supports_paged():
                raise ValueError(
                    f"{model.cfg.name}: batching='paged' needs a full-cache "
                    f"dense/moe config")
            if block_size <= 0 or max_len % block_size:
                raise ValueError(
                    "block_size must be positive and divide max_len")
            self.block_size = block_size
            self.blocks_per_seq = max_len // block_size
            n_blocks = (n_kv_blocks if n_kv_blocks is not None
                        else default_kv_blocks(max_batch, max_len,
                                               block_size))
            self._block_bytes = model.kv_block_bytes(block_size)
            self.allocator = KVPageAllocator(n_blocks, block_size,
                                             block_bytes=self._block_bytes)
            self.pages = PageTable(self.allocator)
            self._tables = np.full((max_batch, self.blocks_per_seq),
                                   NULL_BLOCK, np.int32)
            self._pos = np.zeros((max_batch,), np.int32)
            self._decode_paged = _jit(
                model, ("decode_paged",),
                lambda: jax.jit(lambda *a: model.decode_step_paged(*a)))
            self._decode_paged_tok = _jit(
                model, ("decode_paged_tok",),
                lambda: jax.jit(lambda *a: model.decode_step_paged_tokens(*a),
                                donate_argnums=(1, 2, 4)))
            self._append = _jit(
                model, ("append",),
                lambda: jax.jit(lambda *a: model.append_paged(*a), donate_argnums=(0,)))
            self._copy_block = _jit(
                model, ("copy_block",),
                lambda: jax.jit(lambda *a: model.copy_block(*a), donate_argnums=(0,)))
            self._tables_dev: Optional[jax.Array] = None
            self._pos_dev: Optional[jax.Array] = None
            self._active_dev: Optional[jax.Array] = None
            self._state_dirty = True
        # -- stochastic sampling + speculative decoding ---------------------
        # The PRNG key is device state threaded through the fused round and
        # donated like the token vector; the fused=False reference replays
        # the identical split sequence eagerly, so sampled token streams
        # diff bit-identical between the paths.
        self.sampling = sampling
        self.speculate = speculate
        self.draft_model = draft_model
        self.draft_key = draft_key
        self.draft_params: Optional[Any] = None
        self.dcache: Optional[Any] = None  # draft slot-cache side pool
        self.spec_proposed = 0  # draft tokens proposed (telemetry)
        self.spec_accepted = 0  # draft tokens accepted (telemetry)
        self._round_spec: Optional[tuple[Any, Any]] = None
        self._key_dev: Optional[jax.Array] = None
        if sampling is not None or speculate is not None:
            seed = sampling.seed if sampling is not None else speculate.seed
            self._key_dev = jax.random.PRNGKey(seed)
        if sampling is not None:
            self._sample = _jit(
                model, ("sample", sampling),
                lambda: jax.jit(lambda l, k: model.sample_tokens(l, k,
                                                                 sampling)))
            self._decode_tok_s = _jit(
                model, ("decode_tok_sampled", sampling),
                lambda: jax.jit(
                    lambda p, t, c, k: model.decode_step_tokens(
                        p, t, c, key=k, sampling=sampling),
                    donate_argnums=(1, 2, 3)))
            if batching == "paged":
                self._decode_paged_tok_s = _jit(
                    model, ("decode_paged_tok_sampled", sampling),
                    lambda: jax.jit(
                        lambda p, t, c, tb, pos, act, k:
                        model.decode_step_paged_tokens(
                            p, t, c, tb, pos, act, key=k, sampling=sampling),
                        donate_argnums=(1, 2, 4, 6)))
        if speculate is not None:
            if not self.fused or batching == "static":
                raise ValueError(
                    "speculate requires the fused continuous/paged hot path "
                    "(the draft/verify loop is a single donated round)")
            if not model.supports_speculative():
                raise ValueError(
                    f"{model.cfg.name}: speculative verify needs a "
                    f"full-cache dense/moe target (no int8 KV)")
            if draft_model is None or draft_key is None:
                raise ValueError("speculate needs a draft model + weights "
                                 "key (engine.deploy builds them)")
            if not draft_model.supports_speculative():
                raise ValueError(
                    f"{draft_model.cfg.name}: the draft must be a "
                    f"full-cache dense/moe config")
            if draft_model.cfg.vocab_size != model.cfg.vocab_size:
                raise ValueError("draft and target must share vocab_size")
            self.draft_params = store.get(draft_key)
            samp = sampling if sampling is not None else GREEDY
            build = (spec_round_paged if batching == "paged"
                     else spec_round_continuous)
            donate = (2, 3, 4, 6, 8) if batching == "paged" else (2, 3, 4, 5)
            self._spec_round = _jit(
                model, ("spec_round", batching, speculate.k, samp,
                        draft_model.cfg.name),
                lambda: jax.jit(build(model, draft_model, speculate.k, samp),
                                donate_argnums=donate))
            self._dprefill = _jit(
                draft_model, ("prefill", max_len),
                lambda: jax.jit(lambda p, t: draft_model.prefill(
                    p, t, max_len=max_len)))
            self._dprefill_len = _jit(
                draft_model, ("prefill_len", max_len),
                lambda: jax.jit(lambda p, t, n: draft_model.prefill(
                    p, t, max_len=max_len, length=n))
            ) if self.bucketed else None
            self._dmerge = _jit(
                draft_model, ("merge",),
                lambda: jax.jit(draft_model.merge_slot, donate_argnums=(0,)))

    def close(self) -> None:
        if self.batching == "paged":
            self.pages.release_all()  # defensive: drained closes freed all
        if self.draft_params is not None:
            self.store.put_back(self.draft_key)
        self.store.put_back(self.weights_key)

    # -- KV accounting -----------------------------------------------------

    def kv_bytes_in_use(self) -> int:
        """Physical KV bytes currently held by live requests (paged) or
        reserved by the allocated pool (dense slot modes)."""
        if self.batching == "paged":
            return self.pages.bytes_in_use(self._block_bytes)
        return (self.model.dense_kv_bytes(self.max_batch, self.max_len)
                if self.cache is not None else 0)

    def dense_kv_reserved(self) -> int:
        """What the dense slot pool would reserve for this instance's
        capacity — the baseline the paged pool is measured against."""
        return self.model.dense_kv_bytes(self.max_batch, self.max_len)

    @property
    def kv_bytes_peak(self) -> int:
        """Peak physical KV bytes.  Paged: the allocator's block
        high-watermark times block bytes — updated at every allocation
        instead of sampled once per dispatch (the old sampling could miss
        a transient peak between steps), and consistent with refcounted
        sharing: a block mapped by N sequences is one physical block,
        charged once.  Dense modes report the slot-pool reservation."""
        if self.batching != "paged":
            return self.dense_kv_reserved() if self.cache is not None else 0
        return self.allocator.bytes_high_watermark

    def kv_bytes_saved(self) -> int:
        """Bytes prefix sharing is saving right now vs the unshared paged
        plane (extra references minus reserved COW spares, in bytes)."""
        if self.batching != "paged":
            return 0
        return self.pages.bytes_saved(self._block_bytes)

    def has_work(self) -> bool:
        return bool(self.queue) or self.n_active() > 0

    def n_active(self) -> int:
        if self.batching == "static":
            return len(self.active)
        return sum(1 for r in self.slots if r is not None)

    def load(self) -> int:
        """Queue depth + occupied slots (join-shortest-queue metric)."""
        return len(self.queue) + self.n_active()

    def acceptance_rate(self) -> float:
        """Measured draft-token acceptance fraction (0 when the instance
        is not speculating or has not completed a round yet)."""
        if not self.spec_proposed:
            return 0.0
        return self.spec_accepted / self.spec_proposed

    def _clip_tok(self, tok: np.ndarray) -> np.ndarray:
        return np.minimum(tok, self.model.cfg.vocab_size - 1)

    def _mark_first_token(self) -> None:
        """Record the instant the instance's first token became visible
        host-side (every token-landing path calls this)."""
        if self.first_token_at is None:
            self.first_token_at = time.perf_counter()

    # -- device-resident decode state (fused path) --------------------------

    def _tok_dev(self) -> jax.Array:
        """Device-resident per-slot token vector; re-uploaded from the host
        mirror only after migration touched it (``None`` invalidates)."""
        if self._slot_tok_dev is None:
            self._slot_tok_dev = jnp.asarray(self._slot_tok)
        return self._slot_tok_dev

    def _upload_paged_state(self) -> None:
        """Push dirtied host mirrors (tables / positions / active mask) to
        the device — once per admit/release/migrate burst, NOT per round."""
        mask = np.zeros((self.max_batch,), np.int32)
        for slot, req in enumerate(self.slots):
            if req is not None:
                mask[slot] = 1
        self._tables_dev = jnp.asarray(self._tables)
        self._pos_dev = jnp.asarray(self._pos)
        self._active_dev = jnp.asarray(mask)
        self._state_dirty = False
        self.uploads += 1

    def _init_cache(self) -> Any:
        """Fresh slot/paged KV pool, placed on the pod's mesh when the
        instance is sharded: kv-heads split over the tensor axis when they
        divide it, everything else replicated — the bitwise-safe default
        (no cross-device reduction touches the logits).  The sequence-
        sharded slab layout is the opt-in ``distributed.seqshard`` seam."""
        if self.batching == "paged":
            cache = self.model.init_paged_cache(self.allocator.n_blocks,
                                                self.block_size)
            if self.mesh is not None:
                cache = shard_put(
                    cache, self.model.paged_cache_names(
                        self.allocator.n_blocks, self.block_size), self.mesh)
            return cache
        cache = self.model.init_slot_cache(self.max_batch, self.max_len)
        if self.mesh is not None:
            names = dict(self.model.cache_names(self.max_batch,
                                                self.max_len))
            names["pos"] = (None,)  # slot pool pos is (n_slots,), not ()
            cache = shard_put(cache, names, self.mesh)
        return cache

    def hbm_bytes_by_device(self) -> dict[int, int]:
        """Per-device resident bytes of this instance's weights + KV pool
        (+ draft side pool), by ``addressable_shards`` — the per-shard HBM
        high-watermark a sharded pod is benchmarked on."""
        return per_device_bytes(self.params, self.cache, self.draft_params,
                                self.dcache)

    # -- continuous path ---------------------------------------------------

    def _prefill_one(self, prompt: np.ndarray):
        """Prefill one prompt, right-padded to its bucket when enabled."""
        n = int(prompt.shape[0])
        if self.bucketed and n < self.max_len:
            pl = min(_bucket_len(n), self.max_len)
            if pl > n:
                padded = np.zeros((pl,), np.int32)
                padded[:n] = prompt
                prompt = padded
            return self._prefill_len(self.params,
                                     jnp.asarray(prompt[None], jnp.int32),
                                     jnp.int32(n))
        return self._prefill(self.params, jnp.asarray(prompt[None], jnp.int32))

    def _dprefill_one(self, prompt: np.ndarray):
        """Draft-model prefill for speculative admission (same bucketing
        discipline as the target's)."""
        n = int(prompt.shape[0])
        if self.bucketed and n < self.max_len:
            pl = min(_bucket_len(n), self.max_len)
            if pl > n:
                padded = np.zeros((pl,), np.int32)
                padded[:n] = prompt
                prompt = padded
            return self._dprefill_len(self.draft_params,
                                      jnp.asarray(prompt[None], jnp.int32),
                                      jnp.int32(n))
        return self._dprefill(self.draft_params,
                              jnp.asarray(prompt[None], jnp.int32))

    def _admit_draft(self, slot: int, req: ServeRequest) -> None:
        """Prefill the draft model and merge its entry into the draft slot
        cache — both async enqueues, sharing the pass's single sync."""
        _, dentry = self._dprefill_one(req.prompt)
        if self.dcache is None:
            self.dcache = self.draft_model.init_slot_cache(self.max_batch,
                                                           self.max_len)
        self.dcache = self._dmerge(self.dcache, dentry, jnp.int32(slot))

    def _spec_k(self, max_new_tokens: int) -> int:
        """Extra KV rows a speculating request can write past the plain
        ``prompt + max_new - 1``: the last verify window starts at most at
        row ``prompt + max_new - 2`` and writes k rows beyond it.  Zero
        for requests that finish at prefill (they never enter a round)."""
        if self.speculate is None or max_new_tokens <= 1:
            return 0
        return self.speculate.k

    def _kv_rows_needed(self, req: ServeRequest) -> int:
        """KV rows a request writes over its lifetime: the prompt plus one
        row per decode round (the final token is emitted, never cached),
        plus the speculation margin for the verify window's overhang."""
        return (int(req.prompt.shape[0]) + req.max_new_tokens - 1
                + self._spec_k(req.max_new_tokens))

    def _plan_paged_admission(self, req: ServeRequest
                              ) -> tuple[int, tuple]:
        """Blocks a paged admission must ALLOCATE for ``req``, plus its
        prefix-sharing plan ``(full_digests, tail_digest, shared_full,
        tail_block)``.

        The charge is ``blocks_needed - matched full blocks``: a shared
        full block costs nothing (it is resident and immutable), while a
        shared prompt-tail block trades its block for a reserved COW
        spare — memory-neutral, charged as one block either way.
        """
        total = blocks_needed(self._kv_rows_needed(req), self.block_size)
        if not self.prefix_sharing:
            return total, ([], None, [], None)
        full, tail_digest = prompt_digests(req.prompt, self.block_size)
        shared, tail_block = self.pages.match_prefix(full, tail_digest)
        return total - len(shared), (full, tail_digest, shared, tail_block)

    def _assert_writes_exclusive(self, append_row: np.ndarray) -> None:
        """Host-side write contract of ``Model.append_paged`` /
        ``paged_cache_write``: every block the scatter will actually
        write must be exclusively owned (refcount 1) — shared blocks are
        mapped read-only and must never be written."""
        for b in append_row:
            b = int(b)
            if b == NULL_BLOCK or b >= self.allocator.n_blocks:
                continue  # null page / drop sentinel: no live write
            assert self.allocator.refcount(b) == 1, (
                f"append would write block {b} with refcount "
                f"{self.allocator.refcount(b)} (shared blocks are "
                f"read-only)")

    def _map_paged_request(self, slot: int, req: ServeRequest, entry: Any,
                           plan: tuple) -> None:
        """Bind a slot's pages (shared prefix + private rest), publish its
        prompt digests, and scatter its prefill entry into the PRIVATE
        blocks only: shared prefix rows are already resident, so their
        entries go to the append drop sentinel and are never written."""
        rows = self._kv_rows_needed(req)
        full_digests, tail_digest, shared, tail_block = plan
        shared_all = shared + ([tail_block] if tail_block is not None
                               else [])
        if shared_all:
            self.pages.allocate_shared(slot, rows, shared_all,
                                       tail_shared=tail_block is not None)
            self.shared_block_hits += len(shared_all)
        else:
            self.pages.allocate(slot, rows)
        if self.prefix_sharing:
            self.pages.register_prefix(slot, full_digests, tail_digest)
        row = self.pages.row(slot, self.blocks_per_seq)
        self._tables[slot] = row
        self._pos[slot] = int(req.prompt.shape[0])
        self._state_dirty = True
        append_row = np.asarray(row, np.int32).copy()
        drop = self.allocator.n_blocks  # positive OOB -> scatter drops it
        append_row[:len(shared_all)] = drop  # resident prefix: read-only
        append_row[len(self.pages.blocks(slot)):] = drop  # padding rows
        self._assert_writes_exclusive(append_row)
        self.cache = self._append(self.cache, entry,
                                  jnp.asarray(append_row))

    def _admit(self) -> list[ServeRequest]:
        """Chunked admission: prefill queued requests one at a time into
        free slots and merge their caches into the live decode batch.

        Paged mode budgets FREE BLOCKS, not just free slots: the head of
        the queue is admitted only when the allocator can cover its whole
        lifetime (prompt + decode rows), so a mid-flight pool exhaustion
        is impossible and admission stays FIFO under block pressure.

        Fused mode never pulls the prefill argmax to the host here: the
        device token is scattered into the slot-token vector in-jit and
        queued for the pass's single ``sync_step`` pull.  The returned
        list holds the requests this admission completed (done at
        prefill) — in fused mode they are *counted* for fill accounting
        but only marked done at sync.
        """
        finished = []
        paged = self.batching == "paged"
        # A refill = joining a batch that was already decoding before this
        # step; cold-start co-admissions in the same pass don't count.
        had_live = self.n_active() > 0
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            head = self.queue[0]
            plan = ([], None, [], None)
            if paged and head.max_new_tokens > 1:
                need, plan = self._plan_paged_admission(head)
                if not self.allocator.can_alloc(need):
                    break  # head-of-line waits for retiring blocks
            req = self.queue.popleft()
            logits, entry = self._prefill_one(req.prompt)
            if self.sampling is not None:
                # Same eager split in the fused and reference paths, so the
                # key stream (one split per admitted prefill, one per
                # round) is identical and sampled streams diff
                # bit-identical.  The split is async — no host pull.
                self._key_dev, sub = jax.random.split(self._key_dev)
                tok_dev = self._sample(logits, sub)
            else:
                tok_dev = self._greedy(logits)  # (1,) int32, stays on device
            if self.fused:
                done_at_prefill = (len(req.tokens_out) + 1
                                   >= req.max_new_tokens)
                self._pending_prefill.append(
                    (req, tok_dev, None if done_at_prefill else slot))
                if done_at_prefill:
                    finished.append(req)  # completed by sync_step
                    continue  # slot stays free for the next queued request
            else:
                self.sync_count += 1
                tok = int(np.asarray(tok_dev)[0])
                req.tokens_out.append(tok)
                self._mark_first_token()
                if len(req.tokens_out) >= req.max_new_tokens:
                    req.done = True
                    finished.append(req)
                    continue
            if self.cache is None:
                self.cache = self._init_cache()
            if had_live:
                self.refills += 1  # joined a live decode batch mid-flight
            if paged:
                # Sequences are keyed by SLOT, not req_id: slots are unique
                # within the instance and always released before reuse,
                # whereas req_ids from different engines can collide when
                # an evict re-routes queued requests across nodes.
                self._map_paged_request(slot, req, entry, plan)
            else:
                self.cache = self._merge(self.cache, entry, jnp.int32(slot))
            if self.speculate is not None:
                self._admit_draft(slot, req)
            self.slots[slot] = req
            if self.fused:
                self._slot_tok_dev = self._set_tok(
                    self._tok_dev(), jnp.int32(slot), tok_dev[0])
            else:
                self._slot_tok[slot] = tok  # type: ignore[possibly-undefined]
        return finished

    def _advance_slot(self, slot: int, tok: int) -> Optional[ServeRequest]:
        """Land one decode round's token on an occupied slot: append it,
        refresh the host mirrors (slot token; paged position, matching the
        in-jit ``pos + active``), and free the slot — paged blocks
        included — when the request finishes.  Returns the request iff
        this token completed it.  The single finish sequence shared by the
        fused sync and both host-argmax reference rounds."""
        req = self.slots[slot]
        req.tokens_out.append(tok)
        self._mark_first_token()
        self._slot_tok[slot] = tok
        if self.batching == "paged":
            self._pos[slot] += 1
        if len(req.tokens_out) >= req.max_new_tokens:
            req.done = True
            self.slots[slot] = None  # freed immediately for refill
            if self.batching == "paged":
                self._release_paged(slot)  # blocks reusable NOW
            return req
        return None

    def _sample_host(self, logits) -> np.ndarray:
        """Reference-path sampler: replay the fused round's in-jit
        ``split(key) -> sample`` sequence eagerly on the same key stream,
        so ``fused=False`` sampled tokens are bit-identical."""
        self._key_dev, sub = jax.random.split(self._key_dev)
        return np.asarray(self._sample(logits, sub), np.int32)

    def _decode_round_continuous(self) -> list[ServeRequest]:
        """Host-side argmax reference round (``fused=False``)."""
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self._slot_tok), self.cache)
        self.sync_count += 1
        if self.sampling is not None:
            next_tok = self._sample_host(logits)
        else:
            next_tok = self._clip_tok(
                np.asarray(jnp.argmax(logits, axis=-1), np.int32))
        finished = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue  # free slot decoded garbage; ignore it
            done = self._advance_slot(slot, int(next_tok[slot]))
            if done is not None:
                finished.append(done)
        return finished

    def _release_paged(self, slot: int) -> None:
        """Free a finished slot's blocks and park the slot on the null
        block so its garbage decode writes land in the trash page."""
        self.pages.release(slot)
        self._tables[slot] = NULL_BLOCK
        self._pos[slot] = 0
        self._state_dirty = True

    def _cow_round(self) -> None:
        """Resolve copy-on-write before a decode round's writes land.

        Every occupied slot's next append position is checked against the
        COW rule: a position inside a shared (refcount > 1) prompt-tail
        block pops that block's reserved spare, copies the device page
        (``Model.copy_block``), and re-points the slot's block table —
        the first divergent append then writes the private copy.  The
        closing assert is the host-side half of the paged write contract:
        after this pass, no dispatched write can touch a shared block.

        A speculating round writes a W = k+1 row window instead of one
        row, so COW resolves for EVERY block the window can touch
        (``pos .. pos+k``) — speculative rejection rollback is then a pure
        position trim: rejected rows land in exclusively-owned blocks,
        nothing is freed, and no shared/COW block is ever written.
        """
        span = 1 + (self.speculate.k if self.speculate is not None else 0)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            pos = int(self._pos[slot])
            first = pos // self.block_size
            last = (pos + span - 1) // self.block_size
            for idx in range(first, last + 1):
                block, moved = self.pages.writable_block(
                    slot, idx * self.block_size)
                if moved is not None:
                    old, new = moved
                    self.cache = self._copy_block(self.cache, jnp.int32(old),
                                                  jnp.int32(new))
                    self._tables[slot][idx] = new
                    self._state_dirty = True
                    self.cow_count += 1
                assert self.allocator.refcount(block) == 1

    def _decode_round_paged(self) -> list[ServeRequest]:
        """Host-side argmax reference round (``fused=False``)."""
        logits, self.cache = self._decode_paged(
            self.params, jnp.asarray(self._slot_tok), self.cache,
            jnp.asarray(self._tables), jnp.asarray(self._pos))
        self.sync_count += 1
        if self.sampling is not None:
            next_tok = self._sample_host(logits)
        else:
            next_tok = self._clip_tok(
                np.asarray(jnp.argmax(logits, axis=-1), np.int32))
        finished = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue  # free slot decoded into the null block; ignore it
            done = self._advance_slot(slot, int(next_tok[slot]))
            if done is not None:
                finished.append(done)
        return finished

    # -- fused round: dispatch now, sync once per pass ----------------------

    def _dispatch_round(self) -> None:
        """Enqueue one fused decode round on the device — no host pull.

        The token vector, KV pool, and (paged) position vector are donated
        to the call and immediately replaced by the returned buffers; the
        results land in ``self._round`` for ``sync_step``.
        """
        active = [s for s, r in enumerate(self.slots) if r is not None]
        if self.speculate is not None:
            # Draft-k -> verify-1 in ONE donated jitted round: the k draft
            # steps, the W=k+1 verify forward, on-device rejection
            # sampling, and the per-slot position advance all ride the
            # pass's single sync (sync_step pulls the (B, k+1) window +
            # (B,) acceptance counts instead of a (B,) token vector).
            if self.batching == "paged":
                if self._state_dirty:
                    self._upload_paged_state()
                (tok, self.cache, self.dcache, self._pos_dev, out, n_emit,
                 self._key_dev) = self._spec_round(
                    self.params, self.draft_params, self._tok_dev(),
                    self.cache, self.dcache, self._tables_dev,
                    self._pos_dev, self._active_dev, self._key_dev)
            else:
                (tok, self.cache, self.dcache, out, n_emit,
                 self._key_dev) = self._spec_round(
                    self.params, self.draft_params, self._tok_dev(),
                    self.cache, self.dcache, self._key_dev)
            self._slot_tok_dev = tok
            self._round = (tok, active)
            self._round_spec = (out, n_emit)
            return
        if self.batching == "paged":
            if self._state_dirty:
                self._upload_paged_state()
            if self.sampling is not None:
                (tok, self.cache, self._pos_dev,
                 self._key_dev) = self._decode_paged_tok_s(
                    self.params, self._tok_dev(), self.cache,
                    self._tables_dev, self._pos_dev, self._active_dev,
                    self._key_dev)
            else:
                tok, self.cache, self._pos_dev = self._decode_paged_tok(
                    self.params, self._tok_dev(), self.cache,
                    self._tables_dev, self._pos_dev, self._active_dev)
        elif self.sampling is not None:
            tok, self.cache, self._key_dev = self._decode_tok_s(
                self.params, self._tok_dev(), self.cache, self._key_dev)
        else:
            tok, self.cache = self._decode_tok(
                self.params, self._tok_dev(), self.cache)
        self._slot_tok_dev = tok  # device-resident input of the next round
        self._round = (tok, active)

    def dispatch_step(self) -> bool:
        """Dispatch one token-gated step WITHOUT any host synchronisation.

        Fused modes enqueue admission prefills and the decode round and
        return immediately (JAX async dispatch keeps the device busy while
        the caller dispatches sibling instances); the host-synchronous
        reference modes (``static``, ``fused=False``) execute the step in
        full and stash its completions.  Either way ``sync_step`` finishes
        the pass.  Returns False when paused (nothing dispatched).
        """
        if self.paused:
            # Mid-migration: admission and decode are frozen — the KV pool
            # is being gathered out from under the slots.
            return False
        self.steps += 1
        if self.batching == "static":
            if self.active:
                self.last_fill = sum(1 for r in self.active if not r.done)
                self._host_finished = self._decode_round_static()
            else:
                finished = self._admit_static()
                self.last_fill = len(self.active) or len(finished)
                self._host_finished = finished
            return True
        finished = self._admit()
        self.last_fill = self.n_active() + len(finished)
        if (self.batching == "paged" and self.prefix_sharing
                and self.n_active() > 0):
            # COW must resolve before this round's writes dispatch —
            # both the fused round below and the host-argmax reference.
            self._cow_round()
        if self.fused:
            if self.n_active() > 0:
                self._dispatch_round()
            return True
        if self.n_active() > 0:
            finished += (self._decode_round_paged()
                         if self.batching == "paged"
                         else self._decode_round_continuous())
        self._host_finished = finished
        return True

    def sync_step(self) -> list[ServeRequest]:
        """Complete the dispatched pass with ONE host synchronisation.

        Pulls every deferred device token (admission prefills + the decode
        round) in a single blocking point, appends them to their requests,
        refreshes the host mirrors (slot tokens, paged positions), and
        releases finished slots.  Returns the requests the pass completed.
        """
        if not self.fused:
            finished, self._host_finished = self._host_finished, []
            return finished
        if not self._pending_prefill and self._round is None:
            return []
        self.sync_count += 1  # the pass's single synchronisation point
        arrays = [t for _, t, _ in self._pending_prefill]
        if self._round is not None:
            arrays.append(self._round[0])
        if self._round_spec is not None:
            arrays.extend(self._round_spec)
        jax.block_until_ready(arrays)
        finished = []
        for req, tok_dev, slot in self._pending_prefill:
            tok = int(np.asarray(tok_dev)[0])
            req.tokens_out.append(tok)
            self._mark_first_token()
            if slot is None:  # whole request served by its prefill
                req.done = True
                finished.append(req)
            else:
                self._slot_tok[slot] = tok  # host mirror (migration seam)
        self._pending_prefill = []
        if self._round is not None and self._round_spec is not None:
            # Speculative round: land the accepted window per slot.  A slot
            # that reaches max_new mid-window is released immediately and
            # its surplus tokens dropped — the device position overshot,
            # but release resets the mirrors (paged: dirty re-upload;
            # continuous: the next merge overwrites the slot's pos).
            _, active = self._round
            out_dev, n_dev = self._round_spec
            self._round = self._round_spec = None
            out_np = np.asarray(out_dev)
            n_np = np.asarray(n_dev)
            for slot in active:
                n = int(n_np[slot])
                self.spec_proposed += self.speculate.k
                self.spec_accepted += n - 1
                done = None
                for t in out_np[slot, :n]:
                    done = self._advance_slot(slot, int(t))
                    if done is not None:
                        break
                if done is not None:
                    finished.append(done)
        elif self._round is not None:
            tok_dev, active = self._round
            self._round = None
            toks = np.asarray(tok_dev)
            for slot in active:
                done = self._advance_slot(slot, int(toks[slot]))
                if done is not None:
                    finished.append(done)
        return finished

    # -- migration seam (pause -> gather -> merge) --------------------------

    def export_slot(self, slot: int) -> tuple[ServeRequest, Any, int]:
        """Gather one occupied slot's full decode state for migration:
        ``(request, batch-1 cache entry, last emitted token)``.

        Paged slots are re-gathered to the dense batch-1 layout
        (``Model.gather_pages``) so the entry is portable to any target
        instance, whatever physical blocks it has free.  Valid only
        between pump passes (every dispatched round synced): the host
        mirrors are refreshed by ``sync_step``.
        """
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} of {self.inst_id} is empty")
        if self.speculate is not None:
            raise ValueError(
                f"{self.inst_id}: speculating slots cannot be exported — "
                f"the draft side cache does not travel (migrate gate)")
        if self.batching == "paged":
            entry = self.model.gather_pages(
                self.cache, jnp.asarray(self._tables[slot]),
                int(self._pos[slot]))
        else:
            entry = self.model.gather_slot(self.cache, jnp.int32(slot))
        return req, entry, int(self._slot_tok[slot])

    def import_slot(self, slot: int, req: ServeRequest, entry: Any,
                    tok: int) -> None:
        """Merge an exported slot into this instance at ``slot`` — the
        exact inverse of :meth:`export_slot`, so a migrated request's
        remaining decode rounds produce bit-identical tokens."""
        if self.batching == "static":
            raise ValueError("static batches cannot absorb migrated slots")
        if self.slots[slot] is not None:
            raise ValueError(f"slot {slot} of {self.inst_id} is occupied")
        paged = self.batching == "paged"
        if self.cache is None:
            self.cache = self._init_cache()
        if paged:
            # Same worst-case reservation admission made on the source, so
            # the migrated request can never exhaust the pool mid-flight.
            # Prefix sharing re-establishes across a migrated cohort: FULL
            # prompt blocks match/register on the target (bit-identical —
            # cohort members shared the same physical pages on the
            # source), but the prompt-tail block stays private: the
            # gathered entry already holds decode rows past the prompt at
            # its tail offsets, which a later sharer must never see.
            rows = self._kv_rows_needed(req)
            full_digests: list = []
            if self.prefix_sharing:
                full_digests, _ = prompt_digests(req.prompt, self.block_size)
            shared, _ = self.pages.match_prefix(full_digests, None)
            if shared:
                self.pages.allocate_shared(slot, rows, shared)
                self.shared_block_hits += len(shared)
            else:
                self.pages.allocate(slot, rows)
            if self.prefix_sharing:
                self.pages.register_prefix(slot, full_digests, None)
            row = self.pages.row(slot, self.blocks_per_seq)
            self._tables[slot] = row
            self._pos[slot] = int(entry["pos"])
            self._state_dirty = True
            append_row = np.asarray(row, np.int32).copy()
            drop = self.allocator.n_blocks
            append_row[:len(shared)] = drop  # resident prefix: read-only
            append_row[len(self.pages.blocks(slot)):] = drop  # padding
            self._assert_writes_exclusive(append_row)
            self.cache = self._append(self.cache, entry,
                                      jnp.asarray(append_row))
        else:
            self.cache = self._merge(self.cache, entry, jnp.int32(slot))
        self.slots[slot] = req
        self._slot_tok[slot] = tok
        self._slot_tok_dev = None  # host mirror changed: re-upload lazily

    # -- static reference path ---------------------------------------------

    def _admit_static(self) -> list[ServeRequest]:
        batch = []
        while self.queue and len(batch) < self.max_batch:
            batch.append(self.queue.popleft())
        if not batch:
            return []
        prompts = np.stack([r.prompt for r in batch])
        logits, cache = self._prefill(self.params,
                                      jnp.asarray(prompts, jnp.int32))
        self.sync_count += 1
        next_tok = self._clip_tok(
            np.asarray(jnp.argmax(logits, axis=-1), np.int32))
        finished = []
        for r, t in zip(batch, next_tok):
            r.tokens_out.append(int(t))
            self._mark_first_token()
            if len(r.tokens_out) >= r.max_new_tokens:
                r.done = True
                finished.append(r)
        self.active = batch
        self.cache = cache
        self._retire_static_if_done()
        return finished

    def _decode_round_static(self) -> list[ServeRequest]:
        # Finished members keep their row in the batch (that is the point
        # of static batching) but stop accumulating tokens.
        toks = jnp.asarray([r.tokens_out[-1] for r in self.active], jnp.int32)
        logits, self.cache = self._decode(self.params, toks, self.cache)
        self.sync_count += 1
        next_tok = self._clip_tok(
            np.asarray(jnp.argmax(logits, axis=-1), np.int32))
        finished = []
        for r, t in zip(self.active, next_tok):
            if r.done:
                continue
            r.tokens_out.append(int(t))
            if len(r.tokens_out) >= r.max_new_tokens:
                r.done = True
                finished.append(r)
        self._retire_static_if_done()
        return finished

    def _retire_static_if_done(self) -> None:
        # Static-batch semantics: the batch retires together once ALL
        # members finish; no slot is re-filled mid-flight.
        if self.active and all(r.done for r in self.active):
            self.active = []
            self.cache = None

    # -- one token-gated step ----------------------------------------------

    def run_step(self) -> list[ServeRequest]:
        """One token-gated step; returns requests completed by it.

        ``dispatch_step`` + ``sync_step`` back to back — the synchronous
        protocol for callers outside the overlapping engine pump.
        """
        if not self.dispatch_step():
            return []
        return self.sync_step()


class ServingEngine:
    """One node: token scheduler + N weight-shared instances."""

    def __init__(self, window: float = 0.2, idle_sleep_s: float = 0.001):
        self.scheduler = TokenScheduler(window=window)
        self.store = ModelStore()
        self.instances: dict[str, FunctionInstance] = {}
        self.recorders: dict[str, SLORecorder] = {}
        self.alive = True
        self._req_ids = itertools.count()
        self._inst_seq = itertools.count()
        self._t0 = time.perf_counter()
        # Quota-blocked idle lull: how long pump yields when a pass grants
        # nothing and the previous pass did no work.  0 disables the sleep
        # entirely (soak/chaos benchmarks run hot).
        self.idle_sleep_s = idle_sleep_s
        # Fault-injection hook: an artificial per-pass stall (seconds)
        # inside the timed dispatch region — the chaos harness's straggler
        # lever.  0 (default) is a no-op.
        self.pump_delay_s = 0.0
        # Gray-failure quarantine: routing and placement stop, occupants
        # keep draining through pump.  One-way, set by the frontend.
        self.quarantined = False
        # Pass-latency EWMAs for the health score: the fast one tracks the
        # current regime, the slow one the long-run baseline; their ratio
        # is the gray-failure signal (1.0 healthy, -> 0 degraded).
        self._lat_fast = 0.0
        self._lat_slow = 0.0
        # Per-instance expired-in-queue counts (telemetry).
        self._expired: dict[str, int] = {}
        # Scale-down hook: called with the instance id once a retired
        # instance has fully drained and released its resources (the
        # frontend uses it to release the MRA rectangle).
        self.on_instance_closed: Optional[Any] = None

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def deploy(self, fn: str, model: Model, params: Any, alloc: Alloc, *,
               n_instances: int = 1, max_batch: int = 4, max_len: int = 64,
               batching: str = "continuous", prefill_buckets: bool = True,
               block_size: int = 16, n_kv_blocks: Optional[int] = None,
               fused: bool = True, prefix_sharing: bool = True,
               sampling: Optional[SamplingConfig] = None,
               speculate: Optional[SpecConfig] = None,
               draft_params: Any = None,
               mesh: Optional[Any] = None) -> list[str]:
        if not self.alive:
            raise RuntimeError("cannot deploy to a failed node")
        if fn not in self.recorders:
            self.recorders[fn] = SLORecorder(fn=fn)
        # A sharded pod's weights live under their own store entry keyed by
        # the tensor degree, so shards=1 replicas of the same function keep
        # sharing the intact single-device tree.  shard_put is a no-op for
        # leaves the modelstore already uploaded to their owning devices.
        weights_key = fn if mesh is None else f"{fn}@tp{mesh.devices.size}"
        if not self.store.contains(weights_key):
            if mesh is not None:
                params = shard_put(params, model.param_names(), mesh,
                                   resolver=serve_pspec)
            self.store.store(weights_key, params)
        draft_model = None
        draft_key = None
        if speculate is not None:
            from repro.models.model import build_model
            # Draft models are cached per function so their shared jit
            # executors (stored on the Model object) survive redeploys.
            cache = self.__dict__.setdefault("_draft_models", {})
            draft_model = cache.get(fn)
            if draft_model is None:
                draft_model = cache[fn] = build_model(speculate.draft_cfg)
            draft_key = f"{fn}#draft"
            if not self.store.contains(draft_key):
                if draft_params is None:
                    raise ValueError(
                        f"{fn}: speculate set but no draft weights staged "
                        f"(pass draft_params on the first deploy)")
                self.store.store(draft_key, draft_params)
        ids = []
        for _ in range(n_instances):
            inst_id = f"{fn}/{next(self._inst_seq)}"
            inst = FunctionInstance(inst_id, model, self.store, weights_key,
                                    alloc,
                                    max_batch=max_batch, max_len=max_len,
                                    batching=batching,
                                    prefill_buckets=prefill_buckets,
                                    block_size=block_size,
                                    n_kv_blocks=n_kv_blocks, fused=fused,
                                    prefix_sharing=prefix_sharing,
                                    sampling=sampling, speculate=speculate,
                                    draft_model=draft_model,
                                    draft_key=draft_key, mesh=mesh)
            self.instances[inst_id] = inst
            self.scheduler.register(inst_id, alloc)
            ids.append(inst_id)
        return ids

    # -- scale-down (graceful drain) ---------------------------------------

    def retire(self, inst_id: str,
               strip_queue: bool = True) -> list[ServeRequest]:
        """Stop routing to an instance; returns its queued (not yet
        admitted) requests for the caller to re-route.  Occupied decode
        slots keep decoding under the token scheduler until they finish;
        the instance then closes (weights refcount released, scheduler
        deregistered) and ``on_instance_closed`` fires.

        ``strip_queue=False`` keeps queued requests with the instance — for
        the last replica of a function, which must drain its own queue
        before closing (there is nowhere to re-route)."""
        inst = self.instances[inst_id]
        inst.retired = True
        strays: list[ServeRequest] = []
        if strip_queue:
            strays = list(inst.queue)
            inst.queue.clear()
        if not inst.has_work():
            self._close(inst_id)
        return strays

    def _close(self, inst_id: str) -> None:
        inst = self.instances.pop(inst_id)
        self.scheduler.deregister(inst_id)
        inst.close()
        if self.on_instance_closed is not None:
            self.on_instance_closed(inst_id)

    # -- node failure (crash, no drain) ------------------------------------

    def fail(self) -> list[tuple[str, ServeRequest]]:
        """Simulate a node crash: every instance dies instantly — no drain,
        no ``on_instance_closed`` callbacks, weights and KV gone.

        Returns the stranded unfinished requests as ``(fn, request)``
        pairs, queued and slot-occupying alike.  A slot occupant's partial
        output is reset: its KV died with the node, so a surviving replica
        must re-execute it from the prompt (greedy decode reproduces the
        identical stream).
        """
        self.alive = False
        strays: list[tuple[str, ServeRequest]] = []
        for inst_id, inst in self.instances.items():
            fn = inst_id.split("/")[0]
            occupants = (inst.active if inst.batching == "static"
                         else inst.slots)
            for req in occupants:
                if req is None or req.done:
                    continue
                req.tokens_out = []  # KV lost: re-execute from scratch
                strays.append((fn, req))
            strays.extend((fn, req) for req in inst.queue)
        self.instances.clear()
        self.scheduler.pods.clear()  # crash: tokens die mid-hold
        self.store = ModelStore()    # node memory (weights, KV) is gone
        return strays

    def submit(self, fn: str, prompt: np.ndarray, max_new_tokens: int = 8,
               deadline: Optional[float] = None, tier: str = "best_effort",
               attempts: int = 0) -> ServeRequest:
        req = ServeRequest(req_id=next(self._req_ids), prompt=prompt,
                           max_new_tokens=max_new_tokens,
                           submitted_at=self.now(), deadline=deadline,
                           tier=tier, attempts=attempts)
        # Join-shortest-queue across the function's live instances (retired
        # ones are draining, paused ones are mid-migration: no new work).
        candidates = [v for k, v in self.instances.items()
                      if k.startswith(fn + "/") and not v.retired
                      and not v.paused]
        if not candidates:
            raise KeyError(f"function {fn} has no instances")
        inst = min(candidates, key=lambda i: i.load())
        # Reject requests that can never fit the instance's cache up front:
        # a dense cache would clamp writes past max_len (silent corruption),
        # a paged one would out-grow its block-table row mid-admission —
        # or, worse, head-of-line livelock on a pool smaller than the
        # request's lifetime (nothing in flight to ever free blocks).
        rows = (int(prompt.shape[0]) + max_new_tokens - 1
                + inst._spec_k(max_new_tokens))
        if rows > inst.max_len:
            raise ValueError(
                f"request needs {rows} KV rows (prompt "
                f"{int(prompt.shape[0])} + {max_new_tokens} new tokens + "
                f"{inst._spec_k(max_new_tokens)} speculation margin) > "
                f"max_len {inst.max_len} of {inst.inst_id}")
        if (inst.batching == "paged" and max_new_tokens > 1
                and blocks_needed(rows, inst.block_size)
                > inst.allocator.capacity):
            raise ValueError(
                f"request needs {blocks_needed(rows, inst.block_size)} KV "
                f"blocks > pool capacity {inst.allocator.capacity} of "
                f"{inst.inst_id}; raise n_kv_blocks or shorten the request")
        self.enqueue(inst, req)
        return req

    @staticmethod
    def enqueue(inst: FunctionInstance, req: ServeRequest) -> None:
        """Queue with the batch lane preempted: a non-batch request inserts
        ahead of parked batch-tier work; uniform tiers reduce to a plain
        FIFO append (the bit-identical legacy order)."""
        if req.tier != "batch":
            idx = next((i for i, r in enumerate(inst.queue)
                        if r.tier == "batch"), None)
            if idx is not None:
                inst.queue.insert(idx, req)
                return
        inst.queue.append(req)

    def has_work(self) -> bool:
        return any(i.has_work() for i in self.instances.values())

    def pump(self, budget_s: float = 1.0, *, overlap: bool = True) -> int:
        """Run token-gated dispatch until idle or budget exhausted.

        ``overlap=True`` (default) pipelines co-located instances: every
        granted instance's round is DISPATCHED first (JAX async dispatch
        queues the work and returns), then a second pass performs each
        instance's single host sync — so instance B's kernels execute
        while Python is still dispatching C and pulling A.
        ``overlap=False`` is the serialized reference (dispatch + sync one
        instance at a time) that ``benchmarks/decode_throughput.py``
        measures the overlap win against.
        """
        if not self.alive:
            return 0
        completed = 0
        deadline = time.perf_counter() + budget_s
        worked_last_pass = False
        while time.perf_counter() < deadline:
            self._expire_queued()
            any_work = False
            for inst_id, inst in list(self.instances.items()):
                if inst.has_work() and not inst.paused:
                    any_work = True
                    self.scheduler.request_token(inst_id, self.now())
            if not any_work:
                break
            granted = self.scheduler.dispatch(self.now())
            if not granted:
                # Quota-blocked, not idle: when the previous pass did real
                # work we are saturated and the next scheduling window is
                # imminent — spin instead of yielding mid-burst.  Only a
                # genuinely idle lull sleeps.
                if not worked_last_pass and self.idle_sleep_s > 0:
                    time.sleep(self.idle_sleep_s)
                worked_last_pass = False
                continue
            worked_last_pass = True
            t_prev = time.perf_counter()
            if self.pump_delay_s > 0:
                # Injected straggler stall: lands inside the timed region,
                # so it inflates the pass latency the health EWMAs see and
                # the Q_used the scheduler charges — a slow node looks
                # slow everywhere, exactly like the real gray failure.
                time.sleep(self.pump_delay_s)
            if overlap:
                # Only fused instances join the early dispatch pass: their
                # dispatch_step is a cheap async enqueue.  Host-synchronous
                # modes (static, fused=False) execute their whole round in
                # dispatch_step, so they run in the sync pass where their
                # compute is timed against their own Q_used, not the
                # first-synced sibling's.
                for token in granted:
                    inst = self.instances[token.pod_id]
                    if inst.fused:
                        inst.dispatch_step()
            # Sync pass: each instance's elapsed is the wall-clock delta to
            # its sync point — the clock starts BEFORE the dispatch pass,
            # so the full pass wall time (host dispatch overhead included,
            # exactly what the serialized path charged) is apportioned
            # across the overlapped instances without double-charging
            # Q_used; the first-synced instance absorbs the (cheap,
            # enqueue-only) dispatch leg.
            for token in granted:
                inst = self.instances[token.pod_id]
                if not overlap or not inst.fused:
                    inst.dispatch_step()
                finished = inst.sync_step()
                t_now = time.perf_counter()
                elapsed = t_now - t_prev
                t_prev = t_now
                self._observe_pass(elapsed)
                # Drained occupancy scales with slot fill: an underfilled
                # decode round cannot saturate the instance's SM share.
                occ = token.occ * min(inst.last_fill / inst.max_batch, 1.0)
                self.scheduler.complete(token.pod_id, elapsed, self.now(),
                                        occ=occ)
                fn = token.pod_id.split("/")[0]
                for r in finished:
                    r.finished_at = self.now()
                    met = (None if r.deadline is None
                           else r.finished_at <= r.deadline)
                    self.recorders[fn].record(r.finished_at - r.submitted_at,
                                              r.finished_at,
                                              deadline_met=met)
                    completed += 1
                if inst.retired and not inst.has_work():
                    self._close(token.pod_id)  # drained: release resources
        return completed

    def _expire_queued(self) -> None:
        """Drop queued non-guaranteed requests whose deadline has passed
        (typed outcome ``"expired"``) before spending a decode slot on
        them.  A no-op while every queued request is deadline-free."""
        now = self.now()
        for inst_id, inst in self.instances.items():
            if not inst.queue:
                continue
            kept, dropped = [], []
            for r in inst.queue:
                if (r.deadline is not None and r.tier != "guaranteed"
                        and now > r.deadline):
                    dropped.append(r)
                else:
                    kept.append(r)
            if not dropped:
                continue
            fn = inst_id.split("/")[0]
            for r in dropped:
                r.done = True
                r.outcome = "expired"
                r.finished_at = now
                self._expired[inst_id] = self._expired.get(inst_id, 0) + 1
                if fn in self.recorders:
                    self.recorders[fn].record_expired()
            inst.queue.clear()
            inst.queue.extend(kept)

    def _observe_pass(self, elapsed: float) -> None:
        """Feed one pump-pass latency into the fast/slow EWMAs."""
        if self._lat_slow == 0.0:
            self._lat_fast = self._lat_slow = elapsed
            return
        self._lat_fast = 0.6 * self._lat_fast + 0.4 * elapsed
        self._lat_slow = 0.98 * self._lat_slow + 0.02 * elapsed

    def health(self) -> float:
        """Node health score in (0, 1]: the slow/fast pass-latency EWMA
        ratio.  1.0 while pass latency tracks its long-run baseline; a node
        whose recent passes run Nx slower scores ~1/N.  A dead node is 0."""
        if not self.alive:
            return 0.0
        if self._lat_fast <= self._lat_slow or self._lat_fast == 0.0:
            return 1.0
        return self._lat_slow / self._lat_fast

    def memory_bytes(self) -> int:
        return self.store.used_bytes()

    def kv_bytes_in_use(self) -> int:
        """Physical KV bytes live requests hold across this node."""
        return sum(i.kv_bytes_in_use() for i in self.instances.values())

    def dense_kv_reserved(self) -> int:
        """What dense slot pools would reserve for the same capacity."""
        return sum(i.dense_kv_reserved() for i in self.instances.values())

    def kv_bytes_saved(self) -> int:
        """Bytes prefix sharing is saving across this node's instances."""
        return sum(i.kv_bytes_saved() for i in self.instances.values())

    # -- hot-path telemetry -------------------------------------------------

    def sync_counts(self) -> dict[str, int]:
        """Per-instance host-synchronisation counts.  The fused hot path's
        budget is exactly ONE per instance per pump pass (prefill argmaxes
        and the decode round share it); the host-argmax reference spends
        one per admitted prompt plus one per round."""
        return {k: v.sync_count for k, v in self.instances.items()}

    def telemetry(self) -> dict[str, dict[str, int]]:
        """Hot-path counters per instance: steps, host syncs, (paged)
        device-state uploads — ``uploads << steps`` proves the block
        tables/positions stay device-resident between admission events —
        plus prefix-sharing hits and COW resolutions and the count of
        queued requests expired past their deadline."""
        return {k: {"steps": v.steps, "syncs": v.sync_count,
                    "uploads": v.uploads, "shared_hits": v.shared_block_hits,
                    "cow": v.cow_count, "spec_proposed": v.spec_proposed,
                    "spec_accepted": v.spec_accepted,
                    "expired": self._expired.get(k, 0)}
                for k, v in self.instances.items()}
