"""Live serving engine: FaST-GShare control plane over real JAX executors.

This is the paper's data plane made real on this container: N instances of
a function share ONE param pytree through the ``ModelStore`` (model
sharing, §3.5), each instance's dispatch loop is gated by the node's
``TokenScheduler`` (FaST-Manager, §3.3), and requests flow through dynamic
batching with continuous decode.

One engine == one node.  Wall-clock step times feed ``Q_used`` exactly as
the paper's CUDA-event accounting does (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.manager import TokenScheduler
from repro.core.model_sharing import ModelStore
from repro.core.resources import Alloc
from repro.core.slo import SLORecorder
from repro.models.model import Model


@dataclasses.dataclass
class ServeRequest:
    req_id: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 8
    submitted_at: float = 0.0
    tokens_out: list = dataclasses.field(default_factory=list)
    done: bool = False
    finished_at: float = 0.0


class FunctionInstance:
    """One FaSTPod-equivalent: jitted prefill/decode with shared weights."""

    def __init__(self, inst_id: str, model: Model, store: ModelStore,
                 weights_key: str, alloc: Alloc, *, max_batch: int = 4,
                 max_len: int = 64):
        self.inst_id = inst_id
        self.model = model
        self.alloc = alloc
        self.max_batch = max_batch
        self.max_len = max_len
        self.store = store
        self.weights_key = weights_key
        self.params = store.get(weights_key)  # shared, zero-copy
        self.queue: deque[ServeRequest] = deque()
        self.active: list[ServeRequest] = []
        self._prefill = jax.jit(
            lambda p, t: model.prefill(p, t, max_len=max_len))
        self._decode = jax.jit(model.decode_step)
        self.cache: Optional[Any] = None
        self.steps = 0

    def close(self) -> None:
        self.store.put_back(self.weights_key)

    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    def run_step(self) -> list[ServeRequest]:
        """One token-gated step: batch prefill or one decode round.

        Returns requests completed by this step.
        """
        self.steps += 1
        if self.active:
            return self._decode_round()
        batch = []
        while self.queue and len(batch) < self.max_batch:
            batch.append(self.queue.popleft())
        if not batch:
            return []
        prompts = np.stack([r.prompt for r in batch])
        logits, cache = self._prefill(self.params,
                                      jnp.asarray(prompts, jnp.int32))
        next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        next_tok = np.minimum(next_tok, self.model.cfg.vocab_size - 1)
        for r, t in zip(batch, next_tok):
            r.tokens_out.append(int(t))
        self.active = batch
        self.cache = cache
        return []

    def _decode_round(self) -> list[ServeRequest]:
        toks = jnp.asarray([r.tokens_out[-1] for r in self.active], jnp.int32)
        logits, self.cache = self._decode(self.params, toks, self.cache)
        next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        next_tok = np.minimum(next_tok, self.model.cfg.vocab_size - 1)
        finished = []
        for r, t in zip(self.active, next_tok):
            r.tokens_out.append(int(t))
            if len(r.tokens_out) >= r.max_new_tokens:
                r.done = True
                finished.append(r)
        if any(r.done for r in self.active):
            # Static-batch semantics: the batch retires together once all
            # members finish (continuous batching would re-fill slots; kept
            # simple here — the cluster sim models slot-level batching).
            if all(r.done for r in self.active):
                self.active = []
                self.cache = None
        return finished


class ServingEngine:
    """One node: token scheduler + N weight-shared instances."""

    def __init__(self, window: float = 0.2):
        self.scheduler = TokenScheduler(window=window)
        self.store = ModelStore()
        self.instances: dict[str, FunctionInstance] = {}
        self.recorders: dict[str, SLORecorder] = {}
        self._req_ids = itertools.count()
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def deploy(self, fn: str, model: Model, params: Any, alloc: Alloc, *,
               n_instances: int = 1, max_batch: int = 4, max_len: int = 64
               ) -> list[str]:
        if fn not in self.recorders:
            self.recorders[fn] = SLORecorder(fn=fn)
        if not self.store.contains(fn):
            self.store.store(fn, params)
        ids = []
        base = sum(1 for k in self.instances if k.startswith(fn + "/"))
        for i in range(n_instances):
            inst_id = f"{fn}/{base + i}"
            inst = FunctionInstance(inst_id, model, self.store, fn, alloc,
                                    max_batch=max_batch, max_len=max_len)
            self.instances[inst_id] = inst
            self.scheduler.register(inst_id, alloc)
            ids.append(inst_id)
        return ids

    def submit(self, fn: str, prompt: np.ndarray, max_new_tokens: int = 8
               ) -> ServeRequest:
        req = ServeRequest(req_id=next(self._req_ids), prompt=prompt,
                           max_new_tokens=max_new_tokens,
                           submitted_at=self.now())
        # Join-shortest-queue across the function's instances.
        candidates = [v for k, v in self.instances.items()
                      if k.startswith(fn + "/")]
        if not candidates:
            raise KeyError(f"function {fn} has no instances")
        inst = min(candidates, key=lambda i: len(i.queue) + len(i.active))
        inst.queue.append(req)
        return req

    def pump(self, budget_s: float = 1.0) -> int:
        """Run token-gated dispatch until idle or budget exhausted."""
        completed = 0
        deadline = time.perf_counter() + budget_s
        while time.perf_counter() < deadline:
            any_work = False
            for inst_id, inst in self.instances.items():
                if inst.has_work():
                    any_work = True
                    self.scheduler.request_token(inst_id, self.now())
            if not any_work:
                break
            granted = self.scheduler.dispatch(self.now())
            if not granted:
                time.sleep(0.001)
                continue
            for token in granted:
                inst = self.instances[token.pod_id]
                t0 = time.perf_counter()
                finished = inst.run_step()
                elapsed = time.perf_counter() - t0
                self.scheduler.complete(token.pod_id, elapsed, self.now())
                fn = token.pod_id.split("/")[0]
                for r in finished:
                    r.finished_at = self.now()
                    self.recorders[fn].record(r.finished_at - r.submitted_at,
                                              r.finished_at)
                    completed += 1
        return completed

    def memory_bytes(self) -> int:
        return self.store.used_bytes()
