"""Sequence-sharded paged decode: all-gather-free attention over block slabs.

``cache_pspec`` falls back to sharding the *sequence* axis when neither
batch nor kv-heads divide the mesh (batch=1 long-context decode, GQA with
kv < TP).  For the paged plane that means each device owns a contiguous
slab of physical KV blocks, and a decode step must attend all of them —
flash-decoding style: every device computes a *partial* softmax over its
local blocks and the partials merge with one log-sum-exp combine
(``softmax_combine``), two tiny collectives instead of all-gathering the
KV itself.

This seam is opt-in: the engine's default cache placement shards kv-heads
and replicates when they don't divide (bitwise-safe — no cross-device
reduction touches the logits), so ``paged_decode_attention_seqshard``
exists for the configs whose KV genuinely cannot fit replicated.  It is
numerically equivalent (f32 accumulation, ~1 ulp reassociation) to
``kernels.ops.paged_decode_attention``, not bit-identical — exactly the
trade the docstring of ``cache_pspec`` promises.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
try:  # jax >= 0.8 promotes shard_map out of experimental
    from jax import shard_map
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -1e30  # finite, like attention.py: exp(NEG_INF - m) underflows to 0


def softmax_combine(num: jax.Array, m: jax.Array, den: jax.Array,
                    axis: str) -> jax.Array:
    """Merge per-shard partial softmaxes with one log-sum-exp rescale.

    ``num``: unnormalized weighted-value partials ``(..., D)``;
    ``m``: per-shard row maxima ``(...)``; ``den``: per-shard partition
    sums ``(...)``, all computed against the shard-local keys only.
    Returns the globally-normalized attention output — identical (up to
    f32 reassociation) to a softmax over the concatenated keys.
    """
    m_glob = jax.lax.pmax(m, axis)
    scale = jnp.exp(m - m_glob)
    total_num = jax.lax.psum(num * scale[..., None], axis)
    total_den = jax.lax.psum(den * scale, axis)
    return total_num / jnp.maximum(total_den, 1e-30)[..., None]


def _local_partials(q: jax.Array, k_loc: jax.Array, v_loc: jax.Array,
                    block_tables: jax.Array, cache_len: jax.Array,
                    shard: jax.Array
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Partial attention of ``q`` against this shard's block slab.

    ``k_loc``/``v_loc``: (N_local, bs, K, Dh) — the shard's slab; global
    block ``t`` lives here iff ``t // N_local == shard``.  Rows of
    ``block_tables`` pointing off-shard (or past ``cache_len``) are
    masked, so each device scores only the tokens it physically holds.
    Returns (num (B,K,G,Dh), m (B,K,G), den (B,K,G)) in f32.
    """
    b, _, h, dh = q.shape
    n_loc, bs, kv, _ = k_loc.shape
    g = h // kv
    qf = q.astype(jnp.float32).reshape(b, kv, g, dh) * dh ** -0.5

    t = block_tables  # (B, M) global block ids
    owned = (t >= shard * n_loc) & (t < (shard + 1) * n_loc)
    local = jnp.clip(t - shard * n_loc, 0, n_loc - 1)
    k_g = jnp.take(k_loc, local, axis=0).astype(jnp.float32)  # (B,M,bs,K,Dh)
    v_g = jnp.take(v_loc, local, axis=0).astype(jnp.float32)

    scores = jnp.einsum("bkgd,bmskd->bkgms", qf, k_g)  # (B,K,G,M,bs)
    pos_tok = (jnp.arange(t.shape[1])[:, None] * bs
               + jnp.arange(bs)[None, :])  # (M, bs)
    valid = (owned[:, :, None]
             & (pos_tok[None] < cache_len[:, None, None]))  # (B,M,bs)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    m = scores.max(axis=(-2, -1))  # (B,K,G)
    # NEG_INF is finite: an all-masked shard has m == NEG_INF and every
    # exp() == 1, so the valid mask must gate the weights, not the scores.
    p = jnp.exp(scores - m[..., None, None]) * valid[:, None, None]
    den = p.sum(axis=(-2, -1))
    num = jnp.einsum("bkgms,bmskd->bkgd", p, v_g)
    return num, m, den


def paged_decode_attention_seqshard(q: jax.Array, k_pages: jax.Array,
                                    v_pages: jax.Array,
                                    block_tables: jax.Array,
                                    cache_len: jax.Array,
                                    mesh: Mesh,
                                    axis: str = "model") -> jax.Array:
    """``ops.paged_decode_attention`` with the page pool sharded over
    ``axis`` on the physical-block dimension.

    q: (B, 1, H, Dh); k_pages/v_pages: (N, bs, K, Dh) with
    ``N % mesh.shape[axis] == 0``; block_tables: (B, M) int32;
    cache_len: (B,) int32.  Returns (B, 1, H, Dh).
    """
    n_blocks = k_pages.shape[0]
    tp = int(mesh.shape[axis])
    if n_blocks % tp != 0:
        raise ValueError(
            f"n_blocks={n_blocks} must divide over {axis}={tp} to "
            f"sequence-shard the page pool")
    b, _, h, dh = q.shape

    def body(ql, kl, vl, tables, lens):
        shard = jax.lax.axis_index(axis)
        num, m, den = _local_partials(ql, kl, vl, tables, lens, shard)
        out = softmax_combine(num, m, den, axis)  # (B,K,G,Dh)
        return out.reshape(b, 1, h, dh).astype(q.dtype)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(), P()),
        out_specs=P(), check_rep=False)
    return fn(q, k_pages, v_pages, block_tables, cache_len)
