"""Divisibility-aware logical sharding rules.

Model code annotates tensors with *logical* dimension names; this module
resolves them to ``PartitionSpec``s against whatever mesh is active.  A rule
is applied only when the dimension size divides the product of the mapped
mesh axes — otherwise that dimension is left unsharded.  This single policy
makes every assigned architecture shard cleanly on the production meshes:

* qwen2-7b has 28 query heads (not divisible by model=16) -> heads stay
  replicated over TP while d_ff / vocab still shard (the §Perf hillclimb
  measures what that costs and fixes it with head padding);
* GQA kv heads (4, 5, 8) < 16 -> kv tensors replicate over TP, the standard
  GQA tensor-parallel fallback;
* long_500k has batch=1 -> batch rules no-op and the KV cache shards its
  *sequence* axis instead (context parallelism), see ``cache_pspec``.

The active mesh comes from ``use_mesh`` (a contextvar), so reduced-config
smoke tests on one CPU device run the exact same model code with every
constraint collapsing to a no-op.
"""

from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# The serving-path tensor axis (sharded pods).  Distinct from the
# training axis "model" on purpose: rules that would split a contraction
# (d_ff, vocab, row-parallel "tp") deliberately do NOT map to it, so a
# serving mesh only ever moves data with exact collectives (all-gather /
# masked gather) and a sharded pod's token streams stay bit-identical to
# the single-device reference even in bf16.
SERVE_AXIS = "serve"

# Logical dimension name -> preferred mesh axes (in order).
RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),  # unsharded by default (sequence parallelism is opt-in)
    "seq_shard": ("pod", "data"),  # context-parallel sequence (long decode)
    "d_model": (),  # activations keep d_model local
    "heads": ("model", SERVE_AXIS),
    "kv_heads": ("model", SERVE_AXIS),
    "d_ff": ("model",),
    "vocab": ("model",),
    "fsdp": ("data",),  # parameter d_model/d_ff dims shard over data (FSDP)
    "experts": ("model",),
    "layers": (),  # stacked-layer leading dim of scanned params
    "state": (),
    None: (),
}

_mesh_var: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    token = _mesh_var.set(mesh)
    try:
        yield mesh
    finally:
        _mesh_var.reset(token)


def current_mesh() -> Optional[Mesh]:
    return _mesh_var.get()


def _axes_in_mesh(mesh: Mesh, axes: Sequence[str]) -> tuple[str, ...]:
    return tuple(a for a in axes if a in mesh.shape)


def resolve_pspec(names: Sequence[Optional[str]], shape: Sequence[int],
                  mesh: Mesh) -> P:
    """Logical names -> PartitionSpec, dropping non-divisible rules."""
    if len(names) != len(shape):
        raise ValueError(f"rank mismatch: {names} vs shape {shape}")
    spec: list[Any] = []
    used: set[str] = set()
    for name, dim in zip(names, shape):
        axes = _axes_in_mesh(mesh, RULES.get(name, ()))
        axes = tuple(a for a in axes if a not in used)
        # Largest prefix of the preferred axes that divides the dim.
        while axes and dim % math.prod(mesh.shape[a] for a in axes) != 0:
            axes = axes[:-1]
        if axes:
            used.update(axes)
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    return P(*spec)


def named(x: jax.Array | Any, *names: Optional[str]) -> jax.Array:
    """Annotate ``x`` with logical dimension names (no-op without a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve_pspec(names, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ``constrain`` is the verb used inside model code.
constrain = named


def cache_pspec(shape: Sequence[int], mesh: Mesh,
                layout: Sequence[Optional[str]] = ("layers", "batch", "seq",
                                                   "kv_heads", None)) -> P:
    """KV-cache spec: every mesh axis must shard *something* or the cache
    replicates and overflows HBM (e.g. qwen1.5-110b decode_32k is 1.4 TB).

    Assignment policy:
      * batch takes (pod, data) when divisible; otherwise those axes move
        to seq (context parallelism — long_500k, batch=1);
      * kv_heads takes model when divisible (GQA with kv >= TP); otherwise
        model also moves to seq (kv=4/5/8 archs), giving flash-decoding
        style sequence-sharded attention with a softmax combine.
    """
    names = list(layout)
    if "batch" not in names or "seq" not in names:
        return resolve_pspec(names, shape, mesh)
    b_idx, s_idx = names.index("batch"), names.index("seq")
    seq_axes: list[str] = []
    dp_axes = _axes_in_mesh(mesh, RULES["batch"])
    dp = math.prod(mesh.shape[a] for a in dp_axes)
    if dp and shape[b_idx] % dp != 0:
        names[b_idx] = None
        seq_axes.extend(dp_axes)
    if "kv_heads" in names:
        k_idx = names.index("kv_heads")
        tp_axes = _axes_in_mesh(mesh, RULES["kv_heads"])
        tp = math.prod(mesh.shape[a] for a in tp_axes)
        if tp and shape[k_idx] % tp != 0:
            names[k_idx] = None
            seq_axes.extend(tp_axes)
    if seq_axes:
        total = math.prod(mesh.shape[a] for a in seq_axes)
        if shape[s_idx] % total == 0:
            spec = resolve_pspec(names, shape, mesh)
            parts = list(spec)
            parts[s_idx] = tuple(seq_axes) if len(seq_axes) > 1 else seq_axes[0]
            return P(*parts)
    return resolve_pspec(names, shape, mesh)


def tp_mesh(shards: int,
            devices: Optional[Sequence[Any]] = None) -> Optional[Mesh]:
    """Single-axis ``(SERVE_AXIS,)`` tensor-parallel mesh over ``shards``
    devices — the mesh a multi-rectangle FaSTPod runs under.

    ``shards == 1`` returns ``None`` — the caller's single-device path must
    stay byte-identical to today's, so no mesh object exists to thread.
    ``devices`` selects the member devices explicitly (a sharded pod's
    rectangles name their own nodes); default is the first ``shards`` of
    ``jax.devices()``.
    """
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if shards == 1:
        return None
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < shards:
        raise ValueError(
            f"need {shards} devices for a tp mesh, have {len(devs)}")
    import numpy as np
    return Mesh(np.asarray(devs[:shards]), (SERVE_AXIS,))


def serve_tp(mesh: Optional[Mesh] = None) -> int:
    """Size of the serving tensor axis in ``mesh`` (or the active mesh);
    1 when absent — i.e. on every training/single-device path."""
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return 1
    return int(mesh.shape.get(SERVE_AXIS, 1))


def serve_pspec(names: Sequence[Optional[str]], shape: Sequence[int],
                mesh: Mesh) -> P:
    """Column-only tensor-parallel placement for serving-path parameters.

    Shards a parameter's OUTPUT dimensions — a trailing ``"tp"`` (column-
    parallel projections and their biases) or any ``"vocab"`` dim — over
    ``SERVE_AXIS`` and replicates everything else, in particular the
    row-parallel ``"tp"`` dims of wo / w_down.  With contracting rows
    replicated, every dot runs its full reduction on-device and the only
    cross-device exchanges are exact (all-gathers, masked embedding
    gathers), so a sharded pod's logits are bitwise those of the
    single-device reference — the reassociation of a split-K all-reduce
    in bf16 would flip near-tie argmax tokens.  Non-divisible dims stay
    replicated (the usual divisibility fallback).
    """
    if len(names) != len(shape):
        raise ValueError(f"rank mismatch: {names} vs shape {shape}")
    n = mesh.shape.get(SERVE_AXIS, 0)
    spec: list[Any] = []
    for i, (name, dim) in enumerate(zip(names, shape)):
        col = name == "vocab" or (name == "tp" and i == len(names) - 1)
        spec.append(SERVE_AXIS if (col and n > 1 and dim % n == 0)
                    else None)
    return P(*spec)


def _is_name_tuple(x: Any) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(i, (str, type(None))) for i in x)


def shard_put(tree: Any, names_tree: Any, mesh: Mesh,
              resolver=resolve_pspec) -> Any:
    """``device_put`` every leaf of ``tree`` to its resolved NamedSharding.

    ``names_tree`` mirrors ``tree`` with logical-name tuples at the leaves
    (``Model.param_names()`` / ``Model.cache_names()`` shape); ``resolver``
    maps ``(names, shape, mesh)`` to a PartitionSpec (``serve_pspec`` for
    serving-path parameters).  Re-placing an already-correctly-sharded
    leaf is a no-op, so this is safe to call on the output of a sharded
    upload.
    """
    return jax.tree_util.tree_map(
        lambda names, leaf: jax.device_put(
            leaf, NamedSharding(mesh, resolver(names, leaf.shape, mesh))),
        names_tree, tree, is_leaf=_is_name_tuple)


def sharding_for(names: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_pspec(names, shape, mesh))


def tree_shardings(spec_tree: Any, shape_tree: Any, mesh: Mesh) -> Any:
    """Map a pytree of logical-name tuples + matching ShapeDtypeStructs to
    NamedShardings (used to build jit in_shardings for the dry-run)."""
    return jax.tree_util.tree_map(
        lambda names, sds: NamedSharding(
            mesh, resolve_pspec(names, sds.shape, mesh)),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, (str, type(None))) for i in x),
    )
