"""Divisibility-aware logical sharding rules.

Model code annotates tensors with *logical* dimension names; this module
resolves them to ``PartitionSpec``s against whatever mesh is active.  A rule
is applied only when the dimension size divides the product of the mapped
mesh axes — otherwise that dimension is left unsharded.  This single policy
makes every assigned architecture shard cleanly on the production meshes:

* qwen2-7b has 28 query heads (not divisible by model=16) -> heads stay
  replicated over TP while d_ff / vocab still shard (the §Perf hillclimb
  measures what that costs and fixes it with head padding);
* GQA kv heads (4, 5, 8) < 16 -> kv tensors replicate over TP, the standard
  GQA tensor-parallel fallback;
* long_500k has batch=1 -> batch rules no-op and the KV cache shards its
  *sequence* axis instead (context parallelism), see ``cache_pspec``.

The active mesh comes from ``use_mesh`` (a contextvar), so reduced-config
smoke tests on one CPU device run the exact same model code with every
constraint collapsing to a no-op.
"""

from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical dimension name -> preferred mesh axes (in order).
RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),  # unsharded by default (sequence parallelism is opt-in)
    "seq_shard": ("pod", "data"),  # context-parallel sequence (long decode)
    "d_model": (),  # activations keep d_model local
    "heads": ("model",),
    "kv_heads": ("model",),
    "d_ff": ("model",),
    "vocab": ("model",),
    "fsdp": ("data",),  # parameter d_model/d_ff dims shard over data (FSDP)
    "experts": ("model",),
    "layers": (),  # stacked-layer leading dim of scanned params
    "state": (),
    None: (),
}

_mesh_var: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    token = _mesh_var.set(mesh)
    try:
        yield mesh
    finally:
        _mesh_var.reset(token)


def current_mesh() -> Optional[Mesh]:
    return _mesh_var.get()


def _axes_in_mesh(mesh: Mesh, axes: Sequence[str]) -> tuple[str, ...]:
    return tuple(a for a in axes if a in mesh.shape)


def resolve_pspec(names: Sequence[Optional[str]], shape: Sequence[int],
                  mesh: Mesh) -> P:
    """Logical names -> PartitionSpec, dropping non-divisible rules."""
    if len(names) != len(shape):
        raise ValueError(f"rank mismatch: {names} vs shape {shape}")
    spec: list[Any] = []
    used: set[str] = set()
    for name, dim in zip(names, shape):
        axes = _axes_in_mesh(mesh, RULES.get(name, ()))
        axes = tuple(a for a in axes if a not in used)
        # Largest prefix of the preferred axes that divides the dim.
        while axes and dim % math.prod(mesh.shape[a] for a in axes) != 0:
            axes = axes[:-1]
        if axes:
            used.update(axes)
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    return P(*spec)


def named(x: jax.Array | Any, *names: Optional[str]) -> jax.Array:
    """Annotate ``x`` with logical dimension names (no-op without a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve_pspec(names, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ``constrain`` is the verb used inside model code.
constrain = named


def cache_pspec(shape: Sequence[int], mesh: Mesh,
                layout: Sequence[Optional[str]] = ("layers", "batch", "seq",
                                                   "kv_heads", None)) -> P:
    """KV-cache spec: every mesh axis must shard *something* or the cache
    replicates and overflows HBM (e.g. qwen1.5-110b decode_32k is 1.4 TB).

    Assignment policy:
      * batch takes (pod, data) when divisible; otherwise those axes move
        to seq (context parallelism — long_500k, batch=1);
      * kv_heads takes model when divisible (GQA with kv >= TP); otherwise
        model also moves to seq (kv=4/5/8 archs), giving flash-decoding
        style sequence-sharded attention with a softmax combine.
    """
    names = list(layout)
    if "batch" not in names or "seq" not in names:
        return resolve_pspec(names, shape, mesh)
    b_idx, s_idx = names.index("batch"), names.index("seq")
    seq_axes: list[str] = []
    dp_axes = _axes_in_mesh(mesh, RULES["batch"])
    dp = math.prod(mesh.shape[a] for a in dp_axes)
    if dp and shape[b_idx] % dp != 0:
        names[b_idx] = None
        seq_axes.extend(dp_axes)
    if "kv_heads" in names:
        k_idx = names.index("kv_heads")
        tp_axes = _axes_in_mesh(mesh, RULES["kv_heads"])
        tp = math.prod(mesh.shape[a] for a in tp_axes)
        if tp and shape[k_idx] % tp != 0:
            names[k_idx] = None
            seq_axes.extend(tp_axes)
    if seq_axes:
        total = math.prod(mesh.shape[a] for a in seq_axes)
        if shape[s_idx] % total == 0:
            spec = resolve_pspec(names, shape, mesh)
            parts = list(spec)
            parts[s_idx] = tuple(seq_axes) if len(seq_axes) > 1 else seq_axes[0]
            return P(*parts)
    return resolve_pspec(names, shape, mesh)


def sharding_for(names: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_pspec(names, shape, mesh))


def tree_shardings(spec_tree: Any, shape_tree: Any, mesh: Mesh) -> Any:
    """Map a pytree of logical-name tuples + matching ShapeDtypeStructs to
    NamedShardings (used to build jit in_shardings for the dry-run)."""
    return jax.tree_util.tree_map(
        lambda names, sds: NamedSharding(
            mesh, resolve_pspec(names, sds.shape, mesh)),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, (str, type(None))) for i in x),
    )
