from repro.distributed.sharding import (SERVE_AXIS, cache_pspec, constrain,
                                        current_mesh, named, resolve_pspec,
                                        serve_pspec, serve_tp, shard_put,
                                        sharding_for, tp_mesh, tree_shardings,
                                        use_mesh)

__all__ = ["SERVE_AXIS", "constrain", "use_mesh", "current_mesh",
           "resolve_pspec", "cache_pspec", "named", "serve_pspec",
           "serve_tp", "shard_put", "sharding_for", "tp_mesh",
           "tree_shardings"]
