from repro.distributed.sharding import (cache_pspec, constrain, current_mesh,
                                        named, resolve_pspec, use_mesh)

__all__ = ["constrain", "use_mesh", "current_mesh", "resolve_pspec",
           "cache_pspec", "named"]
