"""FaST-Manager: the multi-token spatio-temporal scheduler (paper §3.3).

One ``TokenScheduler`` runs per accelerator node (the paper's FaST Backend).
Instances (pods) register their 2D allocation; whenever an instance wants to
launch work it *requests a token*.  Each scheduling round performs the three
operations of the paper's Multi-tokens Scheduler:

1. **Filtering** — compute ``Q_miss = Q_request - Q_used`` and
   ``Q_remain = Q_limit - Q_used``; block pods with ``Q_remain <= 0`` until
   the next window (elastic quota: pods past ``Q_request`` but under
   ``Q_limit`` stay eligible, realizing the Kubernetes-style request/limit
   elasticity of §3.3.2).
2. **Candidate enqueuing** — ready pods enter a priority queue sorted
   descending by ``Q_miss`` (largest timing gap first).
3. **Token dispatching** — the SM Allocation Adapter grants tokens from the
   queue head while ``S_running + S_next <= SM_GLOBAL_LIMIT``.

The scheduler is time-agnostic: callers pass ``now`` (virtual time in the
discrete-event simulator, wall time in the live serving engine).  A token
covers one dispatched inference step — the TPU analogue of a CUDA kernel
burst between synchronization points (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.resources import Alloc


@dataclasses.dataclass
class Token:
    """Permission for one step-dispatch on the node."""

    pod_id: str
    granted_at: float
    sm: float  # spatial share *held* (allocation) while outstanding
    occ: float = 0.0  # spatial share actually *drained* by the kernels
    #                   (DCGM-style occupancy: min(allocated, model's
    #                   saturation share) — a racing pod holds 100% but
    #                   occupies only what its kernels can fill, Fig. 1b)


@dataclasses.dataclass
class _PodState:
    alloc: Alloc
    occupied_sm: float = 0.0  # effective occupancy while holding a token
    q_used: float = 0.0  # seconds of accelerator time used this window
    wants_token: bool = False
    holding: Optional[Token] = None
    # lifetime accounting
    total_busy: float = 0.0
    tokens_granted: int = 0
    blocked_rounds: int = 0

    def q_miss(self, window: float) -> float:
        return self.alloc.quota_request * window - self.q_used

    def q_remain(self, window: float) -> float:
        return self.alloc.quota_limit * window - self.q_used


@dataclasses.dataclass
class WindowStats:
    """Per-window utilization accounting (drives Fig. 1/10/11 metrics)."""

    start: float
    busy_time: float = 0.0  # Σ token-held seconds (temporal load, can be >1)
    busy_area: float = 0.0  # Σ token-held seconds x SM share (occupancy)
    busy_union: float = 0.0  # union of token-held intervals (nvidia-smi
    #                          style "GPU utilization", capped at window)


class TokenScheduler:
    """FaST Backend with Multi-tokens Scheduler for one node."""

    def __init__(
        self,
        window: float = 1.0,
        sm_global_limit: float = 1.0,
        on_grant: Optional[Callable[[Token], None]] = None,
    ):
        if window <= 0:
            raise ValueError("window must be positive")
        self.window = window
        self.sm_global_limit = sm_global_limit
        self.on_grant = on_grant
        self.pods: dict[str, _PodState] = {}
        self._window_start = 0.0
        self.stats_history: list[WindowStats] = []
        self._stats = WindowStats(start=0.0)
        # busy-union tracking: #outstanding tokens + last accrual time.
        self._active = 0
        self._last_evt = 0.0

    # -- registration (FaSTPod sync of the backend table, §3.2) ----------

    def register(self, pod_id: str, alloc: Alloc,
                 occupied_sm: Optional[float] = None) -> None:
        """``occupied_sm``: the share the pod's kernels can actually drain
        (defaults to the allocation; callers with a service model pass
        ``min(alloc.sm, sm_sat)``)."""
        if pod_id in self.pods:
            raise ValueError(f"pod {pod_id} already registered")
        self.pods[pod_id] = _PodState(
            alloc=alloc,
            occupied_sm=alloc.sm if occupied_sm is None else occupied_sm)

    def deregister(self, pod_id: str) -> None:
        state = self.pods.pop(pod_id)
        if state.holding is not None:
            raise RuntimeError(f"pod {pod_id} deregistered while holding a token")

    def update_alloc(self, pod_id: str, alloc: Alloc) -> None:
        """FaST-Scheduler pushed a new resource configuration."""
        self.pods[pod_id].alloc = alloc

    # -- frontend hook: token request / completion ------------------------

    def request_token(self, pod_id: str, now: float) -> None:
        self._maybe_roll(now)
        self.pods[pod_id].wants_token = True

    def complete(self, pod_id: str, elapsed: float, now: float,
                 occ: Optional[float] = None) -> None:
        """Frontend sync point: step finished, charge ``elapsed`` to Q_used.

        ``occ`` overrides the token's registered drained occupancy for this
        step — continuous-batching callers scale it by slot fill, since an
        underfilled decode round cannot saturate the pod's SM share.
        """
        state = self.pods[pod_id]
        if state.holding is None:
            raise RuntimeError(f"pod {pod_id} completed without a token")
        state.q_used += elapsed
        state.total_busy += elapsed
        self._stats.busy_time += elapsed
        self._stats.busy_area += elapsed * (
            state.holding.occ if occ is None else occ)
        self._maybe_roll(now)  # accrue busy-union while the token is live
        self._active = max(self._active - 1, 0)
        state.holding = None

    # -- scheduling round --------------------------------------------------

    def sm_running(self) -> float:
        return sum(p.holding.sm for p in self.pods.values() if p.holding)

    def dispatch(self, now: float) -> list[Token]:
        """One Filter -> Enqueue -> Dispatch round; returns granted tokens."""
        self._maybe_roll(now)
        # 1. Filtering.
        ready: list[tuple[float, str]] = []
        for pod_id, st in self.pods.items():
            if not st.wants_token or st.holding is not None:
                continue
            if st.q_remain(self.window) <= 0:
                st.blocked_rounds += 1  # blocked until next window (e.g. F3)
                continue
            ready.append((st.q_miss(self.window), pod_id))
        # 2. Ready-function priority queue: descending Q_miss.
        ready.sort(key=lambda t: (-t[0], t[1]))
        # 3. SM Allocation Adapter.
        granted: list[Token] = []
        s_running = self.sm_running()
        for _, pod_id in ready:
            st = self.pods[pod_id]
            if s_running + st.alloc.sm > self.sm_global_limit + 1e-9:
                # Head-of-queue blocking, per the paper: the adapter returns
                # tokens "until it encounters S_SMs + S_running > 100%".
                break
            token = Token(pod_id=pod_id, granted_at=now, sm=st.alloc.sm,
                          occ=st.occupied_sm)
            st.holding = token
            st.wants_token = False
            st.tokens_granted += 1
            s_running += st.alloc.sm
            granted.append(token)
            if self.on_grant:
                self.on_grant(token)
        self._active += len(granted)
        return granted

    # -- window management ---------------------------------------------------

    def _maybe_roll(self, now: float) -> None:
        """Roll complete windows and accrue the busy-interval union."""
        while now - self._window_start >= self.window:
            end = self._window_start + self.window
            if self._active > 0 and end > self._last_evt:
                self._stats.busy_union += end - max(self._last_evt,
                                                    self._window_start)
            self._last_evt = max(self._last_evt, end)
            self.stats_history.append(self._stats)
            self._window_start = end
            self._stats = WindowStats(start=end)
            for st in self.pods.values():
                st.q_used = 0.0
        if self._active > 0 and now > self._last_evt:
            self._stats.busy_union += now - self._last_evt
        self._last_evt = max(self._last_evt, now)

    # -- metrics ---------------------------------------------------------------

    def utilization(self, last_n: int = 10) -> float:
        """GPU utilization: union of busy intervals / window (nvidia-smi
        semantics — "some kernel is running", capped at 1; cf. Fig. 1)."""
        hist = self.stats_history[-last_n:]
        if not hist:
            return 0.0
        return sum(w.busy_union for w in hist) / (len(hist) * self.window)

    def temporal_load(self, last_n: int = 10) -> float:
        """Σ token-held seconds / window — the uncapped concurrency load."""
        hist = self.stats_history[-last_n:]
        if not hist:
            return 0.0
        return sum(w.busy_time for w in hist) / (len(hist) * self.window)

    def occupancy(self, last_n: int = 10) -> float:
        """SM occupancy: busy-area / window (spatial x temporal product)."""
        hist = self.stats_history[-last_n:]
        if not hist:
            return 0.0
        return sum(w.busy_area for w in hist) / (len(hist) * self.window)

    def isolation_error(self, pod_id: str, last_n: int = 10) -> float:
        """|delivered - entitled| quota over recent windows, for isolation tests."""
        st = self.pods[pod_id]
        hist = self.stats_history[-last_n:]
        if not hist:
            return 0.0
        entitled = st.alloc.quota_limit * len(hist) * self.window
        # Delivered time is tracked per-pod only in total_busy; scope it by
        # assuming steady registration (tests use dedicated schedulers).
        delivered = st.total_busy
        return max(0.0, delivered - entitled) / max(entitled, 1e-9)


def fair_share_baseline(allocs: dict[str, Alloc], window: float = 1.0) -> dict[str, float]:
    """NVIDIA time-slicing reference: equal time slices, no SM awareness.

    Used by benchmarks as the paper's "time sharing" baseline — each pod gets
    ``window / n`` seconds at 100% SM serially, which is why its SM occupancy
    collapses (Fig. 1b).
    """
    n = len(allocs)
    if n == 0:
        return {}
    return {pod: window / n for pod in allocs}
