"""Cluster control plane + discrete-event simulator.

Faithfully executes the paper's full serving stack — FaST-Manager token
scheduling per node, MRA node selection, heuristic auto-scaling, model
sharing admission — over virtual time, so every benchmark figure can be
reproduced deterministically on this CPU-only container.  The *algorithms*
are the real implementations from this package (not re-derivations); only
step wall-times come from calibrated ``ServiceCurve``s (DESIGN.md §7).

Fault-tolerance features exercised here (large-scale runnability):

* **Node failure**: ``fail_node`` only records the damage — pods are marked
  dead, their rectangles dropped, and stranded requests re-queued to
  surviving replicas (or parked until one exists).  Re-placement is the
  reconciler's job: ``ControlPlane.reconcile`` prunes dead pods from its
  L_j capacity queues (the ``Backend.alive`` verb) and the resulting
  processing gap + below-floor healing re-converge the fleet.
* **Pod migration**: ``migrate`` moves one pod (queue + occupied decode
  slots) to a target node between token-gated steps — the simulator
  analogue of the live engine's KV migration, used by the reconciler's
  MRA defragmentation pass.
* **Straggler mitigation**: nodes carry a ``slowdown`` factor; the control
  loop compares per-pod service rates against the fleet median and re-places
  pods whose node is degraded beyond a threshold.
* **Elastic scaling**: the autoscale loop adds/removes pods from live
  predicted RPS using the paper's Alg. 1.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
import statistics
from collections import deque
from typing import Callable, Optional

from repro.core.links import NetworkLinks
from repro.core.manager import TokenScheduler
from repro.core.maximal_rectangles import MaxRectsPool, Placement
from repro.core.model_sharing import MemoryModel
from repro.core.resources import Alloc
from repro.core.scaling import (FunctionPodQueue, ProfilePoint, ScaleDecision,
                                heuristic_scale, processing_gap)
from repro.core.slo import (TIER_BATCH, TIER_BEST_EFFORT, TIER_GUARANTEED,
                            RetryPolicy, SLORecorder, deadline_budget,
                            observed_rate, record_arrival)
from repro.core.workload import Request, ServiceCurve


# --------------------------------------------------------------------------
# Event engine
# --------------------------------------------------------------------------


class Simulator:
    """Minimal deterministic discrete-event engine."""

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.now = 0.0

    def at(self, time: float, fn: Callable[[], None]) -> None:
        if time < self.now - 1e-12:
            raise ValueError(f"event in the past: {time} < {self.now}")
        heapq.heappush(self._heap, (time, next(self._seq), fn))

    def after(self, delay: float, fn: Callable[[], None]) -> None:
        self.at(self.now + delay, fn)

    def run(self, until: float) -> None:
        while self._heap and self._heap[0][0] <= until:
            time, _, fn = heapq.heappop(self._heap)
            self.now = time
            fn()
        self.now = max(self.now, until)


# --------------------------------------------------------------------------
# Pods and nodes
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _DecodeSlot:
    """One occupied decode slot: a request and its remaining token rounds."""

    req: Request
    remaining: int


@dataclasses.dataclass
class PodRuntime:
    """A running function instance bound to a node.

    ``slots`` is the pod's decode-slot pool (slot-level batching, mirroring
    the live engine): each entry is a request part-way through its
    ``n_tokens`` decode rounds.  ``in_flight`` lists the requests being
    advanced by the step currently holding a token (empty between steps).
    """

    pod_id: str
    fn: str
    curve: ServiceCurve
    alloc: Alloc
    point: ProfilePoint
    placement: Placement
    max_batch: int = 1
    queue: deque = dataclasses.field(default_factory=deque)
    slots: list = dataclasses.field(default_factory=list)
    in_flight: list = dataclasses.field(default_factory=list)
    waiting_token: bool = False
    retired: bool = False
    steps: int = 0
    refills: int = 0  # mid-flight slot admissions (continuous only)
    # Virtual time the pod's weights finish uploading (cold-start tier):
    # no token is granted before it.  0 = instantly ready (legacy model).
    ready_at: float = 0.0
    # Tensor-parallel pod: one MRA rectangle per member device, index 0
    # being the primary (``placement`` / ``placement.node``).  Empty tuples
    # for single-device pods.  ``link_bps`` is the group's bottleneck link
    # bandwidth, fed into ``ServiceCurve.round_time``'s collective term.
    shards: int = 1
    member_nodes: tuple = ()
    member_placements: tuple = ()
    link_bps: float = 0.0
    # A member node died: the pod's KV shard is gone and the pod must fold
    # as soon as any in-flight step returns its token.
    dead: bool = False

    def pending(self) -> bool:
        """Work exists: queued requests or slots with rounds remaining."""
        return bool(self.queue) or any(s.remaining > 0 for s in self.slots)


class Node:
    """One accelerator node: token scheduler + memory accounting."""

    def __init__(self, node_id: int, mem_bytes: int, window: float = 1.0,
                 sharing: bool = True, slowdown: float = 1.0):
        self.node_id = node_id
        self.mem_bytes = mem_bytes
        self.scheduler = TokenScheduler(window=window)
        self.sharing = sharing
        self.slowdown = slowdown
        self.alive = True
        # Gray-failure state: a quarantined node stops receiving new routes
        # and placements but keeps draining its occupants (unlike death).
        self.quarantined = False
        # EWMA of observed/nominal round-duration ratio (1.0 = nominal);
        # ``Cluster.health`` inverts it into a 0..1 health score.
        self.lat_ewma = 1.0
        self.pods: dict[str, PodRuntime] = {}
        # function -> instance count, for the shared-memory footprint model
        self._fn_instances: dict[str, int] = {}
        self._fn_memmodel: dict[str, MemoryModel] = {}
        # Functions whose weights are staged in this node's host RAM — the
        # simulator's model of the fleet store's warm tier.  Populated by
        # deploys that model a cold start (``cold_start_s > 0``); cleared
        # when the node dies (host RAM dies with it).
        self.warm_fns: set[str] = set()

    def mem_used(self) -> int:
        return sum(
            self._fn_memmodel[fn].footprint(n, self.sharing)
            for fn, n in self._fn_instances.items() if n > 0
        )

    def admits(self, fn: str, mm: MemoryModel) -> bool:
        n = self._fn_instances.get(fn, 0)
        projected = self.mem_used() - mm.footprint(n, self.sharing) \
            + mm.footprint(n + 1, self.sharing)
        return projected <= self.mem_bytes

    def add_pod(self, pod: PodRuntime, mm: MemoryModel) -> None:
        self.pods[pod.pod_id] = pod
        self._fn_memmodel[pod.fn] = mm
        self._fn_instances[pod.fn] = self._fn_instances.get(pod.fn, 0) + 1
        # DCGM-style occupancy: a pod drains at most its model's saturation
        # share, however large its allocation (Fig. 1b's racing pods).
        self.scheduler.register(
            pod.pod_id, pod.alloc,
            occupied_sm=min(pod.alloc.sm, pod.curve.sm_sat))

    def remove_pod(self, pod_id: str) -> PodRuntime:
        pod = self.pods.pop(pod_id)
        self._fn_instances[pod.fn] -= 1
        self.scheduler.deregister(pod_id)
        return pod

    def add_member(self, fn: str, mm: MemoryModel) -> None:
        """Charge a sharded pod's secondary member shard to this node's
        memory model.  No scheduler registration: the pod's decode rounds
        are token-gated on its primary node only (all members advance in
        lockstep, so one token stream models the whole group)."""
        self._fn_memmodel[fn] = mm
        self._fn_instances[fn] = self._fn_instances.get(fn, 0) + 1

    def remove_member(self, fn: str) -> None:
        self._fn_instances[fn] -= 1


# --------------------------------------------------------------------------
# Cluster
# --------------------------------------------------------------------------


class Cluster:
    """FaST-GShare control plane over a simulated node fleet."""

    def __init__(
        self,
        n_nodes: int,
        mem_bytes: int = 16 * 1024**3,
        window: float = 1.0,
        sharing: bool = True,
        allow_grow: bool = False,
        max_batch: int = 1,
        scheduler_period: float = 0.05,
        continuous: bool = False,
        batch_alpha: Optional[float] = None,
        links: Optional[NetworkLinks] = None,
        retry: Optional[RetryPolicy] = None,
    ):
        """``continuous=True`` enables slot-level batching: finished
        requests free their decode slot immediately and queued requests are
        admitted mid-flight, matching the live engine's continuous mode.
        ``continuous=False`` keeps static batches that retire together.
        ``batch_alpha`` overrides the weight-bound (batch-shared) fraction
        of a decode round for EVERY function; the default (None) uses each
        curve's own ``alpha`` — 0.5 unless roofline-calibrated via
        ``workload.calibrate_round_alpha``.  ``links`` is the inter-node
        bandwidth graph used by sharded (multi-rectangle) deploys; the
        default is a uniform topology.  ``retry`` (a
        ``repro.core.slo.RetryPolicy``) turns failure re-routing into
        bounded jittered-backoff retries from the policy's own seeded
        PRNG; the default (None) keeps the legacy immediate re-route."""
        self.sim = Simulator()
        self.links = links if links is not None else NetworkLinks(n_nodes)
        self.window = window
        self.max_batch = max_batch
        self.continuous = continuous
        self.batch_alpha = batch_alpha
        self.nodes = [Node(i, mem_bytes, window, sharing) for i in range(n_nodes)]
        self.pool = MaxRectsPool(n_nodes, allow_grow=allow_grow)
        self.pods: dict[str, PodRuntime] = {}
        self.fn_pods: dict[str, list[str]] = {}
        self.fn_curves: dict[str, ServiceCurve] = {}
        self.fn_queues: dict[str, FunctionPodQueue] = {}
        self.recorders: dict[str, SLORecorder] = {}
        self._rr: dict[str, int] = {}
        self._pod_seq = itertools.count()
        self._arrival_log: dict[str, list[float]] = {}
        self._rps_horizon: dict[str, float] = {}
        # Requests for a registered function that momentarily has zero live
        # pods (e.g. every replica died with the node): parked here and
        # re-routed as soon as a replacement pod deploys.
        self._pending: dict[str, deque] = {}
        self.dropped = 0
        self.rescheduled = 0
        self.migrated = 0
        # SLO lifecycle (all zero unless deadlines/retries are configured):
        self.retry = retry
        self.shed = 0      # rejected at admission: could not make deadline
        self.expired = 0   # deadline passed while queued
        self.lost = 0      # retry budget exhausted after failures
        self.fn_tiers: dict[str, str] = {}
        self.fn_deadlines: dict[str, Optional[float]] = {}
        # Cold-start tier telemetry: one entry per delayed deploy —
        # {pod, fn, node, tier, delay}.
        self.cold_events: list[dict] = []
        # Periodic scheduler pump so window rolls release blocked pods.
        for node in self.nodes:
            self._tick(node, scheduler_period)

    # -- deployment -------------------------------------------------------

    def register_function(self, fn: str, curve: ServiceCurve,
                          slo_latency: Optional[float] = None,
                          slo_tier: str = TIER_BEST_EFFORT,
                          deadline_s: Optional[float] = None) -> None:
        self.fn_curves[fn] = curve
        self.fn_queues.setdefault(fn, FunctionPodQueue())
        self.recorders[fn] = SLORecorder(fn=fn, slo_latency=slo_latency)
        self.fn_pods.setdefault(fn, [])
        self.fn_tiers[fn] = slo_tier
        # None (best-effort, no deadline) keeps the whole deadline/shedding
        # machinery dormant for this function.
        self.fn_deadlines[fn] = deadline_budget(slo_tier, deadline_s,
                                                slo_latency)

    def memory_model(self, fn: str) -> MemoryModel:
        c = self.fn_curves[fn]
        return MemoryModel(weight_bytes=c.weight_bytes,
                           framework_bytes=c.framework_bytes)

    def deploy(self, fn: str, point: ProfilePoint,
               elastic_limit: float | None = None,
               track: bool = True,
               cold_start_s: float = 0.0,
               shards: int = 1) -> Optional[str]:
        """Place one pod of ``fn`` at profile point ``point`` via MRA.

        ``track=False`` skips the L_j capacity-queue push — used by
        ``autoscale``, which manages L_j itself (Alg. 1 already pushed a
        provisional entry).

        ``cold_start_s`` models the weight-upload tier: node selection
        prefers warm nodes (whose host RAM already stages the function's
        weights), and the pod's first token grant is delayed by the full
        ``cold_start_s`` on a cold node, half of it on a peer-warm
        placement (host-to-host copy + upload), and nothing on a warm
        node.  The delay never enters scale decisions — ``decision_
        signature`` replay is unaffected by whether a fleet modeled it.

        ``shards`` (or a sharded ``point.shards`` — the larger wins) makes
        this a tensor-parallel pod spanning that many nodes: one rectangle
        per member, acquired atomically on the best-linked group.
        """
        alloc = point.to_alloc(elastic_limit)
        pod_id = f"{fn}-{next(self._pod_seq)}"
        mm = self.memory_model(fn)
        shards = max(shards, point.shards)
        if shards > 1:
            return self._deploy_sharded(fn, point, alloc, pod_id, mm,
                                        shards, cold_start_s, track)
        warm_ids = ({n.node_id for n in self.nodes
                     if n.alive and fn in n.warm_fns}
                    if cold_start_s > 0 else set())
        all_ids = {n.node_id for n in self.pool.nodes}
        phases: list[set[int]] = []
        if warm_ids and warm_ids != all_ids:
            phases.append(all_ids - warm_ids)  # warm-first pass
        phases.append(set())
        placement = None
        for base_exclude in phases:
            excluded = set(base_exclude)
            while True:
                placement = self.pool.schedule(alloc, pod_id,
                                               exclude=excluded)
                if placement is None:
                    break
                if placement.node >= len(self.nodes):  # grew (allow_grow)
                    self.nodes.append(Node(placement.node,
                                           self.nodes[0].mem_bytes,
                                           self.window,
                                           self.nodes[0].sharing))
                    self.links.grow(len(self.nodes))
                    self._tick(self.nodes[-1], 0.05)
                node = self.nodes[placement.node]
                if node.alive and node.admits(fn, mm):
                    break
                # Rectangle fit but node infeasible (dead / memory): retry
                # the remaining nodes.
                self.pool.release(placement)
                excluded.add(placement.node)
            if placement is not None:
                break
        if placement is None:
            return None
        node = self.nodes[placement.node]
        pod = PodRuntime(pod_id=pod_id, fn=fn, curve=self.fn_curves[fn],
                         alloc=alloc, point=point, placement=placement,
                         max_batch=self.max_batch)
        if cold_start_s > 0:
            if placement.node in warm_ids:
                tier, delay = "host", 0.0
            elif warm_ids:
                tier, delay = "peer", 0.5 * cold_start_s
            else:
                tier, delay = "cold", cold_start_s
            pod.ready_at = self.sim.now + delay
            node.warm_fns.add(fn)  # staged by this placement's upload
            self.cold_events.append({"pod": pod_id, "fn": fn,
                                     "node": placement.node, "tier": tier,
                                     "delay": delay})
            if delay > 0:
                # Wake the pod once its weights land (idempotent: the
                # ready gate in _want_token refuses earlier grants).
                self.sim.at(pod.ready_at, lambda: self._want_token(pod))
        node.add_pod(pod, mm)
        self.pods[pod_id] = pod
        self.fn_pods[fn].append(pod_id)
        if track:
            self.fn_queues[fn].push(pod_id, point)
        # Requests parked while the function had zero live pods.
        pending = self._pending.pop(fn, None)
        if pending:
            for r in pending:
                self._route(r)
        return pod_id

    def _deploy_sharded(self, fn: str, point: ProfilePoint, alloc: Alloc,
                        pod_id: str, mm: MemoryModel, shards: int,
                        cold_start_s: float, track: bool) -> Optional[str]:
        """Multi-rectangle deploy: one (S, Q) rectangle per member node.

        Walks candidate groups best collective link first (Helix-style:
        the pod's per-round all-gather rides the group's bottleneck link)
        and takes the first group where every member yields a rectangle
        AND admits the memory footprint — acquired rectangles are rolled
        back whole-group on any member failure, so a half-placed pod never
        leaks.  Link bandwidth outranks the warm tier here: re-uploading
        weights is a one-off, a slow collective is paid every round.
        """
        candidates = [n.node_id for n in self.nodes if n.alive]
        all_ids = {n.node_id for n in self.pool.nodes}
        for group in self.links.best_groups(candidates, shards):
            rects: list[Placement] = []
            for member in group:
                rect = self.pool.schedule(alloc, f"{pod_id}@{member}",
                                          exclude=all_ids - {member})
                if rect is not None and not self.nodes[member].admits(fn, mm):
                    self.pool.release(rect)
                    rect = None
                if rect is None:
                    for r in rects:
                        self.pool.release(r)
                    rects = []
                    break
                rects.append(rect)
            if not rects:
                continue
            primary = group[0]
            node = self.nodes[primary]
            pod = PodRuntime(pod_id=pod_id, fn=fn, curve=self.fn_curves[fn],
                             alloc=alloc, point=point, placement=rects[0],
                             max_batch=self.max_batch, shards=shards,
                             member_nodes=tuple(group),
                             member_placements=tuple(rects),
                             link_bps=self.links.bottleneck(group))
            if cold_start_s > 0:
                warm = {n.node_id for n in self.nodes
                        if n.alive and fn in n.warm_fns}
                if primary in warm:
                    tier, delay = "host", 0.0
                elif warm:
                    tier, delay = "peer", 0.5 * cold_start_s
                else:
                    tier, delay = "cold", cold_start_s
                pod.ready_at = self.sim.now + delay
                for m in group:
                    self.nodes[m].warm_fns.add(fn)
                self.cold_events.append({"pod": pod_id, "fn": fn,
                                         "node": primary, "tier": tier,
                                         "delay": delay})
                if delay > 0:
                    self.sim.at(pod.ready_at,
                                lambda: self._want_token(pod))
            node.add_pod(pod, mm)
            for m in group[1:]:
                self.nodes[m].add_member(fn, mm)
            self.pods[pod_id] = pod
            self.fn_pods[fn].append(pod_id)
            if track:
                self.fn_queues[fn].push(pod_id, point)
            pending = self._pending.pop(fn, None)
            if pending:
                for r in pending:
                    self._route(r)
            return pod_id
        return None

    def retire(self, pod_id: str, drain: bool = True) -> None:
        """Scale-down: stop routing to the pod; release resources when idle."""
        pod = self.pods[pod_id]
        pod.retired = True
        self.fn_pods[pod.fn].remove(pod_id)
        self.fn_queues[pod.fn].remove(pod_id)
        if not drain or (not pod.pending() and not pod.in_flight
                         and not pod.waiting_token):
            self._teardown(pod)

    def _teardown(self, pod: PodRuntime) -> None:
        node = self.nodes[pod.placement.node]
        if pod.pod_id in node.pods and not pod.waiting_token \
                and node.scheduler.pods[pod.pod_id].holding is None:
            node.remove_pod(pod.pod_id)
            self.pool.release(pod.placement)
            for m, rect in zip(pod.member_nodes[1:],
                               pod.member_placements[1:]):
                if self.nodes[m].alive:
                    self.nodes[m].remove_member(pod.fn)
                    self.pool.release(rect)
            self.pods.pop(pod.pod_id, None)

    # -- request path -------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.sim.at(req.arrival, lambda: self._arrive(req))

    def submit_all(self, reqs: list[Request]) -> None:
        for r in reqs:
            self.submit(r)

    def _arrive(self, req: Request) -> None:
        record_arrival(self._arrival_log, self._rps_horizon, req.fn,
                       self.sim.now)
        # Stamp the function's deadline/tier onto the request at admission
        # (inert when the function has no deadline budget — the default).
        budget = self.fn_deadlines.get(req.fn)
        tier = self.fn_tiers.get(req.fn, TIER_BEST_EFFORT)
        if budget is not None and req.deadline is None:
            req = dataclasses.replace(req, deadline=self.sim.now + budget,
                                      tier=tier)
        elif tier != req.tier:
            req = dataclasses.replace(req, tier=tier)
        self._route(req, admission=True)

    def _route(self, req: Request, admission: bool = False) -> None:
        """Route without logging an arrival (re-injection after failures
        must not inflate the observed-RPS signal)."""
        pods = [p for p in self.fn_pods.get(req.fn, ())
                if not self.pods[p].retired
                and not self.nodes[self.pods[p].placement.node].quarantined]
        if not pods:
            if req.fn in self.fn_curves:
                # Registered but momentarily podless (a failure killed the
                # last replica): park until the reconciler heals the fleet.
                self._pending.setdefault(req.fn, deque()).append(req)
            else:
                self.dropped += 1
            return
        # Join-shortest-queue routing across the function's replicas
        # (queue depth + occupied decode slots).
        pod = min((self.pods[p] for p in pods),
                  key=lambda p: len(p.queue) + len(p.slots))
        # Deadline shedding ("reject fast"): at admission only, estimate
        # completion from queue depth x the profile point's service rate
        # and shed a non-guaranteed request that cannot make its deadline.
        if (admission and req.deadline is not None
                and req.tier != TIER_GUARANTEED):
            load = len(pod.queue) + len(pod.slots)
            est = (load + 1) / max(pod.point.throughput, 1e-9)
            if self.sim.now + est > req.deadline + 1e-12:
                self.shed += 1
                self.recorders[req.fn].record_shed()
                return
        self._enqueue_pod(pod, req)
        self._want_token(pod)

    def _enqueue_pod(self, pod: PodRuntime, req: Request) -> None:
        """Queue with the batch lane preempted: a non-batch request inserts
        ahead of parked batch-tier work; uniform tiers reduce to a plain
        FIFO append (the bit-identical legacy order)."""
        if req.tier != TIER_BATCH:
            idx = next((i for i, r in enumerate(pod.queue)
                        if r.tier == TIER_BATCH), None)
            if idx is not None:
                pod.queue.insert(idx, req)
                return
        pod.queue.append(req)

    def _want_token(self, pod: PodRuntime) -> None:
        node = self.nodes[pod.placement.node]
        if not node.alive or pod.waiting_token or not pod.pending():
            return
        if self.sim.now < pod.ready_at - 1e-12:
            # Weights still uploading (cold-start tier): the wake event
            # scheduled at deploy re-arms the pod at ready_at.
            return
        if node.scheduler.pods[pod.pod_id].holding is not None:
            return
        pod.waiting_token = True
        node.scheduler.request_token(pod.pod_id, self.sim.now)
        self._pump(node)

    def _pump(self, node: Node) -> None:
        if not node.alive:
            return
        for token in node.scheduler.dispatch(self.sim.now):
            pod = node.pods[token.pod_id]
            pod.waiting_token = False
            self._start_step(node, pod)

    def _start_step(self, node: Node, pod: PodRuntime) -> None:
        """One token-gated step: slot admission + one decode round.

        Admission — continuous mode tops up free slots every step; static
        mode only forms a new batch once the previous one has fully
        retired.  The round then advances every live (unfinished) slot by
        one token; its wall time comes from the calibrated service curve at
        the *live* slot count, and its drained SM occupancy is scaled by
        slot fill — an underfilled round cannot saturate the partition.
        """
        if self.continuous or not pod.slots:
            # A refill = joining a batch that was already decoding before
            # this step; cold-start co-admissions in the same pass aren't.
            had_live = bool(pod.slots)
            while pod.queue and len(pod.slots) < pod.max_batch:
                r = pod.queue.popleft()
                # Mid-queue expiry: a non-guaranteed request whose deadline
                # already passed is dropped with a typed outcome instead of
                # wasting a decode slot on a response nobody can use.
                if (r.deadline is not None and r.tier != TIER_GUARANTEED
                        and self.sim.now > r.deadline + 1e-12):
                    self.expired += 1
                    self.recorders[r.fn].record_expired()
                    continue
                if had_live and self.continuous:
                    pod.refills += 1
                pod.slots.append(_DecodeSlot(r, max(1, r.n_tokens)))
        live = [s for s in pod.slots if s.remaining > 0]
        if not live:
            # Token granted but work drained (e.g. rebalanced away).
            node.scheduler.complete(pod.pod_id, 0.0, self.sim.now)
            return
        pod.in_flight = [s.req for s in live]
        dur = (pod.curve.round_time(pod.alloc.sm, len(live),
                                    alpha=self.batch_alpha,
                                    shards=pod.shards,
                                    link_bps=pod.link_bps)
               * node.slowdown)
        occ = (min(pod.alloc.sm, pod.curve.sm_sat)
               * len(live) / max(pod.max_batch, 1))
        pod.steps += 1
        self.sim.after(dur,
                       lambda: self._finish_step(node, pod, live, dur, occ))

    def _finish_step(self, node: Node, pod: PodRuntime,
                     live: list[_DecodeSlot], dur: float, occ: float) -> None:
        if not node.alive:
            return  # failure handler already re-queued them
        if pod.dead:
            # A member node died mid-step: the round's result is void (its
            # KV shard went with the node, the strays were already
            # re-queued).  Return the token, then fold the pod.
            pod.in_flight = []
            node.scheduler.complete(pod.pod_id, dur, self.sim.now, occ=occ)
            self._teardown(pod)
            self._pump(node)
            return
        pod.in_flight = []
        completed: list[Request] = []
        for s in live:
            s.remaining -= 1
            if s.remaining <= 0:
                completed.append(s.req)
        if self.continuous:
            # Continuous: finished requests free their slot immediately.
            pod.slots = [s for s in pod.slots if s.remaining > 0]
        elif all(s.remaining <= 0 for s in pod.slots):
            # Static: the batch retires together once ALL members finish.
            pod.slots = []
        rec = self.recorders[pod.fn]
        for r in completed:
            met = (None if r.deadline is None
                   else self.sim.now <= r.deadline + 1e-12)
            rec.record(self.sim.now - r.arrival, self.sim.now,
                       deadline_met=met)
        # Gray-failure signal: EWMA of the observed/nominal duration ratio
        # (the straggler multiplier is exactly that ratio here).
        nominal = dur / max(node.slowdown, 1e-9)
        ratio = dur / max(nominal, 1e-12)
        node.lat_ewma = 0.7 * node.lat_ewma + 0.3 * ratio
        node.scheduler.complete(pod.pod_id, dur, self.sim.now, occ=occ)
        if pod.retired and not pod.pending():
            self._teardown(pod)
        else:
            self._want_token(pod)
        self._pump(node)

    def _tick(self, node: Node, period: float) -> None:
        def tick() -> None:
            if node.alive:
                self._pump(node)
                # Re-arm any pod that has work but lost its request across a
                # window roll.
                for pod in list(node.pods.values()):
                    if (pod.pending() and not pod.waiting_token
                            and not pod.in_flight):
                        self._want_token(pod)
            self.sim.after(period, tick)

        self.sim.after(period, tick)

    # -- autoscaling (paper Alg. 1 in the loop) ------------------------------

    def autoscale(self, predicted: dict[str, float],
                  profiles: dict[str, list[ProfilePoint]],
                  slo_latency: dict[str, float] | None = None,
                  headroom: float = 1.2,
                  elastic_limit: float | None = 1.0) -> list[ScaleDecision]:
        """Paper Alg. 1 in the loop.

        ``headroom`` over-provisions capacity relative to predicted load
        (target utilization 1/headroom) so queueing delay stays bounded —
        provisioning at exactly rho=1 would violate any latency SLO.
        ``elastic_limit`` sets Q_limit above Q_request for scaled-up pods
        (§3.3.2: "enable pods to utilize more GPU resources when the GPU is
        idle") — Poisson bursts are absorbed instead of blocking until the
        next window.
        """
        inflated = {fn: rps * headroom for fn, rps in predicted.items()}
        gaps = processing_gap(inflated, self.fn_queues)
        decisions = heuristic_scale(gaps, profiles, self.fn_queues, slo_latency)
        applied: list[ScaleDecision] = []
        for d in decisions:
            if d.direction > 0:
                # Alg. 1 reserved capacity under a provisional id; settle the
                # reservation against the deployer's outcome so L_j capacity
                # never drifts above what is actually running.
                queue = self.fn_queues[d.function]
                real = self.deploy(d.function, d.point,
                                   elastic_limit=elastic_limit, track=False)
                if real is None:
                    queue.abort(d.pod_id)
                    continue
                queue.confirm(d.pod_id, real)
                applied.append(d)
            else:
                assert d.pod_id is not None
                if d.pod_id in self.pods:
                    self.retire(d.pod_id)
                applied.append(d)
        return applied

    # -- fault tolerance -------------------------------------------------------

    def fail_node(self, node_id: int) -> int:
        """Kill a node: mark its pods dead, re-queue stranded requests.

        Deliberately NOT self-healing: the failure only records the damage
        (dead pods leave ``pods``/``fn_pods``/the tracked L_j queues, the
        node's rectangles are dropped, unfinished requests re-route to
        surviving replicas or park in the pending buffer).  Re-placement
        is owned by the reconciler — ``ControlPlane.reconcile`` prunes the
        dead pods via ``Backend.alive`` and the processing gap + below-
        floor healing bring the fleet back, identically on the live path.
        Returns the number of pods lost.
        """
        node = self.nodes[node_id]
        node.alive = False
        node.warm_fns.clear()  # host RAM (staged weights) dies with it
        self.pool.drain_node(node_id)
        displaced: list[PodRuntime] = list(node.pods.values())
        strays: list[Request] = []
        for pod in displaced:
            # Only unfinished slot occupants: static mode keeps completed
            # (already-recorded) requests in their slots until the batch
            # retires, and those must not be served twice.
            strays.extend(s.req for s in pod.slots if s.remaining > 0)
            strays.extend(pod.queue)
            pod.slots, pod.in_flight, pod.queue = [], [], deque()
            if pod.fn in self.fn_pods and pod.pod_id in self.fn_pods[pod.fn]:
                self.fn_pods[pod.fn].remove(pod.pod_id)
            self.fn_queues[pod.fn].remove(pod.pod_id)
            # Sharded primary: member rectangles on surviving nodes free up.
            for m, rect in zip(pod.member_nodes[1:],
                               pod.member_placements[1:]):
                if m != node_id and self.nodes[m].alive:
                    self.nodes[m].remove_member(pod.fn)
                    self.pool.release(rect)
            del self.pods[pod.pod_id]
        node.pods.clear()
        # Sharded pods anchored elsewhere that had a member shard here die
        # too: the shard's rectangle (and its slice of every KV cache) went
        # with the node, so the whole pod folds.
        for pod in [p for p in self.pods.values()
                    if node_id in p.member_nodes
                    and p.placement.node != node_id]:
            strays.extend(s.req for s in pod.slots if s.remaining > 0)
            strays.extend(pod.queue)
            pod.slots, pod.in_flight, pod.queue = [], [], deque()
            self.fn_pods[pod.fn].remove(pod.pod_id)
            self.fn_queues[pod.fn].remove(pod.pod_id)
            pod.retired = True
            pod.dead = True
            displaced.append(pod)
            primary = self.nodes[pod.placement.node]
            if primary.scheduler.pods[pod.pod_id].holding is None:
                # Between steps: tear down now (the token request, if any,
                # dies with the scheduler deregistration, as in migrate).
                pod.waiting_token = False
                self._teardown(pod)
            # else: _finish_step's dead-pod guard returns the token and
            # tears down when the in-flight round lands.
            self.pods.pop(pod.pod_id, None)
        self.rescheduled += len(displaced)
        # Re-inject stranded requests at the current time (no arrival log:
        # they were already counted when they first arrived).
        for r in strays:
            self._reinject(r)
        return len(displaced)

    def _reinject(self, req: Request) -> None:
        """Re-route a stranded request — immediately (legacy, no policy) or
        through the bounded jittered-backoff retry policy."""
        if self.retry is None:
            self._route(dataclasses.replace(req, arrival=req.arrival))
            return
        attempt = req.attempts + 1
        if (req.tier != TIER_GUARANTEED
                and self.retry.exhausted(req.attempts)):
            # Best-effort/batch: retry budget spent — typed loss, not an
            # eternal park.  Guaranteed requests retry without bound.
            self.lost += 1
            if req.fn in self.recorders:
                self.recorders[req.fn].record_lost()
            return
        retry_req = dataclasses.replace(req, attempts=attempt)
        self.sim.after(self.retry.delay(attempt),
                       lambda: self._route(retry_req))

    def alive(self, pod_id: str) -> bool:
        """Whether a pod still exists on a live, non-quarantined node (dead
        pods are removed from ``pods`` by ``fail_node``, drained ones by
        ``_teardown``; a quarantined node's pods read as not-alive so the
        reconciler prunes and heals them exactly like a crash)."""
        pod = self.pods.get(pod_id)
        if pod is None:
            return False
        nodes = set(pod.member_nodes) or {pod.placement.node}
        return not any(self.nodes[n].quarantined for n in nodes)

    def health(self, node_id: int) -> float:
        """Node health score in (0, 1]: 1.0 nominal, lower = slower.  The
        inverse of the node's observed/nominal round-duration EWMA."""
        node = self.nodes[node_id]
        if not node.alive:
            return 0.0
        return 1.0 / max(node.lat_ewma, 1.0)

    def quarantine(self, node_id: int) -> int:
        """Gray-failure quarantine: stop routing and placement to the node,
        let occupants drain.  One-way, like death — but the node keeps
        serving what it already holds.  The reconciler heals the capacity
        through the ordinary ``alive`` prune + processing gap.  Returns the
        number of pods the quarantine took out of rotation."""
        node = self.nodes[node_id]
        if node.quarantined or not node.alive:
            return 0
        node.quarantined = True
        self.pool.cordon(node_id)
        return sum(1 for p in self.pods.values()
                   if node_id in (set(p.member_nodes)
                                  or {p.placement.node}))

    def node_of(self, pod_id: str) -> Optional[int]:
        pod = self.pods.get(pod_id)
        return None if pod is None else pod.placement.node

    def fragmentation(self) -> dict[int, float]:
        """Per-node MRA fragmentation over schedulable (alive) nodes."""
        return self.pool.fragmentation()

    def node_load(self) -> dict[int, float]:
        """Per-node allocated-area fraction over schedulable nodes."""
        return self.pool.node_load()

    def warm_nodes(self, fn: str) -> list[int]:
        """Alive nodes whose host RAM stages ``fn``'s weights (the
        simulator's fleet-store warm tier; empty unless deploys modeled a
        ``cold_start_s``)."""
        return sorted(n.node_id for n in self.nodes
                      if n.alive and fn in n.warm_fns)

    def migrate(self, pod_id: str, target: int) -> Optional[str]:
        """Move one pod to ``target``: the simulator's KV migration.

        The pod must be between token-gated steps (its per-slot decode
        state is then plain host bookkeeping); its queue and occupied
        decode slots transfer wholesale, and the source rectangle is only
        released after the replacement pod is live (copy-then-delete, so
        an admission failure on the target leaves the pod untouched).
        Returns the new pod id, or None when the pod is mid-step, retired,
        or the target cannot host it.
        """
        pod = self.pods.get(pod_id)
        if pod is None or pod.retired:
            return None
        if pod.shards > 1:
            # A sharded pod's KV lives as one shard per member; moving it
            # means re-acquiring a whole device group — re-place instead
            # (the live path refuses identically).
            return None
        src = pod.placement.node
        if target == src or not 0 <= target < len(self.nodes):
            return None
        src_node = self.nodes[src]
        if pod.in_flight or src_node.scheduler.pods[pod_id].holding is not None:
            return None  # mid-step: its KV is "on device"; retry next tick
        tnode = self.nodes[target]
        mm = self.memory_model(pod.fn)
        if not tnode.alive or not tnode.admits(pod.fn, mm):
            return None
        new_id = f"{pod.fn}-{next(self._pod_seq)}"
        exclude = {n.node_id for n in self.pool.nodes} - {target}
        placement = self.pool.schedule(pod.alloc, new_id, exclude=exclude)
        if placement is None:
            return None
        if placement.node != target:  # pool grew instead of using target
            self.pool.release(placement)
            return None
        new_pod = PodRuntime(pod_id=new_id, fn=pod.fn, curve=pod.curve,
                             alloc=pod.alloc, point=pod.point,
                             placement=placement, max_batch=pod.max_batch,
                             steps=pod.steps, refills=pod.refills)
        if pod.fn in src_node.warm_fns:
            # The move stages the weights on the target; the source's host
            # copy stays cached (both nodes are warm afterwards).
            tnode.warm_fns.add(pod.fn)
        # Pause -> move: between steps the queue and slot state are host
        # data; the live path's gather/merge per slot collapses to this.
        new_pod.queue, pod.queue = pod.queue, deque()
        new_pod.slots, pod.slots = pod.slots, []
        pod.waiting_token = False  # the token request dies with deregister
        tnode.add_pod(new_pod, mm)
        self.pods[new_id] = new_pod
        self.fn_pods[pod.fn].append(new_id)
        # Source teardown only after the replacement is live.
        self.fn_pods[pod.fn].remove(pod_id)
        if pod_id in self.fn_queues[pod.fn]:
            self.fn_queues[pod.fn].rekey(pod_id, new_id)
        src_node.remove_pod(pod_id)
        self.pool.release(pod.placement)
        del self.pods[pod_id]
        self.migrated += 1
        self._want_token(new_pod)
        return new_id

    def detect_stragglers(self, threshold: float = 2.0) -> list[int]:
        """Nodes whose effective service rate lags the fleet median."""
        rates = {n.node_id: 1.0 / n.slowdown for n in self.nodes if n.alive}
        if len(rates) < 2:
            return []
        med = statistics.median(rates.values())
        return [nid for nid, r in rates.items() if med / max(r, 1e-9) > threshold]

    def mitigate_stragglers(self, threshold: float = 2.0) -> int:
        """Re-place pods off straggler nodes (paper-adjacent; DESIGN.md §5)."""
        moved = 0
        for nid in self.detect_stragglers(threshold):
            node = self.nodes[nid]
            self.pool.cordon(nid)  # stop MRA from re-choosing the straggler
            for pod in list(node.pods.values()):
                if pod.retired or pod.shards > 1:
                    continue  # sharded pods re-place via the reconciler
                if pod.in_flight or pod.slots or pod.waiting_token:
                    continue  # move only idle pods; busy ones drain first
                node.remove_pod(pod.pod_id)
                self.pool.release(pod.placement)
                self.fn_pods[pod.fn].remove(pod.pod_id)
                self.fn_queues[pod.fn].remove(pod.pod_id)
                strays = list(pod.queue)
                del self.pods[pod.pod_id]
                if self.deploy(pod.fn, pod.point) is not None:
                    moved += 1
                for r in strays:
                    self._route(r)
        return moved

    # -- metrics ---------------------------------------------------------------

    def run(self, until: float) -> None:
        self.sim.run(until)

    def observed_rps(self, fn: str, window: float) -> float:
        """Arrival rate over the trailing ``window`` of virtual time — the
        simulator's analogue of gateway-side RPS observation."""
        return observed_rate(self._arrival_log, self._rps_horizon,
                             fn, window, self.sim.now)

    def inflight(self, fn: str) -> int:
        """Queued + live slot-occupying requests across the function's
        pods, draining (retired) ones included — matching the live
        frontend's count.  Finished members lingering in a static batch
        don't count."""
        return sum(len(pod.queue)
                   + sum(1 for s in pod.slots if s.remaining > 0)
                   for pod in self.pods.values() if pod.fn == fn)

    def gpu_utilization(self, last_n: int = 10) -> float:
        live = [n for n in self.nodes if n.alive and n.pods]
        if not live:
            return 0.0
        return sum(n.scheduler.utilization(last_n) for n in live) / len(live)

    def sm_occupancy(self, last_n: int = 10) -> float:
        live = [n for n in self.nodes if n.alive and n.pods]
        if not live:
            return 0.0
        return sum(n.scheduler.occupancy(last_n) for n in live) / len(live)

    def nodes_in_use(self) -> int:
        return sum(1 for n in self.nodes if n.alive and n.pods)
