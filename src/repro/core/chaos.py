"""Deterministic chaos harness: one seeded fault schedule, two fleets.

Robustness claims are only credible when the faults that back them are
reproducible.  This module separates chaos into three pieces so the SAME
schedule can be replayed against the simulator and the live JAX fleet:

* **ChaosSchedule** — a frozen, seeded list of :class:`FaultEvent`s.
  ``ChaosSchedule.generate(seed, ...)`` derives every event (time, kind,
  node, magnitude, duration) from its own ``np.random.default_rng(seed)``
  — no wall-clock randomness, so two runs with the same seed inject
  byte-identical fault sequences.
* **Targets** — thin adapters mapping each fault kind onto one backend:
  ``SimChaosTarget`` over ``repro.core.cluster.Cluster`` and
  ``LiveChaosTarget`` over ``repro.serving.frontend.ClusterFrontend``.
  Every non-kill fault returns an undo closure, so bounded-duration
  faults restore cleanly.
* **ChaosInjector** — the clock-agnostic replayer: ``advance(now)``
  applies every event (and expires every bounded fault) whose time has
  come, in deterministic order.  The caller owns the clock — virtual
  ticks for the simulator, wall time for the live fleet — which is what
  lets one schedule drive both through identical logical timelines.

Fault kinds and their per-backend semantics:

==============  ==================================  =========================
kind            simulator                           live fleet
==============  ==================================  =========================
``kill``        ``Cluster.fail_node``               ``ClusterFrontend.fail_node``
``straggler``   ``Node.slowdown *= magnitude``      ``engine.pump_delay_s`` +=
                (rounds dilate, health EWMA          unit x (magnitude - 1)
                rises toward magnitude)              (passes dilate inside the
                                                     timed region)
``link``        all links touching ``node``         same, through the shared
                divided by ``magnitude``             ``NetworkLinks`` table
``kv_pressure`` ``Node.mem_bytes /= magnitude``     fleet admission budget
                (per-node admission shrinks)         ``mem_bytes /= magnitude``
==============  ==================================  =========================

``kill`` is permanent (restore would be resurrection); the other kinds
honour ``duration`` and restore exactly what they changed.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Callable, Optional

import numpy as np

FAULT_KINDS = ("kill", "straggler", "link", "kv_pressure")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``kind`` hits ``node`` at time ``at``.

    ``magnitude`` is the severity knob (slowdown factor for stragglers,
    bandwidth/memory divisor for link/kv faults; ignored by ``kill``).
    ``duration`` bounds non-kill faults — the injector restores the
    original state at ``at + duration``; None means permanent.
    """

    at: float
    kind: str
    node: int
    magnitude: float = 2.0
    duration: Optional[float] = None

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.at < 0:
            raise ValueError(f"fault time must be >= 0, got {self.at}")
        if self.kind != "kill" and self.magnitude <= 1.0:
            raise ValueError(
                f"{self.kind} magnitude must be > 1, got {self.magnitude}")
        if self.duration is not None and self.duration <= 0:
            raise ValueError(f"duration must be > 0, got {self.duration}")


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """A frozen fault timeline, optionally derived from a seed."""

    events: tuple[FaultEvent, ...]
    seed: int = 0

    @classmethod
    def generate(cls, seed: int, *, duration: float, n_nodes: int,
                 n_events: int = 6,
                 kinds: tuple[str, ...] = FAULT_KINDS,
                 max_kills: Optional[int] = None,
                 fault_duration: float | None = None) -> "ChaosSchedule":
        """Derive a schedule entirely from ``seed`` (deterministic).

        Kills draw nodes without replacement and are capped at
        ``max_kills`` (default ``n_nodes - 1``) so at least one node
        survives; non-kill faults get ``fault_duration`` (default a
        quarter of the horizon) and a magnitude in [2, 5).
        """
        if n_nodes < 1:
            raise ValueError("need at least one node")
        rng = np.random.default_rng(seed)
        if max_kills is None:
            max_kills = n_nodes - 1
        if fault_duration is None:
            fault_duration = duration / 4.0
        killable = list(rng.permutation(n_nodes))
        events = []
        for _ in range(n_events):
            kind = kinds[int(rng.integers(len(kinds)))]
            at = float(rng.uniform(0.0, duration))
            if kind == "kill":
                if max_kills <= 0 or not killable:
                    kind = "straggler"  # kill budget spent: degrade instead
                else:
                    max_kills -= 1
                    events.append(FaultEvent(at=at, kind="kill",
                                             node=int(killable.pop())))
                    continue
            events.append(FaultEvent(
                at=at, kind=kind, node=int(rng.integers(n_nodes)),
                magnitude=float(rng.uniform(2.0, 5.0)),
                duration=float(fault_duration)))
        events.sort(key=lambda e: (e.at, e.node, e.kind))
        return cls(events=tuple(events), seed=seed)


class SimChaosTarget:
    """Fault application over the discrete-event ``Cluster``."""

    def __init__(self, cluster: Any):
        self.cluster = cluster

    def kill(self, node: int) -> None:
        if self.cluster.nodes[node].alive:
            self.cluster.fail_node(node)
        return None

    def straggler(self, node: int, magnitude: float) -> Callable[[], None]:
        n = self.cluster.nodes[node]
        prev = n.slowdown
        n.slowdown = prev * magnitude

        def undo() -> None:
            n.slowdown = prev
        return undo

    def link(self, node: int, magnitude: float) -> Callable[[], None]:
        links = self.cluster.links
        prev = {other: links.bandwidth(node, other)
                for other in range(links.n_nodes) if other != node}
        for other, bps in prev.items():
            links.set_link(node, other, bps / magnitude)

        def undo() -> None:
            for other, bps in prev.items():
                links.set_link(node, other, bps)
        return undo

    def kv_pressure(self, node: int, magnitude: float) -> Callable[[], None]:
        n = self.cluster.nodes[node]
        prev = n.mem_bytes
        n.mem_bytes = int(prev / magnitude)

        def undo() -> None:
            n.mem_bytes = prev
        return undo


class LiveChaosTarget:
    """Fault application over the live ``ClusterFrontend``.

    ``straggler_unit_s`` converts the schedule's dimensionless slowdown
    factor into the engine's pump-delay hook: a magnitude-M straggler
    sleeps ``unit x (M - 1)`` seconds INSIDE each pass's timed region, so
    the degradation shows up in both the health EWMAs and the token
    scheduler's measured quota usage — a gray failure, not a crash.
    """

    def __init__(self, frontend: Any, straggler_unit_s: float = 0.02):
        self.frontend = frontend
        self.straggler_unit_s = straggler_unit_s

    def kill(self, node: int) -> None:
        if self.frontend.engines[node].alive:
            self.frontend.fail_node(node)
        return None

    def straggler(self, node: int, magnitude: float) -> Callable[[], None]:
        eng = self.frontend.engines[node]
        prev = eng.pump_delay_s
        eng.pump_delay_s = prev + self.straggler_unit_s * (magnitude - 1.0)

        def undo() -> None:
            eng.pump_delay_s = prev
        return undo

    def link(self, node: int, magnitude: float) -> Callable[[], None]:
        links = self.frontend.links
        prev = {other: links.bandwidth(node, other)
                for other in range(links.n_nodes) if other != node}
        for other, bps in prev.items():
            links.set_link(node, other, bps / magnitude)

        def undo() -> None:
            for other, bps in prev.items():
                links.set_link(node, other, bps)
        return undo

    def kv_pressure(self, node: int, magnitude: float) -> Callable[[], None]:
        # The live admission budget is fleet-wide (one mem_bytes for all
        # nodes), so KV pressure squeezes every node's headroom at once.
        prev = self.frontend.mem_bytes
        self.frontend.mem_bytes = int(prev / magnitude)

        def undo() -> None:
            self.frontend.mem_bytes = prev
        return undo


class ChaosInjector:
    """Replay one schedule against one target, clock supplied by caller.

    ``advance(now)`` applies every not-yet-applied event with
    ``event.at <= now`` (and runs every due restore) in deterministic
    (time, insertion) order.  Call it at the top of each control tick with
    the same logical timestamps on both backends and the two fleets see
    identical fault histories.
    """

    def __init__(self, schedule: ChaosSchedule, target: Any):
        self.schedule = schedule
        self.target = target
        self._seq = itertools.count()
        # (time, seq, fn): applies and restores share one heap so a
        # restore due before a later fault runs first.
        self._heap: list[tuple[float, int, Callable[[], Any]]] = []
        for ev in schedule.events:
            heapq.heappush(self._heap,
                           (ev.at, next(self._seq),
                            lambda e=ev: self._apply(e)))
        self.applied: list[tuple[float, FaultEvent]] = []

    def _apply(self, ev: FaultEvent) -> None:
        undo = getattr(self.target, ev.kind)(**self._kwargs(ev))
        self.applied.append((ev.at, ev))
        if undo is not None and ev.duration is not None:
            heapq.heappush(self._heap,
                           (ev.at + ev.duration, next(self._seq), undo))

    @staticmethod
    def _kwargs(ev: FaultEvent) -> dict[str, Any]:
        if ev.kind == "kill":
            return {"node": ev.node}
        return {"node": ev.node, "magnitude": ev.magnitude}

    def advance(self, now: float) -> int:
        """Apply everything due at or before ``now``; returns the number
        of actions (faults + restores) executed."""
        n = 0
        while self._heap and self._heap[0][0] <= now + 1e-12:
            _, _, fn = heapq.heappop(self._heap)
            fn()
            n += 1
        return n

    def pending(self) -> int:
        """Scheduled actions (faults or restores) not yet due."""
        return len(self._heap)
