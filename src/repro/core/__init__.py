"""FaST-GShare core: the paper's spatio-temporal sharing control plane."""

from repro.core.cluster import Cluster, Node, Simulator
from repro.core.manager import Token, TokenScheduler, fair_share_baseline
from repro.core.maximal_rectangles import MaxRectsNode, MaxRectsPool, Placement
from repro.core.model_sharing import MemoryModel, ModelStore, pytree_nbytes
from repro.core.profiler import (ProfileDB, TrialResult, measure_callable_trial,
                                 profile_function, simulate_trial)
from repro.core.resources import SCALE, Alloc, Rect
from repro.core.scaling import (FunctionPodQueue, ProfilePoint, ScaleDecision,
                                heuristic_scale, processing_gap)
from repro.core.slo import SLORecorder
from repro.core.workload import (PAPER_ZOO, Request, ServiceCurve,
                                 diurnal_trace, poisson_arrivals,
                                 predicted_rps, trace_arrivals)

__all__ = [
    "Alloc", "Rect", "SCALE",
    "TokenScheduler", "Token", "fair_share_baseline",
    "MaxRectsPool", "MaxRectsNode", "Placement",
    "ModelStore", "MemoryModel", "pytree_nbytes",
    "ProfilePoint", "ScaleDecision", "FunctionPodQueue",
    "heuristic_scale", "processing_gap",
    "ProfileDB", "TrialResult", "profile_function", "simulate_trial",
    "measure_callable_trial",
    "Cluster", "Node", "Simulator",
    "SLORecorder",
    "ServiceCurve", "PAPER_ZOO", "Request",
    "poisson_arrivals", "trace_arrivals", "diurnal_trace", "predicted_rps",
]
