"""SLO accounting: tiers, deadlines, retry policy, and goodput recording."""

from __future__ import annotations

import bisect
import dataclasses
from typing import Optional

import numpy as np

# ---------------------------------------------------------------------------
# SLO tiers and typed request outcomes
# ---------------------------------------------------------------------------

#: Never shed, never expired, retried without bound: losing one is a bug.
TIER_GUARANTEED = "guaranteed"
#: The default: sheddable under load, bounded retries.  Dormant unless a
#: deadline is configured — with no deadline the tier behaves exactly like
#: the pre-SLO plane.
TIER_BEST_EFFORT = "best_effort"
#: Preemptible batch lane: same shedding rules as best-effort, but queued
#: BEHIND every non-batch request (guaranteed/best-effort admissions insert
#: ahead of parked batch work).
TIER_BATCH = "batch"

SLO_TIERS = (TIER_GUARANTEED, TIER_BEST_EFFORT, TIER_BATCH)

#: Typed request outcomes (the "reject fast" contract): a request that will
#: not complete gets exactly one of these instead of parking forever.
OUTCOME_SHED = "shed"          # rejected at admission: cannot make deadline
OUTCOME_EXPIRED = "expired"    # deadline passed while queued
OUTCOME_REJECTED = "rejected"  # function unregistered / no longer servable
OUTCOME_FAILED = "failed"      # retry budget exhausted after failures


def deadline_budget(tier: str, deadline_s: Optional[float],
                    slo_latency: Optional[float]) -> Optional[float]:
    """Per-request deadline budget (seconds from arrival), or None.

    An explicit ``deadline_s`` always wins; a non-best-effort tier falls
    back to the latency SLO; the default (best-effort, no deadline) yields
    None — the whole deadline machinery stays dormant.
    """
    if deadline_s is not None:
        return deadline_s
    if tier != TIER_BEST_EFFORT:
        return slo_latency
    return None


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded jittered-backoff retry for stranded/timed-out requests.

    All randomness comes from the policy's own seeded PRNG stream — no
    wall-clock entropy ever enters a scheduling decision, so two fleets
    constructed with the same seed retry at identical offsets.
    """

    max_attempts: int = 3
    base_s: float = 0.05
    multiplier: float = 2.0
    jitter: float = 0.5      # fraction of the backoff added as U[0, jitter)
    seed: int = 0

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_s < 0 or self.multiplier < 1.0:
            raise ValueError("base_s >= 0 and multiplier >= 1 required")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")
        object.__setattr__(self, "_rng",
                           np.random.default_rng(self.seed))

    def delay(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (1-based)."""
        base = self.base_s * self.multiplier ** max(attempt - 1, 0)
        rng = getattr(self, "_rng")
        return base * (1.0 + self.jitter * float(rng.random()))

    def exhausted(self, attempt: int) -> bool:
        return attempt >= self.max_attempts


def record_arrival(log: dict[str, list[float]], horizons: dict[str, float],
                   fn: str, now: float, retention: float = 60.0) -> None:
    """Append an arrival timestamp with append-side pruning.

    Entries older than the largest observation window asked of ``fn`` (or
    ``retention``, whichever is larger) are dropped opportunistically, so
    the log stays bounded even when nobody ever polls ``observed_rate``
    (e.g. a spec with an explicit target-RPS source).
    """
    ts = log.setdefault(fn, [])
    ts.append(now)
    horizon = max(horizons.get(fn, 0.0), retention)
    if len(ts) > 1024 and ts[0] < now - 2 * horizon:
        del ts[:bisect.bisect_right(ts, now - horizon)]


def observed_rate(log: dict[str, list[float]], horizons: dict[str, float],
                  fn: str, window: float, now: float) -> float:
    """Trailing-window event rate over a per-function timestamp log.

    Prunes opportunistically: timestamps older than the largest window
    ever asked of ``fn`` are dropped, so long-lived gateways don't grow
    their arrival logs without bound.
    """
    if window <= 0:
        return 0.0
    ts = log.get(fn)
    if not ts:
        return 0.0
    horizons[fn] = max(window, horizons.get(fn, 0.0))
    cut = bisect.bisect_right(ts, now - horizons[fn])
    if cut:
        del ts[:cut]
    lo = bisect.bisect_right(ts, now - window)
    return (len(ts) - lo) / window


@dataclasses.dataclass
class SLORecorder:
    """Streaming latency recorder for one function.

    Beyond latency percentiles it tracks the *goodput* view: a completion
    is counted deadline-met or deadline-missed, and the non-completions
    (shed at admission, expired in queue, lost to retry exhaustion) are
    tallied so ``goodput()`` is honest about every request the gateway
    accepted responsibility for.
    """

    fn: str
    slo_latency: Optional[float] = None  # seconds; None = best-effort
    latencies: list[float] = dataclasses.field(default_factory=list)
    completion_times: list[float] = dataclasses.field(default_factory=list)
    deadline_met: int = 0
    deadline_missed: int = 0
    shed: int = 0
    expired: int = 0
    lost: int = 0

    def record(self, latency: float, completed_at: float,
               deadline_met: Optional[bool] = None) -> None:
        self.latencies.append(latency)
        self.completion_times.append(completed_at)
        # A request with no deadline (the dormant default) counts as met.
        if deadline_met is None or deadline_met:
            self.deadline_met += 1
        else:
            self.deadline_missed += 1

    def record_shed(self) -> None:
        self.shed += 1

    def record_expired(self) -> None:
        self.expired += 1

    def record_lost(self) -> None:
        self.lost += 1

    def goodput(self) -> float:
        """Fraction of accepted-or-offered requests that completed in time."""
        total = (self.deadline_met + self.deadline_missed
                 + self.shed + self.expired + self.lost)
        if total == 0:
            return 1.0
        return self.deadline_met / total

    def count(self) -> int:
        return len(self.latencies)

    def percentile(self, q: float, since: float = 0.0) -> float:
        lats = self._window(since)
        return float(np.percentile(lats, q)) if lats else 0.0

    def p50(self, since: float = 0.0) -> float:
        return self.percentile(50, since)

    def p99(self, since: float = 0.0) -> float:
        return self.percentile(99, since)

    def violation_ratio(self, since: float = 0.0) -> float:
        """Fraction of requests exceeding the SLO (paper: <=1% for ResNet)."""
        if self.slo_latency is None:
            return 0.0
        lats = self._window(since)
        if not lats:
            return 0.0
        return sum(1 for l in lats if l > self.slo_latency) / len(lats)

    def throughput(self, t_start: float, t_end: float) -> float:
        lo = bisect.bisect_left(self.completion_times, t_start)
        hi = bisect.bisect_right(self.completion_times, t_end)
        dur = max(t_end - t_start, 1e-9)
        return (hi - lo) / dur

    def _window(self, since: float) -> list[float]:
        if since <= 0.0:
            return self.latencies
        lo = bisect.bisect_left(self.completion_times, since)
        return self.latencies[lo:]
