"""SLO accounting: per-function latency recorder and violation ratios."""

from __future__ import annotations

import bisect
import dataclasses
from typing import Optional

import numpy as np


def record_arrival(log: dict[str, list[float]], horizons: dict[str, float],
                   fn: str, now: float, retention: float = 60.0) -> None:
    """Append an arrival timestamp with append-side pruning.

    Entries older than the largest observation window asked of ``fn`` (or
    ``retention``, whichever is larger) are dropped opportunistically, so
    the log stays bounded even when nobody ever polls ``observed_rate``
    (e.g. a spec with an explicit target-RPS source).
    """
    ts = log.setdefault(fn, [])
    ts.append(now)
    horizon = max(horizons.get(fn, 0.0), retention)
    if len(ts) > 1024 and ts[0] < now - 2 * horizon:
        del ts[:bisect.bisect_right(ts, now - horizon)]


def observed_rate(log: dict[str, list[float]], horizons: dict[str, float],
                  fn: str, window: float, now: float) -> float:
    """Trailing-window event rate over a per-function timestamp log.

    Prunes opportunistically: timestamps older than the largest window
    ever asked of ``fn`` are dropped, so long-lived gateways don't grow
    their arrival logs without bound.
    """
    if window <= 0:
        return 0.0
    ts = log.get(fn)
    if not ts:
        return 0.0
    horizons[fn] = max(window, horizons.get(fn, 0.0))
    cut = bisect.bisect_right(ts, now - horizons[fn])
    if cut:
        del ts[:cut]
    lo = bisect.bisect_right(ts, now - window)
    return (len(ts) - lo) / window


@dataclasses.dataclass
class SLORecorder:
    """Streaming latency recorder for one function."""

    fn: str
    slo_latency: Optional[float] = None  # seconds; None = best-effort
    latencies: list[float] = dataclasses.field(default_factory=list)
    completion_times: list[float] = dataclasses.field(default_factory=list)

    def record(self, latency: float, completed_at: float) -> None:
        self.latencies.append(latency)
        self.completion_times.append(completed_at)

    def count(self) -> int:
        return len(self.latencies)

    def percentile(self, q: float, since: float = 0.0) -> float:
        lats = self._window(since)
        return float(np.percentile(lats, q)) if lats else 0.0

    def p50(self, since: float = 0.0) -> float:
        return self.percentile(50, since)

    def p99(self, since: float = 0.0) -> float:
        return self.percentile(99, since)

    def violation_ratio(self, since: float = 0.0) -> float:
        """Fraction of requests exceeding the SLO (paper: <=1% for ResNet)."""
        if self.slo_latency is None:
            return 0.0
        lats = self._window(since)
        if not lats:
            return 0.0
        return sum(1 for l in lats if l > self.slo_latency) / len(lats)

    def throughput(self, t_start: float, t_end: float) -> float:
        lo = bisect.bisect_left(self.completion_times, t_start)
        hi = bisect.bisect_right(self.completion_times, t_end)
        dur = max(t_end - t_start, 1e-9)
        return (hi - lo) / dur

    def _window(self, since: float) -> list[float]:
        if since <= 0.0:
            return self.latencies
        lo = bisect.bisect_left(self.completion_times, since)
        return self.latencies[lo:]
