"""Maximal Rectangles Algorithm (paper Alg. 2) for 2D GPU/TPU-node packing.

A node's spatio-temporal capacity is the rectangle ``W x H = 100% quota x
100% SMs``.  Each node keeps a list of *free* rectangles — maximal, possibly
overlapping, axis-aligned — representing resources available to new pods.

Placement of a pod rectangle ``F`` follows the paper exactly:

1. **Global best matching** (line 1): across all nodes, pick the free
   rectangle with the minimum ``Area(R) - Area(F)`` that fits ``F`` (the
   paper's ``secondCores`` best-area-fit).  Ties prefer lower node index,
   then bottom-left position, for determinism.
2. **PlaceAndNewJointRect** (line 5): place ``F`` at the bottom-left of the
   chosen rectangle and create the two *maximal* complement rectangles
   (right strip, full height; top strip, full width) — Fig. 6 left.
3. **Intersection update** (lines 8-14): every other free rectangle that
   intersects the placed pod is subdivided into up to four maximal
   complements — Fig. 6 right.
4. **Redundant-rectangle removal** (lines 15-19): free rectangles fully
   contained in another are dropped.
5. **Keep-restructure reclamation** (§3.4.2): freed pod rectangles are put
   back verbatim (cheap reuse by the same function); once the free list
   exceeds ``restructure_threshold``, the node is re-initialized to one
   ``W x H`` rectangle and the live pods are re-subtracted.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from repro.core.resources import FULL_NODE, SCALE, Alloc, Rect, total_free_area


@dataclasses.dataclass(frozen=True)
class Placement:
    """A pod bound to a node at a concrete rectangle."""

    node: int
    rect: Rect
    pod_id: str


def _split_place_and_new_joint(free: Rect, w: int, h: int) -> tuple[Rect, Rect, Rect]:
    """Place a w*h pod at the bottom-left of ``free``; return (pod, R', R'').

    R' and R'' are the two *maximal* complements (paper Fig. 6): the right
    strip keeps the full height of ``free``; the top strip keeps its full
    width.  They overlap in the top-right corner by design — free rectangles
    are not mutually exclusive.
    """
    pod = Rect(free.x, free.y, w, h)
    right = Rect(free.x + w, free.y, free.w - w, free.h)
    top = Rect(free.x, free.y + h, free.w, free.h - h)
    return pod, right, top


def _subdivide(rect: Rect, hole: Rect) -> list[Rect]:
    """Maximal sub-rectangles of ``rect`` minus ``hole`` (paper ``Subdivide``).

    Up to four complements (left/right strips full height, bottom/top strips
    full width), each maximal in its direction.
    """
    inter = rect.intersection(hole)
    if inter is None:
        return [rect]
    out: list[Rect] = []
    if inter.x > rect.x:  # left
        out.append(Rect(rect.x, rect.y, inter.x - rect.x, rect.h))
    if inter.x2 < rect.x2:  # right
        out.append(Rect(inter.x2, rect.y, rect.x2 - inter.x2, rect.h))
    if inter.y > rect.y:  # bottom
        out.append(Rect(rect.x, rect.y, rect.w, inter.y - rect.y))
    if inter.y2 < rect.y2:  # top
        out.append(Rect(rect.x, inter.y2, rect.w, rect.y2 - inter.y2))
    return [r for r in out if not r.is_empty()]


def _prune_contained(rects: list[Rect]) -> list[Rect]:
    """Remove rectangles contained in another (paper lines 15-19)."""
    keep: list[Rect] = []
    for i, r in enumerate(rects):
        contained = False
        for j, other in enumerate(rects):
            if i == j:
                continue
            if other.contains(r) and not (r == other and i < j):
                contained = True
                break
        if not contained:
            keep.append(r)
    # Dedup identical rects (mutual containment keeps the first).
    seen: set[Rect] = set()
    out = []
    for r in keep:
        if r not in seen:
            seen.add(r)
            out.append(r)
    return out


class MaxRectsNode:
    """Free-rectangle bookkeeping for one accelerator node."""

    def __init__(self, node_id: int, restructure_threshold: int = 24):
        self.node_id = node_id
        self.free: list[Rect] = [FULL_NODE]
        self.placements: dict[str, Rect] = {}
        self.restructure_threshold = restructure_threshold
        self.restructure_count = 0
        self.offline = False  # cordoned: failed or straggling node

    # -- queries ---------------------------------------------------------

    def best_fit(self, w: int, h: int) -> Optional[Rect]:
        """Smallest-area free rectangle that fits w*h (best-area-fit)."""
        if self.offline:
            return None
        best: Optional[Rect] = None
        for r in self.free:
            if r.fits(w, h) and (best is None or r.area < best.area
                                 or (r.area == best.area and (r.y, r.x) < (best.y, best.x))):
                best = r
        return best

    def used_area(self) -> int:
        return sum(r.area for r in self.placements.values())

    def free_area(self) -> int:
        """Exact un-allocated area (free rects overlap; use union)."""
        return total_free_area(self.free)

    def fragmentation(self) -> float:
        """1 - (largest placeable rect area / total free area)."""
        free = self.free_area()
        if free == 0:
            return 0.0
        largest = max((r.area for r in self.free), default=0)
        return 1.0 - largest / free

    # -- mutation --------------------------------------------------------

    def place_in(self, target: Rect, pod_id: str, w: int, h: int) -> Rect:
        """Place pod into ``target`` (must be in the free list)."""
        if target not in self.free:
            raise ValueError(f"rect {target} not free on node {self.node_id}")
        pod, right, top = _split_place_and_new_joint(target, w, h)
        new_free = [r for r in self.free if r != target]
        new_free += [r for r in (right, top) if not r.is_empty()]
        # Intersection update against the placed pod rectangle.
        updated: list[Rect] = []
        for r in new_free:
            if r.intersects(pod):
                updated.extend(_subdivide(r, pod))
            else:
                updated.append(r)
        self.free = _prune_contained(updated)
        self.placements[pod_id] = pod
        return pod

    def release(self, pod_id: str) -> None:
        """Keep-restructure reclamation (§3.4.2)."""
        rect = self.placements.pop(pod_id)
        self.free.append(rect)
        self.free = _prune_contained(self.free)
        if len(self.free) > self.restructure_threshold:
            self.restructure()

    def restructure(self) -> None:
        """Re-initialize to W x H and re-subtract live pods."""
        self.restructure_count += 1
        free = [FULL_NODE]
        for pod in self.placements.values():
            nxt: list[Rect] = []
            for r in free:
                nxt.extend(_subdivide(r, pod) if r.intersects(pod) else [r])
            free = nxt
        self.free = _prune_contained(free)


class MaxRectsPool:
    """The paper's node-selection scheduler over ``n`` nodes (Alg. 2)."""

    def __init__(self, n_nodes: int, restructure_threshold: int = 24,
                 allow_grow: bool = True):
        self.nodes: list[MaxRectsNode] = [
            MaxRectsNode(i, restructure_threshold) for i in range(n_nodes)
        ]
        self.allow_grow = allow_grow
        self._seq = itertools.count()

    # -- Alg. 2 entry point ------------------------------------------------

    def schedule(self, alloc: Alloc, pod_id: str,
                 exclude: frozenset[int] | set[int] = frozenset()
                 ) -> Optional[Placement]:
        """Bind a pod to the globally best-fitting node rectangle.

        ``exclude`` skips nodes the caller found infeasible on other
        dimensions (e.g. memory admission).  Returns None when no rectangle
        fits and growing is disabled; otherwise grows the pool by one node
        ("A new GPU required").
        """
        w, h = alloc.width_m, alloc.height_m
        best: Optional[tuple[int, Rect]] = None
        for node in self.nodes:
            if node.node_id in exclude:
                continue
            r = node.best_fit(w, h)
            if r is None:
                continue
            # argmin over Area(R) - Area(F); Area(F) is constant, so this is
            # best-area-fit.  Ties go to the lowest node id (determinism).
            if best is None or r.area < best[1].area:
                best = (node.node_id, r)
        if best is None:
            if not self.allow_grow:
                return None
            node = MaxRectsNode(len(self.nodes),
                                self.nodes[0].restructure_threshold
                                if self.nodes else 24)
            self.nodes.append(node)
            best = (node.node_id, FULL_NODE)
        node_id, target = best
        pod = self.nodes[node_id].place_in(target, pod_id, w, h)
        return Placement(node=node_id, rect=pod, pod_id=pod_id)

    def schedule_batch(self, allocs: list[tuple[Alloc, str]]
                       ) -> list[Optional[Placement]]:
        """Schedule a batch largest-first (decreasing best-area-fit).

        Scaling events deliver pods in function order; packing them in
        descending ``secondCores`` order is the classic decreasing-fit
        refinement of 2D bin packing and is what lets the paper's Fig.-11
        mix (2x bert 60x50 + 2x rnnt + 4x resnet) land on a single node.
        Results are returned in the caller's original order.
        """
        order = sorted(range(len(allocs)),
                       key=lambda i: -allocs[i][0].second_cores)
        out: list[Optional[Placement]] = [None] * len(allocs)
        for i in order:
            alloc, pod_id = allocs[i]
            out[i] = self.schedule(alloc, pod_id)
        return out

    def release(self, placement: Placement) -> None:
        self.nodes[placement.node].release(placement.pod_id)

    def cordon(self, node_id: int) -> None:
        """Take a node out of scheduling (failure / straggler drain)."""
        self.nodes[node_id].offline = True

    def uncordon(self, node_id: int) -> None:
        self.nodes[node_id].offline = False

    def drain_node(self, node_id: int) -> list[str]:
        """Cordon a node and drop all its placements (node failure)."""
        node = self.nodes[node_id]
        node.offline = True
        evicted = list(node.placements)
        node.placements.clear()
        node.restructure()
        return evicted

    # -- metrics -----------------------------------------------------------

    def fragmentation(self) -> dict[int, float]:
        """Per-node MRA fragmentation (offline nodes excluded).

        A node reads 0.0 both when fully free and when fully packed; it
        rises when the free area is shattered into rectangles none of
        which is close to the whole — the signal the reconciler's
        defragmentation pass keys on.
        """
        return {n.node_id: n.fragmentation()
                for n in self.nodes if not n.offline}

    def node_load(self) -> dict[int, float]:
        """Per-node allocated-area fraction (offline nodes excluded)."""
        return {n.node_id: n.used_area() / (SCALE * SCALE)
                for n in self.nodes if not n.offline}

    def nodes_in_use(self) -> int:
        return sum(1 for n in self.nodes if n.placements)

    def utilization(self) -> float:
        """Mean fraction of capacity allocated across nodes in use."""
        used = [n.used_area() / (SCALE * SCALE) for n in self.nodes if n.placements]
        return sum(used) / len(used) if used else 0.0

    def total_used_area(self) -> int:
        return sum(n.used_area() for n in self.nodes)
