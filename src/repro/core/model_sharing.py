"""Model sharing (paper §3.5): one weight copy per function per node.

The paper shares model tensors between instances of the same function via
CUDA IPC handles exported by a Model Storage Server.  The TPU/JAX analogue
(DESIGN.md §2) is **weight-buffer aliasing**: jitted executables are pure
functions of their inputs, so N instances of a function can be passed *the
same* device-resident param pytree — the runtime never copies it.  What the
GPU design achieves with `cuIpcGetMemHandle`, JAX gets from referential
transparency; what remains to build is the *bookkeeping*: a per-node store
with STORE/GET semantics, refcounts, eviction, and exact memory accounting
(reproducing Fig. 13).

Two layers:

* ``ModelStore`` — the live store used by the serving engine; holds real
  pytrees (JAX arrays or numpy) keyed by (function, tensor-set id).
* ``MemoryModel`` — closed-form per-node accelerator-memory accounting used
  by the scheduler's admission control and the Fig.-13 benchmark:
  ``no_share(n) = n * (framework + weights)``;
  ``share(n) = (weights + server_overhead) + n * framework``.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

# Fixed per-model overhead of the storage-server process context measured by
# the paper on V100 (§5.5, hatched areas of Fig. 13).
SERVER_CONTEXT_OVERHEAD = 300 * 1024 * 1024


def pytree_nbytes(tree: Any) -> int:
    """Total bytes of all leaf buffers in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        else:
            total += int(np.asarray(leaf).nbytes)
    return total


@dataclasses.dataclass
class _Entry:
    tree: Any
    nbytes: int
    refcount: int = 0


class ModelStore:
    """Per-node shared weight store with STORE()/GET() (paper Fig. 7).

    ``get`` is the hot path: it returns the stored pytree *by reference*
    (zero-copy) and bumps the refcount; ``put_back`` releases.  A miss with a
    ``loader`` triggers the STORE path, exactly like the paper's GET-miss
    falling back to STORE.
    """

    def __init__(self, capacity_bytes: Optional[int] = None):
        self._entries: dict[str, _Entry] = {}
        self._lock = threading.Lock()
        self.capacity_bytes = capacity_bytes
        self.stores = 0
        self.hits = 0
        self.misses = 0

    # -- STORE -----------------------------------------------------------

    def store(self, key: str, tree: Any) -> int:
        """Insert (or overwrite) the tensor set for ``key``; returns bytes."""
        nbytes = pytree_nbytes(tree)
        with self._lock:
            if self.capacity_bytes is not None:
                projected = self.used_bytes_locked() + nbytes - (
                    self._entries[key].nbytes if key in self._entries else 0
                )
                if projected > self.capacity_bytes:
                    self._evict_locked(projected - self.capacity_bytes)
            old = self._entries.get(key)
            refcount = old.refcount if old else 0
            self._entries[key] = _Entry(tree=tree, nbytes=nbytes, refcount=refcount)
            self.stores += 1
        return nbytes

    # -- GET -------------------------------------------------------------

    def get(self, key: str, loader: Optional[Callable[[], Any]] = None) -> Any:
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                entry.refcount += 1
                self.hits += 1
                return entry.tree
            self.misses += 1
        if loader is None:
            raise KeyError(f"model {key!r} not in store and no loader given")
        tree = loader()
        self.store(key, tree)
        with self._lock:
            self._entries[key].refcount += 1
        return tree

    def put_back(self, key: str) -> None:
        with self._lock:
            entry = self._entries[key]
            if entry.refcount <= 0:
                raise RuntimeError(f"refcount underflow for {key!r}")
            entry.refcount -= 1

    # -- accounting / eviction --------------------------------------------

    def used_bytes_locked(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def used_bytes(self) -> int:
        with self._lock:
            return self.used_bytes_locked()

    def refcount(self, key: str) -> int:
        with self._lock:
            return self._entries[key].refcount if key in self._entries else 0

    def contains(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    def _evict_locked(self, need_bytes: int) -> None:
        """Evict unreferenced entries (largest first) to free ``need_bytes``."""
        freed = 0
        victims = sorted(
            (k for k, e in self._entries.items() if e.refcount == 0),
            key=lambda k: -self._entries[k].nbytes,
        )
        for k in victims:
            if freed >= need_bytes:
                break
            freed += self._entries.pop(k).nbytes
        if freed < need_bytes:
            raise MemoryError(
                f"model store over capacity: need {need_bytes} more bytes but "
                f"only {freed} evictable"
            )


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Closed-form footprint of a function's instances on one node (Fig. 13).

    ``framework_bytes`` is the per-instance runtime footprint (framework,
    activations, CUDA/XLA context); ``weight_bytes`` the parameters.
    """

    weight_bytes: int
    framework_bytes: int
    server_overhead: int = SERVER_CONTEXT_OVERHEAD

    def footprint(self, n_instances: int, sharing: bool, *,
                  server: bool = True) -> int:
        """Bytes ``n_instances`` of this function occupy on a node.

        ``server=False`` drops the storage-server context from the shared
        footprint — used by :func:`node_shared_footprint` when ONE store
        tier owns every function's weights on the node, so the context is
        charged once per tier rather than once per function.
        """
        if n_instances == 0:
            return 0
        if not sharing:
            return n_instances * (self.weight_bytes + self.framework_bytes)
        server_bytes = self.server_overhead if server else 0
        return (self.weight_bytes + server_bytes
                + n_instances * self.framework_bytes)

    def reduction(self, n_instances: int) -> float:
        """Fractional footprint reduction from sharing at ``n_instances``."""
        base = self.footprint(n_instances, sharing=False)
        shared = self.footprint(n_instances, sharing=True)
        return 1.0 - shared / base

    def max_instances(self, capacity_bytes: int, sharing: bool) -> int:
        """How many instances fit in ``capacity_bytes`` (Fig.-13 claim:
        7 ResNeXt pods with sharing vs 4 without on a 16G V100)."""
        n = 0
        while self.footprint(n + 1, sharing) <= capacity_bytes:
            n += 1
        return n


def node_shared_footprint(entries) -> int:
    """Node footprint when one store TIER owns every function's weights.

    The paper's Fig.-13 model charges one storage-server context per
    shared function; with the fleet model store there is exactly one
    server process per node, so the context is charged ONCE per node —
    ``max`` of the participating overheads, conservatively covering the
    largest context any function would have needed.

    ``entries`` iterates ``(MemoryModel, n_instances)`` pairs for the
    functions resident on the node (``n_instances == 0`` pairs are
    skipped).
    """
    total = 0
    overhead = 0
    for mm, n in entries:
        if n <= 0:
            continue
        total += mm.footprint(n, sharing=True, server=False)
        overhead = max(overhead, mm.server_overhead)
    return total + overhead
