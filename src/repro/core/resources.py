"""2D spatio-temporal resource algebra.

The paper formalizes an accelerator's resources as a rectangle
``W x H = 100% quota x 100% compute`` (GPU: SMs; TPU: chips of a node, see
DESIGN.md §2).  Every allocation is an ``Alloc`` — a (spatial fraction,
temporal quota) pair — and every placed allocation occupies an axis-aligned
``Rect`` inside a node's resource rectangle.

All fractions live in integer **milli-units** (1000 == 100%) to keep the
rectangle arithmetic exact; the public API accepts floats in [0, 1].
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

SCALE = 1000  # milli-units per 100%


def to_milli(x: float) -> int:
    """Convert a [0, 1] fraction to integer milli-units (round-half-up)."""
    m = int(round(x * SCALE))
    if m < 0 or m > SCALE:
        raise ValueError(f"fraction {x} outside [0, 1]")
    return m


def from_milli(m: int) -> float:
    return m / SCALE


@dataclasses.dataclass(frozen=True)
class Alloc:
    """A spatio-temporal allocation request.

    Attributes:
      sm: spatial fraction in [0,1] (paper: ``sm_partition`` %SMs; here: chip
        fraction of a node).
      quota_request: guaranteed temporal quota per window (paper Q_request).
      quota_limit: elastic maximum temporal quota per window (paper Q_limit).
      mem_bytes: accelerator memory demand (paper ``gpu_mem``).
    """

    sm: float
    quota_request: float
    quota_limit: float
    mem_bytes: int = 0

    def __post_init__(self) -> None:
        if not (0.0 < self.sm <= 1.0):
            raise ValueError(f"sm partition {self.sm} outside (0, 1]")
        if not (0.0 < self.quota_request <= self.quota_limit <= 1.0):
            raise ValueError(
                f"need 0 < quota_request <= quota_limit <= 1, got "
                f"{self.quota_request}, {self.quota_limit}"
            )
        if self.mem_bytes < 0:
            raise ValueError("mem_bytes must be >= 0")

    @property
    def width_m(self) -> int:
        """Temporal footprint in milli-units (rectangle width = quota)."""
        return to_milli(self.quota_request)

    @property
    def height_m(self) -> int:
        """Spatial footprint in milli-units (rectangle height = SM/chips)."""
        return to_milli(self.sm)

    @property
    def second_cores(self) -> float:
        """Paper's uniform 2D size metric: ``Quota x SMs``."""
        return self.quota_request * self.sm


@dataclasses.dataclass(frozen=True)
class Rect:
    """Axis-aligned rectangle in the (quota, SM) plane, milli-units.

    ``x`` spans the temporal axis (width W = quota), ``y`` the spatial axis
    (height H = SM fraction), matching Fig. 6 of the paper.
    """

    x: int
    y: int
    w: int
    h: int

    def __post_init__(self) -> None:
        if self.w < 0 or self.h < 0:
            raise ValueError(f"negative extent: {self}")

    @property
    def x2(self) -> int:
        return self.x + self.w

    @property
    def y2(self) -> int:
        return self.y + self.h

    @property
    def area(self) -> int:
        return self.w * self.h

    def is_empty(self) -> bool:
        return self.w == 0 or self.h == 0

    def fits(self, w: int, h: int) -> bool:
        return self.w >= w and self.h >= h

    def contains(self, other: "Rect") -> bool:
        return (
            self.x <= other.x
            and self.y <= other.y
            and self.x2 >= other.x2
            and self.y2 >= other.y2
        )

    def intersects(self, other: "Rect") -> bool:
        return not (
            other.x >= self.x2
            or other.x2 <= self.x
            or other.y >= self.y2
            or other.y2 <= self.y
        )

    def intersection(self, other: "Rect") -> "Rect | None":
        if not self.intersects(other):
            return None
        x = max(self.x, other.x)
        y = max(self.y, other.y)
        return Rect(x, y, min(self.x2, other.x2) - x, min(self.y2, other.y2) - y)

    def cells(self) -> Iterator[tuple[int, int]]:  # pragma: no cover - debug aid
        for i in range(self.x, self.x2):
            for j in range(self.y, self.y2):
                yield (i, j)


FULL_NODE = Rect(0, 0, SCALE, SCALE)  # W x H = 100% quota x 100% SMs


def rect_for(alloc: Alloc, x: int, y: int) -> Rect:
    """Rectangle occupied by ``alloc`` when placed at (x, y)."""
    return Rect(x, y, alloc.width_m, alloc.height_m)


def total_free_area(rects: list[Rect]) -> int:
    """Exact area of the union of (possibly overlapping) free rectangles.

    Sweep-line over x with interval merging over y.  Used by tests and by the
    fragmentation metric; O(n^2 log n) is fine at control-plane sizes.
    """
    xs = sorted({r.x for r in rects} | {r.x2 for r in rects})
    area = 0
    for x0, x1 in zip(xs, xs[1:]):
        spans = sorted(
            (r.y, r.y2) for r in rects if r.x <= x0 and r.x2 >= x1
        )
        covered = 0
        cur_lo = cur_hi = None
        for lo, hi in spans:
            if cur_hi is None:
                cur_lo, cur_hi = lo, hi
            elif lo <= cur_hi:
                cur_hi = max(cur_hi, hi)
            else:
                covered += cur_hi - cur_lo
                cur_lo, cur_hi = lo, hi
        if cur_hi is not None:
            covered += cur_hi - cur_lo
        area += covered * (x1 - x0)
    return area
