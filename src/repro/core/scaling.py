"""Heuristic Scaling Algorithm (paper Alg. 1).

Given each function's RPS processing gap ``ΔRPS_j`` and the profile table
``P = {<F_j, S_p, Q_p, T_p>}`` from the FaST-Profiler, emit scale-up /
scale-down configuration decisions:

* Scale **up** (``ΔRPS_j >= 0``): choose the most *efficient* profile point
  ``p_eff = argmax_p RPR`` where ``RPR = T_p / (S_p * Q_p)`` ("RPS per
  Resource"); deploy ``n = floor(ΔRPS / T_eff)`` pods of it, then one
  minimal-but-sufficient ``p_ideal = argmin_p (T_p - r)`` with ``T_p > r``
  for the residual ``r``.
* Scale **down** (``ΔRPS_j < 0``): pop lowest-RPR running pods (the ``L_j``
  priority queue is kept in ascending RPR) while removing a pod keeps the
  remaining capacity sufficient (``ΔR + T_i <= 0``).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Iterable, Optional

from repro.core.resources import Alloc


@dataclasses.dataclass(frozen=True)
class ProfilePoint:
    """One profiler measurement: throughput T at allocation (S, Q)."""

    sm: float
    quota: float
    throughput: float  # requests/second
    p99_latency: float = 0.0  # seconds, used for SLO-feasibility filtering
    # Paged-KV block budget (TOTAL pool, incl. the null page) handed to an
    # instance placed at this point.  profile_points stamps one shared
    # budget on every point of a table — it does not scale with (sm, quota).
    # 0 = not profiled / dense slot pool.
    kv_blocks: int = 0
    # Shared-fraction axis: the profiled fraction of KV blocks expected to
    # be prefix-shared duplicates at this point's workload (0 = unshared /
    # not profiled).  ``paged_kv_capacity`` folds it into kv_blocks and the
    # live frontend discounts its KV admission charge by it — honest
    # over-admission backed by the engine's per-request worst-case
    # reservation and validated by observed ``kv_bytes_saved``.
    kv_shared_frac: float = 0.0
    # Speculation axis: the profiled speculation depth and the measured
    # draft-token acceptance fraction at this point's workload.  When
    # ``spec_k > 0`` the point's ``throughput`` is already *effective*
    # (verify rounds x expected_tokens_per_round(spec_k, acceptance)), so
    # Alg. 1 budgets real emitted tokens/s — 0 = not speculating.
    spec_k: int = 0
    acceptance: float = 0.0
    # Tensor-parallel axis: devices one pod of this point spans.  A sharded
    # point's ``throughput`` is the *aggregate* rate of the whole pod (the
    # profiler measures the pod, not a member), so Alg. 1 needs no special
    # casing — but its RPR divides by the full resource footprint below.
    shards: int = 1

    def __post_init__(self) -> None:
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if not 0.0 <= self.kv_shared_frac < 1.0:
            raise ValueError(
                f"kv_shared_frac must be in [0, 1), got "
                f"{self.kv_shared_frac}")
        if self.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {self.spec_k}")
        if not 0.0 <= self.acceptance <= 1.0:
            raise ValueError(
                f"acceptance must be in [0, 1], got {self.acceptance}")
        if self.acceptance > 0.0 and self.spec_k == 0:
            raise ValueError("acceptance > 0 needs spec_k > 0")

    @property
    def rpr(self) -> float:
        """RPS per Resource = T / (shards * S * Q).

        A sharded pod occupies one (S, Q) rectangle on *each* member
        device, so efficiency divides by the whole footprint — otherwise
        Alg. 1 would prefer an N-way pod over N independent pods with the
        same aggregate throughput despite identical resource use.
        """
        return self.throughput / (self.shards * self.sm * self.quota)

    def to_alloc(self, elastic_limit: float | None = None,
                 mem_bytes: int = 0) -> Alloc:
        limit = self.quota if elastic_limit is None else max(self.quota, elastic_limit)
        return Alloc(sm=self.sm, quota_request=self.quota,
                     quota_limit=limit, mem_bytes=mem_bytes)


def expected_tokens_per_round(k: int, acceptance: float) -> float:
    """Expected emitted tokens per speculative verify round under i.i.d.
    per-position acceptance probability ``a``: sum_{i=0..k} a^i =
    (1 - a^(k+1)) / (1 - a), saturating at ``k + 1`` for a = 1.  The factor
    the profiler scales verify-round throughput by to get *effective*
    tokens/s (the canonical definition; ``repro.serving.speculative``
    re-exports it)."""
    if k <= 0:
        return 1.0
    a = min(max(acceptance, 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    function: str
    point: ProfilePoint
    direction: int  # +1 scale-up, -1 scale-down
    # Scale-down: the concrete victim pod.  Scale-up: the provisional id the
    # algorithm pushed into L_j; the deployer replaces it with the real pod id
    # (or removes it if placement fails).
    pod_id: Optional[str] = None


@dataclasses.dataclass(order=True)
class _RunningPod:
    rpr: float
    seq: int
    pod_id: str = dataclasses.field(compare=False)
    point: ProfilePoint = dataclasses.field(compare=False)


class FunctionPodQueue:
    """Per-function priority queue L_j, ascending RPR (Alg. 1 input).

    Scale-up entries start *provisional*: Alg. 1 reserves capacity under a
    fresh pod id before any deployer has run, so repeated gap computations
    don't double-provision.  The deployer then settles each reservation with
    :meth:`confirm` (placement succeeded — re-key to the real pod id) or
    :meth:`abort` (placement failed — drop the reservation), keeping
    ``capacity()`` from drifting above what is actually running.
    """

    def __init__(self) -> None:
        self._heap: list[_RunningPod] = []
        self._ids: set[str] = set()  # pushed and not yet removed/popped
        self._dead: set[str] = set()
        self._seq = itertools.count()
        self._provisional: dict[str, ProfilePoint] = {}

    def push(self, pod_id: str, point: ProfilePoint) -> None:
        self._ids.add(pod_id)
        heapq.heappush(self._heap, _RunningPod(point.rpr, next(self._seq),
                                               pod_id, point))

    def push_provisional(self, pod_id: str, point: ProfilePoint) -> None:
        """Reserve capacity for a pod the deployer has not placed yet."""
        self._provisional[pod_id] = point
        self.push(pod_id, point)

    def confirm(self, provisional_id: str, real_id: str) -> None:
        """Placement succeeded: swap the reservation for the real pod id."""
        point = self._provisional.pop(provisional_id)
        self.remove(provisional_id)
        self.push(real_id, point)

    def abort(self, provisional_id: str) -> None:
        """Placement failed: release the reserved capacity."""
        self._provisional.pop(provisional_id)
        self.remove(provisional_id)

    def provisional_ids(self) -> set[str]:
        return set(self._provisional)

    def __contains__(self, pod_id: str) -> bool:
        return pod_id in self._ids

    def rekey(self, old_id: str, new_id: str) -> None:
        """Re-key a live entry (pod migration): same profile point, same
        capacity, a new concrete pod id.  Raises ``KeyError`` when
        ``old_id`` is not a live entry."""
        if old_id not in self._ids:
            raise KeyError(f"pod {old_id!r} is not in the queue")
        point = next(p.point for p in self._heap
                     if p.pod_id == old_id and p.pod_id not in self._dead)
        self.remove(old_id)
        self.push(new_id, point)

    def remove(self, pod_id: str) -> None:
        # No-op for ids never pushed (e.g. untracked pods a shared teardown
        # path retires) — a lazy tombstone for them would never be GC'd.
        if pod_id in self._ids:
            self._ids.discard(pod_id)
            self._dead.add(pod_id)

    def _gc(self) -> None:
        while self._heap and self._heap[0].pod_id in self._dead:
            self._dead.discard(heapq.heappop(self._heap).pod_id)

    def front(self) -> Optional[_RunningPod]:
        self._gc()
        return self._heap[0] if self._heap else None

    def pop(self) -> _RunningPod:
        self._gc()
        pod = heapq.heappop(self._heap)
        self._ids.discard(pod.pod_id)
        return pod

    def __len__(self) -> int:
        self._gc()
        return sum(1 for p in self._heap if p.pod_id not in self._dead)

    def capacity(self) -> float:
        self._gc()
        return sum(p.point.throughput for p in self._heap
                   if p.pod_id not in self._dead)


def heuristic_scale(
    delta_rps: dict[str, float],
    profiles: dict[str, list[ProfilePoint]],
    queues: dict[str, FunctionPodQueue],
    slo_latency: dict[str, float] | None = None,
) -> list[ScaleDecision]:
    """Paper Algorithm 1. Mutates ``queues`` to reflect the decisions.

    ``slo_latency`` optionally filters profile points whose measured p99
    exceeds the function's SLO — a point that violates latency cannot be used
    no matter how efficient (FaST-Profiler records latency for exactly this).

    Scale-up entries are pushed as *provisional* reservations; the caller
    must settle each one with ``queue.confirm(pod_id, real_id)`` once the
    deployer places the pod, or ``queue.abort(pod_id)`` when placement
    fails, before the next scaling pass reads ``capacity()``.  Scale-down
    decisions pop concrete running pods; the caller evicts them.
    """
    cfgs: list[ScaleDecision] = []
    for fn, gap in delta_rps.items():
        points = profiles[fn]
        if slo_latency and fn in slo_latency:
            feasible = [p for p in points if p.p99_latency <= slo_latency[fn]]
            points = feasible or points  # degrade gracefully if none feasible
        if not points:
            raise ValueError(f"no profile points for function {fn}")
        queue = queues.setdefault(fn, FunctionPodQueue())
        if gap >= 0:
            if gap == 0:
                continue
            p_eff = max(points, key=lambda p: p.rpr)
            t_eff = p_eff.throughput
            n = math.floor(gap / t_eff)
            r = gap - n * t_eff
            for _ in range(n):
                pid = _fresh_pod_id(fn)
                cfgs.append(ScaleDecision(fn, p_eff, +1, pod_id=pid))
                queue.push_provisional(pid, p_eff)
            if r > 0:
                # Minimal sufficient residual config: argmin (T_p - r), T_p > r.
                candidates = [p for p in points if p.throughput > r]
                if candidates:
                    p_ideal = min(candidates, key=lambda p: p.throughput - r)
                else:  # residual exceeds every point: one more p_eff pod
                    p_ideal = p_eff
                pid = _fresh_pod_id(fn)
                cfgs.append(ScaleDecision(fn, p_ideal, +1, pod_id=pid))
                queue.push_provisional(pid, p_ideal)
        else:
            delta_r = gap
            while delta_r < 0 and len(queue) > 0:
                front = queue.front()
                assert front is not None
                # Only remove while the remaining pods still cover the load.
                if delta_r + front.point.throughput <= 0:
                    queue.pop()
                    cfgs.append(ScaleDecision(fn, front.point, -1,
                                              pod_id=front.pod_id))
                    delta_r += front.point.throughput
                else:
                    break
    return cfgs


_pod_counter = itertools.count()


def _fresh_pod_id(fn: str) -> str:
    return f"{fn}-pod-{next(_pod_counter)}"


def processing_gap(predicted_rps: dict[str, float],
                   queues: dict[str, FunctionPodQueue]) -> dict[str, float]:
    """ΔRPS_j = R_j - Σ T_{j,i} over the function's running pods."""
    return {
        fn: rps - (queues[fn].capacity() if fn in queues else 0.0)
        for fn, rps in predicted_rps.items()
    }
