"""FaST-Profiler (paper §3.2): Experiment -> Trial automatic profiling.

Profiles a function's throughput and latency over a grid of spatio-temporal
allocations.  Two trial backends:

* ``simulate_trial`` — deploys one FaSTPod on a dedicated simulated node
  (real TokenScheduler + MRA in the loop) and drives closed-loop load,
  measuring completed RPS and p99 — the default, exact reproduction of the
  paper's Experiment->Trial workflow.
* ``measure_callable_trial`` — wall-clock profiles a *real* jitted executor
  (reduced-config model on CPU); the spatial axis is realized by the token
  scheduler's concurrency accounting, the temporal axis by duty-cycling the
  dispatch loop.  ``measure_engine_profile`` wires it to a live
  ``FunctionInstance``'s fused executors and emits a spec-ready
  ``{<F, S, Q, T>}`` table (``FunctionSpec.profile`` takes it directly).

Default profiling grid = the paper's (§5.2):
  temporal: 20/40/60/80/100%;  spatial: 6/12/24/50/60/80/100%.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

from repro.core.cluster import Cluster
from repro.core.scaling import ProfilePoint, expected_tokens_per_round
from repro.core.workload import Request, ServiceCurve, poisson_arrivals

TEMPORAL_GRID: tuple[float, ...] = (0.2, 0.4, 0.6, 0.8, 1.0)
SPATIAL_GRID: tuple[float, ...] = (0.06, 0.12, 0.24, 0.5, 0.6, 0.8, 1.0)


@dataclasses.dataclass(frozen=True)
class TrialResult:
    sm: float
    quota: float
    throughput: float
    p50: float
    p99: float

    def to_point(self) -> ProfilePoint:
        return ProfilePoint(sm=self.sm, quota=self.quota,
                            throughput=self.throughput, p99_latency=self.p99)


def simulate_trial(curve: ServiceCurve, sm: float, quota: float, *,
                   duration: float = 30.0, overload_factor: float = 1.5,
                   seed: int = 0) -> TrialResult:
    """One Trial: dedicated node, one pod at (sm, quota), saturating load.

    The client over-drives the pod (``overload_factor`` x its analytic rate)
    so the measured completion rate is the pod's *capacity* under the token
    scheduler — which is what the paper's profiler records.
    """
    cluster = Cluster(n_nodes=1, sharing=True)
    cluster.register_function(curve.name, curve)
    point = ProfilePoint(sm=sm, quota=quota, throughput=0.0)
    pod = cluster.deploy(curve.name, point)
    assert pod is not None, "dedicated profiling node must admit one pod"
    target_rps = max(curve.rate(sm, quota) * overload_factor, 1.0)
    cluster.submit_all(
        poisson_arrivals(curve.name, target_rps, duration, seed=seed)
    )
    cluster.run(duration + 5.0)
    rec = cluster.recorders[curve.name]
    warm = duration * 0.2  # discard warm-up
    thr = rec.throughput(warm, duration)
    return TrialResult(sm=sm, quota=quota, throughput=thr,
                       p50=rec.p50(since=warm), p99=rec.p99(since=warm))


def measure_callable_trial(step_fn: Callable[[], None], sm: float, quota: float,
                           *, window: float = 0.2, n_windows: int = 5,
                           warmup: int = 2) -> TrialResult:
    """Profile a real executor by duty-cycled dispatch (CPU wall-clock).

    ``step_fn`` runs one inference step to completion (blocking).  The
    temporal quota is enforced exactly as FaST-Manager does: within each
    scheduling window, steps are dispatched until ``quota * window`` seconds
    of measured execution have been charged, then the pod blocks to the next
    window.  The spatial share cannot be enforced on CPU; it is recorded so
    the caller can attach an analytic scaling factor.
    """
    for _ in range(warmup):
        step_fn()
    lat: list[float] = []
    completed = 0
    t_total0 = time.perf_counter()
    for _ in range(n_windows):
        w0 = time.perf_counter()
        used = 0.0
        while used < quota * window:
            s0 = time.perf_counter()
            step_fn()
            dt = time.perf_counter() - s0
            used += dt
            lat.append(dt)
            completed += 1
        # Block for the remainder of the window (Q_remain <= 0).
        leftover = window - (time.perf_counter() - w0)
        if leftover > 0:
            time.sleep(leftover)
    elapsed = time.perf_counter() - t_total0
    lat.sort()
    return TrialResult(
        sm=sm, quota=quota, throughput=completed / elapsed,
        p50=lat[len(lat) // 2] if lat else 0.0,
        p99=lat[min(len(lat) - 1, int(len(lat) * 0.99))] if lat else 0.0,
    )


def measure_engine_profile(
    model,
    params,
    *,
    spatial: Sequence[float] = (0.25, 0.45),
    temporal: Sequence[float] = (0.4, 0.8),
    max_batch: int = 4,
    max_len: int = 64,
    batching: str = "continuous",
    prompt_len: int = 8,
    new_tokens: int = 4,
    window: float = 0.1,
    n_windows: int = 3,
    seed: int = 0,
    sm_scale=None,
    kv_budget_bytes: int = 0,
    kv_block_bytes: int = 0,
    kv_shared_frac: float = 0.0,
    sampling=None,
    speculate=None,
    draft_params=None,
) -> list[ProfilePoint]:
    """Spec-ready ``{<F, S, Q, T>}`` table measured on the REAL jitted
    executors (ROADMAP "Live profiler backend for specs").

    Builds one ``FunctionInstance`` — the same fused prefill/decode
    executors the serving engine dispatches — and runs
    ``measure_callable_trial`` per grid cell: one ``step_fn`` serves a
    full batch of ``max_batch`` requests (``prompt_len`` prompt +
    ``new_tokens`` greedy tokens each) to completion, so the temporal
    quota is enforced on real wall-clock executor time exactly as
    FaST-Manager charges ``Q_used``.  Throughput is scaled to requests/s;
    the batch's step latency stands in for per-request p99 (batch members
    finish together).  The spatial axis cannot be partitioned on CPU:
    ``sm_scale(sm) -> factor`` attaches an analytic scaling when given
    (throughput x factor, latency / factor), else points share the
    measured rate.  The returned ``ProfilePoint``s feed
    ``repro.control.FunctionSpec.profile`` directly —
    ``examples/autoscale_live.py --measured-profile`` runs exactly that.

    ``kv_budget_bytes`` / ``kv_block_bytes`` / ``kv_shared_frac`` stamp
    paged capacity and the shared-fraction axis
    (``ProfilePoint.kv_blocks`` / ``kv_shared_frac``) as in
    :func:`profile_points`.

    ``speculate`` (a ``repro.serving.speculative.SpecConfig``) profiles
    the speculative draft/verify hot path: ``draft_params`` are staged
    next to the target weights and each trial drives the real fused
    speculative round.  The measured throughput is then already
    *effective* requests/s (requests complete in fewer rounds); the
    points carry ``spec_k`` and the instance's MEASURED acceptance so the
    reconciler and sim replay see the same axis.  ``sampling`` (a
    ``SamplingConfig``) profiles the stochastic-sampling executor
    instead of greedy argmax.
    """
    import itertools

    import numpy as np

    # Lazy import: repro.core must not depend on repro.serving at import
    # time (the serving engine already imports core modules).
    from repro.core.model_sharing import ModelStore
    from repro.core.resources import Alloc
    from repro.serving.engine import FunctionInstance, ServeRequest

    store = ModelStore()
    store.store("__profile__", params)
    draft_model = None
    draft_key = None
    if speculate is not None:
        from repro.models.model import build_model
        if draft_params is None:
            raise ValueError("speculate set but no draft_params staged")
        draft_model = build_model(speculate.draft_cfg)
        draft_key = "__profile__#draft"
        store.store(draft_key, draft_params)
    req_ids = itertools.count()
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, model.cfg.vocab_size, prompt_len,
                            dtype=np.int32) for _ in range(max_batch)]
    kv_blocks = paged_kv_capacity(kv_budget_bytes, kv_block_bytes,
                                  kv_shared_frac)
    points: list[ProfilePoint] = []
    for sm in spatial:
        inst = FunctionInstance(
            "__profile__/0", model, store, "__profile__",
            Alloc(sm=sm, quota_request=1.0, quota_limit=1.0),
            max_batch=max_batch, max_len=max_len, batching=batching,
            sampling=sampling, speculate=speculate,
            draft_model=draft_model, draft_key=draft_key)

        def step_fn() -> None:
            for p in prompts:
                inst.queue.append(ServeRequest(req_id=next(req_ids),
                                               prompt=p,
                                               max_new_tokens=new_tokens))
            while inst.has_work():
                inst.run_step()

        factor = sm_scale(sm) if sm_scale is not None else 1.0
        trials = [(quota, measure_callable_trial(step_fn, sm, quota,
                                                 window=window,
                                                 n_windows=n_windows))
                  for quota in temporal]
        # Stamp the speculation axis with the acceptance the instance
        # actually measured over this sweep (telemetry accumulates across
        # trials), not a declared estimate.
        acc = inst.acceptance_rate() if speculate is not None else 0.0
        for quota, r in trials:
            points.append(ProfilePoint(
                sm=sm, quota=quota,
                throughput=r.throughput * max_batch * factor,
                p99_latency=r.p99 / max(factor, 1e-9),
                kv_blocks=kv_blocks, kv_shared_frac=kv_shared_frac,
                spec_k=speculate.k if speculate is not None else 0,
                acceptance=acc))
        inst.close()
    return points


@dataclasses.dataclass
class ProfileDB:
    """The profiler's results database (paper: stored for the scheduler)."""

    points: dict[str, list[ProfilePoint]] = dataclasses.field(default_factory=dict)

    def add(self, fn: str, result: TrialResult) -> None:
        self.points.setdefault(fn, []).append(result.to_point())

    def best_rpr(self, fn: str) -> ProfilePoint:
        return max(self.points[fn], key=lambda p: p.rpr)

    def table(self, fn: str) -> list[ProfilePoint]:
        return list(self.points[fn])


def profile_function(
    curve: ServiceCurve,
    *,
    temporal: Sequence[float] = TEMPORAL_GRID,
    spatial: Sequence[float] = SPATIAL_GRID,
    duration: float = 30.0,
    db: ProfileDB | None = None,
) -> ProfileDB:
    """The Experiment phase: sweep the full grid (paper Fig. 8)."""
    db = db or ProfileDB()
    for sm in spatial:
        for quota in temporal:
            db.add(curve.name, simulate_trial(curve, sm, quota,
                                              duration=duration))
    return db


def paged_kv_capacity(kv_budget_bytes: int, kv_block_bytes: int,
                      shared_frac: float = 0.0) -> int:
    """TOTAL physical KV blocks a memory budget can hold — the value to
    hand the engine as ``n_kv_blocks`` (the null page is one of them, so
    a usable pool needs at least 2; smaller budgets report 0).

    ``shared_frac`` is the shared-fraction axis: with fraction ``s`` of a
    workload's blocks expected to be prefix-shared duplicates, the same
    budget honestly covers a pool stretched by ``1 / (1 - s)`` — the
    expected PHYSICAL use of the larger pool is back at the budget,
    because duplicated blocks are mapped, not materialised.  This mirrors
    the live frontend's discounted KV admission charge; the engine still
    enforces worst-case per-request reservations inside whatever pool it
    is handed.
    """
    if not 0.0 <= shared_frac < 1.0:
        raise ValueError(
            f"shared_frac must be in [0, 1), got {shared_frac}")
    if kv_block_bytes <= 0 or kv_budget_bytes <= 0:
        return 0
    n = int(kv_budget_bytes / (kv_block_bytes * (1.0 - shared_frac)))
    return n if n >= 2 else 0


def profile_points(
    curve: ServiceCurve,
    *,
    spatial: Sequence[float] = (0.12, 0.24, 0.5),
    temporal: Sequence[float] = (0.4, 1.0),
    duration: float = 12.0,
    loaded_factor: float = 0.8,
    seed: int = 0,
    kv_budget_bytes: int = 0,
    kv_block_bytes: int = 0,
    kv_shared_frac: float = 0.0,
    spec_k: int = 0,
    acceptance: float = 0.0,
) -> list[ProfilePoint]:
    """Spec-ready profile table: ``{<F_j, S_p, Q_p, T_p>}`` with SLO p99s.

    Per grid cell, two Trials: a *saturating* probe for capacity ``T_p``
    (the throughput Alg. 1 budgets with) and a *loaded* probe at
    ``loaded_factor`` of the analytic rate for the p99 latency (the SLO
    filter must see service latency under realistic load, not the queueing
    blow-up of the saturation probe).  The merged points feed
    ``repro.control.FunctionSpec.profile`` directly.

    ``kv_budget_bytes`` / ``kv_block_bytes`` (both > 0) additionally stamp
    each point with its paged-KV capacity (``ProfilePoint.kv_blocks``) —
    the block budget a ``batching="paged"`` spec hands the engine, derived
    from the same ``Model.kv_block_bytes`` layout admission charges.
    ``kv_shared_frac`` stretches that capacity for prefix-shared workloads
    (see :func:`paged_kv_capacity`) and is stamped on the points so the
    live frontend can discount its admission charge by the same axis.

    ``spec_k`` / ``acceptance`` stamp the speculation axis: the simulated
    curve models the non-speculative round rate, so with ``spec_k > 0``
    each point's throughput is scaled by
    ``expected_tokens_per_round(spec_k, acceptance)`` — the reconciler
    then budgets *effective* tokens/s, exactly matching what a live
    speculating instance completes per verify round.
    """
    kv_blocks = paged_kv_capacity(kv_budget_bytes, kv_block_bytes,
                                  kv_shared_frac)
    spec_factor = expected_tokens_per_round(spec_k, acceptance)
    points: list[ProfilePoint] = []
    for sm in spatial:
        for quota in temporal:
            cap = simulate_trial(curve, sm, quota, duration=duration,
                                 seed=seed)
            lat = simulate_trial(curve, sm, quota, duration=duration,
                                 overload_factor=loaded_factor, seed=seed)
            points.append(ProfilePoint(sm=sm, quota=quota,
                                       throughput=cap.throughput
                                       * spec_factor,
                                       p99_latency=lat.p99,
                                       kv_blocks=kv_blocks,
                                       kv_shared_frac=kv_shared_frac,
                                       spec_k=spec_k,
                                       acceptance=acceptance))
    return points
