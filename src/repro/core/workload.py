"""Workload generation and calibrated service models.

Two halves:

* **Arrival processes** — Poisson open-loop, closed-loop (k6/Locust style
  virtual users), and step/diurnal RPS traces used by the autoscaling
  benchmark (paper Fig. 12).
* **ServiceCurve** — the per-model performance model used by the
  discrete-event simulator.  Calibrated against the paper's V100 numbers
  (§5.3): single-instance throughput saturates at a model-specific spatial
  share ``sm_sat`` — the *reason* spatial sharing wins — and scales
  proportionally with the temporal quota (§5.2 "throughput over temporal
  dimension is basically proportional").

The saturation shape is the power law ``c(s) = (s / sm_sat) ** p`` clamped
to 1 beyond ``sm_sat``; ``p`` is fit per model so the curve passes exactly
through the paper's measured per-pod throughput at 12% SM (an exponential
shape cannot: it is concave-only, while RNNT/GNMT measure *convex*
sub-saturation scaling, c(0.12) < 0.12/sm_sat).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Optional

import numpy as np


# --------------------------------------------------------------------------
# Service model
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServiceCurve:
    """Throughput/latency model of one function (DL model) on one node.

    ``r_max``: saturated single-instance throughput (req/s) at full quota.
    ``sm_sat``: spatial share where a single instance saturates.
    ``tau``: concavity of the sub-saturation region.
    ``weight_bytes``/``framework_bytes``: memory model inputs (Fig. 13).
    """

    name: str
    r_max: float
    sm_sat: float
    p: float  # power-law exponent of the sub-saturation region
    weight_bytes: int = 0
    framework_bytes: int = 0
    # Weight-bound (batch-shared) fraction of one decode round.  0.5 is
    # the uncalibrated default; ``calibrate_round_alpha`` replaces it with
    # the model's roofline split (repro.analysis.roofline.decode_round_alpha).
    alpha: float = 0.5
    # Bytes exchanged per live slot per decode round by a tensor-parallel
    # pod's collectives (the all-gather volume of the column-only layout).
    # 0 keeps single-device behaviour exactly.
    allreduce_bytes: int = 0

    def rate(self, sm: float, quota: float = 1.0) -> float:
        """Sustainable throughput (req/s) at allocation (sm, quota)."""
        c = min(sm / self.sm_sat, 1.0) ** self.p
        return self.r_max * c * quota

    def step_time(self, sm: float, batch: int = 1) -> float:
        """Wall time of one dispatched step processing ``batch`` requests."""
        return batch / self.rate(sm, quota=1.0)

    def round_time(self, sm: float, live: int,
                   alpha: float | None = None, *, shards: int = 1,
                   link_bps: float = 0.0) -> float:
        """Wall time of one decode round advancing ``live`` slots.

        A round pays a fixed weight-bound cost (reading the model once,
        fraction ``alpha``) plus a per-slot activation/KV-bound cost — the
        standard roofline decomposition of batched decode.  Underfilled
        rounds therefore waste the shared ``alpha`` portion, which is
        exactly the inefficiency continuous batching removes.  With
        ``live == 1`` this reduces to ``step_time(sm, 1)``, so single-slot
        pods keep the paper-calibrated service rates.  ``alpha=None`` uses
        the curve's own (possibly roofline-calibrated) fraction.

        A tensor-parallel pod (``shards > 1``) divides the compute term
        by its degree and adds the collective cost: the standard ring
        exchange moves ``2 (N-1)/N`` of the payload over the group's
        bottleneck link (``link_bps``, from ``Cluster.links``).  With
        ``shards == 1`` or no link model the expression is bit-identical
        to the single-device one — the sim-vs-live decision-signature
        equality rides on that.
        """
        a = self.alpha if alpha is None else alpha
        t = (a + (1.0 - a) * live) / (self.rate(sm, quota=1.0) * shards)
        if shards > 1 and self.allreduce_bytes and link_bps > 0.0:
            t += (2.0 * (shards - 1) / shards
                  * self.allreduce_bytes * live / link_bps)
        return t


def calibrate_round_alpha(curve: ServiceCurve, cfg,
                          seq_len: int = 1024) -> ServiceCurve:
    """Replace the curve's fixed alpha=0.5 with the model's roofline split.

    ``cfg`` is the architecture's ``ModelConfig``; the weight-bound
    fraction comes from ``repro.analysis.roofline.decode_round_alpha`` at
    a representative decode context length.  Single-slot behaviour
    (``round_time(sm, 1) == step_time(sm, 1)``) is alpha-independent, so
    paper-calibrated rates survive calibration unchanged.
    """
    from repro.analysis.roofline import decode_round_alpha

    return dataclasses.replace(curve,
                               alpha=decode_round_alpha(cfg, seq_len))


def _curve(name: str, r_max: float, sm_sat: float, s_ref: float, c_ref: float,
           weight_mb: int, framework_mb: int) -> ServiceCurve:
    """Fit p so the curve passes exactly through (s_ref, c_ref)."""
    p = math.log(c_ref) / math.log(min(s_ref / sm_sat, 1.0 - 1e-9))
    return ServiceCurve(
        name=name,
        r_max=r_max,
        sm_sat=sm_sat,
        p=p,
        weight_bytes=weight_mb * 1024 * 1024,
        framework_bytes=framework_mb * 1024 * 1024,
    )


# Calibration targets (paper §5.3, §5.5):
#   resnet: racing pod 71.37 req/s; 8 pods @12% -> 296.8 => c(0.12)=0.52
#   rnnt:   racing pod 12.51 req/s; 8 pods @12% -> ~40   => c(0.12)=0.40
#   gnmt:   racing pod 28.85 req/s; spatial 43.79 (0.52x gain) => c(0.12)=0.19
#   memory: resnet 1525M total / ~100M weights; vit_huge 4735M / 2634M weights.
PAPER_ZOO: dict[str, ServiceCurve] = {
    "resnet": _curve("resnet", r_max=71.37, sm_sat=0.24, s_ref=0.12, c_ref=0.52,
                     weight_mb=98, framework_mb=1427),
    "rnnt": _curve("rnnt", r_max=12.51, sm_sat=0.24, s_ref=0.12, c_ref=0.40,
                   weight_mb=460, framework_mb=1260),
    "gnmt": _curve("gnmt", r_max=28.85, sm_sat=0.50, s_ref=0.12, c_ref=0.19,
                   weight_mb=520, framework_mb=1300),
    "bert": _curve("bert", r_max=48.0, sm_sat=0.50, s_ref=0.12, c_ref=0.30,
                   weight_mb=420, framework_mb=1350),
    # resnext memory calibrated to the §5.5 claim "a 16G V100 can accommodate
    # 7 ResNeXt pods with sharing, whereas only 4 without": total must lie in
    # (3277, 4096] MB and framework > 1726 MB for both bounds to bind.
    "resnext": _curve("resnext", r_max=33.0, sm_sat=0.60, s_ref=0.12, c_ref=0.25,
                      weight_mb=2200, framework_mb=1850),
    "vit_huge": _curve("vit_huge", r_max=21.0, sm_sat=0.80, s_ref=0.12, c_ref=0.18,
                       weight_mb=2634, framework_mb=2101),
}


# --------------------------------------------------------------------------
# Arrival processes
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Request:
    fn: str
    arrival: float
    req_id: int
    # Decode steps the request needs (autoregressive output length).  1 ==
    # the classic single-shot inference the paper benchmarks; >1 makes the
    # request hold a decode slot for n_tokens token-gated rounds, which is
    # what continuous batching exploits.
    n_tokens: int = 1
    # SLO lifecycle (all inert by default): absolute deadline stamped at
    # admission from the function's tier/deadline budget (None = no
    # deadline, never shed or expired), the tier it was admitted under,
    # and how many times it has been re-routed after a failure.
    deadline: Optional[float] = None
    tier: str = "best_effort"
    attempts: int = 0


def poisson_arrivals(fn: str, rps: float, duration: float, *,
                     seed: int = 0, start: float = 0.0,
                     n_tokens: int = 1) -> list[Request]:
    """Open-loop Poisson arrivals at ``rps`` for ``duration`` seconds."""
    rng = np.random.default_rng(seed)
    out: list[Request] = []
    t = start
    i = 0
    while True:
        t += rng.exponential(1.0 / rps)
        if t >= start + duration:
            break
        out.append(Request(fn=fn, arrival=t, req_id=i, n_tokens=n_tokens))
        i += 1
    return out


def trace_arrivals(fn: str, rps_trace: list[tuple[float, float]],
                   *, seed: int = 0) -> list[Request]:
    """Piecewise-constant RPS trace [(t_start, rps), ...] -> arrivals.

    Drives the Fig.-12 autoscaling experiment (RPS steps over time).
    """
    rng = np.random.default_rng(seed)
    out: list[Request] = []
    i = 0
    for (t0, rps), (t1, _) in zip(rps_trace, rps_trace[1:] + [(math.inf, 0.0)]):
        if rps <= 0:
            continue
        t = t0
        while True:
            t += rng.exponential(1.0 / rps)
            if t >= t1:
                break
            out.append(Request(fn=fn, arrival=t, req_id=i))
            i += 1
            if t1 is math.inf and i > 10_000_000:  # pragma: no cover
                raise RuntimeError("unbounded trace")
    return out


def diurnal_trace(base_rps: float, peak_rps: float, period: float,
                  duration: float, step: float = 10.0) -> list[tuple[float, float]]:
    """Sinusoidal day/night RPS trace sampled every ``step`` seconds."""
    out = []
    t = 0.0
    while t < duration:
        phase = 2 * math.pi * t / period
        rps = base_rps + (peak_rps - base_rps) * 0.5 * (1 - math.cos(phase))
        out.append((t, rps))
        t += step
    return out


def predicted_rps(window: list[Request], horizon: float, now: float) -> float:
    """Gateway-style load prediction: mean RPS over the trailing horizon."""
    recent = [r for r in window if now - horizon <= r.arrival <= now]
    return len(recent) / horizon if horizon > 0 else 0.0
