"""Inter-node network links: per-pair bandwidth for link-aware placement.

Helix (ASPLOS'25) models a serving cluster as a bandwidth-constrained
graph: where a multi-device pod lands matters because its per-round
collectives ride the slowest link in its device group.  ``NetworkLinks``
is that graph for both backends — the simulator folds it into
``ServiceCurve.round_time`` and the live frontend uses it to co-locate a
sharded pod's MRA rectangles on the highest-bandwidth group and to pick
the fastest peer for host-to-host weight transfers.

Bandwidths are symmetric bytes/second.  The default topology is uniform
(every pair at ``default_bps``), which keeps single-node fleets and older
tests unaffected; heterogeneous topologies are declared with
``set_link``.
"""

from __future__ import annotations

import itertools
from typing import Iterable, Optional, Sequence

# One NVLink-ish default: high enough that the all-reduce term is small
# but non-zero, so the link model is exercised whenever it is enabled.
DEFAULT_LINK_BPS = 16 * (1 << 30)  # 16 GiB/s


def _key(a: int, b: int) -> tuple[int, int]:
    return (a, b) if a <= b else (b, a)


class NetworkLinks:
    """Symmetric per-pair bandwidth table over ``n_nodes`` nodes."""

    def __init__(self, n_nodes: int, default_bps: float = DEFAULT_LINK_BPS):
        if n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
        if default_bps <= 0:
            raise ValueError(f"default_bps must be > 0, got {default_bps}")
        self.n_nodes = n_nodes
        self.default_bps = float(default_bps)
        self._bps: dict[tuple[int, int], float] = {}

    # -- declaration -------------------------------------------------------

    def set_link(self, a: int, b: int, bps: float) -> None:
        if a == b:
            raise ValueError("no self-links")
        if bps <= 0:
            raise ValueError(f"bandwidth must be > 0, got {bps}")
        self._bps[_key(a, b)] = float(bps)

    def grow(self, n_nodes: int) -> None:
        """Extend the node count (new pairs read the default)."""
        self.n_nodes = max(self.n_nodes, n_nodes)

    # -- queries -----------------------------------------------------------

    def bandwidth(self, a: int, b: int) -> float:
        if a == b:
            return float("inf")  # same device: no wire
        return self._bps.get(_key(a, b), self.default_bps)

    def pairs(self) -> dict[tuple[int, int], float]:
        """Every (a < b) pair's bandwidth — the ``Backend.links()`` payload."""
        return {
            (a, b): self.bandwidth(a, b)
            for a, b in itertools.combinations(range(self.n_nodes), 2)
        }

    def bottleneck(self, nodes: Iterable[int]) -> float:
        """Slowest pairwise link inside a device group (the collective's
        effective bandwidth under a ring all-reduce)."""
        ns = sorted(set(nodes))
        if len(ns) < 2:
            return float("inf")
        return min(self.bandwidth(a, b)
                   for a, b in itertools.combinations(ns, 2))

    def best_peer(self, target: int,
                  candidates: Iterable[int]) -> Optional[int]:
        """Candidate with the highest bandwidth to ``target`` (ties to the
        lowest node id, for determinism)."""
        cands = sorted(c for c in set(candidates) if c != target)
        if not cands:
            return None
        return max(cands, key=lambda c: (self.bandwidth(target, c), -c))

    def best_groups(self, candidates: Sequence[int],
                    k: int) -> list[tuple[int, ...]]:
        """All k-subsets of ``candidates``, best collective group first:
        descending bottleneck bandwidth, then descending total bandwidth,
        then ascending ids (deterministic).  The placement loop walks this
        order and takes the first group whose every member admits."""
        cands = sorted(set(candidates))
        if k > len(cands):
            return []
        groups = list(itertools.combinations(cands, k))

        def score(g: tuple[int, ...]) -> tuple[float, float]:
            total = sum(self.bandwidth(a, b)
                        for a, b in itertools.combinations(g, 2))
            return (-self.bottleneck(g), -total)

        return sorted(groups, key=lambda g: (score(g), g))
