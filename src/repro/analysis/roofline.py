"""Trip-count-aware roofline analysis from optimized (post-SPMD) HLO.

``compiled.cost_analysis()`` counts every ``while`` body **once**, but the
models scan over layers (trip 28..80) and the flash kernels loop over KV
blocks, so raw cost numbers under-count FLOPs/bytes/collectives by 1-2
orders of magnitude.  This module parses the HLO text into computations,
propagates a *call multiplier* through the call graph (``while`` bodies get
``x known_trip_count``), and accumulates:

* **flops** — 2 x numel(result) x contraction for every ``dot``,
  multiplier-weighted (fusion-internal dots included);
* **hbm_bytes** — operand+result bytes per instruction in *executed*
  computations (fusions are one instruction; in-place
  ``dynamic-update-slice`` counts only the updated window, matching XLA's
  buffer-aliasing behaviour — not the full aliased buffer);
* **collective wire bytes** — per-device link traffic with the standard
  ring-cost model: all-reduce 2B, all-gather/reduce-scatter/all-to-all B,
  collective-permute B.

The three roofline terms then follow from the TPU v5e constants
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).  All figures are
per-device: the parsed HLO is already the partitioned SPMD program.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

# -- TPU v5e hardware constants (per chip) -----------------------------------
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link (conservative: 1 link serializes)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# `  %name = <type> <op>(<rest...>`   (type may be a tuple `(...)`;
# tuples of >=6 elements carry `/*index=5*/` comments, so the tuple matcher
# must admit `=` — it excludes parens instead, which tuple types never nest)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],\s{}:#*]+?)\s+"
    r"([\w\-]+)\((.*)$")
# `%comp_name (p0: type, ...) -> type {`   /  `ENTRY %main (...) -> type {`
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\))|[\w\[\],{}]+)")

# to_apply targets of these ops are per-element lambdas, not real calls.
_APPLY_OPS = {"reduce", "reduce-window", "scatter", "select-and-scatter",
              "map", "sort", "all-reduce", "reduce-scatter",
              "all-reduce-start"}
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "opt-barrier", "custom-call",
                   # Control ops: their operand/result tuples alias the live
                   # buffers; the *bodies* are walked separately.
                   "while", "conditional", "call",
                   "copy-start", "copy-done", "send", "recv"}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


def _numel(type_str: str) -> int:
    n = 1
    for d in _shape_dims(type_str):
        n *= d
    return max(n, 1) if _SHAPE_RE.search(type_str) else 0


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str

    def operand_refs(self) -> list[str]:
        args = self.rest.split(")")[0]
        return re.findall(r"%([\w\.\-]+)", args)

    def attr_ref(self, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", self.rest)
        return m.group(1) if m else None

    def attr_refs(self, key: str) -> list[str]:
        m = re.search(key + r"=\{([^}]*)\}", self.rest)
        if not m:
            return []
        return re.findall(r"%?([\w\.\-]+)", m.group(1))

    def trip_count(self) -> Optional[int]:
        m = _TRIP_RE.search(self.rest)
        return int(m.group(1)) if m else None


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list[Instr] = dataclasses.field(default_factory=list)

    @property
    def root(self) -> Optional[Instr]:
        return self.instrs[-1] if self.instrs else None


def parse_module(hlo_text: str) -> tuple[dict[str, Computation], str,
                                         dict[str, str]]:
    """-> (computations by name, entry name, global name->type table)."""
    comps: dict[str, Computation] = {}
    types: dict[str, str] = {}
    entry = ""
    current: Optional[Computation] = None
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            is_entry, name, params = mc.group(1), mc.group(2), mc.group(3)
            current = Computation(name=name, is_entry=bool(is_entry))
            comps[name] = current
            if is_entry:
                entry = name
            for pname, ptype in _PARAM_RE.findall(params):
                types[pname] = ptype
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        instr = Instr(*mi.groups())
        current.instrs.append(instr)
        types[instr.name] = instr.type_str
    return comps, entry, types


def _multipliers(comps: dict[str, Computation], entry: str
                 ) -> tuple[dict[str, float], set[str], int]:
    """Call-graph walk: computation -> summed call multiplier.

    Returns (multipliers, fusion-called computation names,
    #while loops with unknown trip count).
    """
    mult: dict[str, float] = defaultdict(float)
    fusion_comps: set[str] = set()
    unknown_trips = 0
    mult[entry] = 1.0
    work = [entry]
    seen_order: list[str] = []
    # Worklist with accumulation: process in topological-ish order by
    # repeated relaxation (call graphs here are DAGs; loop bound for safety).
    pending: list[tuple[str, float]] = [(entry, 1.0)]
    mult = defaultdict(float)
    while pending:
        cname, m = pending.pop()
        mult[cname] += m
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.op == "while":
                trip = ins.trip_count()
                if trip is None:
                    trip = 1
                    unknown_trips += 1
                body = ins.attr_ref("body")
                cond = ins.attr_ref("condition")
                if body:
                    pending.append((body, m * trip))
                if cond:
                    pending.append((cond, m * (trip + 1)))
            elif ins.op == "fusion":
                tgt = ins.attr_ref("calls")
                if tgt:
                    fusion_comps.add(tgt)
                    pending.append((tgt, m))
            elif ins.op == "call":
                tgt = ins.attr_ref("to_apply")
                if tgt:
                    pending.append((tgt, m))
            elif ins.op == "conditional":
                for tgt in (ins.attr_refs("branch_computations")
                            or [ins.attr_ref("true_computation") or "",
                                ins.attr_ref("false_computation") or ""]):
                    if tgt:
                        pending.append((tgt, m))
            # reduce/scatter/sort to_apply: per-element lambda, skip.
    return dict(mult), fusion_comps, unknown_trips


def _dot_flops(ins: Instr, types: dict[str, str]) -> float:
    refs = ins.operand_refs()
    if not refs:
        return 0.0
    lhs_dims = _shape_dims(types.get(refs[0], ""))
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    contraction = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contraction *= lhs_dims[i]
    return 2.0 * _numel(ins.type_str) * contraction


def _instr_bytes(ins: Instr, types: dict[str, str],
                 comps: dict[str, Computation]) -> float:
    """HBM bytes for one executed instruction (aliasing-aware)."""
    op = ins.op
    if op in _SKIP_BYTES_OPS:
        return 0.0
    refs = ins.operand_refs()
    if op == "dynamic-update-slice":
        upd = types.get(refs[1], "") if len(refs) > 1 else ""
        return 2.0 * _shape_bytes(upd)
    if op == "dynamic-slice":
        return 2.0 * _shape_bytes(ins.type_str)
    if op == "fusion":
        tgt = ins.attr_ref("calls")
        comp = comps.get(tgt or "")
        if comp is not None:
            return _fusion_bytes(ins, comp, types)
    if op == "broadcast" or op == "iota":
        return float(_shape_bytes(ins.type_str))
    operand = sum(_shape_bytes(types.get(r, "")) for r in refs)
    return float(operand + _shape_bytes(ins.type_str))


def _fusion_bytes(ins: Instr, comp: Computation,
                  types: dict[str, str]) -> float:
    """HBM traffic of one fusion: aliasing- and slicing-aware.

    A fused ``dynamic-slice`` reads only the slice window of its parameter,
    and a root ``dynamic-update-slice`` writes only the updated window (the
    full buffer is aliased in place).  Parameters consumed any other way are
    read in full; elementwise/reduce fusions therefore count full operands,
    exactly as XLA's own bytes-accessed does.
    """
    # parameter index -> instruction name, and a 1-hop bitcast alias map.
    param_names: dict[int, str] = {}
    alias: dict[str, str] = {}
    for i in comp.instrs:
        if i.op == "parameter":
            idx_str = i.rest.split(")")[0]
            if idx_str.isdigit():
                param_names[int(idx_str)] = i.name
        elif i.op in ("bitcast", "reshape", "transpose", "copy"):
            refs = i.operand_refs()
            if refs:
                alias[i.name] = refs[0]

    def canon(name: str) -> str:
        seen = set()
        while name in alias and name not in seen:
            seen.add(name)
            name = alias[name]
        return name

    # Names sliced inside the fusion -> window bytes of the slice result.
    sliced: dict[str, float] = {}
    for i in comp.instrs:
        if i.op in ("dynamic-slice", "slice"):
            refs = i.operand_refs()
            if refs:
                src = canon(refs[0])
                sliced[src] = sliced.get(src, 0.0) + _shape_bytes(i.type_str)
        elif i.op == "gather" and i.operand_refs():
            src = canon(i.operand_refs()[0])
            sliced[src] = sliced.get(src, 0.0) + _shape_bytes(i.type_str)

    fusion_refs = ins.operand_refs()
    total = 0.0
    for pos, ref in enumerate(fusion_refs):
        pname = param_names.get(pos)
        full = _shape_bytes(types.get(ref, ""))
        if pname is not None and pname in sliced:
            total += min(sliced[pname], float(full))
        else:
            total += float(full)
    # XLA:CPU FloatNormalization widens bf16 values to f32 with convert
    # round-trips inside fusions; the TPU target moves them at bf16.  Halve
    # the traffic of normalized fusions (approximate; flagged in §Roofline).
    if any(i.op == "convert" and "bf16" in i.type_str for i in comp.instrs):
        roundtrip = True
    else:
        roundtrip = False
    root = comp.root
    if root is not None and root.op == "dynamic-update-slice":
        rrefs = root.operand_refs()
        upd = types.get(canon(rrefs[1]), "") if len(rrefs) > 1 else ""
        # The written window (+ the aliased big operand was counted as read
        # in full above only if not sliced; subtract it — DUS aliases it).
        if rrefs:
            big = canon(rrefs[0])
            for pos, ref in enumerate(fusion_refs):
                if param_names.get(pos) == big:
                    total -= _shape_bytes(types.get(ref, ""))
                    break
        total += 2.0 * _shape_bytes(upd)
    else:
        total += _shape_bytes(ins.type_str)
    if roundtrip:
        total *= 0.5
    return max(total, 0.0)


def _true_width_factor(ins: Instr, types: dict[str, str],
                       comps: dict[str, Computation],
                       producers: dict[str, "Instr"]) -> float:
    """XLA:CPU's FloatNormalization pass rewrites bf16 compute to f32 and
    wraps values in bf16<->f32 convert round-trips; collectives then carry
    f32 payloads the TPU target would move as bf16.  Detect the round-trip
    on the producer side and count such collectives at half width."""
    if "f32" not in ins.type_str:
        return 1.0
    refs = ins.operand_refs()
    prod = producers.get(refs[0]) if refs else None
    if prod is None:
        return 1.0
    if prod.op == "convert" and "bf16" in types.get(
            prod.operand_refs()[0] if prod.operand_refs() else "", ""):
        return 0.5
    if prod.op == "fusion":
        tgt = comps.get(prod.attr_ref("calls") or "")
        if tgt is not None:
            for i in tgt.instrs:
                if i.op == "convert" and "bf16" in i.type_str:
                    return 0.5
    return 1.0


def _collective_wire_bytes(ins: Instr, types: dict[str, str],
                           comps: dict[str, Computation],
                           producers: dict[str, "Instr"]) -> tuple[
        Optional[str], float]:
    op = ins.op
    kind = None
    for c in COLLECTIVE_OPS:
        if op == c or op == c + "-start":
            kind = c
            break
    if kind is None:
        return None, 0.0
    operand = sum(_shape_bytes(types.get(r, "")) for r in ins.operand_refs())
    if operand == 0:
        operand = _shape_bytes(ins.rest.split(")")[0]) or _shape_bytes(
            ins.type_str)
    result = _shape_bytes(ins.type_str)
    f = _true_width_factor(ins, types, comps, producers)
    # Ring-cost model, per device.
    if kind == "all-reduce":
        return kind, 2.0 * operand * f
    if kind == "all-gather":
        return kind, float(max(result, operand)) * f
    # reduce-scatter / all-to-all / collective-permute: send ~operand bytes.
    return kind, float(operand) * f


# The CPU-lowered stand-ins for the Pallas kernels materialize per-block
# score/mask tensors that live in VMEM on the TPU target.  Instructions
# whose op_name metadata points inside a kernel are bucketed separately so
# the roofline can report raw and kernel-adjusted memory terms.
KERNEL_MARKERS = ("flash_attention", "decode_attention", "wkv6", "ssm_scan")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


@dataclasses.dataclass
class HloAnalysis:
    """Trip-count-corrected per-device totals."""

    flops: float = 0.0
    hbm_bytes: float = 0.0
    kernel_internal_bytes: float = 0.0  # subset of hbm_bytes inside kernels
    collective_wire: dict[str, float] = dataclasses.field(
        default_factory=dict)
    flops_uncorrected: float = 0.0  # bodies counted once (= cost_analysis)
    unknown_trip_whiles: int = 0
    n_dots: int = 0

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective_wire.values())


def _in_kernel(ins: Instr) -> bool:
    m = _OPNAME_RE.search(ins.rest)
    if not m:
        return False
    name = m.group(1)
    return any(k in name for k in KERNEL_MARKERS)


def analyze_hlo(hlo_text: str) -> HloAnalysis:
    comps, entry, types = parse_module(hlo_text)
    mult, fusion_comps, unknown = _multipliers(comps, entry)
    producers: dict[str, Instr] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            producers[ins.name] = ins
    out = HloAnalysis(unknown_trip_whiles=unknown)
    coll: dict[str, float] = defaultdict(float)
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        executed = cname not in fusion_comps
        for ins in comp.instrs:
            if ins.op == "dot":
                f = _dot_flops(ins, types)
                out.flops += m * f
                out.flops_uncorrected += f
                out.n_dots += 1
            if executed:
                b = m * _instr_bytes(ins, types, comps)
                out.hbm_bytes += b
                if b and _in_kernel(ins):
                    out.kernel_internal_bytes += b
                kind, wire = _collective_wire_bytes(ins, types, comps,
                                                    producers)
                if kind:
                    coll[kind] += m * wire
    out.collective_wire = dict(coll)
    return out


def bytes_by_opname(hlo_text: str, depth: int = 6,
                    collectives_only: bool = False) -> dict[str, float]:
    """Trip-count-weighted HBM bytes (or collective wire bytes) grouped by
    op_name prefix — the §Perf 'where do the bytes go?' profile."""
    comps, entry, types = parse_module(hlo_text)
    mult, fusion_comps, _ = _multipliers(comps, entry)
    producers = {i.name: i for c in comps.values() for i in c.instrs}
    out: dict[str, float] = defaultdict(float)
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or cname in fusion_comps:
            continue
        for ins in comp.instrs:
            if collectives_only:
                kind, wire = _collective_wire_bytes(ins, types, comps,
                                                    producers)
                b = wire if kind else 0.0
            else:
                b = _instr_bytes(ins, types, comps)
            if not b:
                continue
            om = _OPNAME_RE.search(ins.rest)
            name = om.group(1) if om else f"<{ins.op}>"
            key = "/".join(name.split("/")[:depth])
            if collectives_only:
                key = f"{ins.op}: {key}"
            out[key] += m * b
    return dict(out)


def flops_by_opname(hlo_text: str, depth: int = 3) -> dict[str, float]:
    """Trip-count-weighted dot FLOPs grouped by op_name prefix (profiling
    aid for the perf loop: 'where does the compute actually go?')."""
    comps, entry, types = parse_module(hlo_text)
    mult, _, _ = _multipliers(comps, entry)
    out: dict[str, float] = defaultdict(float)
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            if ins.op != "dot":
                continue
            om = _OPNAME_RE.search(ins.rest)
            name = om.group(1) if om else "<?>"
            key = "/".join(name.split("/")[:depth])
            out[key] += m * _dot_flops(ins, types)
    return dict(out)


# --------------------------------------------------------------------------
# Roofline terms
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one (arch x shape x mesh) cell, per step.

    ``memory_s`` is the raw parsed term; ``memory_adj_s`` replaces the
    kernel-internal traffic of the CPU stand-ins (score/mask blocks that
    stay in VMEM on TPU) with the analytic Pallas-kernel traffic.  The
    dominant-term analysis uses the adjusted term — it reflects the TPU
    target, not the CPU fallback artifact.  Both are reported.
    """

    compute_s: float
    memory_s: float
    memory_adj_s: float
    collective_s: float
    model_flops: float  # useful (analytic) FLOPs for the whole step, global
    hlo_flops_global: float
    n_chips: int = 0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_adj_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_adj_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — how much compiled compute is useful."""
        return self.model_flops / max(self.hlo_flops_global, 1.0)

    @property
    def mfu_bound(self) -> float:
        """Best-case MFU if the step runs exactly at the dominant term."""
        if not self.n_chips:
            return 0.0
        return (self.model_flops / max(self.bound_s, 1e-12)) / (
            PEAK_FLOPS * self.n_chips)


def roofline_terms(analysis: HloAnalysis, n_chips: int,
                   model_flops: float,
                   kernel_bytes_global: float = 0.0) -> Roofline:
    """Per-device analysis -> step-level roofline terms (seconds)."""
    adj_bytes = (analysis.hbm_bytes - analysis.kernel_internal_bytes
                 + kernel_bytes_global / max(n_chips, 1))
    return Roofline(
        compute_s=analysis.flops / PEAK_FLOPS,
        memory_s=analysis.hbm_bytes / HBM_BW,
        memory_adj_s=max(adj_bytes, 0.0) / HBM_BW,
        collective_s=analysis.collective_bytes / LINK_BW,
        model_flops=model_flops,
        hlo_flops_global=analysis.flops * n_chips,
        n_chips=n_chips,
    )


# --------------------------------------------------------------------------
# Decode-round roofline split (calibrates ServiceCurve.round_time's alpha)
# --------------------------------------------------------------------------


def decode_round_alpha(cfg, seq_len: int) -> float:
    """Weight-bound fraction of a batched decode round for this model.

    ``ServiceCurve.round_time`` models a round as a batch-shared
    weight-bound cost (fraction ``alpha``) plus a per-slot KV/activation
    cost: ``t(live) proportional to alpha + (1 - alpha) * live``.  The
    roofline decomposition gives alpha directly: one round streams the
    (active) weights once — ``W`` bytes, shared by every slot — and each
    slot's KV cache once — ``K`` bytes per sequence at ``seq_len`` context
    (``kernel_hbm_bytes``), so ``alpha = W / (W + K)``.

    Short contexts on weight-heavy models are weight-bound (alpha -> 1:
    batching is nearly free); long contexts are KV-bound (alpha -> 0:
    rounds scale linearly with live slots and continuous batching's
    fill advantage shrinks).
    """
    import types

    case = types.SimpleNamespace(kind="decode", global_batch=1,
                                 seq_len=max(seq_len, 1))
    w = 2.0 * cfg.active_param_count()  # bf16 weight stream, batch-shared
    k = kernel_hbm_bytes(cfg, case)  # per-sequence KV stream
    return w / max(w + k, 1.0)


# --------------------------------------------------------------------------
# Analytic MODEL_FLOPS per (arch x shape)
# --------------------------------------------------------------------------


def kernel_hbm_bytes(cfg, case, *, block_q: int = 512) -> float:
    """Analytic HBM traffic (global bytes/step) of the Pallas kernels.

    On TPU the attention/scan kernels stream Q/K/V/O between HBM and VMEM;
    the per-block score/mask tensors the CPU stand-in materializes never
    leave VMEM.  This is the traffic that replaces ``kernel_internal_bytes``
    in the kernel-adjusted memory term.  Model: flash fwd reads Q once and
    K/V once per (causal-reachable) Q-block pass and writes O; backward ~2x
    forward; remat re-runs forward once.  Decode reads the KV cache once.
    """
    b, s = case.global_batch, case.seq_len
    d, kv_d = cfg.n_heads * cfg.dh, cfg.n_kv_heads * cfg.dh
    bf16 = 2.0
    if cfg.family == "rwkv":
        # wkv6 scan: read r/k/v/w + write out + state traffic ~ 6 x (b,s,d).
        return 6.0 * b * s * cfg.d_model * bf16 * cfg.n_layers * (
            3.0 if case.kind == "train" else 1.0)
    n_attn = cfg.n_layers + getattr(cfg, "encoder_layers", 0)
    if case.kind in ("train", "prefill"):
        q_o = 2.0 * b * s * d * bf16
        if cfg.sliding_window and cfg.local_global_ratio > 0:
            n_glob = max(cfg.n_layers // (cfg.local_global_ratio + 1), 1)
            kv_pass_g = max(-(-s // block_q) / 2.0, 1.0)
            kv_pass_l = max(cfg.sliding_window / block_q, 1.0)
            kv = 2.0 * b * s * kv_d * bf16
            fwd = (n_glob * (q_o + kv_pass_g * kv)
                   + (cfg.n_layers - n_glob) * (q_o + kv_pass_l * kv))
        else:
            w = cfg.sliding_window or s
            eff = min(w, s)
            kv_pass = max(-(-s // block_q) / 2.0, 1.0) if w >= s else max(
                eff / block_q, 1.0)
            kv = 2.0 * b * s * kv_d * bf16
            fwd = n_attn * (q_o + kv_pass * kv)
        if cfg.family == "hybrid" and cfg.ssm_state:
            fwd += 4.0 * b * s * cfg.d_model * bf16 * cfg.n_layers
        return fwd * (4.0 if case.kind == "train" else 1.0)
    # decode: stream the KV cache once per step.
    w = cfg.sliding_window or s
    eff = min(w, s)
    traffic = 2.0 * b * eff * kv_d * bf16 * cfg.n_layers
    if cfg.family == "hybrid" and cfg.ssm_state:
        traffic += 2.0 * b * cfg.n_heads * cfg.dh * cfg.ssm_state * 4.0 * \
            cfg.n_layers
    return traffic


def model_flops(cfg, case) -> float:
    """Useful FLOPs of one step, whole cluster (6ND / 2ND + attention)."""
    b, s = case.global_batch, case.seq_len
    n_active = cfg.active_param_count()
    # Matmul params exclude the input embedding lookup (a gather); a tied
    # head still *matmuls* the shared V x D table, so it stays counted.
    emb = cfg.padded_vocab * cfg.d_model
    n_matmul = n_active if cfg.tie_embeddings else n_active - emb
    tokens = b * s
    attn_dim = cfg.n_heads * cfg.dh
    if cfg.family == "rwkv":
        attn_fwd = 0.0
    else:
        n_attn_layers = cfg.n_layers + getattr(cfg, "encoder_layers", 0)
        if case.kind in ("train", "prefill"):
            # causal: half of the s^2 block matrix, QK^T + AV.
            per_layer = 2.0 * b * s * s * attn_dim  # 2 matmuls x 1/2 causal x 2flops
            if cfg.sliding_window and cfg.local_global_ratio > 0:
                w = cfg.sliding_window
                n_glob = max(cfg.n_layers // (cfg.local_global_ratio + 1), 1)
                n_loc = cfg.n_layers - n_glob
                per_loc = 2.0 * b * s * min(s, w) * attn_dim * 2
                attn_fwd = n_glob * per_layer + n_loc * per_loc
            elif cfg.sliding_window:
                w = cfg.sliding_window
                attn_fwd = n_attn_layers * 2.0 * b * s * min(s, w) * attn_dim * 2
            else:
                attn_fwd = n_attn_layers * per_layer
        else:  # decode: one token vs s keys
            attn_fwd = cfg.n_layers * 4.0 * b * s * attn_dim
            if cfg.sliding_window:
                w = min(cfg.sliding_window, s)
                attn_fwd = cfg.n_layers * 4.0 * b * w * attn_dim
    if case.kind == "train":
        return 6.0 * n_matmul * tokens + 3.0 * attn_fwd
    if case.kind == "prefill":
        return 2.0 * n_matmul * tokens + attn_fwd
    # decode: one new token per sequence.
    return 2.0 * n_matmul * b + attn_fwd
