"""HLO text analysis: collective-op operand bytes for the roofline.

``cost_analysis()`` does not report communication, so we parse the
optimized (post-SPMD) HLO: build a name -> shape table from every
instruction definition, then sum operand sizes for each collective op
(all-gather, all-reduce, reduce-scatter, all-to-all, collective-permute).
Shapes are per-device (the HLO is the partitioned single-program module).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# `%name = bf16[1,2,3]{2,1,0} op-name(...)` or tuple results (tuples of
# >=6 elements carry `/*index=5*/` comments, so admit `=` inside parens).
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],\s{}:#*]+?)\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes in a type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, float]:
    """Sum of operand bytes per collective kind, per device.

    Operand sizes are resolved through a name->bytes table built from all
    instruction definitions; `all-reduce(%x)` style references then look up
    %x.  For `all-gather`, operand bytes understate the wire cost by
    (N-1)/N ~= 1, so operand-sum is the standard approximation.
    """
    name_bytes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    defs = []
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        name_bytes[name] = _shape_bytes(type_str)
        defs.append((name, type_str, op, rest))

    out: dict[str, float] = defaultdict(float)
    for name, type_str, op, rest in defs:
        kind = None
        for c in COLLECTIVE_OPS:
            if op == c or op.startswith(c + "-start") or op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        # Operand list is everything up to the matching ')': grab %refs.
        args_part = rest.split(")")[0]
        refs = re.findall(r"%([\w\.\-]+)", args_part)
        operand_bytes = sum(name_bytes.get(r, 0) for r in refs)
        if operand_bytes == 0:
            # Fallback: inline-typed operands, or size from the result.
            operand_bytes = _shape_bytes(args_part) or _shape_bytes(type_str)
        out[kind] += float(operand_bytes)
    return dict(out)


def count_ops(hlo_text: str, *ops: str) -> dict[str, int]:
    counts = {o: 0 for o in ops}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            for o in ops:
                if m.group(3).startswith(o):
                    counts[o] += 1
    return counts
