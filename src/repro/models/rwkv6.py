"""RWKV-6 "Finch": attention-free LM with data-dependent decay (rwkv6-1.6b).

Per layer: a TimeMix block (token-shift ddlerp for r/k/v/w/g, low-rank
data-dependent decay, WKV recurrence with per-head state) and a ChannelMix
block (token-shift, squared-relu FFN).  The WKV recurrence runs through
``repro.kernels.ops.wkv6_scan`` (Pallas kernel on TPU, scan on CPU).

Decode state per layer: (tm_x (B,D), cm_x (B,D), wkv (B,H,Dh,Dh)) — O(1) in
sequence length, which is why rwkv6 runs the long_500k cell.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import named
from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.layers import PSpec, rms_norm, stack_tree

DECAY_LORA = 64


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    dh = 64  # rwkv6 head size
    return cfg.d_model // dh, dh


def time_mix_specs(cfg: ModelConfig) -> dict[str, PSpec]:
    d = cfg.d_model
    h, dh = _heads(cfg)
    return {
        "ln": PSpec((d,), (None,), init="zeros"),
        # token-shift interpolation vectors for r, k, v, w, g
        "mu": PSpec((5, d), (None, None), init="small"),
        "w_r": PSpec((d, d), ("fsdp", "tp")),
        "w_k": PSpec((d, d), ("fsdp", "tp")),
        "w_v": PSpec((d, d), ("fsdp", "tp")),
        "w_g": PSpec((d, d), ("fsdp", "tp")),
        "w_o": PSpec((d, d), ("tp", "fsdp")),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x@a)@b))
        "decay_w0": PSpec((d,), (None,), init="small"),
        "decay_a": PSpec((d, DECAY_LORA), ("fsdp", None)),
        "decay_b": PSpec((DECAY_LORA, d), (None, "fsdp")),
        "bonus_u": PSpec((h, dh), (None, None), init="small"),
        "gn": PSpec((d,), (None,), init="zeros"),  # per-head group norm scale
    }


def channel_mix_specs(cfg: ModelConfig) -> dict[str, PSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln": PSpec((d,), (None,), init="zeros"),
        "mu": PSpec((2, d), (None, None), init="small"),
        "w_k": PSpec((d, f), ("fsdp", "tp")),
        "w_v": PSpec((f, d), ("tp", "fsdp")),
        "w_r": PSpec((d, d), ("fsdp", None)),
    }


def rwkv_specs(cfg: ModelConfig) -> dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    layer = {"tm": time_mix_specs(cfg), "cm": channel_mix_specs(cfg)}
    return {
        "embed": PSpec((v, d), ("vocab", "fsdp"), init="small"),
        "ln_in": PSpec((d,), (None,), init="zeros"),
        "layers": stack_tree(layer, cfg.n_layers),
        "ln_f": PSpec((d,), (None,), init="zeros"),
        "head": PSpec((d, v), ("fsdp", "vocab")),
    }


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """Token shift: previous token's features (zeros / carried state)."""
    if last is None:
        return jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    return jnp.concatenate([last[:, None, :], x[:, :-1]], axis=1)


def _ddlerp(x: jax.Array, shifted: jax.Array, mu: jax.Array) -> jax.Array:
    return x + (shifted - x) * mu.astype(x.dtype)


def _group_norm(x: jax.Array, scale: jax.Array, h: int, dh: int,
                eps: float) -> jax.Array:
    b, s, d = x.shape
    xf = x.astype(jnp.float32).reshape(b, s, h, dh)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    out = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(b, s, d)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def time_mix(p: dict, x: jax.Array, state: jax.Array,
             last_x: jax.Array | None, cfg: ModelConfig
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out, new wkv state, new last_x)."""
    h, dh = _heads(cfg)
    b, s, d = x.shape
    xs = _shift(x, last_x)
    xr = _ddlerp(x, xs, p["mu"][0])
    xk = _ddlerp(x, xs, p["mu"][1])
    xv = _ddlerp(x, xs, p["mu"][2])
    xw = _ddlerp(x, xs, p["mu"][3])
    xg = _ddlerp(x, xs, p["mu"][4])
    r = (xr @ p["w_r"]).reshape(b, s, h, dh)
    k = (xk @ p["w_k"]).reshape(b, s, h, dh)
    v = (xv @ p["w_v"]).reshape(b, s, h, dh)
    g = jax.nn.silu((xg @ p["w_g"]).astype(jnp.float32)).astype(x.dtype)
    # Data-dependent decay in log space: w <= 0 guarantees stability.
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["decay_a"].astype(jnp.float32))
    w = -jnp.exp(p["decay_w0"].astype(jnp.float32)
                 + lora @ p["decay_b"].astype(jnp.float32))
    w = w.reshape(b, s, h, dh)
    out, state = ops.wkv6_scan(r, k, v, w.astype(x.dtype), p["bonus_u"], state)
    out = _group_norm(out.reshape(b, s, d), p["gn"], h, dh, cfg.norm_eps)
    out = (out * g) @ p["w_o"]
    return named(out, "batch", "seq", None), state, x[:, -1, :]


def channel_mix(p: dict, x: jax.Array, last_x: jax.Array | None
                ) -> tuple[jax.Array, jax.Array]:
    xs = _shift(x, last_x)
    xk = _ddlerp(x, xs, p["mu"][0])
    xr = _ddlerp(x, xs, p["mu"][1])
    k = jnp.square(jax.nn.relu((xk @ p["w_k"]).astype(jnp.float32)))
    k = named(k.astype(x.dtype), "batch", "seq", "d_ff")
    r = jax.nn.sigmoid((xr @ p["w_r"]).astype(jnp.float32)).astype(x.dtype)
    return r * (k @ p["w_v"]), x[:, -1, :]


def _block(lp: dict, x: jax.Array, wkv: jax.Array,
           tm_x: jax.Array | None, cm_x: jax.Array | None, cfg: ModelConfig
           ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    h = rms_norm(x, lp["tm"]["ln"], cfg.norm_eps)
    a, wkv, tm_x = time_mix(lp["tm"], h, wkv, tm_x, cfg)
    x = x + a
    h = rms_norm(x, lp["cm"]["ln"], cfg.norm_eps)
    m, cm_x = channel_mix(lp["cm"], h, cm_x)
    x = named(x + m, "batch", "seq", None)
    return x, wkv, tm_x, cm_x


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            ctx=None, remat: bool = False,
            train: bool = True) -> tuple[jax.Array, jax.Array]:
    b, s = tokens.shape
    h, dh = _heads(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = rms_norm(x, params["ln_in"], cfg.norm_eps)
    x = named(x, "batch", "seq", None)
    wkv0 = jnp.zeros((b, h, dh, dh), jnp.float32)

    def body(x, lp):
        x, _, _, _ = _block(lp, x, wkv0, None, None, cfg)
        return x, None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x @ params["head"]).astype(jnp.float32)
    return named(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            max_len=None, ctx=None) -> tuple[jax.Array, dict]:
    b, s = tokens.shape
    h, dh = _heads(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = rms_norm(x, params["ln_in"], cfg.norm_eps)
    wkv0 = jnp.zeros((b, h, dh, dh), jnp.float32)

    def body(x, lp):
        x, wkv, tm_x, cm_x = _block(lp, x, wkv0, None, None, cfg)
        return x, (wkv, tm_x, cm_x)

    x, (wkvs, tm_xs, cm_xs) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x[:, -1:, :], params["ln_f"], cfg.norm_eps)
    logits = (x @ params["head"]).astype(jnp.float32)[:, 0]
    cache = {"wkv": wkvs, "tm_x": tm_xs, "cm_x": cm_xs,
             "pos": jnp.full((), s, jnp.int32)}
    return logits, cache


def decode_step(params: dict, token: jax.Array, cache: dict,
                cfg: ModelConfig) -> tuple[jax.Array, dict]:
    x = jnp.take(params["embed"], token[:, None], axis=0)
    x = rms_norm(x, params["ln_in"], cfg.norm_eps)

    def body(x, xs):
        lp, wkv, tm_x, cm_x = xs
        x, wkv, tm_x, cm_x = _block(lp, x, wkv, tm_x, cm_x, cfg)
        return x, (wkv, tm_x, cm_x)

    x, (wkvs, tm_xs, cm_xs) = jax.lax.scan(
        body, x, (params["layers"], cache["wkv"], cache["tm_x"],
                  cache["cm_x"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x @ params["head"]).astype(jnp.float32)[:, 0]
    return logits, {"wkv": wkvs, "tm_x": tm_xs, "cm_x": cm_xs,
                    "pos": cache["pos"] + 1}
