"""Model configuration for all assigned architectures.

One frozen dataclass covers the whole zoo; family-specific fields are only
read by the matching model builder.  Static (hashable) so it can be a jit
closure constant.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    mlp: str = "swiglu"  # swiglu | geglu | gelu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scale
    # Attention pattern ------------------------------------------------------
    sliding_window: Optional[int] = None  # SWA window for local layers
    local_global_ratio: int = 0  # gemma3: N local layers per 1 global (0=all global)
    # MoE ---------------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None  # per-expert FFN dim (defaults to d_ff)
    moe_cf_train: float = 1.25  # capacity factor, training (drops allowed)
    moe_cf_eval: float = 2.0  # capacity factor, prefill/decode
    # SSM / RWKV ---------------------------------------------------------------
    ssm_state: int = 0
    # Encoder-decoder -----------------------------------------------------------
    encoder_layers: int = 0  # >0 => enc-dec; n_layers is the decoder depth
    # VLM -----------------------------------------------------------------------
    cross_attn_every: int = 0  # insert one cross-attn layer after every N layers
    n_context_tokens: int = 0  # stubbed modality frontend: frames / patches
    # Vocab padding for clean TP sharding (Megatron-style) ------------------------
    vocab_pad_multiple: int = 128

    def __post_init__(self) -> None:
        if self.n_layers <= 0 or self.d_model <= 0:
            raise ValueError("bad config")
        if self.family not in ("dense", "moe", "rwkv", "hybrid", "encdec", "vlm"):
            raise ValueError(f"unknown family {self.family}")
        if self.family == "moe" and (self.n_experts <= 0 or self.top_k <= 0):
            raise ValueError("moe needs n_experts and top_k")

    # -- derived -----------------------------------------------------------

    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, self.vocab_pad_multiple)

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff else self.d_ff

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.dh

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.dh

    def is_global_layer(self, i: int) -> bool:
        """Local:global interleave (gemma3 style: every (r+1)-th is global)."""
        if self.local_global_ratio <= 0:
            return self.sliding_window is None  # all-global unless pure SWA
        return (i + 1) % (self.local_global_ratio + 1) == 0

    @property
    def attention_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (DESIGN.md §4)."""
        if self.family in ("rwkv", "hybrid"):
            return True
        # SWA-everywhere or local:global with windowed locals is sub-quadratic
        # in cache for all but the global layers; global layers stream O(S).
        return self.sliding_window is not None

    # -- parameter counts (drive MODEL_FLOPS and the memory model) ------------

    def param_count(self) -> int:
        """Exact trainable parameter count."""
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        emb = V * D
        head = 0 if self.tie_embeddings else V * D
        per_attn = (D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D)
        if self.qkv_bias:
            per_attn += self.q_dim + 2 * self.kv_dim
        gated = self.mlp in ("swiglu", "geglu")
        per_mlp = D * F * (3 if gated else 2)
        norms = 2 * D

        def dense_layer() -> int:
            return per_attn + per_mlp + norms

        def moe_layer() -> int:
            fe = self.expert_d_ff
            expert = D * fe * (3 if gated else 2)
            router = D * self.n_experts
            shared = self.n_shared_experts * expert
            return per_attn + norms + router + self.n_experts * expert + shared

        if self.family == "rwkv":
            # time-mix (r,k,v,g,o + decay lora) + channel-mix, per layer
            tm = 5 * D * D + 2 * (D * 64 + 64 * D)
            cm = 2 * D * int(self.d_ff)
            body = self.n_layers * (tm + cm + norms)
            return emb + head + body + 2 * D
        if self.family == "hybrid":
            ssm = self.ssm_state and (2 * D * self.ssm_state + D * 16)
            body = self.n_layers * (per_attn + per_mlp + norms + ssm)
            return emb + head + body + 2 * D
        if self.family == "moe":
            return emb + head + self.n_layers * moe_layer() + D
        if self.family == "encdec":
            enc = self.encoder_layers * dense_layer()
            dec = self.n_layers * (dense_layer() + per_attn + D)  # + cross attn
            return emb + head + enc + dec + 2 * D
        if self.family == "vlm":
            n_cross = self.n_layers // max(self.cross_attn_every, 1)
            cross = n_cross * (per_attn + per_mlp + norms + D)
            return emb + head + self.n_layers * dense_layer() + cross + D
        return emb + head + self.n_layers * dense_layer() + D

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        D, V = self.d_model, self.padded_vocab
        gated = self.mlp in ("swiglu", "geglu")
        fe = self.expert_d_ff
        expert = D * fe * (3 if gated else 2)
        per_attn = (D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D)
        active_layer = (per_attn + 2 * D + D * self.n_experts
                        + (self.top_k + self.n_shared_experts) * expert)
        emb = V * D
        head = 0 if self.tie_embeddings else V * D
        return emb + head + self.n_layers * active_layer + D
