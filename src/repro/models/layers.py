"""Parameter specs and common layers (pure JAX, pytree params).

Parameters are declared once as ``PSpec`` trees — shape, logical sharding
names, dtype, initializer — from which we derive (a) real initialization for
smoke tests/examples, (b) ``ShapeDtypeStruct`` trees for the dry-run (no
allocation), and (c) ``NamedSharding`` trees for jit in_shardings.

Logical names resolve against the active mesh via
``repro.distributed.sharding`` (divisibility-aware): ``tp`` dims shard over
the model axis, ``fsdp`` dims over the data axis, per MaxText-style 2D
sharding.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import RULES, named

# Extra logical rules for parameter dims.
RULES.setdefault("tp", ("model",))
RULES["tp"] = ("model",)


@dataclasses.dataclass(frozen=True)
class PSpec:
    """Declaration of one parameter tensor."""

    shape: tuple[int, ...]
    names: tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | small
    scale: Optional[float] = None  # stddev override (default: 1/sqrt(fan_in))

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.names):
            raise ValueError(f"rank mismatch {self.shape} vs {self.names}")


def is_pspec(x: Any) -> bool:
    return isinstance(x, PSpec)


def init_params(spec_tree: Any, key: jax.Array) -> Any:
    """Materialize real parameters from a PSpec tree."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_pspec)
    keys = jax.random.split(key, len(leaves))

    def one(spec: PSpec, k: jax.Array) -> jax.Array:
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
        if spec.init == "small":
            std = 0.02
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(
            spec.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(s, k) for s, k in zip(leaves, keys)])


def abstract_params(spec_tree: Any) -> Any:
    """PSpec tree -> ShapeDtypeStruct tree (dry-run, no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree, is_leaf=is_pspec)


def param_logical_names(spec_tree: Any) -> Any:
    """PSpec tree -> logical-name-tuple tree (same structure)."""
    return jax.tree_util.tree_map(lambda s: s.names, spec_tree,
                                  is_leaf=is_pspec)


def param_count(spec_tree: Any) -> int:
    return sum(math.prod(s.shape) for s in
               jax.tree_util.tree_leaves(spec_tree, is_leaf=is_pspec))


def stack_layers(spec: PSpec, n: int) -> PSpec:
    """Add a leading stacked-layers dim (for lax.scan over layers)."""
    return PSpec(shape=(n, *spec.shape), names=("layers", *spec.names),
                 dtype=spec.dtype, init=spec.init, scale=spec.scale)


def stack_tree(spec_tree: Any, n: int) -> Any:
    return jax.tree_util.tree_map(lambda s: stack_layers(s, n), spec_tree,
                                  is_leaf=is_pspec)


# --------------------------------------------------------------------------
# Normalization / activations / positional encodings
# --------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (B, S, H, D); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if angles.ndim == 2:  # (S, D/2) -> broadcast over batch
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]  # (B, S, 1, D/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(1e4) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# --------------------------------------------------------------------------
# MLP block
# --------------------------------------------------------------------------


def mlp_specs(d: int, f: int, kind: str) -> dict[str, PSpec]:
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": PSpec((d, f), ("fsdp", "tp")),
            "w_up": PSpec((d, f), ("fsdp", "tp")),
            "w_down": PSpec((f, d), ("tp", "fsdp")),
        }
    return {
        "w_up": PSpec((d, f), ("fsdp", "tp")),
        "b_up": PSpec((f,), ("tp",), init="zeros"),
        "w_down": PSpec((f, d), ("tp", "fsdp")),
        "b_down": PSpec((d,), (None,), init="zeros"),
    }


def mlp_apply(params: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else \
            (lambda g: jax.nn.gelu(g, approximate=True))
        gate = x @ params["w_gate"]
        up = x @ params["w_up"]
        h = act(gate.astype(jnp.float32)).astype(x.dtype) * up
        h = named(h, "batch", "seq", "d_ff")
        return h @ params["w_down"]
    h = x @ params["w_up"] + params["b_up"]
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    h = named(h, "batch", "seq", "d_ff")
    return h @ params["w_down"] + params["b_down"]
